"""search/multiq: one multi-query launch vs Q sequential searches.

The tentpole micro-bench for multi-query serving: aggregate wall time of a
single ``multi_query_search`` call over a Q-query workload against the same
reference vs Q back-to-back ``subsequence_search`` calls (the compiled
single-query program is reused — the comparison is launches/amortization,
not compilation). Both paths run the same backend/variant/batch, and the
bench asserts per-query result parity before timing, so the speedup row
never reports a wrong answer faster.

Measurement protocol: the two paths alternate (seq, multi, seq, multi, ...)
so both see the same background load; the headline ratio is best-of vs
best-of (the minimum is the least-noise estimate of each path's true cost),
with the median of per-pair ratios reported alongside. A best-of split into
two separate timing phases does not share load between the paths and was
observed to flip sign under drift on shared CPU boxes — alternation is what
makes the comparison robust.

CSV rows (name,us_per_call,derived):
  search/multiq/q{Q}/.../sequential — best-of aggregate us of Q calls
  search/multiq/q{Q}/.../multi      — best-of us of the one multi call
  search/multiq/q{Q}/.../speedup    — best-of ratio (value + ``speedup=``
                                      derived; median paired ratio
                                      reported alongside)
"""
from __future__ import annotations

import statistics
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.data.synthetic import make_dataset, make_queries
from repro.search import multi_query_search, subsequence_search


def run(
    ref_len: int = 20_000,
    length: int = 128,
    window_ratio: float = 0.1,
    n_queries: int = 8,
    batch: int = 64,
    pairs: int = 7,
    backend: str = "jax",
    dataset: str = "ECG",
):
    w = max(int(length * window_ratio), 1)
    ref = jnp.asarray(make_dataset(dataset, ref_len, seed=0), jnp.float32)
    queries = jnp.asarray(
        make_queries(dataset, n_queries, length, seed=1), jnp.float32
    )

    def sequential():
        return [
            subsequence_search(
                ref, queries[q], length=length, window=w, batch=batch,
                backend=backend,
            ).best_dist
            for q in range(n_queries)
        ]

    def multi():
        return multi_query_search(
            ref, queries, length=length, window=w, batch=batch,
            backend=backend,
        )

    # warmup/compile both paths, then check per-query parity before timing
    seq_res = [
        subsequence_search(
            ref, queries[q], length=length, window=w, batch=batch,
            backend=backend,
        )
        for q in range(n_queries)
    ]
    multi_res = multi()
    jax.block_until_ready(multi_res.best_dist)
    agree = all(
        int(multi_res.best_start[q]) == int(seq_res[q].best_start)
        for q in range(n_queries)
    )
    max_rel = max(
        abs(float(multi_res.best_dist[q]) - float(seq_res[q].best_dist))
        / max(abs(float(seq_res[q].best_dist)), 1e-12)
        for q in range(n_queries)
    )

    # alternating paired timing (see module docstring)
    t_seq, t_multi, ratios = [], [], []
    for _ in range(pairs):
        t0 = time.time()
        jax.block_until_ready(sequential())
        ts = time.time() - t0
        t0 = time.time()
        jax.block_until_ready(multi().best_dist)
        tm = time.time() - t0
        t_seq.append(ts)
        t_multi.append(tm)
        ratios.append(ts / tm if tm > 0 else 0.0)
    median_ratio = statistics.median(ratios)
    ratio = min(t_seq) / min(t_multi) if min(t_multi) > 0 else 0.0

    tag = f"search/multiq/q{n_queries}/l{length}/r{window_ratio}/{backend}"
    return [
        (f"{tag}/sequential", min(t_seq) * 1e6,
         f"agree={agree};n_queries={n_queries}"),
        (f"{tag}/multi", min(t_multi) * 1e6,
         f"agree={agree};max_rel_dist_err={max_rel:.2e}"),
        (f"{tag}/speedup", ratio,
         f"speedup={ratio:.4f};median_pair_ratio={median_ratio:.4f};"
         f"pairs={pairs}"),
    ]


def main() -> None:
    rows = run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
