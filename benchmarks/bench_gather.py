"""search/gather: fused in-kernel window gather vs the pre-gathered slab.

The tentpole micro-bench for the §2.10 fused normalization path: the same
``subsequence_search`` workload run with ``gather="fused"`` (the DTW stage
slices + z-normalizes each candidate from the resident reference, O(N + K)
working set) against ``gather="slab"`` (the retired default: an O(K·l)
normalized window matrix — and, for the eapruned host driver, an equally
sized cb slab — materialized host-side before every dispatch). The bench
asserts ``best_start`` parity (and ``best_dist`` to float tolerance) before
timing, so the speedup row never reports a wrong answer faster.

The headline structural win is the candidate working set, carried as derived
fields of every speedup row:

  ``cand_bytes_slab``  — bytes of candidate slab the slab arm materializes
                         per dispatch: ``lanes x l x 4`` for the normalized
                         windows, doubled for the host driver's cb slab.
  ``cand_bytes_fused`` — bytes the fused arm ships per lane instead:
                         ``lanes x 12`` (int32 start + f32 mu + f32 sigma).
  ``cand_bytes_ratio`` — their ratio; at l=128 the host/eapruned pair is
                         ``2*128*4 / 12 = 85.3x`` (the slab_ratio gate in
                         bench_diff asserts >= l/2 = 64x).
  ``ref_bytes``        — the O(N) resident reference the fused arm reads
                         from, reported separately: it is paid once per
                         search, not per lane, and the slab arm reads the
                         same reference to build its slabs.

Wall-clock is the secondary signal (the two arms do identical DP work, so
CPU times sit near 1.0x): the ``speedup=`` field rides the same paired
protocol as ``bench_persistent`` (arms alternate so both see the same
background load; best-of vs best-of, with the median of per-pair ratios
alongside) and bench_diff's ±20% guard keeps the fused default honest.

Both drivers pair up: ``host`` (per-round ``(Q x batch)`` slabs vs fused
rounds) and ``persistent`` (the whole best-first order as ONE O(N·l) slab —
the memory cliff the fused sweep removes — vs the addressed fused sweep).
``jax`` is the honest CPU comparison; ``pallas_interpret`` validates the
exact kernel programs under the interpreter.

CSV rows (name,us_per_call,derived):
  search/gather/l{l}/r{ratio}/{backend}/{rounds}/slab    — best-of us
  search/gather/l{l}/r{ratio}/{backend}/{rounds}/fused   — best-of us
  search/gather/l{l}/r{ratio}/{backend}/{rounds}/speedup — best-of ratio
      (+ ``speedup=``, ``median_pair_ratio=``, ``cand_bytes_*=``,
      ``ref_bytes=``)
"""
from __future__ import annotations

import statistics
import time

import jax
import jax.numpy as jnp

from repro.data.synthetic import make_dataset, make_queries
from repro.search import subsequence_search

FUSED_LANE_BYTES = 12  # int32 start + f32 mu + f32 sigma per candidate lane


def _cand_bytes(rounds: str, lanes: int, length: int, batch: int) -> tuple[int, int]:
    """(slab_bytes, fused_bytes) of candidate working set per search.

    Host driver: every round re-materializes a ``batch x l`` normalized
    window slab plus the cb slab of the same shape (eapruned), so the slab
    bytes scale with the lanes actually submitted. Persistent driver: one
    ``k_pad x l`` slab for the whole best-first order up front, regardless
    of how early the sweep's LB gate stops — that is the O(N·l) cliff.
    """
    if rounds == "persistent":
        slab = lanes * length * 4
    else:
        slab = lanes * length * 4 * 2  # cand + cb slabs per round
    return slab, lanes * FUSED_LANE_BYTES


def run(
    ref_len: int = 20_000,
    length: int = 128,
    window_ratio: float = 0.1,
    batch: int = 64,
    block_k: int = 16,
    pairs: int = 7,
    backends=("jax", "pallas_interpret"),
    drivers=("host", "persistent"),
    dataset: str = "ECG",
):
    w = max(int(length * window_ratio), 1)
    ref = jnp.asarray(make_dataset(dataset, ref_len, seed=0), jnp.float32)
    q = jnp.asarray(make_queries(dataset, 1, length, seed=1)[0], jnp.float32)
    n_win = ref_len - length + 1

    rows = []
    for backend in backends:
        for rounds in drivers:
            def arm(gather):
                return subsequence_search(
                    ref, q, length=length, window=w, batch=batch,
                    backend=backend, rounds=rounds, block_k=block_k,
                    gather=gather,
                )

            # warmup/compile both arms, then result parity before timing
            f = arm("fused")
            s = arm("slab")
            jax.block_until_ready(f.best_dist)
            agree = int(f.best_start) == int(s.best_start)
            rel = abs(float(f.best_dist) - float(s.best_dist)) / max(
                abs(float(s.best_dist)), 1e-12
            )
            if not agree or rel > 1e-5:
                raise RuntimeError(
                    f"fused/slab parity broken on {backend}/{rounds}: "
                    f"starts {int(f.best_start)} vs {int(s.best_start)}, "
                    f"rel dist err {rel:.2e}"
                )

            # the persistent slab covers the padded best-first order; the
            # host slabs cover the lanes the rounds actually submitted
            if rounds == "persistent":
                lanes = -(-n_win // block_k) * block_k
            else:
                lanes = int(s.lanes)
            slab_b, fused_b = _cand_bytes(rounds, lanes, length, batch)

            t_slab, t_fused, ratios = [], [], []
            for _ in range(pairs):
                t0 = time.time()
                jax.block_until_ready(arm("slab").best_dist)
                ts = time.time() - t0
                t0 = time.time()
                jax.block_until_ready(arm("fused").best_dist)
                tf = time.time() - t0
                t_slab.append(ts)
                t_fused.append(tf)
                ratios.append(ts / tf if tf > 0 else 0.0)
            median_ratio = statistics.median(ratios)
            ratio = min(t_slab) / min(t_fused) if min(t_fused) > 0 else 0.0

            tag = f"search/gather/l{length}/r{window_ratio}/{backend}/{rounds}"
            rows += [
                (f"{tag}/slab", min(t_slab) * 1e6,
                 f"agree={agree};cand_bytes={slab_b}"),
                (f"{tag}/fused", min(t_fused) * 1e6,
                 f"agree={agree};rel_dist_err={rel:.2e};"
                 f"cand_bytes={fused_b}"),
                (f"{tag}/speedup", ratio,
                 f"speedup={ratio:.4f};median_pair_ratio={median_ratio:.4f};"
                 f"cand_bytes_slab={slab_b};cand_bytes_fused={fused_b};"
                 f"cand_bytes_ratio={slab_b / fused_b:.1f};"
                 f"ref_bytes={ref_len * 4};lanes={lanes};pairs={pairs}"),
            ]
    return rows


def main() -> None:
    rows = run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
