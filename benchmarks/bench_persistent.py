"""search/persistent: one-launch persistent sweep vs the host round driver.

The tentpole micro-bench for the persistent round driver: one
``subsequence_search(rounds="persistent")`` call (single launch, incumbent
carried across candidate blocks on device) against the host driver's
best-first round loop (one dispatch + one incumbent update per round), same
variant/batch/backend. The bench asserts ``best_start`` parity (and
``best_dist`` to float tolerance) before timing, so the speedup row never
reports a wrong answer faster.

The dispatch-count reduction is the headline structural win and is carried
in the derived field of every speedup row: ``host_rounds`` (dispatches the
host driver issued) vs ``persistent_dispatches=1``.

Measurement protocol: identical to ``bench_multiq`` — the two drivers
alternate (host, persistent, host, persistent, ...) so both see the same
background load; the headline ratio is best-of vs best-of with the median
of per-pair ratios alongside.

Both backends run: ``jax`` is the honest CPU wall-clock comparison;
``pallas_interpret`` times the exact kernel *programs* under the Python
interpreter (dispatch-structure validation, not TPU performance — the
persistent kernel's single grid vs one interpreted grid per host round).

``block_k`` is the persistent driver's tightening granularity. The default
here is 16 on CPU: the jax sweep pays outer-loop overhead per block, so the
8-lane TPU default trades badly against lockstep savings on CPU (measured
~0.97x at 8 vs ~1.19x at 16 on the quick workload); the host arm ignores
``block_k`` on the jax backend, so the knob only tunes the persistent arm.

CSV rows (name,us_per_call,derived):
  search/persistent/l{l}/r{ratio}/{backend}/host       — best-of us, host driver
  search/persistent/l{l}/r{ratio}/{backend}/persistent — best-of us, one launch
  search/persistent/l{l}/r{ratio}/{backend}/speedup    — best-of ratio (+
      ``speedup=``, ``median_pair_ratio=``, ``host_rounds=``,
      ``persistent_dispatches=1``)
"""
from __future__ import annotations

import statistics
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.data.synthetic import make_dataset, make_queries
from repro.search import subsequence_search


def run(
    ref_len: int = 20_000,
    length: int = 128,
    window_ratio: float = 0.1,
    batch: int = 64,
    block_k: int = 16,
    pairs: int = 7,
    backends=("jax", "pallas_interpret"),
    dataset: str = "ECG",
):
    w = max(int(length * window_ratio), 1)
    ref = jnp.asarray(make_dataset(dataset, ref_len, seed=0), jnp.float32)
    q = jnp.asarray(make_queries(dataset, 1, length, seed=1)[0], jnp.float32)

    rows = []
    for backend in backends:
        def host():
            # same block_k on both arms: on the Pallas backend it shapes the
            # grid tiling too, and the speedup row must isolate the driver
            # change, not a tile-size change
            return subsequence_search(
                ref, q, length=length, window=w, batch=batch,
                backend=backend, block_k=block_k,
            )

        def persistent():
            return subsequence_search(
                ref, q, length=length, window=w, batch=batch,
                backend=backend, rounds="persistent", block_k=block_k,
            )

        # warmup/compile both drivers, then result parity before timing —
        # a failed parity check aborts the bench rather than timing a
        # wrong answer into a speedup row
        h = host()
        p = persistent()
        jax.block_until_ready(p.best_dist)
        agree = int(h.best_start) == int(p.best_start)
        rel = abs(float(h.best_dist) - float(p.best_dist)) / max(
            abs(float(h.best_dist)), 1e-12
        )
        if not agree or rel > 1e-5:
            raise RuntimeError(
                f"persistent/host parity broken on {backend}: "
                f"starts {int(p.best_start)} vs {int(h.best_start)}, "
                f"rel dist err {rel:.2e}"
            )
        host_rounds = int(h.rounds)

        t_host, t_pers, ratios = [], [], []
        for _ in range(pairs):
            t0 = time.time()
            jax.block_until_ready(host().best_dist)
            th = time.time() - t0
            t0 = time.time()
            jax.block_until_ready(persistent().best_dist)
            tp = time.time() - t0
            t_host.append(th)
            t_pers.append(tp)
            ratios.append(th / tp if tp > 0 else 0.0)
        median_ratio = statistics.median(ratios)
        ratio = min(t_host) / min(t_pers) if min(t_pers) > 0 else 0.0

        tag = f"search/persistent/l{length}/r{window_ratio}/{backend}"
        rows += [
            (f"{tag}/host", min(t_host) * 1e6,
             f"agree={agree};host_rounds={host_rounds}"),
            (f"{tag}/persistent", min(t_pers) * 1e6,
             f"agree={agree};rel_dist_err={rel:.2e};"
             f"lanes={int(p.lanes)};block_k={block_k}"),
            (f"{tag}/speedup", ratio,
             f"speedup={ratio:.4f};median_pair_ratio={median_ratio:.4f};"
             f"host_rounds={host_rounds};persistent_dispatches=1;"
             f"pairs={pairs}"),
        ]
    return rows


def main() -> None:
    rows = run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
