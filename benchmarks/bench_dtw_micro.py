"""DTW micro-benchmarks: algorithmic work saved by EAPrunedDTW.

Table analogue of the paper's per-computation comparison: for matched
(length, window, ub-tightness) settings, rows/cells issued by full DTW vs
PrunedDTW vs EAPrunedDTW (banded), plus wall time of the batched JAX forms.
``run_backends`` additionally compares the two dispatchable batch backends
(banded-vmap JAX vs the Pallas kernel in interpret mode) across a sweep of
batch shapes (K x l), ``block_k`` grid tilings, and multi-query ``Q`` —
interpret-mode wall time validates the dispatch layer, not TPU performance.
CSV: name,us_per_call,derived (derived = rows or cells saved).
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    dtw_batch,
    ea_pruned_dtw_banded,
    ea_pruned_dtw_batch,
    ea_pruned_dtw_multi_batch,
    pruned_dtw,
)
from repro.search.znorm import znorm


def _bench(fn, *args, repeats=3):
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.time() - t0)
    return best, out


def run(length: int = 256, k: int = 256, window_ratio: float = 0.1, seed: int = 0):
    rows = []
    w = max(int(length * window_ratio), 1)
    rng = np.random.default_rng(seed)
    q = znorm(jnp.asarray(np.cumsum(rng.normal(size=length)), jnp.float32))
    cands = znorm(jnp.asarray(np.cumsum(rng.normal(size=(k, length)), axis=1), jnp.float32))

    t_full, d_exact = _bench(lambda: dtw_batch(jnp.broadcast_to(q, (k, length)), cands, window=w))
    exact = np.asarray(d_exact)

    for tag, frac in (("tight", 0.05), ("median", 0.5), ("loose", 1.01)):
        ub = float(np.quantile(exact, frac)) if frac <= 1 else float(exact.max() * 1.01)
        t_ea, _ = _bench(
            lambda u=ub: ea_pruned_dtw_batch(q, cands, u, window=w)
        )
        t_pr, _ = _bench(
            lambda u=ub: jax.vmap(lambda c: pruned_dtw(q, c, u, window=w))(cands)
        )
        # work counters (rows issued) via with_info on the banded kernel
        _, info = jax.vmap(
            lambda c: ea_pruned_dtw_banded(q, c, ub, window=w, with_info=True)
        )(cands)
        rows_issued = int(jnp.sum(info.rows))
        cells_issued = int(jnp.sum(info.cells))
        full_rows = k * length
        rows.append(
            (f"dtw/l{length}/w{w}/ea_{tag}", t_ea * 1e6,
             f"rows={rows_issued}/{full_rows} cells={cells_issued}")
        )
        rows.append((f"dtw/l{length}/w{w}/pruned_{tag}", t_pr * 1e6, ""))
    rows.append((f"dtw/l{length}/w{w}/full", t_full * 1e6, f"rows={k*length}"))
    return rows


def run_backends(
    shapes=((64, 128), (256, 128), (64, 256)),
    window_ratio: float = 0.1,
    seed: int = 0,
    block_ks=(4, 8, 16),
    qs=(1, 4),
):
    """dtw/backend micro-bench: vmap-JAX vs Pallas-interpret per batch shape.

    Sweeps the kernel-shape knobs that matter for the dispatch layer:
    candidate count ``K`` x length ``l`` x ``block_k`` (lanes per grid
    block — the early-exit granularity) x ``Q`` (queries flattened into one
    multi-launch). ``block_k`` only shapes the Pallas grid, so the jax row
    is emitted once per (K, l, Q) and repeated ratios track the kernel's
    shape sweet spot in BENCH_dtw.json over time.
    """
    rows = []
    rng = np.random.default_rng(seed)
    for k, length in shapes:
        w = max(int(length * window_ratio), 1)
        for nq in qs:
            queries = znorm(
                jnp.asarray(
                    np.cumsum(rng.normal(size=(nq, length)), axis=1),
                    jnp.float32,
                )
            )
            cands = znorm(
                jnp.asarray(
                    np.cumsum(rng.normal(size=(nq, k, length)), axis=2),
                    jnp.float32,
                )
            )
            d_exact = jax.vmap(
                lambda qn, cs: dtw_batch(
                    jnp.broadcast_to(qn, (k, length)), cs, window=w
                )
            )(queries, cands)
            ub = jnp.quantile(d_exact, 0.5, axis=1, keepdims=True)  # (Q, 1)
            t_jax, d_jax = _bench(
                lambda: ea_pruned_dtw_multi_batch(
                    queries, cands, ub, window=w, backend="jax"
                )
            )
            base = f"dtw/backend/k{k}/l{length}/q{nq}"
            rows.append((f"{base}/jax", t_jax * 1e6, ""))
            for bk in block_ks:
                t_pal, d_pal = _bench(
                    lambda bk=bk: ea_pruned_dtw_multi_batch(
                        queries, cands, ub, window=w,
                        backend="pallas_interpret", block_k=bk,
                    )
                )
                agree = bool(
                    np.array_equal(
                        np.isfinite(np.asarray(d_jax)),
                        np.isfinite(np.asarray(d_pal)),
                    )
                )
                rows.append(
                    (f"{base}/bk{bk}/pallas_interpret", t_pal * 1e6,
                     f"agree={agree}")
                )
    return rows


def main() -> None:
    out = []
    out += run(length=128, k=256, window_ratio=0.1)
    out += run(length=256, k=128, window_ratio=0.2)
    out += run_backends()
    for name, us, derived in out:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
