"""DTW micro-benchmarks: algorithmic work saved by EAPrunedDTW.

Table analogue of the paper's per-computation comparison: for matched
(length, window, ub-tightness) settings, rows/cells issued by full DTW vs
PrunedDTW vs EAPrunedDTW (banded), plus wall time of the batched JAX forms.
CSV: name,us_per_call,derived (derived = rows or cells saved).
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    dtw_batch,
    ea_pruned_dtw_banded,
    ea_pruned_dtw_batch,
    pruned_dtw,
)
from repro.search.znorm import znorm


def _bench(fn, *args, repeats=3):
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.time() - t0)
    return best, out


def run(length: int = 256, k: int = 256, window_ratio: float = 0.1, seed: int = 0):
    rows = []
    w = max(int(length * window_ratio), 1)
    rng = np.random.default_rng(seed)
    q = znorm(jnp.asarray(np.cumsum(rng.normal(size=length)), jnp.float32))
    cands = znorm(jnp.asarray(np.cumsum(rng.normal(size=(k, length)), axis=1), jnp.float32))

    t_full, d_exact = _bench(lambda: dtw_batch(jnp.broadcast_to(q, (k, length)), cands, window=w))
    exact = np.asarray(d_exact)

    for tag, frac in (("tight", 0.05), ("median", 0.5), ("loose", 1.01)):
        ub = float(np.quantile(exact, frac)) if frac <= 1 else float(exact.max() * 1.01)
        t_ea, _ = _bench(
            lambda u=ub: ea_pruned_dtw_batch(q, cands, u, window=w)
        )
        t_pr, _ = _bench(
            lambda u=ub: jax.vmap(lambda c: pruned_dtw(q, c, u, window=w))(cands)
        )
        # work counters (rows issued) via with_info on the banded kernel
        _, info = jax.vmap(
            lambda c: ea_pruned_dtw_banded(q, c, ub, window=w, with_info=True)
        )(cands)
        rows_issued = int(jnp.sum(info.rows))
        cells_issued = int(jnp.sum(info.cells))
        full_rows = k * length
        rows.append(
            (f"dtw/l{length}/w{w}/ea_{tag}", t_ea * 1e6,
             f"rows={rows_issued}/{full_rows} cells={cells_issued}")
        )
        rows.append((f"dtw/l{length}/w{w}/pruned_{tag}", t_pr * 1e6, ""))
    rows.append((f"dtw/l{length}/w{w}/full", t_full * 1e6, f"rows={k*length}"))
    return rows


def main() -> None:
    out = []
    out += run(length=128, k=256, window_ratio=0.1)
    out += run(length=256, k=128, window_ratio=0.2)
    for name, us, derived in out:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
