"""search/stream: incremental ingest vs full recompute per chunk.

The streaming engine's claim is that serving a live stream costs O(chunk)
per arrival: the appendable stats + boundary-tail cascade scan only the
newly-valid windows, and the carried incumbents make EAPrunedDTW abandon
harder as the stream ages. The honest baseline is what a chunk-arrival loop
looks like *without* the engine: rerun offline ``multi_query_search`` on the
full prefix after every chunk (O(N) stats + cascade each time, incumbents
rebuilt from scratch). Both paths see the same chunk schedule and answer
after every chunk; the bench asserts final-answer parity with the offline
search over the whole series before timing anything.

Measurement protocol: same alternating paired scheme as ``bench_multiq``
(recompute, stream, recompute, stream, ...) so both paths share background
load; headline ratio is best-of vs best-of with the median per-pair ratio
alongside. The stream path builds a fresh engine per repetition (its state
is consumed by ingestion); construction is part of the serving cost and is
included.

CSV rows (name,us_per_call,derived):
  search/stream/q{Q}/l{l}/c{chunk}/{backend}/recompute — best-of aggregate us
  search/stream/q{Q}/l{l}/c{chunk}/{backend}/stream    — best-of aggregate us
  search/stream/q{Q}/l{l}/c{chunk}/{backend}/speedup   — best-of ratio
"""
from __future__ import annotations

import statistics
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.data.synthetic import make_dataset, make_queries
from repro.search import multi_query_search
from repro.serve import StreamSearchEngine


def run(
    ref_len: int = 16_000,
    length: int = 128,
    window_ratio: float = 0.1,
    n_queries: int = 4,
    batch: int = 64,
    chunk: int = 2_000,
    pairs: int = 5,
    backend: str = "jax",
    dataset: str = "ECG",
):
    w = max(int(length * window_ratio), 1)
    ref = jnp.asarray(make_dataset(dataset, ref_len, seed=0), jnp.float32)
    queries = jnp.asarray(
        make_queries(dataset, n_queries, length, seed=1), jnp.float32
    )
    bounds = list(range(chunk, ref_len + 1, chunk))
    if not bounds or bounds[-1] != ref_len:
        bounds.append(ref_len)

    def recompute():
        # chunk-arrival loop without the engine: full offline search on the
        # grown prefix after every chunk
        res = None
        for hi in bounds:
            res = multi_query_search(
                ref[:hi], queries, length=length, window=w, batch=batch,
                backend=backend,
            )
        return res

    def stream():
        eng = StreamSearchEngine(
            queries, length=length, window=w, batch=batch, backend=backend
        )
        lo = 0
        for hi in bounds:
            eng.ingest(ref[lo:hi])
            lo = hi
        return eng

    # warmup/compile both paths (every prefix length and ingest shape), then
    # check parity against the one-shot offline answer before timing
    full = multi_query_search(
        ref, queries, length=length, window=w, batch=batch, backend=backend
    )
    last = recompute()
    eng = stream()
    bs, bd = eng.best()
    agree = bool(
        np.array_equal(np.asarray(bs), np.asarray(full.best_start))
        and np.array_equal(
            np.asarray(last.best_start), np.asarray(full.best_start)
        )
    )
    max_rel = float(
        np.max(
            np.abs(np.asarray(bd) - np.asarray(full.best_dist))
            / np.maximum(np.abs(np.asarray(full.best_dist)), 1e-12)
        )
    )

    t_rec, t_str, ratios = [], [], []
    for _ in range(pairs):
        t0 = time.time()
        jax.block_until_ready(recompute().best_dist)
        tr = time.time() - t0
        t0 = time.time()
        jax.block_until_ready(stream().best()[1])
        ts = time.time() - t0
        t_rec.append(tr)
        t_str.append(ts)
        ratios.append(tr / ts if ts > 0 else 0.0)
    median_ratio = statistics.median(ratios)
    ratio = min(t_rec) / min(t_str) if min(t_str) > 0 else 0.0

    tag = f"search/stream/q{n_queries}/l{length}/c{chunk}/{backend}"
    return [
        (f"{tag}/recompute", min(t_rec) * 1e6,
         f"agree={agree};chunks={len(bounds)}"),
        (f"{tag}/stream", min(t_str) * 1e6,
         f"agree={agree};max_rel_dist_err={max_rel:.2e}"),
        (f"{tag}/speedup", ratio,
         f"speedup={ratio:.4f};median_pair_ratio={median_ratio:.4f};"
         f"pairs={pairs}"),
    ]


def main() -> None:
    rows = run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
