"""Benchmark entry point. One section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  * suite/*       — paper Fig. 5 analogue (four suites x dataset x l x w)
  * dtw/*         — per-computation EA/Pruned/full work + time comparison
  * kernel/*      — Pallas kernel harness checks (interpret mode)
  * roofline/*    — dry-run-derived roofline terms per (arch x shape)

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-roofline]
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    from benchmarks import bench_dtw_micro, bench_kernels, bench_suites

    print("name,us_per_call,derived")
    if args.quick:
        rows = bench_suites.run(ref_len=4_000, lengths=(128,), ratios=(0.1,),
                                datasets=("ECG",), repeats=1)
    else:
        rows = bench_suites.run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)

    for name, us, derived in bench_dtw_micro.run(length=128, k=128, window_ratio=0.1):
        print(f"{name},{us:.1f},{derived}", flush=True)

    bench_kernels.main()

    if not args.skip_roofline:
        from repro.roofline.analysis import load_cells

        try:
            cells = load_cells()
        except Exception as e:
            print(f"roofline/unavailable,0.0,{e}")
            cells = []
        for c in cells:
            if "skipped" in c:
                continue
            name = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
            bound_us = max(c["compute_s"], c["memory_s"], c["collective_s"]) * 1e6
            print(
                f"{name},{bound_us:.1f},"
                f"bound={c['dominant']};frac={c['roofline_fraction']:.4f};"
                f"useful={c['useful_ratio']:.3f}",
                flush=True,
            )


if __name__ == "__main__":
    main()
