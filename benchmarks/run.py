"""Benchmark entry point. One section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  * suite/*        — paper Fig. 5 analogue (four suites x dataset x l x w);
                     suite/SPEEDUP/* rows carry the headline ratios
  * search/multiq/* — one multi_query_search call vs Q sequential searches
  * search/stream/* — streaming engine ingest vs full recompute per chunk
  * search/robustness/* — quarantine-prepass overhead on clean data
                     (must sit within noise of the prepass compiled out)
  * search/resilient/* — fault-tolerant sharded executor vs the plain
                     offline driver on a healthy system (coverage 1.0)
  * search/hedged/* — hedged dispatch: healthy-path overhead (≲5%) plus
                     the deterministic tail win under one injected
                     straggler on a virtual clock (DESIGN.md §2.9)
  * search/persistent/* — one-launch persistent sweep vs host round driver
                     (both backends; dispatch counts in the speedup rows)
  * search/gather/* — fused in-kernel window gather + z-normalization vs
                     the pre-gathered O(K·l) candidate slab (§2.10); the
                     speedup rows carry the working-set byte accounting
  * search/pipeline/* — frontend wrapper (validation + plan resolution)
                     vs the bare jitted pipeline core; the overhead ratio
                     must stay ≈1 (the §2.8 refactor's dispatch guard)
  * dtw/*          — per-computation EA/Pruned/full work + time comparison
  * dtw/backend/*  — batch-backend dispatch comparison (vmap vs
                     Pallas-interpret) across K x l x block_k x Q shapes
  * kernel/*       — Pallas kernel harness checks (interpret mode)
  * roofline/*     — dry-run-derived roofline terms per (arch x shape)

``--json`` additionally writes a ``BENCH_dtw.json`` artifact so the perf
trajectory stays machine-readable across PRs: per-suite ``us_per_call`` and
``cells_ratio``, the ``multiq`` and ``stream`` suites, plus every dtw/*
micro-bench row.

Usage: PYTHONPATH=src python -m benchmarks.run
         [--quick] [--skip-roofline] [--json [PATH]]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _suite_record(name: str, us: float, derived: str) -> dict:
    rec = {"name": name, "us_per_call": round(us, 1)}
    for part in str(derived).split(";"):
        if "=" in part:
            key, val = part.split("=", 1)
            try:
                rec[key] = float(val)
            except ValueError:
                rec[key] = val
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument(
        "--json", nargs="?", const="BENCH_dtw.json", default=None,
        metavar="PATH",
        help="also write a machine-readable artifact (default BENCH_dtw.json)",
    )
    args = ap.parse_args()

    from benchmarks import (
        bench_dtw_micro,
        bench_gather,
        bench_kernels,
        bench_multiq,
        bench_persistent,
        bench_pipeline,
        bench_robustness,
        bench_stream,
        bench_suites,
    )

    import jax

    # quick-scale and full-scale runs are different workloads; the meta block
    # keeps cross-PR comparisons scoped to like-for-like artifacts
    artifact = {
        "meta": {"quick": bool(args.quick), "backend": jax.default_backend()},
        "suites": [], "multiq": [], "stream": [], "robustness": [],
        "resilient": [], "hedged": [], "persistent": [], "gather": [],
        "pipeline": [], "dtw": [], "roofline": [],
    }

    print("name,us_per_call,derived")
    if args.quick:
        rows = bench_suites.run(ref_len=4_000, lengths=(128,), ratios=(0.1,),
                                datasets=("ECG",), repeats=1)
    else:
        rows = bench_suites.run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
        artifact["suites"].append(_suite_record(name, us, derived))

    if args.quick:
        mq_rows = bench_multiq.run(ref_len=8_000, pairs=5)
    else:
        mq_rows = bench_multiq.run()
    for name, us, derived in mq_rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
        artifact["multiq"].append(_suite_record(name, us, derived))

    if args.quick:
        st_rows = bench_stream.run(ref_len=6_000, chunk=1_500, pairs=3)
    else:
        st_rows = bench_stream.run()
    for name, us, derived in st_rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
        artifact["stream"].append(_suite_record(name, us, derived))

    if args.quick:
        # like bench_persistent below, the two arms are near-identical in
        # cost, so the ratio needs extra pairs to beat the box's noise
        rb_rows = bench_robustness.run(ref_len=6_000, chunk=1_500, pairs=9)
    else:
        rb_rows = bench_robustness.run()
    for name, us, derived in rb_rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
        artifact["robustness"].append(_suite_record(name, us, derived))

    if args.quick:
        # few shards over a short ref: the executor's dispatch boundaries
        # dominate, so extra pairs keep the ratio above the box's noise
        rs_rows = bench_robustness.run_resilient(ref_len=6_000, pairs=9)
    else:
        rs_rows = bench_robustness.run_resilient()
    for name, us, derived in rs_rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
        artifact["resilient"].append(_suite_record(name, us, derived))

    if args.quick:
        # the straggler-tail row is exact (virtual clock) at any scale, so
        # quick mode only shrinks the wall-clock healthy-overhead arm
        hg_rows = bench_robustness.run_hedged(ref_len=6_000, pairs=5)
    else:
        hg_rows = bench_robustness.run_hedged()
    for name, us, derived in hg_rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
        artifact["hedged"].append(_suite_record(name, us, derived))

    if args.quick:
        # more pairs than the other quick suites: the two arms are within
        # ~15% of each other on CPU, so the median needs the extra samples
        # to sit above the box's timing noise
        ps_rows = bench_persistent.run(ref_len=4_000, pairs=9)
    else:
        ps_rows = bench_persistent.run()
    for name, us, derived in ps_rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
        artifact["persistent"].append(_suite_record(name, us, derived))

    if args.quick:
        # identical DP work on both arms (the slab is the only difference),
        # so the wall-clock ratio needs extra pairs on a noisy box; the
        # byte-accounting fields are exact at any scale
        gt_rows = bench_gather.run(ref_len=4_000, pairs=9)
    else:
        gt_rows = bench_gather.run()
    for name, us, derived in gt_rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
        artifact["gather"].append(_suite_record(name, us, derived))

    if args.quick:
        # the two arms are one wrapper apart, so the overhead ratio sits
        # right at 1.0 — extra pairs keep it above the box's timing noise
        pl_rows = bench_pipeline.run(ref_len=8_000, pairs=9)
    else:
        pl_rows = bench_pipeline.run()
    for name, us, derived in pl_rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
        artifact["pipeline"].append(_suite_record(name, us, derived))

    micro = bench_dtw_micro.run(length=128, k=128, window_ratio=0.1)
    micro += bench_dtw_micro.run_backends(
        shapes=((64, 128),) if args.quick else ((64, 128), (256, 128), (64, 256)),
        block_ks=(8, 16) if args.quick else (4, 8, 16),
        qs=(1, 4),
    )
    for name, us, derived in micro:
        print(f"{name},{us:.1f},{derived}", flush=True)
        artifact["dtw"].append(_suite_record(name, us, derived))

    bench_kernels.main()

    if not args.skip_roofline:
        from repro.roofline.analysis import load_cells

        try:
            cells = load_cells()
        except Exception as e:
            print(f"roofline/unavailable,0.0,{e}")
            cells = []
        for c in cells:
            if "skipped" in c:
                continue
            name = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
            bound_us = max(c["compute_s"], c["memory_s"], c["collective_s"]) * 1e6
            print(
                f"{name},{bound_us:.1f},"
                f"bound={c['dominant']};frac={c['roofline_fraction']:.4f};"
                f"useful={c['useful_ratio']:.3f}",
                flush=True,
            )
            artifact["roofline"].append(
                {"name": name, "bound_us": round(bound_us, 1),
                 "bound": c["dominant"],
                 "roofline_fraction": c["roofline_fraction"]}
            )

    if args.json:
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
