"""Paper Figure 5 analogue: the four suites across datasets/lengths/windows.

UCR (full), UCR-USP (pruned), UCR-MON (eapruned), UCR-MON-nolb — same
queries, same references, wall-clock + pruning counters. Sizes default to
CPU-tractable scales; ``--paper-scale`` selects the real ones (1M-point
references, 1024-sample queries) for TPU runs.

Timing measures the *counter-free fast round* (the serving default); the
pruning counters come from one extra untimed ``with_info=True`` search so
the paper's cells ratio is still reported. Backend and tuning knobs default
to ``configs.SEARCH_CONFIG``.

Output CSV: name,us_per_call,derived
  derived = cells_computed/cells_full (the paper's pruning-effectiveness ratio)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import SEARCH_CONFIG
from repro.data.synthetic import DATASETS, make_dataset, make_queries
from repro.search import subsequence_search
from repro.search.subsequence import VARIANTS


def run(
    ref_len: int = 20_000,
    lengths=(128, 256),
    ratios=(0.1, 0.3),
    datasets=DATASETS,
    n_queries: int = 1,
    batch: int = 128,
    repeats: int = 2,
    backend: str | None = None,
    rows_per_step: int | None = None,
    block_k: int | None = None,
    row_block: int | None = None,
):
    cfg = SEARCH_CONFIG
    knobs = dict(
        backend=backend if backend is not None else cfg.backend,
        rows_per_step=rows_per_step if rows_per_step is not None else cfg.rows_per_step,
        block_k=block_k if block_k is not None else cfg.block_k,
        row_block=row_block if row_block is not None else cfg.row_block,
    )
    rows = []
    totals = {v: 0.0 for v in VARIANTS}
    for ds in datasets:
        ref = jnp.asarray(make_dataset(ds, ref_len, seed=0), jnp.float32)
        for length in lengths:
            queries = make_queries(ds, n_queries, length, seed=1)
            for ratio in ratios:
                w = max(int(length * ratio), 1)
                n_win = ref_len - length + 1
                full_cells = n_win * min(
                    length * (2 * w + 1) - w * (w + 1), length * length
                )
                for variant in VARIANTS:
                    best, cells = None, 0
                    dt_best = float("inf")
                    for q in queries:
                        qj = jnp.asarray(q, jnp.float32)
                        # warmup / compile
                        res = subsequence_search(
                            ref, qj, length=length, window=w,
                            variant=variant, batch=batch, **knobs,
                        )
                        jax.block_until_ready(res.best_dist)
                        for _ in range(repeats):
                            t0 = time.time()
                            res = subsequence_search(
                                ref, qj, length=length, window=w,
                                variant=variant, batch=batch, **knobs,
                            )
                            jax.block_until_ready(res.best_dist)
                            dt_best = min(dt_best, time.time() - t0)
                        # untimed stats round for the pruning counters
                        stats = subsequence_search(
                            ref, qj, length=length, window=w,
                            variant=variant, batch=batch, with_info=True,
                            **knobs,
                        )
                        cells += int(stats.cells)
                        best = (int(res.best_start), float(res.best_dist))
                    name = f"suite/{ds}/l{length}/r{ratio}/{variant}"
                    ratio_cells = cells / (full_cells * len(queries))
                    rows.append((name, dt_best * 1e6, f"cells_ratio={ratio_cells:.4f}"))
                    totals[variant] += dt_best
    for v in VARIANTS:
        rows.append((f"suite/TOTAL/{v}", totals[v] * 1e6, "sum_best_times"))
    # headline speedups (paper reports MON vs UCR and vs USP). The row value
    # is the ratio itself (not a us_per_call), repeated as ``speedup=`` in
    # the derived field so the JSON artifact carries it as a float.
    for tag, num, den in (
        ("eapruned_vs_full", "full", "eapruned"),
        ("eapruned_vs_pruned", "pruned", "eapruned"),
        ("nolb_vs_full", "full", "eapruned_nolb"),
    ):
        if totals[den] <= 0:
            continue
        ratio = totals[num] / totals[den]
        rows.append(
            (f"suite/SPEEDUP/{tag}", ratio,
             f"speedup={ratio:.4f};base_us={totals[num] * 1e6:.1f};"
             f"opt_us={totals[den] * 1e6:.1f}")
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--ref-len", type=int, default=None)
    args = ap.parse_args()
    if args.paper_scale:
        rows = run(
            ref_len=args.ref_len or 1_000_000,
            lengths=(128, 256, 512, 1024),
            ratios=(0.1, 0.2, 0.3, 0.4, 0.5),
            n_queries=5,
        )
    else:
        rows = run(ref_len=args.ref_len or 20_000)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
