"""Pallas kernel benchmarks (interpret mode on CPU: correctness-scale only).

Wall times here validate the harness, not TPU performance — the kernels are
written for TPU lowering (BlockSpec/VMEM); see EXPERIMENTS.md §Roofline for
the structural analysis. CSV: name,us_per_call,derived.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.lower_bounds import envelope
from repro.kernels.ops import dtw_ea, lb_keogh_all_windows
from repro.kernels.ref import dtw_ea_ref
from repro.search.znorm import window_stats, znorm


def _bench(fn, repeats=2):
    out = fn()
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.time() - t0)
    return best, out


def main() -> None:
    rng = np.random.default_rng(0)
    rows = []

    n, k, w = 128, 64, 12
    q = znorm(jnp.asarray(rng.normal(size=n), jnp.float32))
    c = znorm(jnp.asarray(rng.normal(size=(k, n)), jnp.float32))
    exact = np.asarray(dtw_ea_ref(q, c, jnp.inf, window=w))
    ub = float(np.median(exact))
    t, out = _bench(lambda: dtw_ea(q, c, ub, window=w, block_k=8, row_block=64))
    ref = np.asarray(dtw_ea_ref(q, c, ub, window=w))
    ok = np.array_equal(np.isfinite(np.asarray(out)), np.isfinite(ref))
    rows.append((f"kernel/dtw_ea/l{n}/k{k}", t * 1e6, f"match_ref={ok}"))

    n_ref, length = 4096, 128
    ref_s = jnp.asarray(np.cumsum(rng.normal(size=n_ref)), jnp.float32)
    qr = znorm(jnp.asarray(np.cumsum(rng.normal(size=length)), jnp.float32))
    mu, sg = window_stats(ref_s, length)
    u, low = envelope(qr, w)
    qe = jnp.asarray([qr[0], qr[-1]], jnp.float32)
    t, _ = _bench(
        lambda: lb_keogh_all_windows(ref_s, mu, sg, u, low, qe, length=length, chunk=512)
    )
    rows.append((f"kernel/lb_keogh/N{n_ref}/l{length}", t * 1e6, "all_windows"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
