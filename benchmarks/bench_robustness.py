"""search/robustness: quarantine-prepass overhead on clean data.
Also search/resilient (``run_resilient``): the fault-tolerant sharded
executor's overhead over the plain offline driver on a healthy system.

The non-finite quarantine (DESIGN.md §2.6) is on by default, so its cost on
*clean* data is a tax every search pays. The contract is that the tax is one
extra prefix-sum pass over the ingest context — the same O(N) shape as the
window stats themselves — and therefore within timing noise of running with
the prepass compiled out. This bench pins that claim on the streaming
engine, where the prepass runs once per ingest (the worst case: offline
search amortizes one prepass over the whole series).

Both arms feed the identical clean chunk schedule through identical engines
except for ``quarantine=``; parity of the final ``(start, dist)`` answers is
asserted before timing anything. Measurement is the same alternating paired
protocol as ``bench_stream`` (off, on, off, on, ...) so both arms share
background load; ``quarantine`` is a static jit arg, so each arm owns its
trace and both are warmed before the clock starts.

CSV rows (name,us_per_call,derived):
  search/robustness/q{Q}/l{l}/c{chunk}/{backend}/noprepass — best-of us
  search/robustness/q{Q}/l{l}/c{chunk}/{backend}/prepass   — best-of us
  search/robustness/q{Q}/l{l}/c{chunk}/{backend}/overhead  — best-of ratio
    (off/on; 1.0 = free; ``speedup=`` so >20% regressions gate bench-diff,
    ``overhead_pct`` is the headline the acceptance bar reads)
"""
from __future__ import annotations

import statistics
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.data.synthetic import make_dataset, make_queries
from repro.serve import StreamSearchEngine


def run(
    ref_len: int = 16_000,
    length: int = 128,
    window_ratio: float = 0.1,
    n_queries: int = 4,
    batch: int = 64,
    chunk: int = 2_000,
    pairs: int = 5,
    backend: str = "jax",
    dataset: str = "ECG",
):
    w = max(int(length * window_ratio), 1)
    ref = jnp.asarray(make_dataset(dataset, ref_len, seed=0), jnp.float32)
    queries = jnp.asarray(
        make_queries(dataset, n_queries, length, seed=1), jnp.float32
    )
    bounds = list(range(chunk, ref_len + 1, chunk))
    if not bounds or bounds[-1] != ref_len:
        bounds.append(ref_len)

    def feed(quarantine: bool):
        eng = StreamSearchEngine(
            queries, length=length, window=w, batch=batch, backend=backend,
            quarantine=quarantine,
        )
        lo = 0
        for hi in bounds:
            eng.ingest(ref[lo:hi])
            lo = hi
        return eng

    # warmup/compile both traces, then pin clean-data parity: the prepass
    # must change nothing but the (zero) quarantine count
    e_on, e_off = feed(True), feed(False)
    (s_on, d_on), (s_off, d_off) = e_on.best(), e_off.best()
    agree = bool(
        np.array_equal(np.asarray(s_on), np.asarray(s_off))
        and np.array_equal(np.asarray(d_on), np.asarray(d_off))
        and e_on.quarantined_windows == 0
    )

    t_off, t_on, ratios = [], [], []
    for _ in range(pairs):
        t0 = time.time()
        jax.block_until_ready(feed(False).best()[1])
        toff = time.time() - t0
        t0 = time.time()
        jax.block_until_ready(feed(True).best()[1])
        ton = time.time() - t0
        t_off.append(toff)
        t_on.append(ton)
        ratios.append(toff / ton if ton > 0 else 0.0)
    median_ratio = statistics.median(ratios)
    ratio = min(t_off) / min(t_on) if min(t_on) > 0 else 0.0
    overhead_pct = (1.0 / ratio - 1.0) * 100.0 if ratio > 0 else float("inf")

    tag = f"search/robustness/q{n_queries}/l{length}/c{chunk}/{backend}"
    return [
        (f"{tag}/noprepass", min(t_off) * 1e6,
         f"agree={agree};chunks={len(bounds)}"),
        (f"{tag}/prepass", min(t_on) * 1e6,
         f"agree={agree};quarantined={e_on.quarantined_windows}"),
        (f"{tag}/overhead", ratio,
         f"speedup={ratio:.4f};overhead_pct={overhead_pct:.2f};"
         f"median_pair_ratio={median_ratio:.4f};pairs={pairs}"),
    ]


def run_resilient(
    ref_len: int = 16_000,
    length: int = 128,
    window_ratio: float = 0.1,
    n_queries: int = 4,
    n_shards: int = 2,
    pairs: int = 5,
    backend: str = "jax",
    dataset: str = "ECG",
):
    """search/resilient: per-shard executor overhead on a healthy system.

    The resilient executor (DESIGN.md §2.7) buys shard-failure recovery by
    running the search as ``n_shards`` sequential range dispatches with a
    host-side incumbent fold between them, instead of one offline driver
    call. On a healthy system the contract is that this costs only the
    extra dispatch boundaries — the carried ``ub_init`` seeding means the
    later ranges do *less* DTW work, not more. Parity of the answers is
    asserted before timing; the same alternating paired protocol as above.

    CSV rows (name,us_per_call,derived):
      search/resilient/q{Q}/l{l}/s{S}/{backend}/plain     — offline driver
      search/resilient/q{Q}/l{l}/s{S}/{backend}/sharded   — resilient exec
      search/resilient/q{Q}/l{l}/s{S}/{backend}/overhead  — best-of ratio
        (plain/sharded; ``speedup=`` so regressions gate bench-diff,
        ``coverage`` pinned at 1.0)
    """
    from repro.search import multi_query_search, resilient_search

    w = max(int(length * window_ratio), 1)
    ref = jnp.asarray(make_dataset(dataset, ref_len, seed=0), jnp.float32)
    queries = jnp.asarray(
        make_queries(dataset, n_queries, length, seed=1), jnp.float32
    )

    def plain():
        res = multi_query_search(ref, queries, length, w, backend=backend)
        jax.block_until_ready(res.best_dist)
        return res

    def sharded():
        return resilient_search(ref, queries, length, w, n_shards=n_shards,
                                backend=backend)

    # warm both traces, then pin healthy-path parity before timing
    p, s = plain(), sharded()
    agree = bool(
        s.coverage == 1.0
        and np.array_equal(s.best_start, np.asarray(p.best_start))
        and np.allclose(s.best_dist, np.asarray(p.best_dist), rtol=2e-5)
    )
    assert agree, "resilient executor diverged from the offline driver"

    t_plain, t_shard, ratios = [], [], []
    for _ in range(pairs):
        t0 = time.time()
        plain()
        tp = time.time() - t0
        t0 = time.time()
        sharded()
        ts = time.time() - t0
        t_plain.append(tp)
        t_shard.append(ts)
        ratios.append(tp / ts if ts > 0 else 0.0)
    median_ratio = statistics.median(ratios)
    ratio = min(t_plain) / min(t_shard) if min(t_shard) > 0 else 0.0
    overhead_pct = (1.0 / ratio - 1.0) * 100.0 if ratio > 0 else float("inf")

    tag = f"search/resilient/q{n_queries}/l{length}/s{n_shards}/{backend}"
    return [
        (f"{tag}/plain", min(t_plain) * 1e6, f"agree={agree}"),
        (f"{tag}/sharded", min(t_shard) * 1e6,
         f"agree={agree};attempts={s.attempts}"),
        (f"{tag}/overhead", ratio,
         f"speedup={ratio:.4f};overhead_pct={overhead_pct:.2f};"
         f"median_pair_ratio={median_ratio:.4f};coverage={s.coverage:.2f};"
         f"pairs={pairs}"),
    ]


def main() -> None:
    rows = run() + run_resilient()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
