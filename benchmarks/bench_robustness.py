"""search/robustness: quarantine-prepass overhead on clean data.

The non-finite quarantine (DESIGN.md §2.6) is on by default, so its cost on
*clean* data is a tax every search pays. The contract is that the tax is one
extra prefix-sum pass over the ingest context — the same O(N) shape as the
window stats themselves — and therefore within timing noise of running with
the prepass compiled out. This bench pins that claim on the streaming
engine, where the prepass runs once per ingest (the worst case: offline
search amortizes one prepass over the whole series).

Both arms feed the identical clean chunk schedule through identical engines
except for ``quarantine=``; parity of the final ``(start, dist)`` answers is
asserted before timing anything. Measurement is the same alternating paired
protocol as ``bench_stream`` (off, on, off, on, ...) so both arms share
background load; ``quarantine`` is a static jit arg, so each arm owns its
trace and both are warmed before the clock starts.

CSV rows (name,us_per_call,derived):
  search/robustness/q{Q}/l{l}/c{chunk}/{backend}/noprepass — best-of us
  search/robustness/q{Q}/l{l}/c{chunk}/{backend}/prepass   — best-of us
  search/robustness/q{Q}/l{l}/c{chunk}/{backend}/overhead  — best-of ratio
    (off/on; 1.0 = free; ``speedup=`` so >20% regressions gate bench-diff,
    ``overhead_pct`` is the headline the acceptance bar reads)
"""
from __future__ import annotations

import statistics
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.data.synthetic import make_dataset, make_queries
from repro.serve import StreamSearchEngine


def run(
    ref_len: int = 16_000,
    length: int = 128,
    window_ratio: float = 0.1,
    n_queries: int = 4,
    batch: int = 64,
    chunk: int = 2_000,
    pairs: int = 5,
    backend: str = "jax",
    dataset: str = "ECG",
):
    w = max(int(length * window_ratio), 1)
    ref = jnp.asarray(make_dataset(dataset, ref_len, seed=0), jnp.float32)
    queries = jnp.asarray(
        make_queries(dataset, n_queries, length, seed=1), jnp.float32
    )
    bounds = list(range(chunk, ref_len + 1, chunk))
    if not bounds or bounds[-1] != ref_len:
        bounds.append(ref_len)

    def feed(quarantine: bool):
        eng = StreamSearchEngine(
            queries, length=length, window=w, batch=batch, backend=backend,
            quarantine=quarantine,
        )
        lo = 0
        for hi in bounds:
            eng.ingest(ref[lo:hi])
            lo = hi
        return eng

    # warmup/compile both traces, then pin clean-data parity: the prepass
    # must change nothing but the (zero) quarantine count
    e_on, e_off = feed(True), feed(False)
    (s_on, d_on), (s_off, d_off) = e_on.best(), e_off.best()
    agree = bool(
        np.array_equal(np.asarray(s_on), np.asarray(s_off))
        and np.array_equal(np.asarray(d_on), np.asarray(d_off))
        and e_on.quarantined_windows == 0
    )

    t_off, t_on, ratios = [], [], []
    for _ in range(pairs):
        t0 = time.time()
        jax.block_until_ready(feed(False).best()[1])
        toff = time.time() - t0
        t0 = time.time()
        jax.block_until_ready(feed(True).best()[1])
        ton = time.time() - t0
        t_off.append(toff)
        t_on.append(ton)
        ratios.append(toff / ton if ton > 0 else 0.0)
    median_ratio = statistics.median(ratios)
    ratio = min(t_off) / min(t_on) if min(t_on) > 0 else 0.0
    overhead_pct = (1.0 / ratio - 1.0) * 100.0 if ratio > 0 else float("inf")

    tag = f"search/robustness/q{n_queries}/l{length}/c{chunk}/{backend}"
    return [
        (f"{tag}/noprepass", min(t_off) * 1e6,
         f"agree={agree};chunks={len(bounds)}"),
        (f"{tag}/prepass", min(t_on) * 1e6,
         f"agree={agree};quarantined={e_on.quarantined_windows}"),
        (f"{tag}/overhead", ratio,
         f"speedup={ratio:.4f};overhead_pct={overhead_pct:.2f};"
         f"median_pair_ratio={median_ratio:.4f};pairs={pairs}"),
    ]


def main() -> None:
    rows = run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
