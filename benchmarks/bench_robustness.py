"""search/robustness: quarantine-prepass overhead on clean data.
Also search/resilient (``run_resilient``): the fault-tolerant sharded
executor's overhead over the plain offline driver on a healthy system.
Also search/hedged (``run_hedged``): hedged dispatch's healthy-path
overhead and its deterministic tail win under one injected straggler
(DESIGN.md §2.9).

The non-finite quarantine (DESIGN.md §2.6) is on by default, so its cost on
*clean* data is a tax every search pays. The contract is that the tax is one
extra prefix-sum pass over the ingest context — the same O(N) shape as the
window stats themselves — and therefore within timing noise of running with
the prepass compiled out. This bench pins that claim on the streaming
engine, where the prepass runs once per ingest (the worst case: offline
search amortizes one prepass over the whole series).

Both arms feed the identical clean chunk schedule through identical engines
except for ``quarantine=``; parity of the final ``(start, dist)`` answers is
asserted before timing anything. Measurement is the same alternating paired
protocol as ``bench_stream`` (off, on, off, on, ...) so both arms share
background load; ``quarantine`` is a static jit arg, so each arm owns its
trace and both are warmed before the clock starts.

CSV rows (name,us_per_call,derived):
  search/robustness/q{Q}/l{l}/c{chunk}/{backend}/noprepass — best-of us
  search/robustness/q{Q}/l{l}/c{chunk}/{backend}/prepass   — best-of us
  search/robustness/q{Q}/l{l}/c{chunk}/{backend}/overhead  — best-of ratio
    (off/on; 1.0 = free; ``speedup=`` so >20% regressions gate bench-diff,
    ``overhead_pct`` is the headline the acceptance bar reads)
"""
from __future__ import annotations

import statistics
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.data.synthetic import make_dataset, make_queries
from repro.serve import StreamSearchEngine


def run(
    ref_len: int = 16_000,
    length: int = 128,
    window_ratio: float = 0.1,
    n_queries: int = 4,
    batch: int = 64,
    chunk: int = 2_000,
    pairs: int = 5,
    backend: str = "jax",
    dataset: str = "ECG",
):
    w = max(int(length * window_ratio), 1)
    ref = jnp.asarray(make_dataset(dataset, ref_len, seed=0), jnp.float32)
    queries = jnp.asarray(
        make_queries(dataset, n_queries, length, seed=1), jnp.float32
    )
    bounds = list(range(chunk, ref_len + 1, chunk))
    if not bounds or bounds[-1] != ref_len:
        bounds.append(ref_len)

    def feed(quarantine: bool):
        eng = StreamSearchEngine(
            queries, length=length, window=w, batch=batch, backend=backend,
            quarantine=quarantine,
        )
        lo = 0
        for hi in bounds:
            eng.ingest(ref[lo:hi])
            lo = hi
        return eng

    # warmup/compile both traces, then pin clean-data parity: the prepass
    # must change nothing but the (zero) quarantine count
    e_on, e_off = feed(True), feed(False)
    (s_on, d_on), (s_off, d_off) = e_on.best(), e_off.best()
    agree = bool(
        np.array_equal(np.asarray(s_on), np.asarray(s_off))
        and np.array_equal(np.asarray(d_on), np.asarray(d_off))
        and e_on.quarantined_windows == 0
    )

    t_off, t_on, ratios = [], [], []
    for _ in range(pairs):
        t0 = time.time()
        jax.block_until_ready(feed(False).best()[1])
        toff = time.time() - t0
        t0 = time.time()
        jax.block_until_ready(feed(True).best()[1])
        ton = time.time() - t0
        t_off.append(toff)
        t_on.append(ton)
        ratios.append(toff / ton if ton > 0 else 0.0)
    median_ratio = statistics.median(ratios)
    ratio = min(t_off) / min(t_on) if min(t_on) > 0 else 0.0
    overhead_pct = (1.0 / ratio - 1.0) * 100.0 if ratio > 0 else float("inf")

    tag = f"search/robustness/q{n_queries}/l{length}/c{chunk}/{backend}"
    return [
        (f"{tag}/noprepass", min(t_off) * 1e6,
         f"agree={agree};chunks={len(bounds)}"),
        (f"{tag}/prepass", min(t_on) * 1e6,
         f"agree={agree};quarantined={e_on.quarantined_windows}"),
        (f"{tag}/overhead", ratio,
         f"speedup={ratio:.4f};overhead_pct={overhead_pct:.2f};"
         f"median_pair_ratio={median_ratio:.4f};pairs={pairs}"),
    ]


def run_resilient(
    ref_len: int = 16_000,
    length: int = 128,
    window_ratio: float = 0.1,
    n_queries: int = 4,
    n_shards: int = 2,
    pairs: int = 5,
    backend: str = "jax",
    dataset: str = "ECG",
):
    """search/resilient: per-shard executor overhead on a healthy system.

    The resilient executor (DESIGN.md §2.7) buys shard-failure recovery by
    running the search as ``n_shards`` sequential range dispatches with a
    host-side incumbent fold between them, instead of one offline driver
    call. On a healthy system the contract is that this costs only the
    extra dispatch boundaries — the carried ``ub_init`` seeding means the
    later ranges do *less* DTW work, not more. Parity of the answers is
    asserted before timing; the same alternating paired protocol as above.

    CSV rows (name,us_per_call,derived):
      search/resilient/q{Q}/l{l}/s{S}/{backend}/plain     — offline driver
      search/resilient/q{Q}/l{l}/s{S}/{backend}/sharded   — resilient exec
      search/resilient/q{Q}/l{l}/s{S}/{backend}/overhead  — best-of ratio
        (plain/sharded; ``speedup=`` so regressions gate bench-diff,
        ``coverage`` pinned at 1.0)
    """
    from repro.search import multi_query_search, resilient_search

    w = max(int(length * window_ratio), 1)
    ref = jnp.asarray(make_dataset(dataset, ref_len, seed=0), jnp.float32)
    queries = jnp.asarray(
        make_queries(dataset, n_queries, length, seed=1), jnp.float32
    )

    def plain():
        res = multi_query_search(ref, queries, length, w, backend=backend)
        jax.block_until_ready(res.best_dist)
        return res

    def sharded():
        return resilient_search(ref, queries, length, w, n_shards=n_shards,
                                backend=backend)

    # warm both traces, then pin healthy-path parity before timing
    p, s = plain(), sharded()
    agree = bool(
        s.coverage == 1.0
        and np.array_equal(s.best_start, np.asarray(p.best_start))
        and np.allclose(s.best_dist, np.asarray(p.best_dist), rtol=2e-5)
    )
    assert agree, "resilient executor diverged from the offline driver"

    t_plain, t_shard, ratios = [], [], []
    for _ in range(pairs):
        t0 = time.time()
        plain()
        tp = time.time() - t0
        t0 = time.time()
        sharded()
        ts = time.time() - t0
        t_plain.append(tp)
        t_shard.append(ts)
        ratios.append(tp / ts if ts > 0 else 0.0)
    median_ratio = statistics.median(ratios)
    ratio = min(t_plain) / min(t_shard) if min(t_shard) > 0 else 0.0
    overhead_pct = (1.0 / ratio - 1.0) * 100.0 if ratio > 0 else float("inf")

    tag = f"search/resilient/q{n_queries}/l{length}/s{n_shards}/{backend}"
    return [
        (f"{tag}/plain", min(t_plain) * 1e6, f"agree={agree}"),
        (f"{tag}/sharded", min(t_shard) * 1e6,
         f"agree={agree};attempts={s.attempts}"),
        (f"{tag}/overhead", ratio,
         f"speedup={ratio:.4f};overhead_pct={overhead_pct:.2f};"
         f"median_pair_ratio={median_ratio:.4f};coverage={s.coverage:.2f};"
         f"pairs={pairs}"),
    ]


class _VirtualClock:
    """Deterministic clock the straggler arm races on (no wall time)."""

    def __init__(self):
        self.now = 0.0

    def __call__(self) -> float:
        return self.now


def run_hedged(
    ref_len: int = 16_000,
    length: int = 128,
    window_ratio: float = 0.1,
    n_queries: int = 4,
    n_shards: int = 4,
    pairs: int = 5,
    backend: str = "jax",
    dataset: str = "ECG",
    slow_dt: float = 50.0,
):
    """search/hedged: hedged dispatch vs plain resilient (DESIGN.md §2.9).

    Two claims, two arms:

      * **Healthy path is (almost) free** — ``hedge=True`` with a hedge
        delay that never fires adds only per-attempt health bookkeeping
        (EWMA + breaker updates) to the resilient executor. Measured
        wall-clock with the alternating paired protocol; the contract is
        overhead ≲5%, and the ``speedup=`` row gates >20% drift in
        bench-diff.
      * **Stragglers stop setting the tail** — one shard completes
        correctly but ``slow_dt``× slower (injected on a *virtual* clock,
        so the row is exact and noise-free). The plain executor's summed
        effective latency waits the straggler out; the hedged executor
        races a healthy backup after the hedge delay and finishes at the
        backup's virtual completion time. Answers are asserted bit-equal
        between the arms before any ratio is reported.

    CSV rows (name,us_per_call,derived):
      search/hedged/q{Q}/l{l}/s{S}/{backend}/healthy-plain    — best-of us
      search/hedged/q{Q}/l{l}/s{S}/{backend}/healthy-hedged   — best-of us
      search/hedged/q{Q}/l{l}/s{S}/{backend}/healthy-overhead — best-of
        ratio (plain/hedged; ``speedup=`` gates bench-diff,
        ``overhead_pct`` is the ≲5% headline)
      search/hedged/q{Q}/l{l}/s{S}/{backend}/straggler-tail   — virtual
        latency ratio (plain/hedged under one straggler; deterministic,
        ``speedup=`` gates bench-diff, ``hedges_won`` recorded)
    """
    from repro.search import multi_query_search, resilient_search

    w = max(int(length * window_ratio), 1)
    ref = jnp.asarray(make_dataset(dataset, ref_len, seed=0), jnp.float32)
    queries = jnp.asarray(
        make_queries(dataset, n_queries, length, seed=1), jnp.float32
    )

    def search(hedge):
        # A delay this large never fires on the healthy path: the arm pays
        # only the health/scheduling bookkeeping, which is the overhead
        # under test.
        return resilient_search(
            ref, queries, length, w, n_shards=n_shards, backend=backend,
            hedge=hedge, hedge_delay=1e9,
        )

    # warm both paths, then pin healthy-path parity before timing
    p, h = search(False), search(True)
    agree = bool(
        h.coverage == 1.0
        and h.hedges_launched == 0
        and np.array_equal(h.best_start, p.best_start)
        and np.array_equal(h.best_dist, p.best_dist)
    )
    assert agree, "healthy-path hedged executor diverged from plain"

    t_plain, t_hedged, ratios = [], [], []
    for _ in range(pairs):
        t0 = time.time()
        search(False)
        tp = time.time() - t0
        t0 = time.time()
        search(True)
        th = time.time() - t0
        t_plain.append(tp)
        t_hedged.append(th)
        ratios.append(tp / th if th > 0 else 0.0)
    median_ratio = statistics.median(ratios)
    ratio = min(t_plain) / min(t_hedged) if min(t_hedged) > 0 else 0.0
    overhead_pct = (1.0 / ratio - 1.0) * 100.0 if ratio > 0 else float("inf")

    # -- straggler arm: exact, on the virtual timeline --------------------
    def straggler_run(hedge):
        clock = _VirtualClock()
        slow_shard = 1 % n_shards

        def runner(shard, lo, hi, ub):
            seg = jnp.asarray(ref[lo : hi + length - 1])
            res = multi_query_search(
                seg, queries, length, w, backend=backend,
                ub_init=jnp.asarray(ub, queries.dtype),
            )
            clock.now += slow_dt if shard == slow_shard else 1.0
            s = np.asarray(res.best_start, np.int64)
            return (
                np.where(s >= 0, s + lo, -1),
                np.asarray(res.best_dist, np.float64),
                int(res.quarantined),
            )

        return resilient_search(
            ref, queries, length, w, n_shards=n_shards, runner=runner,
            hedge=hedge, hedge_delay=3.0, clock=clock,
            sleep=lambda _t: None,
        )

    sp, sh = straggler_run(False), straggler_run(True)
    tail_agree = bool(
        sh.coverage == 1.0
        and np.array_equal(sh.best_start, sp.best_start)
        and np.array_equal(sh.best_dist, sp.best_dist)
    )
    assert tail_agree, "hedged straggler run diverged from plain"
    tail_ratio = sp.latency / sh.latency if sh.latency > 0 else 0.0

    tag = f"search/hedged/q{n_queries}/l{length}/s{n_shards}/{backend}"
    return [
        (f"{tag}/healthy-plain", min(t_plain) * 1e6, f"agree={agree}"),
        (f"{tag}/healthy-hedged", min(t_hedged) * 1e6,
         f"agree={agree};hedges_launched={h.hedges_launched}"),
        (f"{tag}/healthy-overhead", ratio,
         f"speedup={ratio:.4f};overhead_pct={overhead_pct:.2f};"
         f"median_pair_ratio={median_ratio:.4f};pairs={pairs}"),
        (f"{tag}/straggler-tail", tail_ratio,
         f"speedup={tail_ratio:.4f};hedges_won={sh.hedges_won};"
         f"plain_latency={sp.latency:.1f};hedged_latency={sh.latency:.1f};"
         f"virtual=1"),
    ]


def main() -> None:
    rows = run() + run_resilient() + run_hedged()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
