"""search/pipeline: frontend dispatch overhead over the bare staged core.

The §2.8 refactor routed every search frontend through ``search.pipeline``:
an un-jitted wrapper now validates inputs, resolves knobs into a frozen
``SearchPlan``, and dispatches the jitted staged program. That seam must
stay free — the wrapper's per-call cost (guards + plan construction +
jit-cache lookup) is pure overhead the old monolithic drivers didn't pay,
so this bench pins it at ≤ noise.

Two arms over the same workload, alternating:

  * ``core``     — the jitted pipeline program called directly with a
                   prebuilt plan (the refactor-free lower bound).
  * ``frontend`` — the full ``multi_query_search`` wrapper (validation,
                   backend resolution, ``make_plan``, dispatch).

The headline ``overhead`` row reports ``speedup = best(core)/best(frontend)``
— ~1.0 when the wrapper is free, dropping as per-call overhead creeps in —
and rides the bench_diff SPEEDUP gate like every other suite, so a change
that makes plan resolution or validation expensive fails ``scripts/check.sh``
even though every test still passes. Parity is asserted before timing
(identical incumbents from both arms), so the row can never report a wrong
answer fast.

Measurement protocol as in ``bench_multiq``: alternating pairs, best-of vs
best-of with the median per-pair ratio alongside.
"""
from __future__ import annotations

import statistics
import time

import jax
import jax.numpy as jnp

import numpy as np

from repro.data.synthetic import make_dataset, make_queries
from repro.search import multi_query_search
from repro.search.pipeline import _offline_search_impl, make_plan


def run(
    ref_len: int = 20_000,
    length: int = 128,
    window_ratio: float = 0.1,
    n_queries: int = 8,
    batch: int = 64,
    pairs: int = 7,
    backend: str = "jax",
    dataset: str = "ECG",
):
    w = max(int(length * window_ratio), 1)
    ref = jnp.asarray(make_dataset(dataset, ref_len, seed=0), jnp.float32)
    queries = jnp.asarray(
        make_queries(dataset, n_queries, length, seed=1), jnp.float32
    )
    plan = make_plan(
        length=length, window=w, batch=batch, backend=backend
    )

    def core():
        return _offline_search_impl(ref, queries, None, plan, False)

    def frontend():
        return multi_query_search(
            ref, queries, length=length, window=w, batch=batch,
            backend=backend,
        )

    # warmup/compile both arms, then assert parity before timing
    state, _, n_quar = core()
    jax.block_until_ready(state.ub)
    res = frontend()
    jax.block_until_ready(res.best_dist)
    agree = bool(
        np.array_equal(np.asarray(state.best), np.asarray(res.best_start))
        and np.array_equal(np.asarray(state.ub), np.asarray(res.best_dist))
        and int(n_quar) == int(res.quarantined)
    )

    t_core, t_front, ratios = [], [], []
    for _ in range(pairs):
        t0 = time.time()
        jax.block_until_ready(core()[0].ub)
        tc = time.time() - t0
        t0 = time.time()
        jax.block_until_ready(frontend().best_dist)
        tf = time.time() - t0
        t_core.append(tc)
        t_front.append(tf)
        ratios.append(tc / tf if tf > 0 else 0.0)
    median_ratio = statistics.median(ratios)
    ratio = min(t_core) / min(t_front) if min(t_front) > 0 else 0.0

    tag = f"search/pipeline/q{n_queries}/l{length}/r{window_ratio}/{backend}"
    return [
        (f"{tag}/core", min(t_core) * 1e6,
         f"agree={agree};n_queries={n_queries}"),
        (f"{tag}/frontend", min(t_front) * 1e6, f"agree={agree}"),
        (f"{tag}/overhead", ratio,
         f"speedup={ratio:.4f};median_pair_ratio={median_ratio:.4f};"
         f"pairs={pairs}"),
    ]


def main() -> None:
    rows = run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
