.PHONY: check test bench bench-diff

# Tier-1 tests + --quick benchmark smoke (writes BENCH_dtw.json).
check:
	./scripts/check.sh

test:
	PYTHONPATH=src python -m pytest -x -q

bench:
	PYTHONPATH=src python -m benchmarks.run --json

# Rerun the quick bench and diff per-suite ratios against the committed
# BENCH_dtw.json; exits nonzero on >20% regressions in SPEEDUP rows.
bench-diff:
	PYTHONPATH=src python scripts/bench_diff.py
