.PHONY: check test bench

# Tier-1 tests + --quick benchmark smoke (writes BENCH_dtw.json).
check:
	./scripts/check.sh

test:
	PYTHONPATH=src python -m pytest -x -q

bench:
	PYTHONPATH=src python -m benchmarks.run --json
