"""Streaming similarity search: parity, boundaries, ring, incumbents.

The contracts under test:

  * ``StreamSearchEngine`` over *any* chunking of a reference series ends
    with the same per-query ``(best_start, best_dist)`` as offline
    ``multi_query_search`` / ``subsequence_search`` on the concatenated
    stream, on both the ``jax`` and ``pallas_interpret`` backends.
  * windows straddling a chunk boundary (the ``length - 1`` carried-tail
    windows) are scanned in the ingest where their last sample arrives — a
    match planted across a boundary is found.
  * ``append_window_stats`` builds the same stats table as one offline
    ``window_stats`` pass, and stays finite on constant (sigma == 0) chunks.
  * per-query incumbents are monotone non-increasing across ingests.
  * the monitoring ring holds exactly the last W samples, oldest first,
    through partial fill, wrap-around, and bigger-than-capacity chunks.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.search import append_window_stats, multi_query_search, window_stats
from repro.search import gather_norm_windows, subsequence_search
from repro.serve import StreamSearchEngine

BACKENDS = ("jax", "pallas_interpret")


def _mk_stream(seed=3, n_ref=900, nq=4, length=96):
    rng = np.random.default_rng(seed)
    ref = jnp.asarray(np.cumsum(rng.normal(size=n_ref)))
    queries = jnp.asarray(np.cumsum(rng.normal(size=(nq, length)), axis=1))
    return ref, queries


def _feed(eng, ref, sizes):
    i = 0
    for c in sizes:
        eng.ingest(ref[i : i + c])
        i += c
    assert i == ref.shape[0], "chunking must cover the stream exactly"
    return eng


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("sizes", [(300, 300, 300), (96, 1, 500, 303), (900,)])
def test_stream_matches_offline_multi(backend, sizes):
    """Any chunking ends exactly where offline multi-query search ends."""
    ref, queries = _mk_stream()
    length, w = queries.shape[1], 9
    off = multi_query_search(
        ref, queries, length=length, window=w, batch=64, backend=backend
    )
    eng = StreamSearchEngine(
        queries, length=length, window=w, batch=64, backend=backend
    )
    _feed(eng, ref, sizes)
    bs, bd = eng.best()
    assert np.array_equal(np.asarray(bs), np.asarray(off.best_start)), sizes
    np.testing.assert_allclose(
        np.asarray(bd), np.asarray(off.best_dist), rtol=2e-5
    )
    assert eng.n_windows == int(ref.shape[0]) - length + 1


def test_stream_matches_offline_single_query():
    """Q == 1 engine agrees with the scalar offline driver."""
    ref, queries = _mk_stream(seed=11, nq=1)
    length, w = queries.shape[1], 9
    one = subsequence_search(
        ref, queries[0], length=length, window=w, batch=64, backend="jax"
    )
    eng = StreamSearchEngine(
        queries[0], length=length, window=w, batch=64, backend="jax"
    )
    _feed(eng, ref, (450, 450))
    bs, bd = eng.best()
    assert int(bs[0]) == int(one.best_start)
    np.testing.assert_allclose(float(bd[0]), float(one.best_dist), rtol=2e-5)


def test_stream_nolb_variant_parity():
    """The no-cascade variant streams to the same answer too."""
    ref, queries = _mk_stream(seed=19, nq=2)
    length, w = queries.shape[1], 9
    off = multi_query_search(
        ref, queries, length=length, window=w, batch=64, backend="jax",
        variant="eapruned_nolb",
    )
    eng = StreamSearchEngine(
        queries, length=length, window=w, batch=64, backend="jax",
        variant="eapruned_nolb",
    )
    _feed(eng, ref, (128,) * 7 + (4,))
    bs, bd = eng.best()
    assert np.array_equal(np.asarray(bs), np.asarray(off.best_start))
    np.testing.assert_allclose(
        np.asarray(bd), np.asarray(off.best_dist), rtol=2e-5
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_boundary_straddling_match_found(backend):
    """A near-copy of the query planted across a chunk boundary is found in
    the ingest where its last sample arrives — chunks smaller than the
    window length force *every* window to straddle appends."""
    rng = np.random.default_rng(7)
    length, w = 96, 9
    q_raw = np.cumsum(rng.normal(size=length))
    ref_np = np.cumsum(rng.normal(size=700))
    plant = 330  # straddles the 350-boundary of 35-sample chunks
    ref_np[plant : plant + length] = 3.0 * q_raw + 11.0  # z-norm identical
    ref = jnp.asarray(ref_np)
    queries = jnp.asarray(q_raw)[None, :]

    eng = StreamSearchEngine(
        queries, length=length, window=w, batch=32, backend=backend
    )
    found_at = None
    for i in range(0, 700, 35):
        bs, _ = eng.ingest(ref[i : i + 35])
        if found_at is None and int(bs[0]) == plant:
            found_at = i + 35
    assert found_at is not None, "planted straddling match never found"
    # found in the first ingest whose samples complete the planted window
    assert found_at == plant + length + (-(plant + length) % 35)
    off = multi_query_search(
        ref, queries, length=length, window=w, batch=32, backend=backend
    )
    assert int(eng.best()[0][0]) == int(off.best_start[0]) == plant


def test_append_window_stats_matches_offline():
    """The appendable stats form rebuilds the offline table exactly, for a
    chunking that exercises empty-ingest and boundary-straddle cases."""
    rng = np.random.default_rng(23)
    ref = jnp.asarray(rng.normal(size=400))
    length = 64
    mu_off, sigma_off = window_stats(ref, length)
    tail = jnp.zeros((0,), ref.dtype)
    mus, sigmas = [], []
    i = 0
    for c in (20, 30, 64, 1, 200, 85):
        tail, mu, sigma = append_window_stats(tail, ref[i : i + c], length)
        mus.append(np.asarray(mu))
        sigmas.append(np.asarray(sigma))
        i += c
    mu_s = np.concatenate(mus)
    sigma_s = np.concatenate(sigmas)
    assert mu_s.shape == np.asarray(mu_off).shape
    np.testing.assert_allclose(mu_s, np.asarray(mu_off), rtol=1e-6, atol=1e-9)
    np.testing.assert_allclose(
        sigma_s, np.asarray(sigma_off), rtol=1e-6, atol=1e-9
    )
    assert int(tail.shape[0]) == length - 1


@pytest.mark.parametrize("backend", BACKENDS)
def test_constant_chunk_mid_stream(backend):
    """Regression (sigma == 0 audit): a flat chunk mid-stream produces no
    inf/NaN anywhere and parity with offline still holds."""
    rng = np.random.default_rng(5)
    a = np.cumsum(rng.normal(size=300))
    flat = np.full(150, a[-1])  # constant segment: sigma == 0 windows
    c = np.cumsum(rng.normal(size=250)) + a[-1]
    ref = jnp.asarray(np.concatenate([a, flat, c]), jnp.float32)
    queries = jnp.asarray(
        np.cumsum(rng.normal(size=(3, 80)), axis=1), jnp.float32
    )
    off = multi_query_search(
        ref, queries, length=80, window=8, batch=32, backend=backend
    )
    eng = StreamSearchEngine(
        queries, length=80, window=8, batch=32, backend=backend
    )
    ub_prev = None
    for i in range(0, 700, 175):
        _, bd = eng.ingest(ref[i : i + 175])
        assert np.all(np.isfinite(np.asarray(bd)))
        if ub_prev is not None:  # incumbent monotonicity through the flat zone
            assert np.all(np.asarray(bd) <= ub_prev)
        ub_prev = np.asarray(bd)
    bs, bd = eng.best()
    assert np.array_equal(np.asarray(bs), np.asarray(off.best_start))
    np.testing.assert_allclose(
        np.asarray(bd), np.asarray(off.best_dist), rtol=2e-4
    )


def test_constant_window_normalizes_finite():
    """A sigma == 0 window gathers to all-zeros, never inf/NaN — the clamp
    contract between raw ``window_stats`` and every normalization site."""
    ref = jnp.concatenate([jnp.arange(32.0), jnp.full((32,), 7.0)])
    mu, sigma = window_stats(ref, 16)
    assert float(jnp.min(sigma)) == 0.0  # raw, unclamped by contract
    win = gather_norm_windows(
        ref, jnp.arange(ref.shape[0] - 15), 16, mu, sigma
    )
    assert bool(jnp.all(jnp.isfinite(win)))
    np.testing.assert_allclose(np.asarray(win[-1]), np.zeros(16))


@pytest.mark.parametrize("backend", BACKENDS)
def test_incumbent_monotonicity(backend):
    """Carried incumbents never loosen across ingests."""
    ref, queries = _mk_stream(seed=29)
    length, w = queries.shape[1], 9
    eng = StreamSearchEngine(
        queries, length=length, window=w, batch=32, backend=backend
    )
    prev = None
    for i in range(0, 900, 150):
        _, bd = eng.ingest(ref[i : i + 150])
        cur = np.asarray(bd)
        if prev is not None:
            assert np.all(cur <= prev), (i, cur, prev)
        prev = cur


def test_ub_init_seeds_carry_into_stream():
    """A hopeless per-query seed is never beaten (best == -1); a loose seed
    leaves its query's offline answer intact."""
    ref, queries = _mk_stream(seed=31)
    length, w = queries.shape[1], 9
    off = multi_query_search(
        ref, queries, length=length, window=w, batch=64, backend="jax"
    )
    seeds = np.full((queries.shape[0],), 1e30, np.float64)
    seeds[1] = 1e-6
    eng = StreamSearchEngine(
        queries, length=length, window=w, batch=64, backend="jax",
        ub_init=jnp.asarray(seeds),
    )
    _feed(eng, ref, (450, 450))
    bs, bd = eng.best()
    assert int(bs[1]) == -1
    assert float(bd[1]) == pytest.approx(1e-6)
    for q in (0, 2, 3):
        assert int(bs[q]) == int(off.best_start[q])


def test_ring_eviction():
    """The monitoring ring always shows the last W samples, oldest first."""
    ref = jnp.asarray(np.arange(1000, dtype=np.float64))
    eng = StreamSearchEngine(
        jnp.asarray(np.random.default_rng(0).normal(size=64)),
        length=64, window=6, batch=32, backend="jax", ring_capacity=100,
    )
    # partial fill
    eng.ingest(ref[:40])
    np.testing.assert_array_equal(eng.recent(), np.arange(40.0))
    # wrap-around across several small chunks
    for i in range(40, 520, 60):
        eng.ingest(ref[i : i + 60])
    np.testing.assert_array_equal(eng.recent(), np.arange(420.0, 520.0))
    # a chunk bigger than capacity overwrites the whole ring
    eng.ingest(ref[520:820])
    np.testing.assert_array_equal(eng.recent(), np.arange(720.0, 820.0))
    assert eng.recent().shape == (100,)
    assert eng.n_seen == 820


def test_no_ring_raises():
    eng = StreamSearchEngine(
        jnp.asarray(np.random.default_rng(0).normal(size=32)),
        length=32, window=3, batch=16, backend="jax",
    )
    with pytest.raises(ValueError):
        eng.recent()


@pytest.mark.parametrize("backend", BACKENDS)
def test_fixed_shape_ingest_parity(backend):
    """stream_chunk mode (padded fixed-shape ingest, split bigger arrivals)
    ends exactly where offline search and the legacy engine end, for
    chunkings that exercise start-up, ragged, and bigger-than-W arrivals."""
    ref, queries = _mk_stream()
    length, w = queries.shape[1], 9
    off = multi_query_search(
        ref, queries, length=length, window=w, batch=64, backend=backend
    )
    for sizes in [(300, 300, 300), (96, 1, 500, 303), (900,), (512, 388)]:
        eng = StreamSearchEngine(
            queries, length=length, window=w, batch=64, backend=backend,
            stream_chunk=256,
        )
        _feed(eng, ref, sizes)
        bs, bd = eng.best()
        assert np.array_equal(np.asarray(bs), np.asarray(off.best_start)), sizes
        np.testing.assert_allclose(
            np.asarray(bd), np.asarray(off.best_dist), rtol=2e-5
        )


def test_fixed_shape_ingest_single_trace():
    """Regression (ROADMAP PR-3 follow-up): with stream_chunk set, mixed
    chunk sizes — start-up, steady state, ragged final chunk — all reuse ONE
    compiled trace of the padded ingest (jax.jit cache inspection)."""
    from repro.search.streaming import _ingest_impl_padded

    ref, queries = _mk_stream(seed=41)
    length, w = queries.shape[1], 9
    eng = StreamSearchEngine(
        queries, length=length, window=w, batch=32, backend="jax",
        stream_chunk=200,
    )
    before = _ingest_impl_padded._cache_size()
    _feed(eng, ref, (30, 170, 200, 77, 123, 200, 100))  # mixed, ragged end
    after = _ingest_impl_padded._cache_size()
    assert after - before <= 1, (before, after)
    # and at least one padded dispatch actually ran through the jit
    assert after >= 1
    off = multi_query_search(
        ref, queries, length=length, window=w, batch=32, backend="jax"
    )
    assert np.array_equal(np.asarray(eng.best()[0]), np.asarray(off.best_start))


def test_small_chunks_before_first_window():
    """Chunks shorter than the query length only extend the tail until a
    window completes; best stays empty meanwhile."""
    ref, queries = _mk_stream(seed=37, n_ref=300, nq=2)
    length, w = queries.shape[1], 9
    eng = StreamSearchEngine(
        queries, length=length, window=w, batch=32, backend="jax"
    )
    for i in range(0, 90, 30):
        bs, _ = eng.ingest(ref[i : i + 30])
        assert np.all(np.asarray(bs) == -1)
        assert eng.n_windows == 0
    _feed(eng, ref[90:], (110, 100))
    off = multi_query_search(
        ref, queries, length=length, window=w, batch=32, backend="jax"
    )
    assert np.array_equal(np.asarray(eng.best()[0]), np.asarray(off.best_start))
