"""Elasticity + multi-pod semantics (subprocess, 8 fake devices)."""
import os
import subprocess
import sys

REPO = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str):
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=540, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_elastic_reshard_preserves_values():
    """Shrink the data axis 4 -> 2: state values bit-identical after move."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, numpy as np
from repro.configs import ARCHS
from repro.models.registry import build
from repro.distributed.fault_tolerance import elastic_reshard
from repro.distributed.sharding import make_state_specs, named
from repro.train.train_step import init_state

cfg = ARCHS["mistral-nemo-12b"].reduced()
model = build(cfg)
old_mesh = jax.make_mesh((4, 2), ("data", "model"))
# node failure: rebuild over the surviving half of the data axis
new_mesh = jax.sharding.Mesh(old_mesh.devices[:2], ("data", "model"))
state = jax.device_put(init_state(model, jax.random.PRNGKey(0)),
                       named(old_mesh, make_state_specs(model, old_mesh)))
before = np.asarray(jax.device_get(state.params["final_norm"]))
wq_before = np.asarray(jax.device_get(state.params["layers"]["attn"]["wq"]))
state2 = elastic_reshard(state, old_mesh, new_mesh,
                         lambda m: make_state_specs(model, m))
after = np.asarray(jax.device_get(state2.params["final_norm"]))
wq_after = np.asarray(jax.device_get(state2.params["layers"]["attn"]["wq"]))
assert np.array_equal(before, after)
assert np.array_equal(wq_before, wq_after)
assert len(state2.params["layers"]["attn"]["wq"].sharding.device_set) == 4
print("ELASTIC OK")
"""
    assert "ELASTIC OK" in _run(code)


def test_multipod_training_semantics():
    """(pod, data, model) mesh: train steps run; loss matches single mesh."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, numpy as np
from repro.configs import ARCHS
from repro.models.registry import build
from repro.data.lm import TokenStream
from repro.distributed.sharding import make_state_specs, make_batch_specs, named
from repro.train.train_step import init_state, make_train_step

cfg = ARCHS["mamba2-130m"].reduced()
model = build(cfg)
stream = TokenStream(cfg.vocab, 8, 32, seed=0)

def run(mesh):
    sspecs = make_state_specs(model, mesh)
    state = jax.device_put(init_state(model, jax.random.PRNGKey(0)), named(mesh, sspecs))
    step = jax.jit(make_train_step(model), in_shardings=(named(mesh, sspecs), None),
                   out_shardings=(named(mesh, sspecs), None))
    for i in range(2):
        batch = stream.batch_at(i)
        bspecs = make_batch_specs({k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}, mesh)
        batch = {k: jax.device_put(v, named(mesh, bspecs[k])) for k, v in batch.items()}
        state, m = step(state, batch)
    return float(m["loss"])

multi = run(jax.make_mesh((2, 2, 2), ("pod", "data", "model")))
single = run(jax.make_mesh((4, 2), ("data", "model")))
print("LOSSES", multi, single)
assert abs(multi - single) < 1e-4, (multi, single)
print("MULTIPOD OK")
"""
    assert "MULTIPOD OK" in _run(code)


def test_ep_moe_matches_dense():
    """shard_map expert-parallel MoE == dense dispatch, bit-close (8 dev)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import dataclasses
import jax, numpy as np, jax.numpy as jnp
from repro.configs import ARCHS
from repro.models.registry import build
from repro.distributed import hints
from repro.distributed.sharding import batch_axes, make_param_specs, named

mesh = jax.make_mesh((4, 2), ("data", "model"))
base = dataclasses.replace(ARCHS["kimi-k2-1t-a32b"].reduced(), capacity_factor=100.0)
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, base.vocab, (8, 16)))
outs = {}
for impl in ("dense", "ep"):
    cfg = dataclasses.replace(base, moe_impl=impl)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params_s = jax.device_put(params, named(mesh, make_param_specs(model, mesh)))
    hints.set_axes(batch_axes(mesh), mesh=mesh)
    fwd = jax.jit(lambda p, t: model.forward(p, tokens=t)[0])
    logits = fwd(params_s, jax.device_put(
        toks, named(mesh, jax.sharding.PartitionSpec(("data",), None))))
    outs[impl] = np.asarray(logits, dtype=np.float32)
    hints.clear()
err = np.max(np.abs(outs["dense"] - outs["ep"]))
assert err < 2e-2, err
print("EP MOE OK", err)
"""
    assert "EP MOE OK" in _run(code)
