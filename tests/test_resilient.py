"""Fault-tolerant sharded search (DESIGN.md §2.7).

The contracts under test:

  * **Shard recovery** — ``resilient_search`` retries transient range
    failures with backoff, reassigns ranges off persistently-failing shards,
    and stays *exact* whenever coverage ends up full (pinned against
    ``multi_query_search`` and the brute-force oracle).
  * **Coverage accounting** — when no healthy shard can complete a range,
    the result reports the exact uncovered window ranges (NumPy oracle) and
    is still exact over the covered set; ``require_full_coverage`` raises.
  * **Quarantine psum parity** — the distributed builders' psum-reduced
    ``quarantined`` counts equal the single-device counts, on a 1-device
    mesh in-process and an 8-device mesh in a subprocess.
  * **Async checkpoints** — the supervisor's async writer commits through a
    barrier that rollback/resume take first; kill-resume is bit-exact, and
    a checkpoint damaged on disk falls back to the next older one.
  * **Quarantine re-admission** — ``StreamSearchEngine.correct`` patches
    backfilled samples and re-scores the revived windows, converging to the
    clean-run answer.

``$REPRO_FAULT_SEED`` (via ``faults.fault_seed``) varies the data draw for
the seeded check.sh pass.
"""
import os
import subprocess
import sys
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import NonFiniteInputError, SearchInputError, StreamStateError
from repro.search import (
    CoverageError,
    make_distributed_multi_search,
    make_distributed_search,
    multi_query_search,
    resilient_search,
    subsequence_search,
)
from repro.search.resilient import partition_ranges
from repro.serve import SearchSupervisor, StreamSearchEngine
from repro.train import checkpoint as ckpt_lib

from faults import (
    ShardFaultInjector,
    best_covered_np,
    coverage_oracle_np,
    fault_seed,
    plant_nonfinite,
)

REPO = os.path.join(os.path.dirname(__file__), "..")


def _mk(seed=None, n_ref=420, nq=3, length=48):
    rng = np.random.default_rng(fault_seed() if seed is None else seed)
    ref = np.cumsum(rng.normal(size=n_ref))
    queries = np.cumsum(rng.normal(size=(nq, length)), axis=1)
    return ref, queries


def _real_runner(ref, queries, length, w):
    """The default per-range dispatch, exposed so recipes can wrap it."""

    def runner(shard, lo, hi, ub):
        seg = jnp.asarray(ref[lo : hi + length - 1])
        res = multi_query_search(
            seg, jnp.asarray(queries), length, w, backend="jax",
            ub_init=jnp.asarray(ub, jnp.float64),
        )
        s = np.asarray(res.best_start, np.int64)
        return (
            np.where(s >= 0, s + lo, -1),
            np.asarray(res.best_dist, np.float64),
            int(res.quarantined),
        )

    return runner


# -- executor: clean path -------------------------------------------------

def test_partition_ranges_cover_exactly():
    for n_win, n_shards in [(100, 4), (7, 3), (3, 8), (0, 4), (1, 1)]:
        ranges = partition_ranges(n_win, n_shards)
        covered = sorted((lo, hi) for lo, hi in ranges)
        # contiguous, disjoint, exactly [0, n_win)
        pos = 0
        for lo, hi in covered:
            assert lo == pos and hi > lo
            pos = hi
        assert pos == n_win
        assert len(ranges) <= n_shards


def test_clean_full_coverage_matches_offline():
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    res = resilient_search(ref, queries, length, w, n_shards=4, backend="jax")
    base = multi_query_search(jnp.asarray(ref), jnp.asarray(queries),
                              length, w, backend="jax")
    assert res.coverage == 1.0 and res.uncovered == ()
    assert res.reassignments == 0 and res.failed_shards == ()
    assert np.array_equal(res.best_start, np.asarray(base.best_start))
    np.testing.assert_allclose(res.best_dist, np.asarray(base.best_dist),
                               rtol=2e-5)


def test_dirty_ref_quarantine_count_matches_single_device():
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    dirty = plant_nonfinite(ref, [(100, 4, np.nan), (250, 2, np.inf)])
    res = resilient_search(dirty, queries, length, w, n_shards=3,
                           backend="jax")
    base = multi_query_search(jnp.asarray(dirty), jnp.asarray(queries),
                              length, w, backend="jax")
    assert res.quarantined == int(base.quarantined)
    assert np.array_equal(res.best_start, np.asarray(base.best_start))
    np.testing.assert_allclose(res.best_dist, np.asarray(base.best_dist),
                               rtol=2e-5)


# -- executor: faults -----------------------------------------------------

def test_flaky_range_retried_with_backoff():
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    n_win = len(ref) - length + 1
    flaky_lo = partition_ranges(n_win, 4)[1][0]
    inj = ShardFaultInjector(_real_runner(ref, queries, length, w),
                             flaky_ranges={flaky_lo})
    sleeps = []
    res = resilient_search(ref, queries, length, w, n_shards=4,
                           runner=inj, backoff=0.01, sleep=sleeps.append)
    base = multi_query_search(jnp.asarray(ref), jnp.asarray(queries),
                              length, w, backend="jax")
    # one first-attempt backoff, then healed; decorrelated jitter draws the
    # sleep from [base, 3*base) (seeded via $REPRO_FAULT_SEED)
    assert len(sleeps) == 1 and 0.01 <= sleeps[0] < 0.03
    assert res.coverage == 1.0 and res.failed_shards == ()
    assert res.attempts == 5  # 4 ranges + 1 retry
    assert np.array_equal(res.best_start, np.asarray(base.best_start))


def test_dead_shard_range_reassigned_to_healthy():
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    inj = ShardFaultInjector(_real_runner(ref, queries, length, w),
                             dead_shards={1})
    res = resilient_search(ref, queries, length, w, n_shards=4, runner=inj,
                           max_retries=1, backoff=0.0, sleep=lambda _t: None)
    base = multi_query_search(jnp.asarray(ref), jnp.asarray(queries),
                              length, w, backend="jax")
    assert res.coverage == 1.0  # the dead shard's range completed elsewhere
    assert res.failed_shards == (1,)
    assert res.reassignments == 1
    # the reassigned attempt ran on a different, healthy shard
    reassigned = [c for c in inj.calls if c[3] and c[0] != 1]
    assert len(reassigned) == 4
    assert np.array_equal(res.best_start, np.asarray(base.best_start))
    np.testing.assert_allclose(res.best_dist, np.asarray(base.best_dist),
                               rtol=2e-5)


def test_fail_after_n_calls_cascades_reassignment():
    """Shard 0 completes one range then dies; its queue drains elsewhere."""
    ref, queries = _mk(n_ref=700)
    length, w = queries.shape[1], 5
    inj = ShardFaultInjector(_real_runner(ref, queries, length, w),
                             dead_shards={1, 2}, fail_after={0: 1})
    # 4 ranges on 4 shards: shard 1 and 2 dead, shard 0 dies after 1 call ->
    # everything funnels onto shard 3.
    res = resilient_search(ref, queries, length, w, n_shards=4, runner=inj,
                           max_retries=0, backoff=0.0, sleep=lambda _t: None)
    base = multi_query_search(jnp.asarray(ref), jnp.asarray(queries),
                              length, w, backend="jax")
    assert res.coverage == 1.0
    assert set(res.failed_shards) == {0, 1, 2}
    assert res.reassignments >= 3
    assert np.array_equal(res.best_start, np.asarray(base.best_start))
    np.testing.assert_allclose(res.best_dist, np.asarray(base.best_dist),
                               rtol=2e-5)


def test_timeout_shard_completes_but_is_struck():
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    real = _real_runner(ref, queries, length, w)

    # deterministic clock: shard 0 "takes" 50ms per attempt, everyone else
    # 1ms — no real sleeping, so the test is immune to box load and the
    # interpret backend's slowness
    fake_now = [0.0]

    def slow0(shard, lo, hi, ub):
        fake_now[0] += 0.05 if shard == 0 else 0.001
        return real(shard, lo, hi, ub)

    res = resilient_search(ref, queries, length, w, n_shards=4, runner=slow0,
                           timeout=0.01, max_retries=0,
                           clock=lambda: fake_now[0])
    base = multi_query_search(jnp.asarray(ref), jnp.asarray(queries),
                              length, w, backend="jax")
    # the slow attempt's (correct) result was kept, the shard marked failed
    assert res.coverage == 1.0 and res.failed_shards == (0,)
    assert np.array_equal(res.best_start, np.asarray(base.best_start))


def test_dead_range_reports_exact_degraded_coverage():
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    n_win = len(ref) - length + 1
    ranges = partition_ranges(n_win, 4)
    dead = ranges[2]
    inj = ShardFaultInjector(_real_runner(ref, queries, length, w),
                             dead_ranges={dead[0]})
    res = resilient_search(ref, queries, length, w, n_shards=4, runner=inj,
                           max_retries=0, backoff=0.0, sleep=lambda _t: None)
    covered = [r for r in ranges if r != dead]
    frac, uncovered = coverage_oracle_np(n_win, covered)
    assert res.coverage == pytest.approx(frac)
    assert res.uncovered == uncovered
    assert set(res.failed_shards) == set(range(4))  # every shard tried it
    # exact over the covered set (brute-force oracle)
    mask = np.zeros(n_win, bool)
    for lo, hi in covered:
        mask[lo:hi] = True
    bs, bd = best_covered_np(ref, queries, length, w, mask)
    assert np.array_equal(res.best_start, bs)
    np.testing.assert_allclose(res.best_dist, bd, rtol=2e-5)


def test_require_full_coverage_raises():
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    inj = ShardFaultInjector(_real_runner(ref, queries, length, w),
                             dead_ranges={0})
    with pytest.raises(CoverageError) as ei:
        resilient_search(ref, queries, length, w, n_shards=4, runner=inj,
                         max_retries=0, backoff=0.0, sleep=lambda _t: None,
                         require_full_coverage=True)
    assert ei.value.uncovered  # the degraded ranges ride on the error
    assert "uncovered" in str(ei.value)


def test_partial_progress_from_failed_attempt_is_folded():
    """A crashed range that reports an achieved (start, dist) pair keeps
    that incumbent even though the range itself stays uncovered."""
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    n_win = len(ref) - length + 1
    ranges = partition_ranges(n_win, 4)
    dead = ranges[1]
    # the achieved pair: the true best window inside the dead range
    mask = np.zeros(n_win, bool)
    mask[dead[0] : dead[1]] = True
    p_best, p_ub = best_covered_np(ref, queries, length, w, mask)
    inj = ShardFaultInjector(
        _real_runner(ref, queries, length, w),
        dead_ranges={dead[0]},
        partial={dead[0]: (p_best, p_ub)},
    )
    res = resilient_search(ref, queries, length, w, n_shards=4, runner=inj,
                           max_retries=0, backoff=0.0, sleep=lambda _t: None)
    assert res.coverage < 1.0
    # final answer now equals the FULL search despite the lost range
    base = multi_query_search(jnp.asarray(ref), jnp.asarray(queries),
                              length, w, backend="jax")
    assert np.array_equal(res.best_start, np.asarray(base.best_start))
    np.testing.assert_allclose(res.best_dist, np.asarray(base.best_dist),
                               rtol=2e-5)


def test_guard_errors_are_not_retried():
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    calls = []

    def bad_runner(shard, lo, hi, ub):
        calls.append(shard)
        raise SearchInputError("malformed")

    with pytest.raises(SearchInputError):
        resilient_search(ref, queries, length, w, n_shards=4,
                         runner=bad_runner, max_retries=5,
                         sleep=lambda _t: None)
    assert len(calls) == 1  # no retry on caller bugs
    with pytest.raises(SearchInputError):
        resilient_search(ref, queries, length, w, n_shards=0)


# -- distributed quarantine psum parity -----------------------------------

def test_distributed_quarantine_parity_one_device():
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    dirty = jnp.asarray(plant_nonfinite(ref, [(90, 3, np.nan),
                                              (260, 2, -np.inf)]))
    mesh = jax.make_mesh((1,), ("d",))
    single = subsequence_search(dirty, jnp.asarray(queries[0]), length, w,
                                backend="jax")
    dist = make_distributed_search(mesh, ("d",), length, w, batch=32)(
        dirty, jnp.asarray(queries[0])
    )
    assert int(dist.quarantined) == int(single.quarantined) > 0
    assert int(dist.best_start) == int(single.best_start)
    np.testing.assert_allclose(float(dist.best_dist),
                               float(single.best_dist), rtol=2e-5)

    multi = multi_query_search(dirty, jnp.asarray(queries), length, w,
                               backend="jax")
    dmulti = make_distributed_multi_search(mesh, ("d",), length, w, batch=32)(
        dirty, jnp.asarray(queries)
    )
    assert int(dmulti.quarantined) == int(multi.quarantined)
    assert np.array_equal(np.asarray(dmulti.best_start),
                          np.asarray(multi.best_start))


def test_distributed_quarantine_parity_multi_shard_subprocess():
    """psum-reduced quarantine counts on 8 fake devices equal the 1-device
    counts, and the best stays the best."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src"); sys.path.insert(0, "tests")
import jax, numpy as np, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.search import (make_distributed_search,
                          make_distributed_multi_search,
                          multi_query_search, subsequence_search)
from faults import plant_nonfinite, fault_seed
rng = np.random.default_rng(fault_seed())
ref = np.cumsum(rng.normal(size=900))
qs = np.cumsum(rng.normal(size=(3, 96)), axis=1)
dirty = jnp.asarray(plant_nonfinite(ref, [(200, 5, np.nan), (700, 2, np.inf)]))
mesh = jax.make_mesh((4, 2), ("data", "model"))
single = subsequence_search(dirty, jnp.asarray(qs[0]), 96, 9)
dist = make_distributed_search(mesh, ("data", "model"), 96, 9, batch=32)(
    dirty, jnp.asarray(qs[0]))
assert int(dist.quarantined) == int(single.quarantined) > 0, (
    int(dist.quarantined), int(single.quarantined))
assert int(dist.best_start) == int(single.best_start)
multi = multi_query_search(dirty, jnp.asarray(qs), 96, 9)
dmulti = make_distributed_multi_search(mesh, ("data", "model"), 96, 9,
                                       batch=32)(dirty, jnp.asarray(qs))
assert int(dmulti.quarantined) == int(multi.quarantined)
assert np.array_equal(np.asarray(dmulti.best_start),
                      np.asarray(multi.best_start))
np.testing.assert_allclose(np.asarray(dmulti.best_dist),
                           np.asarray(multi.best_dist), rtol=1e-6)
print("PARITY OK", int(dist.quarantined))
"""
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=420,
        cwd=REPO, env={**os.environ},
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PARITY OK" in out.stdout


# -- supervisor: corrupt-checkpoint fallback + async ----------------------

def _chunks(series, size):
    return [series[p : p + size] for p in range(0, len(series), size)]


def _fresh(queries, length, w):
    return StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                              stream_chunk=64)


def test_resume_falls_back_past_damaged_checkpoint(tmp_path):
    ref, queries = _mk(n_ref=480)
    length, w = queries.shape[1], 5
    chunks = _chunks(ref, 48)
    baseline = _fresh(queries, length, w)
    for c in chunks:
        baseline.ingest(c)

    sup1 = SearchSupervisor(_fresh(queries, length, w), str(tmp_path),
                            ckpt_every=2, keep=5)
    for c in chunks[:7]:
        sup1.ingest(c)
    steps = ckpt_lib.steps(str(tmp_path))
    assert steps[-1] == 6
    # damage the newest checkpoint AFTER commit (disk fault): truncate a leaf
    latest_dir = os.path.join(str(tmp_path), f"step_{steps[-1]:08d}")
    victim = next(f for f in sorted(os.listdir(latest_dir))
                  if f.endswith(".npy"))
    with open(os.path.join(latest_dir, victim), "wb") as f:
        f.write(b"\x93corrupt")

    sup2 = SearchSupervisor(_fresh(queries, length, w), str(tmp_path),
                            ckpt_every=2, keep=5)
    k = sup2.resume()
    assert k == 4  # fell back past the damaged step 6
    for c in chunks[k:]:
        sup2.ingest(c)
    np.testing.assert_allclose(np.asarray(sup2.engine.best()[1]),
                               np.asarray(baseline.best()[1]), rtol=0)
    assert np.array_equal(np.asarray(sup2.engine.best()[0]),
                          np.asarray(baseline.best()[0]))


def test_resume_from_scratch_when_all_checkpoints_damaged(tmp_path):
    _, queries = _mk()
    length, w = queries.shape[1], 5
    sup1 = SearchSupervisor(_fresh(queries, length, w), str(tmp_path),
                            ckpt_every=1, keep=2)
    sup1.ingest(np.ones(80))
    sup1.ingest(np.ones(80))
    for step in ckpt_lib.steps(str(tmp_path)):
        os.remove(os.path.join(str(tmp_path), f"step_{step:08d}",
                               "manifest.json"))
    sup2 = SearchSupervisor(_fresh(queries, length, w), str(tmp_path))
    assert sup2.resume() == 0  # nothing readable: start the stream over


def test_async_checkpoint_wait_is_a_write_barrier(tmp_path):
    state = {"x": np.arange(8.0)}
    events = []

    def slow_write(tree, step):
        time.sleep(0.1)
        events.append(("written", step))

    ck = ckpt_lib.AsyncCheckpointer(str(tmp_path), write_hook=slow_write)
    t0 = time.time()
    ck.submit(state, 1)
    ck.wait()
    assert time.time() - t0 >= 0.1  # wait really blocked on the write
    assert events == [("written", 1)]
    assert ckpt_lib.latest_step(str(tmp_path)) == 1
    restored, step = ckpt_lib.restore(str(tmp_path), {"x": np.zeros(8)})
    assert step == 1 and np.array_equal(restored["x"], state["x"])
    ck.close()

    def bad_write(tree, step):
        raise OSError("disk full")

    ck2 = ckpt_lib.AsyncCheckpointer(str(tmp_path), write_hook=bad_write)
    ck2.submit(state, 2)
    with pytest.raises(OSError, match="disk full"):
        ck2.wait()


def test_async_supervisor_rollback_waits_for_inflight_write(tmp_path):
    """A transient failure right after an async checkpoint submit: rollback
    barriers on the slow writer, replay stays exact, the checkpoint is
    committed and restorable."""
    ref, queries = _mk(n_ref=480)
    length, w = queries.shape[1], 5
    chunks = _chunks(ref, 48)
    baseline = _fresh(queries, length, w)
    for c in chunks:
        baseline.ingest(c)

    from faults import FaultyEngine

    eng = _fresh(queries, length, w)
    faulty = FaultyEngine(eng, fail_at={2})  # arrival right after ckpt at 2
    sup = SearchSupervisor(faulty, str(tmp_path), ckpt_every=2, backoff=0.0,
                           sleep=lambda _t: None, async_ckpt=True)
    # widen the in-flight window so the rollback provably overlaps a write
    sup._async.close()
    sup._async = ckpt_lib.AsyncCheckpointer(
        str(tmp_path), keep=3,
        write_hook=lambda _tree, _step: time.sleep(0.05),
    )
    for c in chunks:
        sup.ingest(c)
    sup.close()
    assert sup.restarts == 1
    np.testing.assert_allclose(np.asarray(eng.best()[1]),
                               np.asarray(baseline.best()[1]), rtol=0)
    assert ckpt_lib.latest_step(str(tmp_path)) is not None
    state, _ = ckpt_lib.restore(str(tmp_path), eng.save_state())
    fresh = _fresh(queries, length, w)
    fresh.restore_state(state)  # committed checkpoint is well-formed


def test_async_kill_resume_bit_exact(tmp_path):
    ref, queries = _mk(n_ref=480)
    length, w = queries.shape[1], 5
    chunks = _chunks(ref, 48)
    baseline = _fresh(queries, length, w)
    for c in chunks:
        baseline.ingest(c)

    sup1 = SearchSupervisor(_fresh(queries, length, w), str(tmp_path),
                            ckpt_every=2, async_ckpt=True)
    for c in chunks[:5]:
        sup1.ingest(c)
    sup1._barrier()  # in-flight writes land; then the process "dies"
    del sup1

    sup2 = SearchSupervisor(_fresh(queries, length, w), str(tmp_path),
                            ckpt_every=2, async_ckpt=True)
    k = sup2.resume()
    assert k == 4
    for c in chunks[k:]:
        sup2.ingest(c)
    sup2.close()
    np.testing.assert_allclose(np.asarray(sup2.engine.best()[1]),
                               np.asarray(baseline.best()[1]), rtol=0)
    assert np.array_equal(np.asarray(sup2.engine.best()[0]),
                          np.asarray(baseline.best()[0]))


# -- re-admission ----------------------------------------------------------

def test_correct_revives_quarantined_windows():
    """Backfilled samples + rescore converge to the clean-run answer."""
    ref, queries = _mk(n_ref=600, length=64)
    length, w = queries.shape[1], 6
    dirty = plant_nonfinite(ref, [(300, 5, np.nan)])
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                             backend="jax", ring_capacity=400)
    for c in _chunks(dirty, 100):
        eng.ingest(c)
    assert eng.quarantined_windows > 0
    queued = eng.correct(300, ref[300:305])
    assert queued == eng.quarantined_windows  # whole burst retained in ring
    assert eng.pending_rescore == queued
    assert eng.quarantined_samples == 0
    eng.ingest(np.zeros(0))  # the next ingest flushes the rescore
    assert eng.pending_rescore == 0
    assert eng.quarantined_windows == 0
    assert eng.readmitted_windows == queued

    clean = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                               backend="jax")
    for c in _chunks(ref, 100):
        clean.ingest(c)
    assert np.array_equal(np.asarray(eng.best()[0]),
                          np.asarray(clean.best()[0]))
    np.testing.assert_allclose(np.asarray(eng.best()[1]),
                               np.asarray(clean.best()[1]), rtol=2e-5)


def test_correct_validation_guards():
    ref, queries = _mk(n_ref=300)
    length, w = queries.shape[1], 5
    dirty = plant_nonfinite(ref, [(200, 3, np.nan)])
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                             backend="jax", ring_capacity=128)
    eng.ingest(dirty)
    with pytest.raises(StreamStateError):   # the future
        eng.correct(299, np.zeros(5))
    with pytest.raises(StreamStateError):   # already-finite history
        eng.correct(210, np.zeros(2))
    with pytest.raises(NonFiniteInputError):  # re-poisoning
        eng.correct(200, [np.nan, 1.0, 2.0])
    with pytest.raises(StreamStateError):   # outside retained history
        eng.correct(10, np.zeros(1))
    with pytest.raises(SearchInputError):   # empty patch
        eng.correct(200, np.zeros(0))
    # double-correct: after the patch the targets are finite
    assert eng.correct(200, ref[200:203]) > 0
    with pytest.raises(StreamStateError):
        eng.correct(200, ref[200:203])
    no_q = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                              quarantine=False)
    no_q.ingest(ref)
    with pytest.raises(StreamStateError):   # quarantine disabled
        no_q.correct(100, np.zeros(1))


def test_correct_without_ring_heals_straddling_windows_only():
    """No ring: fully-past windows are gone, but a patched tail still
    cleans every window straddling the stream frontier."""
    ref, queries = _mk(n_ref=400)
    length, w = queries.shape[1], 5
    split = 300
    bad_at = split - 3  # inside the carried tail after ingesting [:split]
    dirty = plant_nonfinite(ref, [(bad_at, 2, np.inf)])
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                             backend="jax")
    eng.ingest(dirty[:split])
    quarantined_before = eng.quarantined_windows
    queued = eng.correct(bad_at, ref[bad_at : bad_at + 2])
    assert queued == 0  # no ring: nothing fully-past is recoverable
    eng.ingest(dirty[split:])
    # the straddling windows were searched clean via the patched tail:
    # same incumbents as a stream that was only ever dirty BEFORE the patch
    # position's straddle region... pin directly against per-window oracle
    # by comparing to an offline search over the equivalent series.
    fixed = dirty.copy()
    fixed[bad_at : bad_at + 2] = ref[bad_at : bad_at + 2]
    off = multi_query_search(jnp.asarray(fixed), jnp.asarray(queries),
                             length, w, backend="jax")
    # windows fully scanned before the patch that overlapped the burst stay
    # quarantined (they were scanned dirty and are not recoverable):
    assert eng.quarantined_windows == quarantined_before
    assert eng.readmitted_windows == 0
    # every query whose best lives outside those lost windows agrees
    lost = set(range(bad_at - length + 1, split - length + 1))
    for qi in range(queries.shape[0]):
        if int(off.best_start[qi]) not in lost:
            assert int(eng.best()[0][qi]) == int(off.best_start[qi])


def test_correct_flushes_into_save_state(tmp_path):
    ref, queries = _mk(n_ref=600, length=64)
    length, w = queries.shape[1], 6
    dirty = plant_nonfinite(ref, [(300, 4, np.nan)])
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                             backend="jax", ring_capacity=400)
    for c in _chunks(dirty, 100):
        eng.ingest(c)
    queued = eng.correct(300, ref[300:304])
    assert queued > 0 and eng.pending_rescore == queued
    state = eng.save_state()  # must flush: snapshots never carry a queue
    assert eng.pending_rescore == 0
    assert int(state["readmitted"]) == queued
    fresh = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                               backend="jax", ring_capacity=400)
    fresh.restore_state(state)
    assert fresh.readmitted_windows == queued
    assert np.array_equal(np.asarray(fresh.best()[0]),
                          np.asarray(eng.best()[0]))
    # legacy snapshot without the readmitted key still restores
    legacy = {k: v for k, v in state.items() if k != "readmitted"}
    fresh.restore_state(legacy)
    assert fresh.readmitted_windows == 0


def test_partial_correct_revives_only_all_finite_windows():
    """Patching half a burst revives only the windows that touch no other
    bad sample; the second half revives the rest."""
    ref, queries = _mk(n_ref=600, length=64)
    length, w = queries.shape[1], 6
    dirty = plant_nonfinite(ref, [(300, 4, np.nan)])
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                             backend="jax", ring_capacity=400)
    for c in _chunks(dirty, 100):
        eng.ingest(c)
    total = eng.quarantined_windows
    # patching 300-301 frees exactly the windows ending before 302:
    # starts 300-length+1 .. 302-length
    first = eng.correct(300, ref[300:302])
    assert first == 2
    assert eng.quarantined_samples == 2
    queued = eng.correct(302, ref[302:304])
    assert first + queued == total  # the rest revive with the last patch
    eng.ingest(np.zeros(0))
    assert eng.quarantined_windows == 0
    assert eng.readmitted_windows == total
