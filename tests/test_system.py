"""End-to-end behaviour: the paper's claims, reproduced at test scale.

These are the system-level invariants from Herrmann & Webb §5:
  1. EAPrunedDTW never changes the search answer (exactness),
  2. it computes no more DTW cells than PrunedDTW and full DTW,
  3. lower bounds are dispensable — the nolb variant still returns the
     exact answer and still prunes most of the DTW matrix work,
  4. the batched ub sharing preserves exactness.
"""
import math

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.ea_pruned_dtw_np import dtw_naive
from repro.data.synthetic import make_dataset, make_queries
from repro.search import subsequence_search


def _brute(ref, q, length, window):
    def zn(x):
        return (x - x.mean()) / max(x.std(), 1e-8)

    qn = zn(q)
    best_d, best_s = math.inf, -1
    for s in range(len(ref) - length + 1):
        d = dtw_naive(qn, zn(ref[s : s + length]), window=window)
        if d < best_d:
            best_d, best_s = d, s
    return best_s, best_d


@pytest.mark.parametrize("dataset", ["ECG", "REFIT"])
def test_paper_pipeline_small(dataset):
    ref = make_dataset(dataset, 1200, seed=0)
    q = make_queries(dataset, 1, 128, seed=1)[0]
    length, w = 128, 12
    bs, bd = _brute(ref, q, length, w)

    results = {}
    for variant in ("full", "pruned", "eapruned", "eapruned_nolb"):
        res = subsequence_search(
            jnp.asarray(ref), jnp.asarray(q), length=length, window=w,
            variant=variant, batch=64,
        )
        assert int(res.best_start) == bs, variant
        assert abs(float(res.best_dist) - bd) < 1e-5, variant
        results[variant] = res

    # claim 2: EA does the least DTW work
    assert int(results["eapruned"].cells) <= int(results["pruned"].cells)
    assert int(results["pruned"].cells) <= int(results["full"].cells)
    # claim 3: nolb is exact and prunes most of the full matrix work
    n_win = len(ref) - length + 1
    full_cells_all = n_win * (length * (2 * w + 1) - w * (w + 1))
    assert int(results["eapruned_nolb"].cells) < 0.8 * full_cells_all
