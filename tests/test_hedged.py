"""Hedged dispatch and health-aware scheduling (DESIGN.md §2.9).

The contracts under test:

  * **Hedging never changes the answer** — with a deterministic
    ``FakeClock``, a hedge that fires (straggler shard) and a hedge where
    both attempts complete produce incumbents *bit-identical* to the
    un-hedged run, including the quarantine count (the backup's windows
    are never double-counted). Pinned on the jax and pallas_interpret
    backends.
  * **Hedging changes the latency** — a won hedge completes the
    straggler's range at the backup's virtual finish time instead of
    waiting out the soft ``timeout`` (so the straggler shard is not
    struck), and ``hedge_max_inflight`` bounds the ladder.
  * **Circuit breaker** — ``breaker_threshold`` consecutive failures
    route subsequent ranges off the shard with zero further attempts on
    it (a pause, not a verdict: ``failed_shards`` stays empty), and after
    ``breaker_cooldown`` a half-open probe success puts it back.
  * **Primitives** — ``CircuitBreaker`` state machine, ``hedge_race``
    virtual-timeline adjudication, ``merge_states`` idempotence,
    ``DecorrelatedJitterBackoff`` seeding.
  * **Streaming seam** — a ``StreamSearchEngine`` built over a
    ``HedgedExecutor`` of ingest executors serves bit-identical results.

``$REPRO_FAULT_SEED`` (via ``faults.fault_seed``) varies the data draw
for the seeded check.sh pass; every race here runs on the fake timeline,
so the assertions are exact regardless of wall time.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import SearchInputError
from repro.distributed.fault_tolerance import (
    CircuitBreaker,
    DecorrelatedJitterBackoff,
    WorkerHealth,
    hedge_race,
)
from repro.search import (
    HedgedExecutor,
    IncumbentState,
    get_executor,
    make_plan,
    merge_states,
    multi_query_search,
    resilient_search,
)
from repro.search.pipeline import MULTI_VARIANTS
from repro.serve import SearchSupervisor, StreamSearchEngine

from faults import (
    FakeClock,
    FaultyEngine,
    ShardFaultInjector,
    SlowIngestExecutor,
    plant_nonfinite,
)
from test_resilient import _mk, _real_runner


# -- primitives -----------------------------------------------------------

def test_circuit_breaker_state_machine():
    clock = FakeClock()
    br = CircuitBreaker(threshold=2, cooldown=5.0, clock=clock)
    assert br.state == "closed" and br.ready()
    br.record_failure()
    assert br.state == "closed" and br.ready()  # 1 < threshold
    br.record_failure()
    assert br.state == "open" and br.trips == 1
    assert not br.ready()  # cooldown not elapsed
    clock.advance(5.0)
    assert br.ready()  # cooled: eligible for one probe
    br.acquire()
    assert br.state == "half_open"
    assert not br.ready()  # the probe slot is taken
    br.record_failure()  # probe failed: straight back to open
    assert br.state == "open" and br.trips == 2
    clock.advance(5.0)
    br.acquire()
    br.record_success()
    assert br.state == "closed" and br.ready()
    assert br.consecutive_failures == 0 and br.failures == 3


def test_circuit_breaker_success_resets_consecutive():
    br = CircuitBreaker(threshold=3, cooldown=0.0, clock=FakeClock())
    for _ in range(2):
        br.record_failure()
    br.record_success()
    for _ in range(2):
        br.record_failure()
    assert br.state == "closed"  # never 3 *consecutive*


def test_circuit_breaker_validates_knobs():
    with pytest.raises(SearchInputError):
        CircuitBreaker(threshold=0)
    with pytest.raises(SearchInputError):
        CircuitBreaker(cooldown=-1.0)


def test_hedge_race_virtual_timeline():
    # Primary took 50; delay 5; one fast backup (dt 1) finishes at 5+1=6.
    clock = FakeClock()

    def backup():
        clock.advance(1.0)
        return "b"

    out = hedge_race(50.0, 5.0, iter([("x", backup)]), clock=clock)
    assert out.won and out.launched == 1
    assert out.effective_dt == 6.0
    assert out.completions == (("x", "b", 1.0),)


def test_hedge_race_ladder_and_inflight_cap():
    clock = FakeClock()
    ran = []

    def mk(tag, dt):
        def thunk():
            ran.append(tag)
            clock.advance(dt)
            return tag
        return tag, thunk

    # Both backups slow: the ladder launches max_inflight=2 rungs (at 5 and
    # 10 — nothing virtually finished by then), then stops; rung 3 (which
    # would have won) is never reached.
    out = hedge_race(
        50.0, 5.0, iter([mk("a", 50.0), mk("b", 50.0), mk("c", 1.0)]),
        clock=clock, max_inflight=2,
    )
    assert ran == ["a", "b"] and out.launched == 2
    # a finishes at 5+50=55, b at 10+50=60: neither beats the primary's 50
    assert not out.won and out.effective_dt == 50.0


def test_hedge_race_stops_once_someone_finished():
    clock = FakeClock()
    ran = []

    def mk(tag, dt):
        def thunk():
            ran.append(tag)
            clock.advance(dt)
            return tag
        return tag, thunk

    # Fast first backup finishes at 5+1=6 < second rung's launch time 10:
    # the second backup is never launched.
    out = hedge_race(
        50.0, 5.0, iter([mk("a", 1.0), mk("b", 1.0)]),
        clock=clock, max_inflight=4,
    )
    assert ran == ["a"] and out.launched == 1
    assert out.won and out.effective_dt == 6.0


def test_hedge_race_backup_failure_reported_not_fatal():
    clock = FakeClock()
    failed = []

    def bad():
        raise RuntimeError("backup down")

    def good():
        clock.advance(1.0)
        return "ok"

    out = hedge_race(
        50.0, 5.0, iter([("bad", bad), ("good", good)]), clock=clock,
        on_failure=lambda tag, e: failed.append(tag),
    )
    assert failed == ["bad"]
    # The failed rung still occupied ladder slot 1; the good backup
    # launched at 2*5=10 and finished at 11.
    assert out.won and out.effective_dt == 11.0
    assert out.completions[0][0] == "good"


def test_merge_states_idempotent_and_strict():
    a = IncumbentState(ub=jnp.asarray([1.0, 2.0, 3.0]),
                       best=jnp.asarray([10, 20, 30]))
    same = merge_states(a, a)  # duplicate completion: a no-op
    assert np.array_equal(np.asarray(same.ub), np.asarray(a.ub))
    assert np.array_equal(np.asarray(same.best), np.asarray(a.best))
    b = IncumbentState(ub=jnp.asarray([0.5, 2.0, 9.0]),
                       best=jnp.asarray([11, 21, 31]))
    m = merge_states(a, b)
    # strictly tighter wins; ties keep the first argument's achiever
    assert np.asarray(m.ub).tolist() == [0.5, 2.0, 3.0]
    assert np.asarray(m.best).tolist() == [11, 20, 30]


def test_jitter_backoff_seeded_and_bounded(monkeypatch):
    monkeypatch.setenv("REPRO_FAULT_SEED", "13")
    a = DecorrelatedJitterBackoff(0.01)
    b = DecorrelatedJitterBackoff(0.01)
    seq_a = [a.next() for _ in range(6)]
    seq_b = [b.next() for _ in range(6)]
    assert seq_a == seq_b  # same seed, same draw
    assert all(0.01 <= s <= 0.01 * 16 for s in seq_a)  # [base, cap]
    a.reset()
    assert 0.01 <= a.next() < 0.03  # fresh episode: uniform(base, 3*base)
    assert DecorrelatedJitterBackoff(0.0).next() == 0.0


# -- resilient_search: hedging --------------------------------------------

def _hedged_pair(backend, *, dirty=False, **kw):
    """Run the same straggler scenario hedged and un-hedged; return both."""
    ref, queries = _mk()
    if dirty:
        ref = plant_nonfinite(ref, [(100, 4, np.nan), (250, 2, np.inf)])
    length, w = queries.shape[1], 5

    def run(hedge):
        clock = FakeClock()
        inj = ShardFaultInjector(
            _runner(ref, queries, length, w, backend),
            slow_shards={1: 50.0}, clock=clock, base_dt=1.0,
        )
        res = resilient_search(
            ref, queries, length, w, n_shards=3, runner=inj,
            hedge=hedge, hedge_delay=5.0, timeout=10.0, max_retries=0,
            backoff=0.0, sleep=lambda _t: None, clock=clock, **kw,
        )
        return res, inj

    return (ref, queries, length, w), run(False), run(True)


def _runner(ref, queries, length, w, backend):
    """Like test_resilient._real_runner but with a selectable backend."""

    def runner(shard, lo, hi, ub):
        seg = jnp.asarray(ref[lo : hi + length - 1])
        res = multi_query_search(
            seg, jnp.asarray(queries), length, w, backend=backend,
            ub_init=jnp.asarray(ub, jnp.float64),
        )
        s = np.asarray(res.best_start, np.int64)
        return (
            np.where(s >= 0, s + lo, -1),
            np.asarray(res.best_dist, np.float64),
            int(res.quarantined),
        )

    return runner


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
def test_hedge_win_is_bit_identical_and_skips_timeout(backend):
    """The acceptance scenario: one straggler shard, deterministic clock.

    The hedged run must (a) return bit-identical incumbents and quarantine
    counts to the un-hedged run, (b) complete the straggler's range via a
    won hedge (effective latency 5+1=6 < timeout 10) instead of waiting
    out the soft timeout — the un-hedged run strikes shard 1 off
    (max_retries=0), the hedged run keeps it.
    """
    _, (plain, _inj_p), (hedged, inj_h) = _hedged_pair(backend)
    assert np.array_equal(hedged.best_start, plain.best_start)
    assert np.array_equal(hedged.best_dist, plain.best_dist)  # bitwise
    assert hedged.quarantined == plain.quarantined
    assert hedged.coverage == 1.0 and plain.coverage == 1.0
    assert hedged.hedges_launched == 1 and hedged.hedges_won == 1
    assert plain.hedges_launched == 0 and plain.hedges_won == 0
    # the un-hedged run burned the soft timeout and struck the straggler
    assert plain.failed_shards == (1,)
    assert hedged.failed_shards == ()
    # the backup ran the same (lo, hi) range the straggler completed
    straggler_ranges = [(lo, hi) for s, lo, hi, ok in inj_h.calls if s == 1]
    backup = [c for c in inj_h.calls if c[0] != 1 and c[1:3] ==
              straggler_ranges[0][0:2]]
    assert backup, "hedge backup never ran the straggler's range"


def test_hedge_duplicate_completion_folds_idempotently():
    """Both attempts complete (the host emulation always completes the
    primary): duplicate fold must not change counts or incumbents, dirty
    data included."""
    (ref, queries, length, w), (plain, _), (hedged, inj) = _hedged_pair(
        "jax", dirty=True
    )
    base = multi_query_search(jnp.asarray(ref), jnp.asarray(queries),
                              length, w, backend="jax")
    assert np.array_equal(hedged.best_start, plain.best_start)
    assert np.array_equal(hedged.best_dist, plain.best_dist)
    assert np.array_equal(hedged.best_start, np.asarray(base.best_start))
    # quarantine counted once despite two completions of the range
    assert hedged.quarantined == int(base.quarantined) == plain.quarantined
    # both the primary and the backup really completed (ok=True twice on
    # the straggler's range)
    lo = [c[1] for c in inj.calls if c[0] == 1][0]
    oks = [c for c in inj.calls if c[1] == lo and c[3]]
    assert len(oks) == 2


def test_hedge_determinism_same_seed():
    _, _, (h1, _) = _hedged_pair("jax")
    _, _, (h2, _) = _hedged_pair("jax")
    assert np.array_equal(h1.best_start, h2.best_start)
    assert np.array_equal(h1.best_dist, h2.best_dist)
    assert h1.attempts == h2.attempts
    assert h1.hedges_launched == h2.hedges_launched
    assert h1.hedges_won == h2.hedges_won
    assert h1.latency == h2.latency


def test_hedge_delay_derived_from_ewma():
    """No explicit hedge_delay: the monitor's threshold x EWMA fires the
    hedge once fast shards establish a baseline (shard 2 is the straggler
    so ranges 0 and 1 seed the EWMA first)."""
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    clock = FakeClock()
    inj = ShardFaultInjector(
        _real_runner(ref, queries, length, w),
        slow_shards={2: 50.0}, clock=clock, base_dt=1.0,
    )
    res = resilient_search(
        ref, queries, length, w, n_shards=3, runner=inj,
        hedge=True, backoff=0.0, sleep=lambda _t: None, clock=clock,
    )
    base = multi_query_search(jnp.asarray(ref), jnp.asarray(queries),
                              length, w, backend="jax")
    # EWMA after two fast ranges is 1.0 -> delay 3.0; dt 50 > 3 fires it.
    assert res.hedges_launched >= 1 and res.hedges_won == 1
    assert res.coverage == 1.0
    assert np.array_equal(res.best_start, np.asarray(base.best_start))


def test_hedge_first_attempt_has_no_baseline():
    """Derived delay with no EWMA yet: the very first attempt can never
    hedge, however slow (there is nothing to judge it against)."""
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    clock = FakeClock()
    inj = ShardFaultInjector(
        _real_runner(ref, queries, length, w),
        slow_shards={0: 50.0}, clock=clock, base_dt=1.0,
    )
    res = resilient_search(
        ref, queries, length, w, n_shards=3, runner=inj,
        hedge=True, backoff=0.0, sleep=lambda _t: None, clock=clock,
    )
    assert res.hedges_launched == 0 and res.hedges_won == 0
    assert res.coverage == 1.0


def test_hedge_max_inflight_bounds_the_ladder():
    """Two slow shards: with a ladder depth of 1 the single backup is also
    a straggler and the hedge cannot win; depth 2 reaches the fast shard."""
    ref, queries = _mk()
    length, w = queries.shape[1], 5

    def run(depth):
        clock = FakeClock()
        inj = ShardFaultInjector(
            _real_runner(ref, queries, length, w),
            slow_shards={0: 50.0, 1: 50.0}, clock=clock, base_dt=1.0,
        )
        return resilient_search(
            ref, queries, length, w, n_shards=3, runner=inj,
            hedge=True, hedge_delay=5.0, hedge_max_inflight=depth,
            backoff=0.0, sleep=lambda _t: None, clock=clock,
        )

    shallow = run(1)
    deep = run(2)
    # Both slow shards' ranges hedge (dt 50 > delay 5). At depth 1 the
    # single backup rung is the *other* slow shard for range 0 (id order,
    # no baseline yet) and slow shard 0 for range 1 — no race is won.
    assert shallow.hedges_launched == 2 and shallow.hedges_won == 0
    # Depth 2 reaches fast shard 2 on range 0's rung 2 (finishes at
    # 10+1=11 < 50); by range 1 the EWMA marks shard 0 a straggler, so
    # shard 2 is rung 1 there and wins again.
    assert deep.hedges_launched == 3 and deep.hedges_won == 2
    assert np.array_equal(shallow.best_start, deep.best_start)
    assert np.array_equal(shallow.best_dist, deep.best_dist)


# -- resilient_search: circuit breaker ------------------------------------

def test_breaker_routes_ranges_off_tripped_shard():
    """The acceptance scenario: shard 0 dead, breaker_threshold=2 with a
    generous retry budget. Two failures open the breaker; every later
    range assigned to shard 0 is rerouted at pop time with ZERO further
    attempts on it, and the shard is never marked failed."""
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    clock = FakeClock()
    inj = ShardFaultInjector(
        _real_runner(ref, queries, length, w),
        dead_shards={0}, clock=clock,
    )
    res = resilient_search(
        ref, queries, length, w, n_shards=2, n_ranges=6, runner=inj,
        max_retries=5, breaker_threshold=2, breaker_cooldown=1000.0,
        backoff=0.0, sleep=lambda _t: None, clock=clock,
    )
    base = multi_query_search(jnp.asarray(ref), jnp.asarray(queries),
                              length, w, backend="jax")
    shard0_calls = [c for c in inj.calls if c[0] == 0]
    assert len(shard0_calls) == 2  # exactly breaker_threshold, then routed off
    assert res.failed_shards == ()  # a pause, not a verdict
    assert res.coverage == 1.0
    # range 0 rerouted mid-retry + ranges 2 and 4 rerouted at pop time
    assert res.reassignments == 3
    assert np.array_equal(res.best_start, np.asarray(base.best_start))
    health0 = res.shard_health[0]
    assert health0.state == "open" and health0.trips == 1
    assert health0.consecutive_failures == 2


def test_breaker_half_open_probe_recovers_shard():
    """A shard that fails twice then heals: once the cooldown elapses on
    the fake clock, the next range probes it half-open, succeeds, and the
    breaker closes."""
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    clock = FakeClock()
    real = _real_runner(ref, queries, length, w)
    fails = {"n": 2}

    def flaky(shard, lo, hi, ub):
        if shard == 0 and fails["n"] > 0:
            fails["n"] -= 1
            raise RuntimeError("shard 0 hiccup")
        out = real(shard, lo, hi, ub)
        clock.advance(1.0)
        return out

    calls = []

    def recorder(shard, lo, hi, ub):
        out = flaky(shard, lo, hi, ub)
        calls.append(shard)
        return out

    res = resilient_search(
        ref, queries, length, w, n_shards=2, n_ranges=6, runner=recorder,
        max_retries=5, breaker_threshold=2, breaker_cooldown=2.0,
        backoff=0.0, sleep=lambda _t: None, clock=clock,
    )
    assert res.coverage == 1.0 and res.failed_shards == ()
    # shard 0 came back: while the breaker cooled, its ranges rerouted to
    # shard 1; once the fake clock passed the cooldown (t=2), the next
    # shard-0 range ran there as the half-open probe and succeeded
    assert calls.count(0) == 1
    assert res.shard_health[0].state == "closed"
    assert res.shard_health[0].trips == 1


def test_hedge_backups_avoid_tripped_shards():
    """Hedge routing composes with the breaker: the backup ladder skips a
    shard whose breaker is open, even if it is next in id order."""
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    clock = FakeClock()
    inj = ShardFaultInjector(
        _real_runner(ref, queries, length, w),
        dead_shards={1}, slow_shards={2: 50.0}, clock=clock,
    )
    res = resilient_search(
        ref, queries, length, w, n_shards=4, runner=inj,
        hedge=True, hedge_delay=5.0, max_retries=5,
        breaker_threshold=2, breaker_cooldown=1000.0,
        backoff=0.0, sleep=lambda _t: None, clock=clock,
    )
    assert res.coverage == 1.0
    assert res.hedges_won >= 1
    # shard 1's breaker opened before the straggler's hedge; no hedge
    # backup may have landed on it (its only calls are its own 2 failures)
    shard1 = [c for c in inj.calls if c[0] == 1]
    assert len(shard1) == 2 and not any(ok for *_x, ok in shard1)


def test_seeded_straggler_plus_dead_shard():
    """The check.sh seeded-scenario recipe: one straggler AND one dead
    shard under $REPRO_FAULT_SEED. Hedging and recovery compose; results
    stay exact with full coverage."""
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    clock = FakeClock()
    inj = ShardFaultInjector(
        _real_runner(ref, queries, length, w),
        dead_shards={3}, slow_shards={1: 50.0}, clock=clock, base_dt=1.0,
    )
    res = resilient_search(
        ref, queries, length, w, n_shards=4, runner=inj,
        hedge=True, hedge_delay=5.0, max_retries=1,
        backoff=0.0, sleep=lambda _t: None, clock=clock,
    )
    base = multi_query_search(jnp.asarray(ref), jnp.asarray(queries),
                              length, w, backend="jax")
    assert res.coverage == 1.0
    assert res.failed_shards == (3,)
    assert res.hedges_won >= 1
    assert np.array_equal(res.best_start, np.asarray(base.best_start))
    np.testing.assert_allclose(res.best_dist, np.asarray(base.best_dist),
                               rtol=2e-5)


# -- HedgedExecutor on the run_range seam ---------------------------------

class _SlowRangeExecutor:
    """run_range proxy with declared fake latency (straggler recipe)."""

    def __init__(self, executor, clock, dt):
        self._executor = executor
        self.clock = clock
        self.dt = float(dt)
        self.calls = 0

    def run_range(self, plan, state, lo, hi):
        self.calls += 1
        out = self._executor.run_range(plan, state, lo, hi)
        self.clock.advance(self.dt)
        return out


def test_hedged_executor_run_range_parity():
    """HedgedExecutor over two real executors: identical RangeResult state
    to the plain executor, with the race won by the fast wrapper."""
    ref, queries = _mk()
    length, w = queries.shape[1], 5
    plan = make_plan(length=length, window=w, backend="jax",
                     allowed_variants=MULTI_VARIANTS)
    base_exec = get_executor(plan, jnp.asarray(ref), jnp.asarray(queries))
    clock = FakeClock()
    slow = _SlowRangeExecutor(base_exec, clock, 50.0)
    fast = _SlowRangeExecutor(base_exec, clock, 1.0)
    hedged = HedgedExecutor([slow, fast], hedge_delay=5.0, clock=clock)

    nq = queries.shape[0]
    state0 = IncumbentState(ub=jnp.full((nq,), jnp.inf, jnp.float64),
                            best=jnp.full((nq,), -1, jnp.int64))
    n_win = len(ref) - length + 1
    rr_plain = base_exec.run_range(plan, state0, 0, n_win)
    rr_hedged = hedged.run_range(plan, state0, 0, n_win)
    assert np.array_equal(np.asarray(rr_hedged.state.ub),
                          np.asarray(rr_plain.state.ub))
    assert np.array_equal(np.asarray(rr_hedged.state.best),
                          np.asarray(rr_plain.state.best))
    assert rr_hedged.quarantined == rr_plain.quarantined
    assert hedged.hedges_launched == 1 and hedged.hedges_won == 1
    assert slow.calls == 1 and fast.calls == 1
    assert hedged.last_effective_dt == 6.0  # 1*delay + backup dt


def test_hedged_executor_validates_knobs():
    with pytest.raises(SearchInputError):
        HedgedExecutor([])
    with pytest.raises(SearchInputError):
        HedgedExecutor([object()], hedge_max_inflight=0)


# -- streaming through the hedged seam ------------------------------------

def test_streaming_hedged_executor_bit_identical():
    """StreamSearchEngine(executor=HedgedExecutor([...])): same stream,
    same chunking, bit-identical incumbents and counters to the plain
    engine — with the hedge demonstrably firing on an injected straggler
    ingest."""
    ref, queries = _mk(n_ref=500)
    length, w = queries.shape[1], 5
    dirty = plant_nonfinite(ref, [(200, 3, np.nan)])
    clock = FakeClock()
    captured = {}

    def factory(default):
        slow = SlowIngestExecutor(default, clock, base_dt=1.0,
                                  slow_dt=50.0, slow_at={2})
        fast = SlowIngestExecutor(default, clock, base_dt=1.0)
        hedged = HedgedExecutor([slow, fast], hedge_delay=5.0, clock=clock)
        captured["hedged"] = hedged
        captured["fast"] = fast
        return hedged

    eng_plain = StreamSearchEngine(jnp.asarray(queries), length=length,
                                   window=w, stream_chunk=64)
    eng_hedged = StreamSearchEngine(jnp.asarray(queries), length=length,
                                    window=w, stream_chunk=64,
                                    executor=factory)
    for pos in range(0, len(dirty), 80):
        eng_plain.ingest(dirty[pos : pos + 80])
        eng_hedged.ingest(dirty[pos : pos + 80])
    assert captured["hedged"].hedges_won == 1
    assert captured["fast"].calls >= 1
    sp, dp = eng_plain.best()
    sh, dh = eng_hedged.best()
    assert np.array_equal(np.asarray(sh), np.asarray(sp))
    assert np.array_equal(np.asarray(dh), np.asarray(dp))  # bitwise
    assert eng_hedged.quarantined_windows == eng_plain.quarantined_windows
    assert eng_hedged.rounds == eng_plain.rounds
    assert eng_hedged.lanes == eng_plain.lanes


def test_stream_engine_rejects_bad_executor():
    _, queries = _mk()
    length, w = queries.shape[1], 5
    with pytest.raises(SearchInputError):
        StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                           executor=object())


# -- supervisor health ----------------------------------------------------

def test_supervisor_breaker_sheds_load_in_time(tmp_path):
    """With a single engine there is nowhere to route away to: a tripped
    breaker waits out its cooldown (one extra recorded sleep) before the
    half-open probe, then closes on success."""
    _, queries = _mk()
    length, w = queries.shape[1], 5
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                             stream_chunk=64)
    faulty = FaultyEngine(eng, fail_at={0, 1})
    sleeps = []
    clock = FakeClock()
    sup = SearchSupervisor(faulty, str(tmp_path),
                           max_retries=5, backoff=0.01,
                           breaker_threshold=2, breaker_cooldown=7.0,
                           sleep=sleeps.append, clock=clock)
    sup.ingest(np.ones(100))
    # fail 1: plain backoff; fail 2: backoff, breaker opens -> cooldown
    assert sleeps == [0.01, 0.02, 7.0]
    assert sup.restarts == 2
    assert sup.health.snapshot().state == "closed"  # probe succeeded
    assert sup.health.snapshot().trips == 1


def test_supervisor_jitter_opt_in(tmp_path):
    _, queries = _mk()
    length, w = queries.shape[1], 5
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                             stream_chunk=64)
    faulty = FaultyEngine(eng, fail_at={0})
    sleeps = []
    sup = SearchSupervisor(faulty, str(tmp_path), backoff=0.01, jitter=True,
                           sleep=sleeps.append)
    sup.ingest(np.ones(100))
    assert len(sleeps) == 1 and 0.01 <= sleeps[0] < 0.03
