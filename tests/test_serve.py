"""Serving path: generation loop, rolling SWA cache exactness."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models.registry import build
from repro.serve.generate import generate

KEY = jax.random.PRNGKey(0)


def test_greedy_generation_matches_forward():
    """Greedy continuation must equal argmax of teacher-forced logits."""
    cfg = ARCHS["mistral-nemo-12b"].reduced()
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 6)))
    out = generate(model, params, prompt, max_new_tokens=4)
    assert out.shape == (2, 10)
    # re-check each generated token against full forward
    for t in range(6, 10):
        logits, _ = model.forward(params, tokens=out[:, :t])
        expect = jnp.argmax(logits[:, -1], axis=-1)
        assert jnp.array_equal(expect, out[:, t]), t


def test_generation_with_temperature_is_deterministic_per_key():
    cfg = ARCHS["llama3.2-3b"].reduced()
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(1)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (1, 5)))
    a = generate(model, params, prompt, 5, temperature=1.0, key=jax.random.PRNGKey(7))
    b = generate(model, params, prompt, 5, temperature=1.0, key=jax.random.PRNGKey(7))
    assert jnp.array_equal(a, b)


def test_rolling_swa_cache_exact_across_wraps():
    """Window-sized rolling cache: decode == forward even after 3 wraps."""
    cfg = dataclasses.replace(
        ARCHS["h2o-danube-3-4b"].reduced(), sliding_window=6, n_layers=2
    )
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(2)
    B, S = 2, 20
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    full, _ = model.forward(params, tokens=toks)
    cache = model.init_cache(B, S)
    assert cache["k"].shape[2] == 6  # rolling: window-sized, not S
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t : t + 1], t)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full)))
    assert err < 1e-4, err


def test_rolling_swa_prefill_handoff():
    cfg = dataclasses.replace(
        ARCHS["h2o-danube-3-4b"].reduced(), sliding_window=6, n_layers=2
    )
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(3)
    B, S, t0 = 2, 20, 13
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    full, _ = model.forward(params, tokens=toks)
    cache = model.init_cache(B, S)
    lg, cache = model.prefill(params, cache, tokens=toks[:, :t0])
    assert float(jnp.max(jnp.abs(lg[:, 0] - full[:, t0 - 1]))) < 1e-4
    for t in range(t0, S):
        lg, cache = model.decode_step(params, cache, toks[:, t : t + 1], t)
        assert float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))) < 1e-4


def test_mamba2_generation():
    cfg = ARCHS["mamba2-130m"].reduced()
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(4)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 6)))
    out = generate(model, params, prompt, max_new_tokens=3)
    assert out.shape == (2, 9)
    for t in range(6, 9):
        logits, _ = model.forward(params, tokens=out[:, :t])
        assert jnp.array_equal(jnp.argmax(logits[:, -1], -1), out[:, t]), t
