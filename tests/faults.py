"""Fault-injection harness for the hardened serving tests.

Three failure families, matching what a long-lived search service actually
sees (DESIGN.md §2.6):

  * **Dirty data** — ``plant_nonfinite`` stamps NaN/Inf bursts into a clean
    series at given positions, and ``finite_window_mask_np`` is the NumPy
    oracle for which windows the quarantine must then exclude.
  * **Transient dispatch failure** — ``FaultyEngine`` wraps a
    ``StreamSearchEngine`` and raises ``RuntimeError`` on chosen ingest
    calls (each position fires once, like a device falling over and coming
    back), delegating everything else untouched. Drive it through
    ``SearchSupervisor`` to exercise retry/rollback/replay.
  * **Kill between chunks** — no class needed: drop the engine/supervisor on
    the floor after arrival k, build fresh ones, ``resume()``, and re-feed
    from the returned index. ``test_robustness.py`` pins exact incumbent
    parity for all three.
"""
from __future__ import annotations

import numpy as np


def plant_nonfinite(series, bursts):
    """Copy ``series`` with non-finite bursts stamped in.

    ``bursts`` is an iterable of ``(start, length, value)`` with value NaN,
    +inf or -inf. Returns the dirty copy.
    """
    out = np.array(series, dtype=float, copy=True)
    for start, length, value in bursts:
        out[start : start + length] = value
    return out


def finite_window_mask_np(series, length):
    """NumPy oracle for ``search.znorm.window_finite_mask``."""
    x = np.asarray(series)
    n_win = x.shape[0] - length + 1
    return np.array(
        [np.isfinite(x[s : s + length]).all() for s in range(n_win)]
    )


class FaultyEngine:
    """Engine proxy whose ``ingest`` raises once per scheduled call index.

    ``fail_at`` holds 0-based ingest-call indices; each fires exactly once
    (the retry then succeeds, like a transient device error). All other
    attribute access — ``best``, ``save_state``, counters — delegates to the
    wrapped engine, so the proxy can stand in for it everywhere.
    """

    def __init__(self, engine, fail_at, exc=RuntimeError("injected fault")):
        self._engine = engine
        self._remaining = set(int(i) for i in fail_at)
        self._exc = exc
        self.calls = 0

    def ingest(self, chunk):
        i = self.calls
        self.calls += 1
        if i in self._remaining:
            self._remaining.discard(i)
            raise self._exc
        return self._engine.ingest(chunk)

    def __getattr__(self, name):
        return getattr(self._engine, name)


def adversarial_chunkings(n, length):
    """Chunk-size schedules that historically break streaming code.

    Single samples, one-off-from-window sizes, the window size itself, and
    the whole series in one arrival.
    """
    return [
        [1] * n,
        [max(1, length - 1)],
        [length],
        [length + 1],
        [n],
    ]


def feed(engine_or_supervisor, series, sizes):
    """Feed ``series`` in chunks of the given sizes (cycled to cover it)."""
    pos = 0
    i = 0
    while pos < len(series):
        size = sizes[i % len(sizes)]
        engine_or_supervisor.ingest(series[pos : pos + size])
        pos += size
        i += 1
