"""Fault-injection harness for the hardened serving tests.

Failure families, matching what a long-lived search service actually sees
(DESIGN.md §2.6/§2.7):

  * **Dirty data** — ``plant_nonfinite`` stamps NaN/Inf bursts into a clean
    series at given positions, and ``finite_window_mask_np`` is the NumPy
    oracle for which windows the quarantine must then exclude.
  * **Transient dispatch failure** — ``FaultyEngine`` wraps a
    ``StreamSearchEngine`` and raises ``RuntimeError`` on chosen ingest
    calls (each position fires once, like a device falling over and coming
    back), delegating everything else untouched. Drive it through
    ``SearchSupervisor`` to exercise retry/rollback/replay.
  * **Kill between chunks** — no class needed: drop the engine/supervisor on
    the floor after arrival k, build fresh ones, ``resume()``, and re-feed
    from the returned index. ``test_robustness.py`` pins exact incumbent
    parity for all three.
  * **Shard failures** — ``ShardFaultInjector`` wraps a
    ``search.resilient.resilient_search`` runner with declarative recipes
    (dead shards, shards that die after N calls, shards that time out,
    ranges that fail once then heal, ranges that fail everywhere), and
    ``coverage_oracle_np`` / ``best_covered_np`` are the NumPy oracles for
    what a degraded result must still get exactly right.
    ``tests/test_resilient.py`` drives them; ``$REPRO_FAULT_SEED`` (see
    ``fault_seed``) varies the data so ``scripts/check.sh`` can run a
    seeded pass.
  * **Stragglers on a fake timeline** — ``FakeClock`` is the injectable
    deterministic clock every hedging/breaker test runs on;
    ``ShardFaultInjector(slow_shards={...}, clock=...)`` makes chosen
    shards *complete correctly but slowly* (advancing the fake clock, not
    wall time), and ``SlowIngestExecutor`` is the streaming analogue for
    ``serve.stream.StreamSearchEngine(executor=HedgedExecutor([...]))``.
    ``tests/test_hedged.py`` drives both.
"""
from __future__ import annotations

import os

import numpy as np


def fault_seed(default: int = 0) -> int:
    """Seed for fault-test data, overridable via ``$REPRO_FAULT_SEED``.

    The seeded check.sh pass sets it to exercise the same recipes over a
    different series/query draw — fault handling must not depend on one
    lucky dataset.
    """
    return int(os.environ.get("REPRO_FAULT_SEED", default))


def plant_nonfinite(series, bursts):
    """Copy ``series`` with non-finite bursts stamped in.

    ``bursts`` is an iterable of ``(start, length, value)`` with value NaN,
    +inf or -inf. Returns the dirty copy.
    """
    out = np.array(series, dtype=float, copy=True)
    for start, length, value in bursts:
        out[start : start + length] = value
    return out


def finite_window_mask_np(series, length):
    """NumPy oracle for ``search.znorm.window_finite_mask``."""
    x = np.asarray(series)
    n_win = x.shape[0] - length + 1
    return np.array(
        [np.isfinite(x[s : s + length]).all() for s in range(n_win)]
    )


class FaultyEngine:
    """Engine proxy whose ``ingest`` raises once per scheduled call index.

    ``fail_at`` holds 0-based ingest-call indices; each fires exactly once
    (the retry then succeeds, like a transient device error). All other
    attribute access — ``best``, ``save_state``, counters — delegates to the
    wrapped engine, so the proxy can stand in for it everywhere.
    """

    def __init__(self, engine, fail_at, exc=RuntimeError("injected fault")):
        self._engine = engine
        self._remaining = set(int(i) for i in fail_at)
        self._exc = exc
        self.calls = 0

    def ingest(self, chunk):
        i = self.calls
        self.calls += 1
        if i in self._remaining:
            self._remaining.discard(i)
            raise self._exc
        return self._engine.ingest(chunk)

    def __getattr__(self, name):
        return getattr(self._engine, name)


def adversarial_chunkings(n, length):
    """Chunk-size schedules that historically break streaming code.

    Single samples, one-off-from-window sizes, the window size itself, and
    the whole series in one arrival.
    """
    return [
        [1] * n,
        [max(1, length - 1)],
        [length],
        [length + 1],
        [n],
    ]


class FakeClock:
    """Deterministic clock for hedging/breaker tests (no wall time).

    Call it like ``time.time``; ``advance(dt)`` moves the timeline. Inject
    it as both the scheduler's ``clock`` and the injector's, so measured
    attempt latencies are exactly the declared ones.
    """

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        self.now += float(dt)


class ShardFaultInjector:
    """Wrap a resilient-search runner with declarative shard/range faults.

    Recipes (all optional, composable):

      ``dead_shards``    — shard ids that raise on every call.
      ``timeout_shards`` — shard ids that raise ``TimeoutError`` on every
                           call (an RPC-style hard deadline).
      ``flaky_ranges``   — range ``lo`` values that fail on their first
                           attempt only, then heal (transient).
      ``dead_ranges``    — range ``lo`` values that fail on *every* shard
                           (forces an uncovered range).
      ``fail_after``     — ``{shard_id: n}``: the shard completes ``n``
                           calls, then dies permanently.
      ``partial``        — ``{lo: (best, ub)}``: a failing attempt on that
                           range attaches achieved partial progress
                           (``partial_best`` / ``partial_ub``) to its
                           exception, as a runner that crashed mid-range
                           would.
      ``slow_shards``    — ``{shard_id: dt}`` with ``clock`` a
                           ``FakeClock``: the shard completes *correctly*
                           but advances the fake timeline by ``dt`` (a
                           straggler, the hedging trigger). Every other
                           call advances by ``base_dt``.

    Every call is recorded in ``calls`` as ``(shard, lo, hi, ok)``.
    """

    def __init__(
        self,
        runner,
        dead_shards=(),
        timeout_shards=(),
        flaky_ranges=(),
        dead_ranges=(),
        fail_after=None,
        partial=None,
        slow_shards=None,
        clock=None,
        base_dt: float = 1.0,
    ):
        self._runner = runner
        self.dead_shards = set(dead_shards)
        self.timeout_shards = set(timeout_shards)
        self._flaky = set(flaky_ranges)
        self.dead_ranges = set(dead_ranges)
        self.fail_after = dict(fail_after or {})
        self.partial = dict(partial or {})
        self.slow_shards = dict(slow_shards or {})
        self.clock = clock
        self.base_dt = float(base_dt)
        self.calls = []
        self._per_shard = {}

    def _raise(self, exc, lo):
        if lo in self.partial:
            best, ub = self.partial[lo]
            exc.partial_best = np.asarray(best)
            exc.partial_ub = np.asarray(ub)
        raise exc

    def __call__(self, shard, lo, hi, ub):
        self._per_shard[shard] = self._per_shard.get(shard, 0) + 1
        fail = (
            shard in self.dead_shards
            or lo in self.dead_ranges
            or (
                shard in self.fail_after
                and self._per_shard[shard] > self.fail_after[shard]
            )
        )
        if lo in self._flaky:
            self._flaky.discard(lo)
            fail = True
        if shard in self.timeout_shards:
            self.calls.append((shard, lo, hi, False))
            self._raise(TimeoutError(f"shard {shard} deadline"), lo)
        if fail:
            self.calls.append((shard, lo, hi, False))
            self._raise(RuntimeError(f"injected shard {shard} fault"), lo)
        out = self._runner(shard, lo, hi, ub)
        if self.clock is not None:
            self.clock.advance(self.slow_shards.get(shard, self.base_dt))
        self.calls.append((shard, lo, hi, True))
        return out


class SlowIngestExecutor:
    """Streaming-seam proxy: correct ``run_ingest``, declared fake latency.

    Wraps a ``search.streaming.StreamIngestExecutor`` (or anything with
    ``run_ingest``) and advances a ``FakeClock`` by ``slow_dt`` on the call
    indices in ``slow_at`` (0-based, counted per proxy) and ``base_dt``
    otherwise — the straggler recipe for hedged streaming ingest. ``calls``
    counts invocations so tests can assert which executor actually ran.
    """

    def __init__(self, executor, clock, base_dt=1.0, slow_dt=10.0,
                 slow_at=()):
        self._executor = executor
        self.clock = clock
        self.base_dt = float(base_dt)
        self.slow_dt = float(slow_dt)
        self.slow_at = set(int(i) for i in slow_at)
        self.calls = 0

    def run_ingest(self, *args, **kwargs):
        i = self.calls
        self.calls += 1
        out = self._executor.run_ingest(*args, **kwargs)
        self.clock.advance(self.slow_dt if i in self.slow_at else self.base_dt)
        return out


def coverage_oracle_np(n_win, covered_ranges):
    """NumPy oracle for (coverage fraction, merged uncovered ranges)."""
    mask = np.zeros((n_win,), bool)
    for lo, hi in covered_ranges:
        mask[lo:hi] = True
    frac = mask.mean() if n_win else 1.0
    uncovered = []
    s = None
    for i in range(n_win):
        if not mask[i] and s is None:
            s = i
        elif mask[i] and s is not None:
            uncovered.append((s, i))
            s = None
    if s is not None:
        uncovered.append((s, n_win))
    return float(frac), tuple(uncovered)


def best_covered_np(ref, queries, length, window, covered_mask):
    """Brute-force nearest window per query over the covered starts only.

    The exactness oracle for degraded results: whatever coverage was lost,
    every *covered* window must have been scanned. Returns ``(starts,
    dists)``; ``start == -1`` (dist inf) when nothing is covered/finite.
    """
    from repro.core.ea_pruned_dtw_np import dtw_naive

    ref = np.asarray(ref, np.float64)
    queries = np.atleast_2d(np.asarray(queries, np.float64))

    def zn(x):
        mu, sd = x.mean(), x.std()
        return (x - mu) / max(sd, 1e-8)

    starts_out, dists_out = [], []
    for q in queries:
        qn = zn(q[:length])
        best_s, best_d = -1, np.inf
        for s in np.nonzero(covered_mask)[0]:
            w = ref[s : s + length]
            if not np.isfinite(w).all():
                continue
            d = dtw_naive(qn, zn(w), window)
            if d < best_d:
                best_s, best_d = int(s), float(d)
        starts_out.append(best_s)
        dists_out.append(best_d)
    return np.asarray(starts_out), np.asarray(dists_out)


def feed(engine_or_supervisor, series, sizes):
    """Feed ``series`` in chunks of the given sizes (cycled to cover it)."""
    pos = 0
    i = 0
    while pos < len(series):
        size = sizes[i % len(sizes)]
        engine_or_supervisor.ingest(series[pos : pos + size])
        pos += size
        i += 1
