"""Per-architecture smoke tests (reduced configs) + decode consistency."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models.registry import build

KEY = jax.random.PRNGKey(0)
B, S = 2, 12


def _batch(cfg, rng):
    batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    if cfg.input_embeds:
        batch["embeds"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        if cfg.family == "audio":
            batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke(name):
    """Reduced config: one forward + backward, finite loss/grads, shapes."""
    cfg = ARCHS[name].reduced()
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(1)
    batch = _batch(cfg, rng)
    kw = {k: v for k, v in batch.items() if k != "labels"}
    logits, _ = model.forward(params, **kw)
    assert logits.shape[-1] == cfg.vocab
    assert logits.shape[:2] == (B, S)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize(
    "name",
    ["mistral-nemo-12b", "qwen2-72b", "h2o-danube-3-4b", "mamba2-130m",
     "recurrentgemma-2b", "whisper-large-v3"],
)
def test_decode_matches_forward(name):
    cfg = ARCHS[name].reduced()
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    if cfg.family == "audio":
        emb = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        full, _ = model.forward(params, tokens=toks, embeds=emb)
        cache = model.init_cache(B, S)
        cache = model.prefill(params, cache, embeds=emb)
    else:
        full, _ = model.forward(params, tokens=toks)
        cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t : t + 1], t)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full)))
    assert err < 1e-3, err


@pytest.mark.parametrize("name", ["llama4-scout-17b-a16e", "kimi-k2-1t-a32b"])
def test_moe_decode_matches_forward(name):
    # generous capacity so dropping can't differ between batch shapes
    cfg = dataclasses.replace(ARCHS[name].reduced(), capacity_factor=100.0)
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    full, _ = model.forward(params, tokens=toks)
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t : t + 1], t)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full)))
    assert err < 1e-3, err


def test_transformer_prefill_then_decode():
    cfg = ARCHS["mistral-nemo-12b"].reduced()
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    full, _ = model.forward(params, tokens=toks)
    t0 = S // 2
    cache = model.init_cache(B, S)
    logits, cache = model.prefill(params, cache, tokens=toks[:, :t0])
    assert float(jnp.max(jnp.abs(logits[:, 0] - full[:, t0 - 1]))) < 1e-3
    for t in range(t0, S):
        lg, cache = model.decode_step(params, cache, toks[:, t : t + 1], t)
        assert float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))) < 1e-3


def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor the MoE must actually drop (not crash)."""
    cfg = dataclasses.replace(
        ARCHS["kimi-k2-1t-a32b"].reduced(), capacity_factor=0.1
    )
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(5)
    batch = _batch(cfg, rng)
    loss = model.loss_fn(params, batch)
    assert bool(jnp.isfinite(loss))


def test_sliding_window_masks_long_range():
    """SWA: token far outside the window cannot influence the logits."""
    cfg = dataclasses.replace(
        ARCHS["h2o-danube-3-4b"].reduced(), sliding_window=4, n_layers=1
    )
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(6)
    toks = np.asarray(rng.integers(0, cfg.vocab, (1, S)))
    toks2 = toks.copy()
    toks2[0, 0] = (toks2[0, 0] + 7) % cfg.vocab  # outside window of last pos
    l1, _ = model.forward(params, tokens=jnp.asarray(toks))
    l2, _ = model.forward(params, tokens=jnp.asarray(toks2))
    assert float(jnp.max(jnp.abs(l1[0, -1] - l2[0, -1]))) < 1e-6
    # but it does influence nearby positions
    assert float(jnp.max(jnp.abs(l1[0, 1] - l2[0, 1]))) > 1e-6
