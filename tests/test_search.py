"""Subsequence search: all four suite variants find the exact NN."""
import math
import subprocess
import sys
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.ea_pruned_dtw_np import dtw_naive
from repro.data.synthetic import DATASETS, make_dataset, make_queries
from repro.search import subsequence_search, window_stats, znorm
from repro.search.subsequence import VARIANTS


def _brute(ref, q, length, window):
    def zn(x):
        return (x - x.mean()) / max(x.std(), 1e-8)

    qn = zn(q)
    best_d, best_s = math.inf, -1
    for s in range(len(ref) - length + 1):
        d = dtw_naive(qn, zn(ref[s : s + length]), window=window)
        if d < best_d:
            best_d, best_s = d, s
    return best_s, best_d


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(3)
    n, length, w = 900, 96, 9
    ref = np.cumsum(rng.normal(size=n))
    q = np.cumsum(rng.normal(size=length))
    s, d = _brute(ref, q, length, w)
    return ref, q, length, w, s, d


@pytest.mark.parametrize("variant", VARIANTS)
def test_variant_finds_exact_nn(problem, variant):
    ref, q, length, w, s, d = problem
    res = subsequence_search(
        jnp.asarray(ref), jnp.asarray(q), length=length, window=w,
        variant=variant, batch=64,
    )
    assert int(res.best_start) == s
    assert abs(float(res.best_dist) - d) < 1e-6


def test_pruning_counters_ordering(problem):
    """EA must issue <= rows/cells than PrunedDTW, which <= full DTW."""
    ref, q, length, w, _, _ = problem
    rows = {}
    cells = {}
    for variant in ("eapruned", "pruned", "full"):
        res = subsequence_search(
            jnp.asarray(ref), jnp.asarray(q), length=length, window=w,
            variant=variant, batch=64, with_info=True,
        )
        rows[variant] = int(res.rows)
        cells[variant] = int(res.cells)
    assert rows["eapruned"] <= rows["pruned"] <= rows["full"]
    assert cells["eapruned"] <= cells["pruned"] <= cells["full"]


def test_lb_ordering_prunes_lanes(problem):
    ref, q, length, w, _, _ = problem
    with_lb = subsequence_search(
        jnp.asarray(ref), jnp.asarray(q), length=length, window=w,
        variant="eapruned", batch=64,
    )
    nolb = subsequence_search(
        jnp.asarray(ref), jnp.asarray(q), length=length, window=w,
        variant="eapruned_nolb", batch=64,
    )
    assert int(with_lb.lanes) < int(nolb.lanes)


def test_window_stats_exact():
    rng = np.random.default_rng(0)
    ref = rng.normal(size=333)
    length = 41
    mu, sg = window_stats(jnp.asarray(ref), length)
    for s in (0, 100, 292):
        w = ref[s : s + length]
        assert abs(float(mu[s]) - w.mean()) < 1e-9
        assert abs(float(sg[s]) - w.std()) < 1e-9


@pytest.mark.parametrize("name", DATASETS)
def test_synthetic_datasets(name):
    x = make_dataset(name, 5000, seed=0)
    y = make_dataset(name, 5000, seed=0)
    assert np.array_equal(x, y), "must be deterministic"
    assert np.all(np.isfinite(x))
    qs = make_queries(name, 3, 128, seed=1)
    assert qs.shape == (3, 128) and np.all(np.isfinite(qs))


def test_distributed_search_subprocess():
    """shard_map search on 8 fake devices finds the same NN."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, numpy as np, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.search import make_distributed_search
mesh = jax.make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(3)
ref = np.cumsum(rng.normal(size=900)); q = np.cumsum(rng.normal(size=96))
search = make_distributed_search(mesh, ("data", "model"), length=96, window=9, batch=32)
res = search(jnp.asarray(ref), jnp.asarray(q))
print("RESULT", int(res.best_start), float(res.best_dist))
"""
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=420,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
    _, s, d = line.split()
    rng = np.random.default_rng(3)
    ref = np.cumsum(rng.normal(size=900))
    q = np.cumsum(rng.normal(size=96))
    bs, bd = _brute(ref, q, 96, 9)
    assert int(s) == bs and abs(float(d) - bd) < 1e-5
