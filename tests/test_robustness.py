"""Hardened serving: quarantine, typed guards, crash recovery (DESIGN.md §2.6).

The contracts under test:

  * **Non-finite quarantine** — any window overlapping a NaN/Inf sample is
    excluded from search; every other window's result is *exact* (pinned
    against a brute-force DTW oracle over the surviving windows, and against
    the offline drivers, on both backends). Quarantined counts are reported;
    incumbents stay finite even on an all-NaN stream.
  * **Typed input guards** — every public entry point raises the
    ``core.guards`` taxonomy (``SearchInputError`` / ``NonFiniteInputError``
    / ``StreamStateError``) on malformed input, before device work.
  * **Crash recovery** — ``save_state``/``restore_state`` roundtrip
    bit-exactly; ``SearchSupervisor`` retries transient ingest failures with
    rollback-and-replay and resumes a killed stream from its checkpoint with
    results identical to the uninterrupted run.
  * **Satellite regressions** — zero-new-window ingests are cheap no-ops;
    stream-state violations carry ``n_seen``/``chunk_index`` context.
"""
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    NonFiniteInputError,
    SearchInputError,
    StreamStateError,
    ea_pruned_dtw_batch,
    ea_pruned_dtw_multi_batch,
)
from repro.core import guards
from repro.core.ea_pruned_dtw_np import dtw_naive
from repro.search import (
    IngestResult,
    ingest_chunk,
    initial_incumbents,
    multi_query_search,
    sanitize_series,
    subsequence_search,
    window_finite_mask,
)
from repro.serve import SearchSupervisor, StreamSearchEngine
from repro.core.lower_bounds import envelope
from repro.search.znorm import znorm

from faults import (
    FaultyEngine,
    adversarial_chunkings,
    fault_seed,
    feed,
    finite_window_mask_np,
    plant_nonfinite,
)

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # Deterministic stand-in mirroring the hypothesis surface used below
    # (same pattern as test_dtw_core.py); examples come from a seeded rng.
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mimics `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: int(r.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(options):
            return _Strategy(lambda r: options[int(r.integers(0, len(options)))])

    def settings(**_kw):
        return lambda f: f

    def given(*strategies):
        def deco(f):
            def wrapper():
                rng = np.random.default_rng(7)
                for _ in range(8):
                    f(*(s.draw(rng) for s in strategies))

            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper

        return deco

BACKENDS = ("jax", "pallas_interpret")


def _mk(seed=0, n_ref=360, nq=3, length=48):
    # $REPRO_FAULT_SEED shifts every draw so the seeded check.sh pass
    # exercises the same recipes on a different series (see tests/faults.py)
    rng = np.random.default_rng(seed + 1000 * fault_seed())
    ref = np.cumsum(rng.normal(size=n_ref))
    queries = np.cumsum(rng.normal(size=(nq, length)), axis=1)
    return ref, queries


def _brute_valid(ref, q, length, window):
    """Brute-force nearest valid (finite) window: the quarantine oracle."""

    def zn(x):
        return (x - x.mean()) / max(x.std(), 1e-8)

    qn = zn(np.asarray(q))
    best_d, best_s = math.inf, -1
    for s in range(len(ref) - length + 1):
        w = np.asarray(ref[s : s + length])
        if not np.isfinite(w).all():
            continue
        d = dtw_naive(qn, zn(w), window=window)
        if d < best_d:
            best_d, best_s = d, s
    return best_s, best_d


# -- quarantine: mask + offline drivers ----------------------------------

def test_window_finite_mask_matches_oracle():
    ref, _ = _mk()
    dirty = plant_nonfinite(ref, [(40, 3, np.nan), (200, 1, np.inf),
                                  (300, 5, -np.inf)])
    got = np.asarray(window_finite_mask(jnp.asarray(dirty), 48))
    assert np.array_equal(got, finite_window_mask_np(dirty, 48))
    # sanitize: identity on the finite samples, zero at the bad ones
    s = np.asarray(sanitize_series(jnp.asarray(dirty)))
    bad = ~np.isfinite(dirty)
    assert np.array_equal(s[~bad], dirty[~bad])
    assert np.all(s[bad] == 0.0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_offline_quarantine_exact_on_survivors(backend):
    """Dirty-ref search equals brute force over the finite windows only."""
    ref, queries = _mk(seed=1)
    length, w = queries.shape[1], 5
    dirty = plant_nonfinite(ref, [(100, 4, np.nan), (250, 2, np.inf)])
    n_bad = int((~finite_window_mask_np(dirty, length)).sum())
    res = subsequence_search(
        jnp.asarray(dirty), jnp.asarray(queries[0]), length, w,
        backend=backend,
    )
    bs, bd = _brute_valid(dirty, queries[0], length, w)
    assert int(res.quarantined) == n_bad
    assert int(res.best_start) == bs
    np.testing.assert_allclose(float(res.best_dist), bd, rtol=2e-5)

    multi = multi_query_search(
        jnp.asarray(dirty), jnp.asarray(queries), length, w, backend=backend
    )
    assert int(multi.quarantined) == n_bad
    for qi in range(queries.shape[0]):
        bs_q, bd_q = _brute_valid(dirty, queries[qi], length, w)
        assert int(multi.best_start[qi]) == bs_q
        np.testing.assert_allclose(float(multi.best_dist[qi]), bd_q, rtol=2e-5)


def test_quarantine_agrees_across_variants_and_drivers():
    """nolb / persistent / host all exclude the same windows."""
    ref, queries = _mk(seed=2)
    length, w = queries.shape[1], 5
    dirty = plant_nonfinite(ref, [(80, 6, np.nan)])
    host = multi_query_search(jnp.asarray(dirty), jnp.asarray(queries),
                              length, w)
    nolb = multi_query_search(jnp.asarray(dirty), jnp.asarray(queries),
                              length, w, variant="eapruned_nolb")
    pers = multi_query_search(jnp.asarray(dirty), jnp.asarray(queries),
                              length, w, rounds="persistent")
    for other in (nolb, pers):
        np.testing.assert_allclose(
            np.asarray(host.best_dist), np.asarray(other.best_dist), rtol=2e-5
        )
        assert np.array_equal(
            np.asarray(host.best_start), np.asarray(other.best_start)
        )


def test_quarantine_off_is_the_legacy_path():
    """quarantine=False on clean data is bit-identical to quarantine=True."""
    ref, queries = _mk(seed=3)
    length, w = queries.shape[1], 5
    on = subsequence_search(jnp.asarray(ref), jnp.asarray(queries[0]),
                            length, w)
    off = subsequence_search(jnp.asarray(ref), jnp.asarray(queries[0]),
                             length, w, quarantine=False)
    assert int(on.best_start) == int(off.best_start)
    assert float(on.best_dist) == float(off.best_dist)
    assert int(on.quarantined) == 0 and int(off.quarantined) == 0


# -- quarantine: streaming ------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_stream_quarantine_matches_offline(backend):
    ref, queries = _mk(seed=4)
    length, w = queries.shape[1], 5
    dirty = plant_nonfinite(ref, [(100, 4, np.nan), (250, 2, np.inf)])
    off = multi_query_search(
        jnp.asarray(dirty), jnp.asarray(queries), length, w, backend=backend
    )
    eng = StreamSearchEngine(
        jnp.asarray(queries), length=length, window=w, backend=backend,
        stream_chunk=96,
    )
    feed(eng, dirty, [77])
    bs, bd = eng.best()
    assert np.array_equal(np.asarray(bs), np.asarray(off.best_start))
    np.testing.assert_allclose(np.asarray(bd), np.asarray(off.best_dist),
                               rtol=2e-5)
    assert eng.quarantined_windows == int(off.quarantined)
    assert eng.quarantined_samples == 6


def test_all_nonfinite_stream_keeps_serving():
    """A fully poisoned stream yields no match, finite incumbents, and the
    engine still answers afterwards."""
    _, queries = _mk(seed=5, n_ref=10)
    length, w = queries.shape[1], 5
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                             stream_chunk=64)
    eng.ingest(np.full(150, np.nan))
    bs, bd = eng.best()
    assert np.all(np.asarray(bs) == -1)
    assert np.all(np.isfinite(np.asarray(bd)))  # BIG sentinel, never NaN
    assert eng.quarantined_windows == 150 - length + 1
    # a clean region arriving later is searched exactly (its own windows)
    rng = np.random.default_rng(6)
    clean = np.cumsum(rng.normal(size=200))
    eng.ingest(clean)
    bs2, bd2 = eng.best()
    assert np.all(np.asarray(bs2) >= 150)  # match lives in the clean region
    assert np.all(np.isfinite(np.asarray(bd2)))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 4))
def test_stream_fuzz_quarantine_parity(seed, chunking_idx):
    """Random NaN/Inf runs x adversarial chunkings: offline parity on the
    finite regions, quarantined counts agree with the oracle."""
    rng = np.random.default_rng(seed)
    n, length, w = 230, 32, 3
    ref = np.cumsum(rng.normal(size=n))
    n_bursts = int(rng.integers(0, 3))
    bursts = [
        (int(rng.integers(0, n - 8)), int(rng.integers(1, 8)),
         rng.choice([np.nan, np.inf, -np.inf]))
        for _ in range(n_bursts)
    ]
    dirty = plant_nonfinite(ref, bursts)
    queries = np.cumsum(rng.normal(size=(2, length)), axis=1)
    sizes = adversarial_chunkings(n, length)[chunking_idx]
    off = multi_query_search(jnp.asarray(dirty), jnp.asarray(queries),
                             length, w, backend="jax")
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                             backend="jax", stream_chunk=64)
    feed(eng, dirty, sizes)
    bs, bd = eng.best()
    np.testing.assert_allclose(np.asarray(bd), np.asarray(off.best_dist),
                               rtol=2e-5)
    assert eng.quarantined_windows == int(
        (~finite_window_mask_np(dirty, length)).sum()
    )


# -- satellite: zero-new-window ingest is a no-op -------------------------

def test_zero_window_ingest_noop():
    """ingest_chunk with tail+chunk < length extends the tail and returns
    unchanged incumbents with zero rounds/lanes (regression: used to
    assert)."""
    _, queries = _mk(seed=8, nq=2)
    length, w = queries.shape[1], 5
    qn = znorm(jnp.asarray(queries))
    u, low = jax.vmap(envelope, in_axes=(0, None))(qn, w)
    ub, best = initial_incumbents(2, qn.dtype)
    tail = jnp.asarray(np.ones(10))
    chunk = jnp.asarray(np.ones(5))
    new_tail, res = ingest_chunk(
        tail, chunk, qn, u, low, ub, best, 0, length=length, window=w
    )
    assert isinstance(res, IngestResult)
    assert new_tail.shape[0] == 15
    assert np.array_equal(np.asarray(res.ub), np.asarray(ub))
    assert np.array_equal(np.asarray(res.best), np.asarray(best))
    assert np.all(np.asarray(res.rounds) == 0)
    assert np.all(np.asarray(res.lanes) == 0)
    assert int(res.quarantined) == 0


def test_tiny_chunks_before_first_window():
    """An engine fed single samples below one window length stays a no-op
    and then finds the same result as offline."""
    ref, queries = _mk(seed=9, n_ref=200)
    length, w = queries.shape[1], 5
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w)
    for i in range(length - 1):
        eng.ingest(ref[i : i + 1])
    assert eng.rounds == 0 and eng.n_windows == 0
    eng.ingest(ref[length - 1 :])
    off = multi_query_search(jnp.asarray(ref), jnp.asarray(queries),
                             length, w)
    np.testing.assert_allclose(np.asarray(eng.best()[1]),
                               np.asarray(off.best_dist), rtol=2e-5)


# -- typed guards ---------------------------------------------------------

def test_guard_taxonomy_is_catchable_as_builtin():
    assert issubclass(SearchInputError, ValueError)
    assert issubclass(NonFiniteInputError, SearchInputError)
    assert issubclass(StreamStateError, RuntimeError)


def test_batch_entry_guards():
    q = jnp.asarray(np.random.default_rng(0).normal(size=32))
    cands = jnp.asarray(np.random.default_rng(1).normal(size=(4, 32)))
    with pytest.raises(SearchInputError):
        ea_pruned_dtw_batch(q, cands[:, :16], 10.0, window=3)  # length clash
    with pytest.raises(SearchInputError):
        ea_pruned_dtw_batch(q, cands[None], 10.0, window=3)  # ndim clash
    with pytest.raises(SearchInputError):
        ea_pruned_dtw_batch(q, cands, 10.0, window=-1)
    with pytest.raises(NonFiniteInputError):
        ea_pruned_dtw_batch(q.at[3].set(np.nan), cands, 10.0, window=3)
    with pytest.raises(NonFiniteInputError):
        ea_pruned_dtw_batch(q, cands, np.nan, window=3)
    with pytest.raises(SearchInputError):
        ea_pruned_dtw_multi_batch(q, cands[None], 10.0, window=3)  # 1-D qs
    with pytest.raises(SearchInputError):
        cb_bad = jnp.full((4, 16), 1.0)
        ea_pruned_dtw_batch(q, cands, 10.0, window=3, cb=cb_bad)
    with pytest.raises(SearchInputError):
        cb_neg = jnp.full((4, 32), -1.0)
        ea_pruned_dtw_batch(q, cands, 10.0, window=3, cb=cb_neg)


def test_search_entry_guards():
    ref, queries = _mk(seed=10, n_ref=120)
    length = queries.shape[1]
    with pytest.raises(SearchInputError):
        subsequence_search(jnp.asarray(ref), jnp.asarray(queries), length, 5)
    with pytest.raises(SearchInputError):  # integer dtype
        subsequence_search(jnp.arange(120), jnp.asarray(queries[0]),
                           length, 5)
    with pytest.raises(SearchInputError):  # ref shorter than one window
        subsequence_search(jnp.asarray(ref[: length - 1]),
                           jnp.asarray(queries[0]), length, 5)
    with pytest.raises(SearchInputError):  # window >= length
        subsequence_search(jnp.asarray(ref), jnp.asarray(queries[0]),
                           length, length)
    with pytest.raises(NonFiniteInputError):
        subsequence_search(jnp.asarray(ref),
                           jnp.asarray(queries[0]).at[0].set(np.inf),
                           length, 5)
    with pytest.raises(NonFiniteInputError):
        multi_query_search(jnp.asarray(ref),
                           jnp.asarray(queries).at[1, 3].set(np.nan),
                           length, 5)
    with pytest.raises(NonFiniteInputError):
        StreamSearchEngine(jnp.asarray(queries).at[0, 0].set(np.nan),
                           length=length, window=5)
    with pytest.raises(SearchInputError):
        StreamSearchEngine(jnp.asarray(queries), length=length, window=5,
                           batch=0)


def test_stream_state_errors_carry_context():
    _, queries = _mk(seed=11, nq=2)
    length, w = queries.shape[1], 5
    qn = znorm(jnp.asarray(queries))
    u, low = jax.vmap(envelope, in_axes=(0, None))(qn, w)
    ub, best = initial_incumbents(2, qn.dtype)
    big = jnp.asarray(np.ones(100))
    with pytest.raises(StreamStateError) as ei:
        ingest_chunk(jnp.zeros(0), big, qn, u, low, ub, best, 0,
                     length=length, window=w, pad_to=64, chunk_index=7)
    assert ei.value.chunk_index == 7
    assert "pad_to" in str(ei.value) and "chunk_index=7" in str(ei.value)
    overlong_tail = jnp.asarray(np.ones(length + 3))
    with pytest.raises(StreamStateError) as ei:
        ingest_chunk(overlong_tail, big[:40], qn, u, low, ub, best, 90,
                     length=length, window=w, pad_to=64)
    assert ei.value.n_seen == 90 + length + 3
    with pytest.raises(SearchInputError):  # dtype guard, before any jit
        ingest_chunk(jnp.zeros(0), jnp.arange(100), qn, u, low, ub, best, 0,
                     length=length, window=w)


# -- debug mode -----------------------------------------------------------

def test_checked_call_trips_on_device_nan():
    with pytest.raises(NonFiniteInputError):
        guards.checked_call(jax.jit(lambda x: x - x + jnp.log(x)),
                            jnp.asarray(-1.0))
    out = guards.checked_call(jax.jit(lambda x: x * 2), jnp.asarray(3.0))
    assert float(out) == 6.0


def test_debug_checks_clean_and_dirty_streams():
    """The incumbent tripwire stays silent on clean AND quarantined-dirty
    streams (the quarantine exists so it never needs to fire)."""
    ref, queries = _mk(seed=12, n_ref=220)
    length, w = queries.shape[1], 5
    dirty = plant_nonfinite(ref, [(60, 5, np.nan)])
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                             backend="jax", debug_checks=True,
                             stream_chunk=64)
    feed(eng, dirty, [70])
    assert np.all(np.isfinite(np.asarray(eng.best()[1])))
    assert eng.debug_checks


def test_debug_checks_env_var(monkeypatch):
    monkeypatch.setenv(guards.DEBUG_ENV_VAR, "1")
    assert guards.debug_checks_enabled(None)
    _, queries = _mk(seed=13)
    eng = StreamSearchEngine(jnp.asarray(queries), length=queries.shape[1],
                             window=5)
    assert eng.debug_checks
    monkeypatch.delenv(guards.DEBUG_ENV_VAR)
    assert not guards.debug_checks_enabled(None)


# -- checkpoint/restore ---------------------------------------------------

def _run_engine(dirty, queries, length, w, sizes, **kw):
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                             stream_chunk=64, **kw)
    feed(eng, dirty, sizes)
    return eng


def test_save_restore_roundtrip():
    ref, queries = _mk(seed=14, n_ref=300)
    length, w = queries.shape[1], 5
    dirty = plant_nonfinite(ref, [(90, 3, np.nan)])
    full = _run_engine(dirty, queries, length, w, [64], ring_capacity=40)

    # stop half-way, snapshot, restore into a FRESH engine, finish
    half = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                              stream_chunk=64, ring_capacity=40)
    feed(half, dirty[:128], [64])
    state = half.save_state()
    fresh = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                               stream_chunk=64, ring_capacity=40)
    fresh.restore_state(state)
    assert fresh.n_seen == half.n_seen
    feed(fresh, dirty[128:], [64])
    assert np.array_equal(np.asarray(fresh.best()[0]),
                          np.asarray(full.best()[0]))
    np.testing.assert_allclose(np.asarray(fresh.best()[1]),
                               np.asarray(full.best()[1]), rtol=0)
    assert fresh.quarantined_windows == full.quarantined_windows
    assert np.array_equal(fresh.recent(), full.recent())


def test_restore_rejects_mismatched_state():
    _, queries = _mk(seed=15)
    length, w = queries.shape[1], 5
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w)
    state = eng.save_state()
    with pytest.raises(StreamStateError):  # wrong query count
        StreamSearchEngine(jnp.asarray(queries[:1]), length=length,
                           window=w).restore_state(state)
    bad = dict(state)
    bad["tail"] = np.zeros(length + 5)
    with pytest.raises(StreamStateError):
        eng.restore_state(bad)
    missing = {k: v for k, v in state.items() if k != "ub"}
    with pytest.raises(StreamStateError):
        eng.restore_state(missing)
    with pytest.raises(StreamStateError):  # ring config disagreement
        StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                           ring_capacity=16).restore_state(state)


# -- supervisor -----------------------------------------------------------

def _chunks(series, size):
    return [series[p : p + size] for p in range(0, len(series), size)]


def test_supervisor_retries_transient_faults(tmp_path):
    """Faults on arrivals 2 and 5 (once each): same final result as the
    clean run, with restarts recorded and backoff sleeps taken."""
    ref, queries = _mk(seed=16, n_ref=300)
    length, w = queries.shape[1], 5
    dirty = plant_nonfinite(ref, [(120, 3, np.inf)])
    baseline = _run_engine(dirty, queries, length, w, [48])

    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                             stream_chunk=64)
    faulty = FaultyEngine(eng, fail_at={2, 5})
    sleeps = []
    sup = SearchSupervisor(faulty, str(tmp_path), ckpt_every=2,
                           backoff=0.01, sleep=sleeps.append)
    for c in _chunks(dirty, 48):
        sup.ingest(c)
    assert sup.restarts == 2
    assert sleeps == [0.01, 0.01]  # one first-attempt backoff per fault
    np.testing.assert_allclose(np.asarray(eng.best()[1]),
                               np.asarray(baseline.best()[1]), rtol=0)
    assert np.array_equal(np.asarray(eng.best()[0]),
                          np.asarray(baseline.best()[0]))
    assert sup.monitor.ewma is not None  # straggler stats observed


def test_supervisor_gives_up_after_max_retries(tmp_path):
    _, queries = _mk(seed=17)
    length, w = queries.shape[1], 5
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w)
    sup = SearchSupervisor(eng, str(tmp_path), max_retries=2, backoff=0.0,
                           sleep=lambda _t: None)

    def always_fail(_i):
        raise RuntimeError("hard down")

    with pytest.raises(RuntimeError, match="exceeded 2 retries"):
        sup.ingest(np.ones(100), fail_injector=always_fail)


def test_supervisor_reraises_caller_bugs(tmp_path):
    """StreamStateError is a bug, not a transient: no retry, no rollback."""
    _, queries = _mk(seed=18)
    length, w = queries.shape[1], 5
    eng = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                             stream_chunk=64)
    sup = SearchSupervisor(eng, str(tmp_path), max_retries=5,
                           sleep=lambda _t: None)
    eng._tail = jnp.zeros(length + 3)  # corrupt the carried state
    with pytest.raises(StreamStateError):
        sup.ingest(np.ones(100))
    assert sup.restarts == 0


def test_supervisor_kill_and_resume(tmp_path):
    """Kill after arrival 5, rebuild everything, resume(): identical final
    incumbents to the uninterrupted run."""
    ref, queries = _mk(seed=19, n_ref=300)
    length, w = queries.shape[1], 5
    dirty = plant_nonfinite(ref, [(150, 4, np.nan)])
    chunks = _chunks(dirty, 48)
    baseline = _run_engine(dirty, queries, length, w, [48])

    eng1 = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                              stream_chunk=64, ring_capacity=32)
    sup1 = SearchSupervisor(eng1, str(tmp_path), ckpt_every=2)
    for c in chunks[:5]:
        sup1.ingest(c)
    del eng1, sup1  # the process dies here

    eng2 = StreamSearchEngine(jnp.asarray(queries), length=length, window=w,
                              stream_chunk=64, ring_capacity=32)
    sup2 = SearchSupervisor(eng2, str(tmp_path), ckpt_every=2)
    k = sup2.resume()
    assert k == 4  # last checkpoint: ckpt_every boundary before the kill
    for c in chunks[k:]:
        sup2.ingest(c)
    np.testing.assert_allclose(np.asarray(eng2.best()[1]),
                               np.asarray(baseline.best()[1]), rtol=0)
    assert np.array_equal(np.asarray(eng2.best()[0]),
                          np.asarray(baseline.best()[0]))
    assert eng2.quarantined_windows == baseline.quarantined_windows
    assert eng2.n_seen == baseline.n_seen
