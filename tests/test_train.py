"""Training substrate: optimizers, schedules, loss-goes-down, checkpoints."""
import os
import shutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.data.lm import TokenStream
from repro.distributed.fault_tolerance import StragglerMonitor, TrainingSupervisor
from repro.models.registry import build
from repro.train import checkpoint as ckpt
from repro.train.optimizer import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
)
from repro.train.train_step import init_state, make_train_step


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(params, grads, state, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adafactor_minimizes_quadratic():
    params = {"w": jnp.ones((4, 6)) * 3.0}
    state = adafactor_init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state = adafactor_update(params, grads, state, lr=0.05)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((128, 256)), "b": jnp.zeros((7,))}
    st = adafactor_init(params)
    assert st.vr["w"].shape == (128,)
    assert st.vc["w"].shape == (256,)
    assert st.vr["b"].shape == (7,)
    # factored state is ~O(r+c), not O(r*c)
    n_state = sum(x.size for x in jax.tree.leaves((st.vr, st.vc)))
    assert n_state < params["w"].size // 50


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-6


def test_cosine_schedule_shape():
    warm = float(cosine_schedule(jnp.asarray(5), 1e-3, 10, 100))
    peak = float(cosine_schedule(jnp.asarray(10), 1e-3, 10, 100))
    end = float(cosine_schedule(jnp.asarray(100), 1e-3, 10, 100))
    assert warm < peak
    assert abs(peak - 1e-3) < 1e-9
    assert end < 1e-5


def test_loss_decreases_end_to_end():
    cfg = ARCHS["llama3.2-3b"].reduced()
    model = build(cfg)
    stream = TokenStream(cfg.vocab, 8, 32, seed=0)
    step = jax.jit(make_train_step(model, base_lr=3e-3, warmup=5, total_steps=40))
    state = init_state(model, jax.random.PRNGKey(0))
    losses = []
    for i in range(40):
        state, m = step(state, stream.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::8]


def test_microbatching_matches_full_batch():
    import dataclasses

    cfg = ARCHS["mistral-nemo-12b"].reduced()
    model1 = build(dataclasses.replace(cfg, num_microbatches=1))
    model4 = build(dataclasses.replace(cfg, num_microbatches=4))
    stream = TokenStream(cfg.vocab, 8, 16, seed=0)
    batch = stream.batch_at(0)
    s1 = init_state(model1, jax.random.PRNGKey(0))
    s4 = init_state(model4, jax.random.PRNGKey(0))
    _, m1 = make_train_step(model1)(s1, batch)
    _, m4 = make_train_step(model4)(s4, batch)
    # same params, same data: microbatched grads average to the same values
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-5
    assert abs(float(m1["grad_norm"]) - float(m4["grad_norm"])) < 1e-4


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.asarray([1, 2], jnp.int32)},
    }
    ckpt.save(str(tmp_path), tree, 7)
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 7
    assert np.array_equal(restored["a"], tree["a"])
    assert np.array_equal(restored["nested"]["b"], tree["nested"]["b"])


def test_checkpoint_prune_and_latest(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), tree, s)
    ckpt.prune_old(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    assert sorted(os.listdir(tmp_path)) == ["step_00000003", "step_00000004"]


def test_async_checkpointer(tmp_path):
    acp = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"x": jnp.arange(4.0)}
    acp.submit(tree, 5)
    acp.submit(tree, 10)
    acp.close()
    assert ckpt.latest_step(str(tmp_path)) == 10


def test_supervisor_restart_determinism(tmp_path):
    cfg = ARCHS["mistral-nemo-12b"].reduced()
    model = build(cfg)
    stream = TokenStream(cfg.vocab, 4, 16, seed=0)
    step_fn = jax.jit(make_train_step(model, warmup=2, total_steps=30))

    boom = {"armed": True}

    def injector(step):
        if step == 13 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected failure")

    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    sup = TrainingSupervisor(step_fn, stream.batch_at, d1, ckpt_every=5, async_ckpt=False)
    state = init_state(model, jax.random.PRNGKey(0))
    _, log = sup.run(state, 18, fail_injector=injector)
    assert sup.restarts == 1

    sup2 = TrainingSupervisor(step_fn, stream.batch_at, d2, ckpt_every=5, async_ckpt=False)
    state2 = init_state(model, jax.random.PRNGKey(0))
    _, log2 = sup2.run(state2, 18)
    assert abs(log[-1]["loss"] - log2[-1]["loss"]) < 1e-6


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=3.0)
    for _ in range(10):
        mon.observe(0, 1.0)
    assert mon.observe(10, 10.0) is True
    assert not mon.observe(11, 1.1)
    assert len(mon.flagged) == 1


def test_token_stream_deterministic_and_sharded():
    s1 = TokenStream(1000, 4, 16, seed=0)
    s2 = TokenStream(1000, 4, 16, seed=0)
    b1, b2 = s1.batch_at(7), s2.batch_at(7)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    sh0 = TokenStream(1000, 4, 16, seed=0, n_shards=2, shard=0).batch_at(3)
    sh1 = TokenStream(1000, 4, 16, seed=0, n_shards=2, shard=1).batch_at(3)
    assert not np.array_equal(sh0["tokens"], sh1["tokens"])


def test_int8_grad_compression_error_feedback():
    """Compressed training still converges; error feedback recycles noise."""
    import jax.numpy as jnp

    from repro.train.compression import ErrorFeedback, compress_grads, init_error_feedback

    # unit: quantize-dequantize + residual identity g = deq + res
    g = {"w": jnp.asarray([[0.1, -2.3], [5.0, 0.003]])}
    ef = init_error_feedback(g)
    deq, ef2 = compress_grads(g, ef)
    assert float(jnp.max(jnp.abs(deq["w"] + ef2.residual["w"] - g["w"]))) < 1e-6
    # residual feeds back: compressing zero grads flushes the residual
    deq2, ef3 = compress_grads({"w": jnp.zeros((2, 2))}, ef2)
    assert float(jnp.max(jnp.abs(deq2["w"] - ef2.residual["w"]))) < 1e-2

    # end-to-end: loss decreases with compression on
    cfg = ARCHS["llama3.2-3b"].reduced()
    model = build(cfg)
    stream = TokenStream(cfg.vocab, 8, 32, seed=0)
    step = jax.jit(make_train_step(model, base_lr=3e-3, warmup=5,
                                   total_steps=40, grad_compression="int8"))
    state = init_state(model, jax.random.PRNGKey(0), grad_compression="int8")
    losses = []
    for i in range(40):
        state, m = step(state, stream.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::8]
