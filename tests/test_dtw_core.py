"""Core DTW stack: paper-example values, oracle equivalence, properties."""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # Tiny deterministic fallback so the suite runs from a clean checkout
    # (hypothesis is in requirements-dev.txt but not baked into the image).
    # Same shape as the hypothesis API surface used below; examples are drawn
    # from a seeded rng, so runs are reproducible rather than adversarial.
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mimics `hypothesis.strategies as st`
        @staticmethod
        def floats(min_value, max_value, allow_nan=False):
            return _Strategy(lambda r: float(r.uniform(min_value, max_value)))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: int(r.integers(min_value, max_value + 1)))

        @staticmethod
        def lists(elem, min_size, max_size):
            return _Strategy(
                lambda r: [
                    elem.draw(r)
                    for _ in range(int(r.integers(min_size, max_size + 1)))
                ]
            )

    def settings(**_kw):
        return lambda f: f

    def given(*strategies):
        def deco(f):
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(25):
                    f(*(s.draw(rng) for s in strategies))

            # no functools.wraps: pytest would follow __wrapped__ to the
            # original signature and treat the strategy args as fixtures
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper

        return deco

import jax.numpy as jnp

from repro.core import (
    dtw,
    dtw_matrix,
    ea_pruned_dtw,
    ea_pruned_dtw_banded,
    ea_pruned_dtw_batch,
    envelope,
    lb_keogh_pair,
    lb_kim_fl,
    pruned_dtw,
    cascade_keogh_cumulative,
)
from repro.core.ea_pruned_dtw_np import (
    EATrace,
    dtw_naive,
    dtw_rows,
    ea_pruned_dtw as ea_np,
    pruned_dtw_usp,
    pruned_left,
)

S_PAPER = np.array([3, 1, 4, 4, 1, 1], dtype=float)
T_PAPER = np.array([1, 3, 2, 1, 2, 2], dtype=float)
EPS = 1e-9


class TestPaperExample:
    """Values and abandon behaviour from the paper's running example."""

    def test_dtw_value_is_9(self):
        assert dtw_naive(S_PAPER, T_PAPER) == 9.0
        assert dtw_rows(S_PAPER, T_PAPER) == 9.0
        assert float(dtw(S_PAPER, T_PAPER)) == 9.0

    def test_matrix_corner(self):
        m = dtw_matrix(S_PAPER, T_PAPER)
        assert float(m[-1, -1]) == 9.0
        assert float(m[1, 1]) == 4.0  # cost(3,1) = 4

    def test_no_abandon_at_ub9(self):
        # Fig 3a / 4a: ub = DTW = 9 -> completes, returns 9
        assert ea_np(S_PAPER, T_PAPER, 9.0) == 9.0
        assert float(ea_pruned_dtw(S_PAPER, T_PAPER, 9.0)) == 9.0

    def test_abandon_at_ub6_row5(self):
        # Fig 4b: EAPrunedDTW abandons at the blue cell in row 5
        tr = EATrace()
        assert ea_np(S_PAPER, T_PAPER, 6.0, trace=tr) == math.inf
        assert tr.abandoned_at_row == 5
        _, info = ea_pruned_dtw(S_PAPER, T_PAPER, 6.0, with_info=True)
        assert int(info.rows) == 5

    def test_pruned_left_matches(self):
        assert pruned_left(S_PAPER, T_PAPER, 9.0) == 9.0
        assert pruned_left(S_PAPER, T_PAPER, 6.0) == math.inf


@pytest.mark.parametrize("n,m", [(16, 16), (40, 33), (7, 25), (1, 9)])
def test_oracle_equivalence_random(n, m):
    rng = np.random.default_rng(n * 100 + m)
    for _ in range(10):
        s, t = rng.normal(size=n), rng.normal(size=m)
        li, co = (s, t) if n >= m else (t, s)
        d = dtw_naive(s, t)
        assert abs(float(dtw(li, co)) - d) < 1e-8
        for ub, exp in [(d * 0.5, math.inf), (d * (1 + EPS), d), (d * 1.5, d)]:
            got = float(ea_pruned_dtw(li, co, ub))
            ref = ea_np(li, co, ub)
            assert (got == exp == ref) or (abs(got - exp) < 1e-8 and abs(ref - exp) < 1e-8)
            gp = float(pruned_dtw(li, co, ub))
            rp = pruned_dtw_usp(li, co, ub)
            assert (gp == exp == rp) or (abs(gp - exp) < 1e-8 and abs(rp - exp) < 1e-8)


@pytest.mark.parametrize("n,w", [(32, 4), (32, 16), (48, 0), (64, 63)])
def test_windowed_and_banded(n, w):
    rng = np.random.default_rng(n * 7 + w)
    for _ in range(8):
        s, t = rng.normal(size=n), rng.normal(size=n)
        d = dtw_naive(s, t, window=w)
        cases = [(d * 0.5, math.inf), (d * (1 + EPS), d)] if math.isfinite(d) else [(1.0, math.inf)]
        for ub, exp in cases:
            full = float(ea_pruned_dtw(s, t, ub, window=w))
            band = float(
                ea_pruned_dtw_banded(s, t, ub, window=w, band_width=min(n, 2 * w + 1))
            )
            ref = ea_np(s, t, ub, window=w)
            for got in (full, band, ref):
                assert (got == exp) or abs(got - exp) < 1e-8, (got, exp, ub, w)


def test_cb_tightening_contract():
    rng = np.random.default_rng(3)
    n, w = 40, 5
    for _ in range(10):
        q, c = rng.normal(size=n), rng.normal(size=n)
        u, low = envelope(jnp.asarray(q), w)
        cb = np.asarray(cascade_keogh_cumulative(jnp.asarray(c), u, low))
        d = dtw_naive(q, c, window=w)
        for ub, exp in [(d * 0.5, math.inf), (d * (1 + EPS), d)]:
            gj = float(ea_pruned_dtw(q, c, ub, window=w, cb=jnp.asarray(cb)))
            gn = ea_np(q, c, ub, window=w, cb=cb)
            gb = float(ea_pruned_dtw_banded(q, c, ub, window=w, cb=jnp.asarray(cb)))
            for got in (gj, gn, gb):
                assert (got == exp) or abs(got - exp) < 1e-8


def test_batched_matches_single():
    rng = np.random.default_rng(4)
    n, w, k = 48, 6, 12
    q = rng.normal(size=n)
    cands = rng.normal(size=(k, n))
    ds = np.array([dtw_naive(q, c, window=w) for c in cands])
    ub = float(np.median(ds))
    out = np.asarray(
        ea_pruned_dtw_batch(jnp.asarray(q), jnp.asarray(cands), ub, window=w)
    )
    for i in range(k):
        if ds[i] <= ub * (1 - 1e-12):
            assert abs(out[i] - ds[i]) < 1e-8
        elif ds[i] > ub * (1 + 1e-12):
            assert math.isinf(out[i])


def test_multivariate_dtw():
    rng = np.random.default_rng(5)
    n, dims = 20, 3
    s, t = rng.normal(size=(n, dims)), rng.normal(size=(n, dims))
    m = np.full((n + 1, n + 1), np.inf)
    m[0, 0] = 0
    for i in range(1, n + 1):
        for j in range(1, n + 1):
            c = float(((s[i - 1] - t[j - 1]) ** 2).sum())
            m[i, j] = c + min(m[i - 1, j], m[i, j - 1], m[i - 1, j - 1])
    assert abs(float(dtw(s, t)) - m[n, n]) < 1e-8
    assert abs(float(ea_pruned_dtw(s, t, m[n, n] * (1 + EPS))) - m[n, n]) < 1e-8


# ------------------------- property-based tests ----------------------------

series = st.lists(
    st.floats(min_value=-10, max_value=10, allow_nan=False), min_size=2, max_size=24
)


@settings(max_examples=40, deadline=None)
@given(series)
def test_dtw_self_distance_zero(xs):
    s = np.asarray(xs)
    assert dtw_naive(s, s) == 0.0
    # EA with ub=0 must keep the tie (strictness: never abandon ties)
    assert ea_np(s, s, 0.0) == 0.0


@settings(max_examples=40, deadline=None)
@given(series, series)
def test_dtw_symmetry(xs, ys):
    s, t = np.asarray(xs), np.asarray(ys)
    assert abs(dtw_naive(s, t) - dtw_naive(t, s)) < 1e-9


@settings(max_examples=30, deadline=None)
@given(series, st.integers(min_value=0, max_value=30))
def test_window_monotonicity(xs, w):
    s = np.asarray(xs)
    rng = np.random.default_rng(len(xs))
    t = rng.normal(size=len(s))
    d_small = dtw_naive(s, t, window=w)
    d_big = dtw_naive(s, t, window=w + 3)
    assert d_big <= d_small + 1e-9  # wider window can only help


@settings(max_examples=30, deadline=None)
@given(series, series, st.floats(min_value=0.05, max_value=4.0))
def test_ea_contract(xs, ys, frac):
    """EA returns exact DTW below ub and +inf above (away from ties)."""
    s, t = np.asarray(xs), np.asarray(ys)
    d = dtw_naive(s, t)
    ub = d * frac
    got = ea_np(s, t, ub)
    if d < ub * (1 - 1e-12):
        assert abs(got - d) < 1e-9
    elif d > ub * (1 + 1e-12):
        assert got == math.inf


@settings(max_examples=30, deadline=None)
@given(series, st.integers(min_value=0, max_value=12))
def test_lb_validity(xs, w):
    s = np.asarray(xs)
    rng = np.random.default_rng(w + len(xs))
    t = rng.normal(size=len(s))
    d = dtw_naive(s, t, window=w)
    assert float(lb_keogh_pair(jnp.asarray(s), jnp.asarray(t), w)) <= d + 1e-6
    assert float(lb_kim_fl(jnp.asarray(s), jnp.asarray(t))) <= d + 1e-6


@settings(max_examples=30, deadline=None)
@given(series, st.integers(min_value=0, max_value=12))
def test_envelope_bounds(xs, w):
    q = jnp.asarray(np.asarray(xs))
    u, low = envelope(q, w)
    assert bool(jnp.all(u >= q)) and bool(jnp.all(low <= q))
    u2, l2 = envelope(q, w + 2)
    assert bool(jnp.all(u2 >= u)) and bool(jnp.all(l2 <= low))
