"""Persistent round driver: one launch per search, block-carried incumbent.

The contracts under test:

  * ``rounds="persistent"`` returns the same ``best_start`` as the host
    round driver and a ``best_dist`` equal up to the O(1)-ulp reformulation
    rounding documented in ``core.ea_pruned_dtw`` (mid-sweep incumbents
    differ between the two granularities, which can mask different
    *suboptimal* float paths inside the winner's DP) — on both the ``jax``
    and ``pallas_interpret`` backends, for all four search variants,
    including final candidate blocks padded past ``n_win``.
  * the multi-query persistent driver matches the multi host driver per
    query, including ``ub_init`` seeds (a hopeless seed returns -1 and the
    seed unchanged).
  * a planted near-exact match makes the sweep all-pruned after the first
    blocks: the persistent driver's ``lanes`` stay a small fraction of the
    window count while the result still matches.
  * the persistent primitive's on-device LB gating never runs a block whose
    bounds cannot beat the incumbent (``blocks == 0`` for a hopeless seed).
  * persistent mode is counter-free: combining with ``with_info`` raises.

Run in the forced ``REPRO_DTW_BACKEND=pallas_interpret`` pass of
``scripts/check.sh`` too, so the exact persistent kernel program is
exercised in the local gate.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.batch import ea_pruned_dtw_persistent
from repro.core.common import BIG
from repro.search import multi_query_search, subsequence_search
from repro.search.subsequence import ROUND_DRIVERS, VARIANTS

BACKENDS = ("jax", "pallas_interpret")

# f64 ulp-scale for the jax backend under x64, f32-scale for the kernel;
# one tolerance covers both (values are otherwise bit-identical per lane).
DIST_RTOL = 1e-6


def _mk(seed=3, n_ref=900, length=96):
    rng = np.random.default_rng(seed)
    ref = jnp.asarray(np.cumsum(rng.normal(size=n_ref)))
    q = jnp.asarray(np.cumsum(rng.normal(size=length)))
    return ref, q, length, 9


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("variant", VARIANTS)
def test_persistent_matches_host_all_variants(backend, variant):
    ref, q, length, w = _mk()
    host = subsequence_search(
        ref, q, length=length, window=w, batch=64, variant=variant,
        backend=backend,
    )
    pers = subsequence_search(
        ref, q, length=length, window=w, batch=64, variant=variant,
        backend=backend, rounds="persistent",
    )
    assert int(pers.best_start) == int(host.best_start)
    np.testing.assert_allclose(
        float(pers.best_dist), float(host.best_dist), rtol=DIST_RTOL
    )
    assert int(pers.rounds) == 1  # one dispatch by construction
    assert int(pers.lanes) > 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_persistent_padded_final_block(backend):
    """n_win chosen so the final block_k block is mostly padding lanes, and
    the true nearest neighbour planted INSIDE that ragged final block's
    window range — padding lanes must die without hiding it."""
    rng = np.random.default_rng(11)
    length, w = 64, 6
    n_ref = 64 + 13 * 7  # n_win = 92 = 11*8 + 4: ragged for block_k=8
    q_raw = np.cumsum(rng.normal(size=length))
    ref_np = np.cumsum(rng.normal(size=n_ref))
    plant = n_ref - length  # the very last window
    ref_np[plant : plant + length] = 2.0 * q_raw - 5.0  # z-norm identical
    ref = jnp.asarray(ref_np)
    q = jnp.asarray(q_raw)
    host = subsequence_search(
        ref, q, length=length, window=w, batch=32, backend=backend
    )
    pers = subsequence_search(
        ref, q, length=length, window=w, batch=32, backend=backend,
        rounds="persistent",
    )
    assert int(host.best_start) == plant
    assert int(pers.best_start) == plant
    np.testing.assert_allclose(
        float(pers.best_dist), float(host.best_dist), rtol=DIST_RTOL
    )


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("variant", ("eapruned", "eapruned_nolb"))
def test_persistent_multi_matches_host(backend, variant):
    rng = np.random.default_rng(7)
    ref = jnp.asarray(np.cumsum(rng.normal(size=900)))
    queries = jnp.asarray(np.cumsum(rng.normal(size=(4, 96)), axis=1))
    host = multi_query_search(
        ref, queries, length=96, window=9, batch=64, variant=variant,
        backend=backend,
    )
    pers = multi_query_search(
        ref, queries, length=96, window=9, batch=64, variant=variant,
        backend=backend, rounds="persistent",
    )
    assert np.array_equal(
        np.asarray(host.best_start), np.asarray(pers.best_start)
    )
    np.testing.assert_allclose(
        np.asarray(pers.best_dist, np.float64),
        np.asarray(host.best_dist, np.float64), rtol=DIST_RTOL,
    )
    assert np.all(np.asarray(pers.rounds) == 1)


@pytest.mark.parametrize("backend", BACKENDS)
def test_persistent_multi_ub_init_seeds(backend):
    """Per-query seeds: a hopeless seed is never beaten (best -1, seed
    returned); other queries match the host driver with the same seeds."""
    rng = np.random.default_rng(31)
    ref = jnp.asarray(np.cumsum(rng.normal(size=900)))
    queries = jnp.asarray(np.cumsum(rng.normal(size=(4, 96)), axis=1))
    seeds = np.full((4,), 1e30)
    seeds[1] = 1e-6
    host = multi_query_search(
        ref, queries, length=96, window=9, batch=64, backend=backend,
        ub_init=jnp.asarray(seeds),
    )
    pers = multi_query_search(
        ref, queries, length=96, window=9, batch=64, backend=backend,
        ub_init=jnp.asarray(seeds), rounds="persistent",
    )
    assert int(pers.best_start[1]) == -1
    assert float(pers.best_dist[1]) == pytest.approx(1e-6)
    assert int(pers.lanes[1]) == 0  # gated before a single block ran
    assert np.array_equal(
        np.asarray(host.best_start), np.asarray(pers.best_start)
    )
    np.testing.assert_allclose(
        np.asarray(pers.best_dist, np.float64),
        np.asarray(host.best_dist, np.float64), rtol=DIST_RTOL,
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_persistent_all_pruned_after_first_blocks(backend):
    """A planted exact match: the incumbent collapses in the first blocks
    and the on-device gate prunes the rest of the sweep — ``lanes`` stays a
    small fraction of the window count."""
    rng = np.random.default_rng(5)
    length, w = 96, 9
    q_raw = np.cumsum(rng.normal(size=length))
    ref_np = np.cumsum(rng.normal(size=1200))
    plant = 700
    ref_np[plant : plant + length] = 1.5 * q_raw + 2.0  # z-norm identical
    ref = jnp.asarray(ref_np)
    q = jnp.asarray(q_raw)
    host = subsequence_search(
        ref, q, length=length, window=w, batch=64, backend=backend
    )
    pers = subsequence_search(
        ref, q, length=length, window=w, batch=64, backend=backend,
        rounds="persistent",
    )
    n_win = 1200 - length + 1
    assert int(host.best_start) == plant
    assert int(pers.best_start) == plant
    np.testing.assert_allclose(
        float(pers.best_dist), float(host.best_dist), rtol=DIST_RTOL
    )
    # the LB cascade puts the planted window first; after it lands, the
    # carried incumbent gates (nearly) everything else on device
    assert int(pers.lanes) <= n_win // 4


@pytest.mark.parametrize("backend", BACKENDS)
def test_persistent_primitive_hopeless_seed_runs_zero_blocks(backend):
    """Direct primitive check: a seed below every lower bound never runs a
    block (the pl.when gate / loop exit), and returns the seed with -1."""
    rng = np.random.default_rng(13)
    n, k, w = 64, 24, 6
    from repro.search.znorm import znorm

    q = znorm(jnp.asarray(np.cumsum(rng.normal(size=n)), jnp.float32))
    c = znorm(
        jnp.asarray(np.cumsum(rng.normal(size=(k, n)), axis=1), jnp.float32)
    )
    lb = jnp.full((1, k), 10.0, jnp.float32)  # any positive bound works
    starts = jnp.arange(k, dtype=jnp.int32)[None]
    bd, bs, blocks = ea_pruned_dtw_persistent(
        q[None], c[None], lb, starts, jnp.full((1,), 1e-3), window=w,
        backend=backend, block_k=8, row_block=32,
    )
    assert int(blocks[0]) == 0
    assert int(bs[0]) == -1
    assert float(bd[0]) == pytest.approx(1e-3)


def test_persistent_rejects_with_info_and_bad_driver():
    ref, q, length, w = _mk()
    with pytest.raises(ValueError):
        subsequence_search(
            ref, q, length=length, window=w, rounds="persistent",
            with_info=True,
        )
    with pytest.raises(ValueError):
        subsequence_search(ref, q, length=length, window=w, rounds="turbo")
    with pytest.raises(ValueError):
        multi_query_search(
            ref, q[None], length=length, window=w, rounds="persistent",
            with_info=True,
        )
    assert set(ROUND_DRIVERS) == {"host", "persistent"}


def test_persistent_tuning_knobs_same_answer():
    """block_k / row_block / band_width change scheduling, not results."""
    ref, q, length, w = _mk(seed=17)
    base = subsequence_search(
        ref, q, length=length, window=w, backend="jax", rounds="persistent"
    )
    for kwargs in (
        dict(backend="jax", block_k=4),
        dict(backend="pallas_interpret", block_k=4, row_block=16),
        dict(backend="jax", band_width=length),
    ):
        got = subsequence_search(
            ref, q, length=length, window=w, rounds="persistent", **kwargs
        )
        assert int(got.best_start) == int(base.best_start)
        np.testing.assert_allclose(
            float(got.best_dist), float(base.best_dist), rtol=1e-5
        )


@pytest.mark.parametrize("backend", BACKENDS)
def test_persistent_warm_start_folds_into_seed(backend):
    """``warm_start`` + ``rounds="persistent"`` keeps the prepass result.

    The prepass full-DPs the best-LB candidates per query — with LB-ordered
    candidates that set usually contains the global winner, so the
    persistent sweep's seed equals the winner's exact distance and the
    kernel reports the seed unbeaten (start -1). The driver must fold the
    prepass-achieved (start, dist) back in rather than dropping it: the
    regression this pins returned the warm bound with no achieving start.
    """
    rng = np.random.default_rng(23)
    ref = jnp.asarray(np.cumsum(rng.normal(size=900)))
    queries = jnp.asarray(np.cumsum(rng.normal(size=(4, 96)), axis=1))
    base = multi_query_search(
        ref, queries, length=96, window=9, batch=64, backend=backend,
    )
    for ws in (8, 64):
        warm = multi_query_search(
            ref, queries, length=96, window=9, batch=64, backend=backend,
            rounds="persistent", warm_start=ws,
        )
        assert np.array_equal(
            np.asarray(base.best_start), np.asarray(warm.best_start)
        ), ws
        np.testing.assert_allclose(
            np.asarray(warm.best_dist, np.float64),
            np.asarray(base.best_dist, np.float64), rtol=DIST_RTOL,
        )
        # the prepass dispatch counts as one extra round
        assert np.all(np.asarray(warm.rounds) == 2)
