"""Golden parity: ONE scenario through all five search frontends.

The pipeline refactor's acceptance gate (DESIGN.md §2.8): every frontend —
``subsequence_search``, ``multi_query_search``, streaming ``ingest_chunk``,
the ``make_distributed_search`` / ``make_distributed_multi_search`` mesh
programs, and ``resilient_search`` under injected shard faults — is a thin
adapter over the same staged program (prepare → cascade → execute → fold),
so one fixed (series, queries, faults) scenario must come out with
*identical* per-query ``(best_start, best_dist)`` incumbents and identical
§2.6 quarantine counts from every one of them, on both the ``jax`` and
``pallas_interpret`` backends.

The scenario deliberately includes a non-finite sensor burst (so the
quarantine mask is live, not vacuous) and, for the resilient frontend, a
flaky range plus a dead shard (so the answer survives retry + reassignment,
not just the clean path). The seeded ``scripts/check.sh`` pass varies the
data draw via ``$REPRO_FAULT_SEED``.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from faults import ShardFaultInjector, fault_seed
from repro.search import (
    ingest_chunk,
    initial_incumbents,
    make_distributed_multi_search,
    make_distributed_search,
    multi_query_search,
    resilient_search,
    subsequence_search,
)
from repro.search.resilient import partition_ranges

BACKENDS = ("jax", "pallas_interpret")
LENGTH, WINDOW = 96, 9
N_REF, N_QUERIES = 1100, 3
DIST_RTOL = 2e-5


def _scenario():
    """The one fixed (series, queries) draw, with a quarantine-live burst."""
    rng = np.random.default_rng(1234 + fault_seed())
    ref = np.cumsum(rng.normal(size=N_REF))
    ref[300:304] = np.nan  # dropout burst -> LENGTH + 3 poisoned windows
    queries = np.cumsum(rng.normal(size=(N_QUERIES, LENGTH)), axis=1)
    return jnp.asarray(ref), jnp.asarray(queries)


def _golden(backend):
    """The multi-query host driver is the reference the others must match."""
    ref, queries = _scenario()
    res = multi_query_search(
        ref, queries, length=LENGTH, window=WINDOW, batch=64,
        backend=backend,
    )
    return (
        np.asarray(res.best_start, np.int64),
        np.asarray(res.best_dist, np.float64),
        int(res.quarantined),
    )


def _assert_matches(starts, dists, n_quar, backend):
    g_starts, g_dists, g_quar = _golden(backend)
    assert np.array_equal(np.asarray(starts, np.int64), g_starts)
    np.testing.assert_allclose(
        np.asarray(dists, np.float64), g_dists, rtol=DIST_RTOL
    )
    assert int(n_quar) == g_quar


def test_scenario_quarantine_is_live():
    """Guard the guard: the burst must actually condemn windows."""
    _, _, g_quar = _golden("jax")
    assert g_quar == LENGTH + 3


@pytest.mark.parametrize("backend", BACKENDS)
def test_parity_subsequence(backend):
    ref, queries = _scenario()
    starts, dists, quars = [], [], []
    for q in np.asarray(queries):
        res = subsequence_search(
            ref, jnp.asarray(q), length=LENGTH, window=WINDOW, batch=64,
            backend=backend,
        )
        starts.append(int(res.best_start))
        dists.append(float(res.best_dist))
        quars.append(int(res.quarantined))
    assert len(set(quars)) == 1  # query-independent window property
    _assert_matches(starts, dists, quars[0], backend)


@pytest.mark.parametrize("backend", BACKENDS)
def test_parity_multi_persistent(backend):
    ref, queries = _scenario()
    res = multi_query_search(
        ref, queries, length=LENGTH, window=WINDOW, batch=64,
        backend=backend, rounds="persistent",
    )
    _assert_matches(res.best_start, res.best_dist, res.quarantined, backend)


@pytest.mark.parametrize("backend", BACKENDS)
def test_parity_streaming(backend):
    """Mixed-size chunking (ragged final chunk included) of the same stream."""
    ref, queries = _scenario()
    from repro.core.lower_bounds import envelope
    from repro.search.znorm import znorm

    queries_n = znorm(queries)
    u, low = jax.vmap(envelope, in_axes=(0, None))(queries_n, WINDOW)
    ub, best = initial_incumbents(N_QUERIES, ref.dtype)
    tail = jnp.zeros((0,), ref.dtype)
    offset = 0
    quarantined = 0
    pos = 0
    for size in (137, 400, 263, N_REF):  # last slice is the ragged remainder
        chunk = ref[pos : pos + size]
        if chunk.shape[0] == 0:
            break
        tail, res = ingest_chunk(
            tail, chunk, queries_n, u, low, ub, best, offset,
            length=LENGTH, window=WINDOW, batch=64, backend=backend,
        )
        ub, best = res.ub, res.best
        quarantined += int(res.quarantined)
        pos += int(chunk.shape[0])
        offset = pos - int(tail.shape[0])
    _assert_matches(best, ub, quarantined, backend)


@pytest.mark.parametrize("backend", BACKENDS)
def test_parity_distributed(backend):
    """Both mesh frontends on a 1-device mesh (the SPMD program itself)."""
    mesh = jax.make_mesh((1,), ("d",))
    multi_fn = make_distributed_multi_search(
        mesh, ("d",), length=LENGTH, window=WINDOW, batch=64,
        backend=backend,
    )
    ref, queries = _scenario()
    res = multi_fn(ref, queries)
    _assert_matches(res.best_start, res.best_dist, res.quarantined, backend)

    scalar_fn = make_distributed_search(
        mesh, ("d",), length=LENGTH, window=WINDOW, batch=64,
        backend=backend,
    )
    g_starts, g_dists, g_quar = _golden(backend)
    for q in range(N_QUERIES):
        one = scalar_fn(ref, queries[q])
        assert int(one.best_start) == g_starts[q]
        np.testing.assert_allclose(
            float(one.best_dist), g_dists[q], rtol=DIST_RTOL
        )
        assert int(one.quarantined) == g_quar


@pytest.mark.parametrize("backend", BACKENDS)
def test_parity_resilient_under_faults(backend):
    """Retry + reassignment must not change the answer or the accounting."""
    ref, queries = _scenario()
    n_win = N_REF - LENGTH + 1

    def runner(shard, lo, hi, ub):
        seg = ref[lo : hi + LENGTH - 1]
        res = multi_query_search(
            seg, queries, length=LENGTH, window=WINDOW, batch=64,
            backend=backend, ub_init=jnp.asarray(ub, queries.dtype),
        )
        s = np.asarray(res.best_start, np.int64)
        return (
            np.where(s >= 0, s + lo, -1),
            np.asarray(res.best_dist, np.float64),
            int(res.quarantined),
        )

    flaky_lo = partition_ranges(n_win, 4)[2][0]
    inj = ShardFaultInjector(runner, dead_shards={1}, flaky_ranges={flaky_lo})
    res = resilient_search(
        ref, queries, LENGTH, WINDOW, n_shards=4, runner=inj,
        backoff=0.0, sleep=lambda _dt: None,
    )
    assert res.coverage == 1.0
    assert res.failed_shards == (1,)
    _assert_matches(res.best_start, res.best_dist, res.quarantined, backend)
