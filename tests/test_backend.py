"""Backend dispatch + Pallas interpret-mode parity with the banded JAX path.

The contract under test: for any (window, cb, ub) setting, the Pallas kernel
(`dtw_ea`, interpret mode on CPU) and the banded-vmap JAX path make identical
abandon decisions, identical surviving values (to float32), and identical
rows/cells pruning counters — including ragged shapes where K is not a
multiple of ``block_k`` and n is not a multiple of ``row_block``.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.backend import BACKENDS, resolve_backend
from repro.core.batch import ea_pruned_dtw_batch
from repro.core.ea_pruned_dtw import ea_pruned_dtw_banded
from repro.core.lower_bounds import _lb_keogh_terms, envelope
from repro.kernels.ops import dtw_ea
from repro.search import subsequence_search
from repro.search.znorm import znorm


def _mk(n, k, seed):
    rng = np.random.default_rng(seed)
    q = znorm(jnp.asarray(np.cumsum(rng.normal(size=n)), jnp.float32))
    c = znorm(jnp.asarray(np.cumsum(rng.normal(size=(k, n)), axis=1), jnp.float32))
    return q, c


def _banded_ref(q, c, ub, w, cb=None, band_width=None):
    if cb is None:
        fn = lambda cc: ea_pruned_dtw_banded(
            q, cc, ub, window=w, band_width=band_width, with_info=True
        )
        return jax.vmap(fn)(c)
    fn = lambda cc, cbv: ea_pruned_dtw_banded(
        q, cc, ub, window=w, band_width=band_width, with_info=True, cb=cbv
    )
    return jax.vmap(fn)(c, cb)


def _assert_kernel_matches_banded(q, c, ub, w, cb=None, block_k=8, row_block=32):
    got, rows, cells = dtw_ea(
        q, c, ub, window=w, cb=cb, block_k=block_k, row_block=row_block,
        interpret=True, with_info=True,
    )
    ref, info = _banded_ref(q, c, ub, w, cb=cb)
    got, ref = np.asarray(got), np.asarray(ref)
    assert np.array_equal(np.isfinite(got), np.isfinite(ref)), (got, ref)
    fin = np.isfinite(ref)
    np.testing.assert_allclose(got[fin], ref[fin], rtol=1e-5)
    assert np.array_equal(np.asarray(rows), np.asarray(info.rows))
    assert np.array_equal(np.asarray(cells), np.asarray(info.cells))


@pytest.mark.parametrize(
    "n,k,w,block_k,row_block",
    [
        (96, 16, 10, 8, 32),    # windowed, aligned
        (100, 13, 7, 8, 32),    # K % block_k != 0 and n % row_block != 0
        (70, 9, 5, 4, 16),      # both ragged, small blocks
        (64, 8, 63, 8, 32),     # window ~ whole matrix -> full-width band
    ],
)
def test_kernel_banded_parity_windowed(n, k, w, block_k, row_block):
    q, c = _mk(n, k, seed=n * 3 + k)
    from repro.kernels.ref import dtw_exact_ref

    exact = np.asarray(dtw_exact_ref(q, c, w))
    for ub in (np.median(exact), exact.max() * 1.01):
        _assert_kernel_matches_banded(
            q, c, float(ub), w, block_k=block_k, row_block=row_block
        )


def test_kernel_banded_parity_cb_tightened():
    n, k, w = 96, 20, 10
    q, c = _mk(n, k, seed=17)
    u, low = envelope(q, w)
    terms = _lb_keogh_terms(c, u, low)
    cb = jnp.flip(jnp.cumsum(jnp.flip(terms, -1), -1), -1)
    from repro.kernels.ref import dtw_exact_ref

    exact = np.asarray(dtw_exact_ref(q, c, w))
    _assert_kernel_matches_banded(q, c, float(np.median(exact)), w, cb=cb)


def test_kernel_banded_parity_abandon_heavy():
    """A hopeless ub: every lane must abandon, and early (few rows issued)."""
    n, k, w = 128, 24, 12
    q, c = _mk(n, k, seed=23)
    got, rows, cells = dtw_ea(
        q, c, 1e-3, window=w, block_k=8, row_block=32, interpret=True,
        with_info=True,
    )
    ref, info = _banded_ref(q, c, 1e-3, w)
    assert not np.any(np.isfinite(np.asarray(got)))
    assert not np.any(np.isfinite(np.asarray(ref)))
    assert np.array_equal(np.asarray(rows), np.asarray(info.rows))
    assert np.array_equal(np.asarray(cells), np.asarray(info.cells))
    # early abandon means far fewer rows than the full DP
    assert int(np.asarray(rows).sum()) < k * n // 4


def test_batch_dispatch_backends_agree():
    n, k, w = 96, 20, 10
    q, c = _mk(n, k, seed=5)
    ub = 30.0
    d_jax = np.asarray(ea_pruned_dtw_batch(q, c, ub, window=w, backend="jax"))
    d_pal = np.asarray(
        ea_pruned_dtw_batch(q, c, ub, window=w, backend="pallas_interpret")
    )
    assert np.array_equal(np.isfinite(d_jax), np.isfinite(d_pal))
    fin = np.isfinite(d_jax)
    np.testing.assert_allclose(d_pal[fin], d_jax[fin], rtol=1e-5)


def test_resolve_backend_rules():
    assert resolve_backend("jax") == "jax"
    assert resolve_backend("pallas") == "pallas"
    assert resolve_backend("pallas_interpret") == "pallas_interpret"
    assert resolve_backend("auto") in ("pallas", "jax")
    with pytest.raises(ValueError):
        resolve_backend("mosaic")
    for b in ("jax", "pallas"):
        assert b in BACKENDS


def test_env_var_override_subprocess():
    """REPRO_DTW_BACKEND forces the backend when no argument is given."""
    code = r"""
import sys; sys.path.insert(0, "src")
from repro.core.backend import resolve_backend
print("RESOLVED", resolve_backend())
"""
    env = dict(os.environ, REPRO_DTW_BACKEND="pallas_interpret")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=120, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RESOLVED pallas_interpret" in out.stdout


@pytest.fixture(scope="module")
def search_problem():
    rng = np.random.default_rng(3)
    ref = jnp.asarray(np.cumsum(rng.normal(size=900)))
    q = jnp.asarray(np.cumsum(rng.normal(size=96)))
    return ref, q, 96, 9


def test_search_pallas_backend_matches_jax(search_problem):
    """subsequence_search end-to-end through the Pallas (interpret) backend
    finds the same neighbour as the JAX-vmap backend on the tier-1 fixture."""
    ref, q, length, w = search_problem
    r_jax = subsequence_search(
        ref, q, length=length, window=w, batch=64, backend="jax"
    )
    r_pal = subsequence_search(
        ref, q, length=length, window=w, batch=64, backend="pallas_interpret"
    )
    assert int(r_pal.best_start) == int(r_jax.best_start)
    np.testing.assert_allclose(
        float(r_pal.best_dist), float(r_jax.best_dist), rtol=1e-5
    )


def test_search_stats_round_counters_match(search_problem):
    """Stats rounds agree across backends; fast rounds leave counters at -1."""
    ref, q, length, w = search_problem
    fast = subsequence_search(ref, q, length=length, window=w, batch=64)
    assert int(fast.rows) == -1 and int(fast.cells) == -1
    s_jax = subsequence_search(
        ref, q, length=length, window=w, batch=64, backend="jax",
        with_info=True,
    )
    s_pal = subsequence_search(
        ref, q, length=length, window=w, batch=64, backend="pallas_interpret",
        with_info=True,
    )
    assert int(s_jax.rows) > 0 and int(s_jax.cells) > 0
    assert int(s_pal.rows) == int(s_jax.rows)
    assert int(s_pal.cells) == int(s_jax.cells)
    # fast and stats rounds must agree on the search result itself
    assert int(fast.best_start) == int(s_jax.best_start)


def test_search_tuning_knobs_same_answer(search_problem):
    """rows_per_step / block_k / row_block change scheduling, not results."""
    ref, q, length, w = search_problem
    base = subsequence_search(ref, q, length=length, window=w, batch=64)
    tuned_jax = subsequence_search(
        ref, q, length=length, window=w, batch=64, backend="jax",
        rows_per_step=4,
    )
    tuned_pal = subsequence_search(
        ref, q, length=length, window=w, batch=64, backend="pallas_interpret",
        block_k=4, row_block=16,
    )
    assert int(tuned_jax.best_start) == int(base.best_start)
    assert int(tuned_pal.best_start) == int(base.best_start)
    np.testing.assert_allclose(
        float(tuned_jax.best_dist), float(base.best_dist), rtol=1e-6
    )
    np.testing.assert_allclose(
        float(tuned_pal.best_dist), float(base.best_dist), rtol=1e-5
    )
