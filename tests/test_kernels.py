"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.lower_bounds import _lb_keogh_terms, envelope
from repro.kernels.ops import dtw_ea, lb_keogh_all_windows
from repro.kernels.ref import dtw_ea_ref, dtw_exact_ref, lb_all_windows_ref
from repro.search.znorm import window_stats, znorm


def _mk(n, k, seed):
    rng = np.random.default_rng(seed)
    q = znorm(jnp.asarray(rng.normal(size=n), jnp.float32))
    c = znorm(jnp.asarray(rng.normal(size=(k, n)), jnp.float32))
    return q, c


@pytest.mark.parametrize(
    "n,k,w,block_k,row_block",
    [
        (64, 8, 8, 8, 32),
        (96, 20, 10, 8, 32),   # k not divisible by block_k -> padding
        (128, 16, 16, 4, 128),
        (50, 5, 6, 8, 16),     # n not divisible by row_block
        (32, 8, 40, 8, 32),    # window wider than series -> full DTW
    ],
)
def test_dtw_ea_kernel_sweep(n, k, w, block_k, row_block):
    q, c = _mk(n, k, seed=n + k)
    exact = np.asarray(dtw_exact_ref(q, c, w))
    for ub in (np.median(exact), exact.max() * 1.01, exact.min() * 0.9):
        got = np.asarray(
            dtw_ea(q, c, float(ub), window=w, block_k=block_k, row_block=row_block)
        )
        ref = np.asarray(dtw_ea_ref(q, c, float(ub), window=w))
        assert np.array_equal(np.isfinite(got), np.isfinite(ref)), (got, ref)
        fin = np.isfinite(ref)
        np.testing.assert_allclose(got[fin], ref[fin], rtol=1e-5)


def test_dtw_ea_kernel_cb():
    n, k, w = 96, 16, 10
    q, c = _mk(n, k, seed=7)
    u, low = envelope(q, w)
    terms = _lb_keogh_terms(c, u, low)
    cb = jnp.flip(jnp.cumsum(jnp.flip(terms, -1), -1), -1)
    exact = np.asarray(dtw_exact_ref(q, c, w))
    ub = float(np.median(exact))
    got = np.asarray(dtw_ea(q, c, ub, window=w, cb=cb, block_k=8, row_block=32))
    ref = np.asarray(dtw_ea_ref(q, c, ub, window=w, cb=cb))
    assert np.array_equal(np.isfinite(got), np.isfinite(ref))
    fin = np.isfinite(ref)
    np.testing.assert_allclose(got[fin], ref[fin], rtol=1e-5)


def test_dtw_ea_kernel_value_vs_exact():
    """Survivors must equal exact DTW, not merely match the ref impl."""
    n, k, w = 64, 12, 8
    q, c = _mk(n, k, seed=11)
    exact = np.asarray(dtw_exact_ref(q, c, w))
    got = np.asarray(dtw_ea(q, c, float(exact.max() * 1.01), window=w))
    np.testing.assert_allclose(got, exact, rtol=1e-5)


@pytest.mark.parametrize("n_ref,length,w,chunk", [
    (1500, 64, 8, 256),
    (777, 32, 4, 128),    # ragged: windows not divisible by chunk
    (2048, 128, 12, 512),
])
def test_lb_kernel_sweep(n_ref, length, w, chunk):
    rng = np.random.default_rng(n_ref)
    ref = jnp.asarray(np.cumsum(rng.normal(size=n_ref)), jnp.float32)
    q = znorm(jnp.asarray(np.cumsum(rng.normal(size=length)), jnp.float32))
    mu, sg = window_stats(ref, length)
    u, low = envelope(q, w)
    qe = jnp.asarray([q[0], q[-1]], jnp.float32)
    got = np.asarray(
        lb_keogh_all_windows(ref, mu, sg, u, low, qe, length=length, chunk=chunk)
    )
    want = np.asarray(lb_all_windows_ref(ref, q, mu, sg, length, w))
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=1e-4)


def test_lb_kernel_is_lower_bound():
    from repro.core.ea_pruned_dtw_np import dtw_naive

    rng = np.random.default_rng(9)
    n_ref, length, w = 600, 48, 6
    ref = jnp.asarray(np.cumsum(rng.normal(size=n_ref)), jnp.float32)
    q = znorm(jnp.asarray(np.cumsum(rng.normal(size=length)), jnp.float32))
    mu, sg = window_stats(ref, length)
    u, low = envelope(q, w)
    qe = jnp.asarray([q[0], q[-1]], jnp.float32)
    lbs = np.asarray(lb_keogh_all_windows(ref, mu, sg, u, low, qe, length=length))
    qn = np.asarray(q)
    for s in range(0, n_ref - length + 1, 37):
        wnd = np.asarray(ref[s : s + length])
        c = (wnd - wnd.mean()) / max(wnd.std(), 1e-8)
        d = dtw_naive(qn, c, window=w)
        assert lbs[s] <= d + 1e-3, (s, lbs[s], d)
