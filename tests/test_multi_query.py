"""Multi-query search parity + per-lane-ub semantics across backends.

The contracts under test:

  * ``multi_query_search`` over Q queries returns the same ``best_start`` /
    ``best_dist`` per query as Q independent ``subsequence_search`` calls,
    on both the ``jax`` and ``pallas_interpret`` backends.
  * the per-lane-``ub`` batch primitive agrees with the float64 single-query
    reference (``ea_pruned_dtw_banded`` per lane, each lane with its own
    ``ub``) on every (query, candidate) lane — abandon decisions and
    surviving values.
  * ragged per-query ``ub`` trajectories: negative sentinels kill lanes on
    row 0, per-query seeds (``ub_init``) drive different abandon patterns,
    and a hopeless seed makes its query abandon in round 0.
  * ``$REPRO_DTW_BACKEND`` is re-read on every search call (the un-jitted
    wrapper resolves it into the static backend argument, so changing the
    env var between calls retraces).
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.batch import ea_pruned_dtw_multi_batch
from repro.core.ea_pruned_dtw import ea_pruned_dtw_banded
from repro.search import multi_query_search, subsequence_search
from repro.search.znorm import znorm

BACKENDS = ("jax", "pallas_interpret")


def _mk_problem(seed=3, n_ref=900, nq=4, length=96):
    rng = np.random.default_rng(seed)
    ref = jnp.asarray(np.cumsum(rng.normal(size=n_ref)))
    queries = jnp.asarray(np.cumsum(rng.normal(size=(nq, length)), axis=1))
    return ref, queries


def _mk_lanes(nq, k, n, seed=0):
    rng = np.random.default_rng(seed)
    qs = znorm(jnp.asarray(np.cumsum(rng.normal(size=(nq, n)), axis=1), jnp.float32))
    cs = znorm(
        jnp.asarray(np.cumsum(rng.normal(size=(nq, k, n)), axis=2), jnp.float32)
    )
    return qs, cs


@pytest.mark.parametrize("backend", BACKENDS)
def test_multi_query_matches_sequential(backend):
    """Q-query search == Q independent single-query searches, per query."""
    ref, queries = _mk_problem()
    length, w = queries.shape[1], 9
    res = multi_query_search(
        ref, queries, length=length, window=w, batch=64, backend=backend
    )
    for q in range(queries.shape[0]):
        one = subsequence_search(
            ref, queries[q], length=length, window=w, batch=64, backend=backend
        )
        assert int(res.best_start[q]) == int(one.best_start), (backend, q)
        np.testing.assert_allclose(
            float(res.best_dist[q]), float(one.best_dist), rtol=2e-5
        )


def test_multi_query_backends_agree_with_info():
    """jax and pallas_interpret agree on results AND pruning counters."""
    ref, queries = _mk_problem(seed=11)
    length, w = queries.shape[1], 9
    res = {
        b: multi_query_search(
            ref, queries, length=length, window=w, batch=32, backend=b,
            with_info=True,
        )
        for b in BACKENDS
    }
    a, b = res["jax"], res["pallas_interpret"]
    assert np.array_equal(np.asarray(a.best_start), np.asarray(b.best_start))
    np.testing.assert_allclose(
        np.asarray(a.best_dist), np.asarray(b.best_dist), rtol=1e-5
    )
    assert np.array_equal(np.asarray(a.rows), np.asarray(b.rows))
    assert np.array_equal(np.asarray(a.cells), np.asarray(b.cells))
    assert int(np.asarray(a.rows).min()) > 0


@pytest.mark.parametrize("nq,k,n,w", [(3, 13, 96, 9), (2, 8, 70, 5)])
def test_per_lane_ub_parity_float64_reference(nq, k, n, w):
    """Every (query, candidate) lane agrees with the float64 single-query
    reference run at that lane's own ub — abandon decisions and values."""
    qs, cs = _mk_lanes(nq, k, n, seed=nq * 7 + k)
    rng = np.random.default_rng(1)
    ub = jnp.asarray(rng.uniform(2.0, 80.0, size=(nq, k)), jnp.float32)

    outs = {
        b: np.asarray(
            ea_pruned_dtw_multi_batch(qs, cs, ub, window=w, backend=b)
        )
        for b in BACKENDS
    }
    # float64 single-query reference, one lane at a time
    ref = np.full((nq, k), np.inf)
    for q in range(nq):
        for j in range(k):
            ref[q, j] = float(
                ea_pruned_dtw_banded(
                    jnp.asarray(qs[q], jnp.float64),
                    jnp.asarray(cs[q, j], jnp.float64),
                    float(ub[q, j]),
                    window=w,
                )
            )
    for b, got in outs.items():
        assert np.array_equal(np.isfinite(got), np.isfinite(ref)), b
        fin = np.isfinite(ref)
        np.testing.assert_allclose(got[fin], ref[fin], rtol=1e-4, err_msg=b)


@pytest.mark.parametrize("backend", BACKENDS)
def test_ragged_ub_trajectories(backend):
    """Per-lane ub raggedness: sentinels, tight and loose lanes coexist."""
    nq, k, n, w = 3, 12, 96, 9
    qs, cs = _mk_lanes(nq, k, n, seed=5)
    # lane-dependent ubs: a dead-sentinel lane, a hopeless-tight lane, and a
    # sure-finish lane in the same block
    ub = np.full((nq, k), 50.0, np.float32)
    ub[0, 0] = -1.0    # dead sentinel: must be +inf without work
    ub[1, 2] = 1e-4    # tight: abandons
    ub[2, 5] = 1e6     # loose: must finish
    d = np.asarray(
        ea_pruned_dtw_multi_batch(
            qs, cs, jnp.asarray(ub), window=w, backend=backend
        )
    )
    assert not np.isfinite(d[0, 0])
    assert not np.isfinite(d[1, 2])
    assert np.isfinite(d[2, 5])


@pytest.mark.parametrize("backend", BACKENDS)
def test_query_abandons_in_round_zero(backend):
    """A hopeless ub_init seed: the query drops out of the round loop at
    round 0 with no neighbour, while its siblings search normally."""
    ref, queries = _mk_problem(seed=7)
    length, w = queries.shape[1], 9
    nq = queries.shape[0]
    seeds = np.full((nq,), 1e30, np.float32)
    seeds[1] = 1e-6
    res = multi_query_search(
        ref, queries, length=length, window=w, batch=64, backend=backend,
        ub_init=jnp.asarray(seeds),
    )
    assert int(res.best_start[1]) == -1
    assert int(res.rounds[1]) == 0
    assert float(res.best_dist[1]) == pytest.approx(1e-6)
    # the other queries are unaffected by their sibling's dead lanes
    base = multi_query_search(
        ref, queries, length=length, window=w, batch=64, backend=backend
    )
    for q in (0, 2, 3):
        assert int(res.best_start[q]) == int(base.best_start[q])


def test_warm_start_changes_work_not_results():
    ref, queries = _mk_problem(seed=13)
    length, w = queries.shape[1], 9
    base = multi_query_search(
        ref, queries, length=length, window=w, batch=64, backend="jax",
        warm_start=0,
    )
    warm = multi_query_search(
        ref, queries, length=length, window=w, batch=64, backend="jax",
        warm_start=16,
    )
    assert np.array_equal(
        np.asarray(base.best_start), np.asarray(warm.best_start)
    )
    np.testing.assert_allclose(
        np.asarray(base.best_dist), np.asarray(warm.best_dist), rtol=2e-5
    )
    # warm incumbents can only shrink the round loop
    assert int(np.asarray(warm.rounds).sum()) <= int(np.asarray(base.rounds).sum())


def test_env_var_reread_between_calls(monkeypatch):
    """REPRO_DTW_BACKEND is resolved per call in the un-jitted wrapper: the
    backend reaching the jitted search flips when the env var flips."""
    import repro.search.pipeline as pipeline

    seen = []
    # the default gather="fused" rounds go through the fused batch primitive
    real = pipeline.ea_pruned_dtw_multi_batch_fused

    def recorder(*args, **kwargs):
        seen.append(kwargs.get("backend"))
        return real(*args, **kwargs)

    monkeypatch.setattr(pipeline, "ea_pruned_dtw_multi_batch_fused", recorder)
    rng = np.random.default_rng(17)
    # unique shape so each backend traces fresh through the recorder
    ref = jnp.asarray(np.cumsum(rng.normal(size=777)))
    q = jnp.asarray(np.cumsum(rng.normal(size=80)))

    monkeypatch.setenv("REPRO_DTW_BACKEND", "jax")
    r1 = subsequence_search(ref, q, length=80, window=8, batch=32)
    monkeypatch.setenv("REPRO_DTW_BACKEND", "pallas_interpret")
    r2 = subsequence_search(ref, q, length=80, window=8, batch=32)

    assert "jax" in seen and "pallas_interpret" in seen, seen
    assert int(r1.best_start) == int(r2.best_start)


def test_distributed_multi_query_parity():
    """Sharded (query, candidate-range) search with vectorized pmin
    reconciliation matches the single-device answers (8 fake devices)."""
    import subprocess
    import sys

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.search import make_distributed_multi_search, subsequence_search
rng = np.random.default_rng(7)
ref = jnp.asarray(np.cumsum(rng.normal(size=1100)), jnp.float32)
queries = jnp.asarray(np.cumsum(rng.normal(size=(3, 96)), axis=1), jnp.float32)
mesh = jax.make_mesh((8,), ("d",))
fn = make_distributed_multi_search(mesh, ("d",), length=96, window=9, batch=32, backend="jax")
res = fn(ref, queries)
for q in range(3):
    one = subsequence_search(ref, queries[q], length=96, window=9, batch=32, backend="jax")
    assert int(res.best_start[q]) == int(one.best_start), (q, res.best_start[q], one.best_start)
    np.testing.assert_allclose(float(res.best_dist[q]), float(one.best_dist), rtol=1e-4)
print("DIST MULTI OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=420,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DIST MULTI OK" in out.stdout
