import os
import sys

# Make src/ importable without installation.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

# DTW decision-equivalence tests compare against float64 NumPy oracles;
# model code pins its own dtypes explicitly, so this only affects the
# default dtype of Python-float conversions in tests.
jax.config.update("jax_enable_x64", True)
