"""Fused in-kernel gather + z-normalization parity (DESIGN.md §2.10).

The tentpole's acceptance gate: retiring the O(K·l) candidate slab must not
move a single result. ``gather="fused"`` (candidates sliced + normalized
from the resident reference inside the DTW stage) and ``gather="slab"``
(the pre-gathered baseline) must produce identical ``(best_start,
best_dist)`` incumbents and identical §2.6 quarantine counts, on both the
``jax`` and ``pallas_interpret`` backends, across the awkward cases:
ragged final candidate blocks, flat (sigma == 0) windows, quarantined
lanes, and warm-started incumbents.

Also pinned here:
  * the slab-budget regression — a persistent sweep completes under a
    ``slab_budget`` that the O(K·l) slab form cannot satisfy (it raises at
    trace time instead of allocating), and its results equal host rounds;
  * the HBM reference tier — a ``ref_budget`` too small for VMEM residency
    switches the fused kernels to per-lane DMA streaming with bit-identical
    results;
  * the golden pipeline scenario's slab arm — the frontends' ``"slab"``
    comparison mode still matches the fused default they now run by.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import guards
from repro.core.batch import (
    ea_pruned_dtw_multi_batch,
    ea_pruned_dtw_multi_batch_fused,
    ea_pruned_dtw_persistent,
    ea_pruned_dtw_persistent_fused,
)
from repro.core.common import BIG, DEAD_LANE_UB, norm_window_slice
from repro.core.lower_bounds import envelope
from repro.search import multi_query_search, subsequence_search
from repro.search.pipeline import make_plan
from repro.search.znorm import clamp_sigma, gather_norm_windows, window_stats

BACKENDS = ("jax", "pallas_interpret")
N_REF, LENGTH, WINDOW = 420, 48, 5


def _series(flat=True, nan_at=None):
    rng = np.random.default_rng(7)
    ref = np.cumsum(rng.normal(size=N_REF)).astype(np.float32)
    if flat:
        ref[100:170] = ref[100]  # sigma == 0 for a run of windows
    if nan_at is not None:
        ref[nan_at] = np.nan
    queries = np.cumsum(
        rng.normal(size=(2, LENGTH)), axis=1
    ).astype(np.float32)
    return jnp.asarray(ref), jnp.asarray(queries)


def _znorm(q):
    mu = q.mean(axis=-1, keepdims=True)
    sd = np.maximum(q.std(axis=-1, keepdims=True), 1e-8)
    return jnp.asarray((q - mu) / sd)


def test_norm_window_slice_matches_gather():
    """The fused slice helper is bit-identical to the slab gather."""
    ref, _ = _series()
    mu, sigma = window_stats(ref, LENGTH)
    starts = jnp.asarray([0, 17, 99, 120, N_REF - LENGTH], jnp.int32)
    a = norm_window_slice(ref, starts, LENGTH, mu, sigma)
    b = gather_norm_windows(ref, starts, LENGTH, mu, sigma)
    assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("use_cb", (False, True))
def test_multi_batch_fused_parity(backend, use_cb):
    """Round primitive: fused == slab, with dead lanes and flat windows.

    K = 11 lanes against block_k = 4 exercises the ragged final block on
    the Pallas grid; lanes 3/7 ride dead (the sentinel contract) and lanes
    over the flat segment hit the clamp_sigma path.
    """
    ref, queries = _series()
    qn = _znorm(np.asarray(queries))
    mu, sigma = window_stats(ref, LENGTH)
    starts = jnp.asarray(
        [[0, 50, 110, 130, 200, 260, 300, 310, 330, 350, 372]] * 2,
        jnp.int32,
    )
    ub = jnp.full((2, 11), BIG, jnp.float32)
    ub = ub.at[:, 3].set(DEAD_LANE_UB).at[1, 7].set(DEAD_LANE_UB)
    env = None
    if use_cb:
        u, low = jax.vmap(envelope, in_axes=(0, None))(qn, WINDOW)
        env = (u, low)

    d_fused = ea_pruned_dtw_multi_batch_fused(
        qn, ref, starts, ub, window=WINDOW, mu=mu, sigma=sigma,
        envelopes=env, backend=backend, block_k=4,
    )
    cand = jax.vmap(
        lambda s: gather_norm_windows(ref, s, LENGTH, mu, sigma)
    )(starts)
    cb = None
    if use_cb:
        from repro.core.lower_bounds import cascade_keogh_cumulative

        cb = jax.vmap(
            lambda c, uu, ll: jax.vmap(
                lambda cc: cascade_keogh_cumulative(cc, uu, ll)
            )(c)
        )(cand, env[0], env[1])
    d_slab = ea_pruned_dtw_multi_batch(
        qn, cand, ub, window=WINDOW, cb=cb, backend=backend, block_k=4,
    )
    assert np.array_equal(np.asarray(d_fused), np.asarray(d_slab))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("use_cb", (False, True))
def test_persistent_fused_parity(backend, use_cb):
    """Persistent sweep: fused == slab with a ragged, partly dead order."""
    ref, queries = _series()
    qn = _znorm(np.asarray(queries))
    mu, sigma = window_stats(ref, LENGTH)
    # ascending finite lbs, then a +inf (dead) tail; 10 lanes vs block_k=4
    lb = jnp.asarray(
        [[0.1, 0.5, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, np.inf, np.inf]] * 2,
        jnp.float32,
    )
    starts = jnp.asarray(
        [[30, 110, 150, 0, 210, 260, 310, 350, 0, 0]] * 2, jnp.int32
    )
    ub0 = jnp.asarray([BIG, 40.0], jnp.float32)  # one warm incumbent
    env = None
    if use_cb:
        u, low = jax.vmap(envelope, in_axes=(0, None))(qn, WINDOW)
        env = (u, low)

    out_f = ea_pruned_dtw_persistent_fused(
        qn, ref, lb, starts, ub0, window=WINDOW, mu=mu, sigma=sigma,
        envelopes=env, backend=backend, block_k=4,
    )
    cand = jax.vmap(
        lambda s: gather_norm_windows(ref, s, LENGTH, mu, sigma)
    )(starts)
    out_s = ea_pruned_dtw_persistent(
        qn, cand, lb, starts, ub0, window=WINDOW,
        envelopes=env, backend=backend, block_k=4,
    )
    for a, b in zip(out_f, out_s):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("rounds", ("host", "persistent"))
def test_frontend_parity_fused_vs_slab(backend, rounds):
    """multi_query_search: fused == slab with quarantine + warm starts."""
    ref, queries = _series(nan_at=210)  # condemn a window span (§2.6)
    kw = dict(
        length=LENGTH, window=WINDOW, batch=32, backend=backend,
        rounds=rounds, warm_start=2,
    )
    a = multi_query_search(ref, queries, gather="fused", **kw)
    b = multi_query_search(ref, queries, gather="slab", **kw)
    assert np.array_equal(np.asarray(a.best_start), np.asarray(b.best_start))
    assert np.array_equal(np.asarray(a.best_dist), np.asarray(b.best_dist))
    assert int(a.quarantined) == int(b.quarantined) == LENGTH


@pytest.mark.parametrize("backend", BACKENDS)
def test_slab_budget_persistent_regression(backend):
    """Fused persistent completes where the O(K·l) slab busts the budget.

    The budget admits the O(N) reference but not the O(N·l) candidate
    slab: the slab arm must refuse at trace time (no allocation), while the
    fused sweep runs to completion under the same plan knobs — with
    results identical to host rounds, so the memory win costs nothing.
    """
    ref, queries = _series()
    n_win = N_REF - LENGTH + 1
    budget = 8 * n_win  # floor(N·l·4 / ~24): far below any window slab
    assert n_win * LENGTH * 4 > budget
    kw = dict(
        length=LENGTH, window=WINDOW, batch=32, backend=backend,
        slab_budget=budget,
    )
    with pytest.raises(guards.SearchInputError):
        multi_query_search(
            ref, queries, gather="slab", rounds="persistent", **kw
        )
    pers = multi_query_search(
        ref, queries, gather="fused", rounds="persistent", **kw
    )
    host = multi_query_search(ref, queries, gather="fused", rounds="host", **kw)
    assert np.array_equal(
        np.asarray(pers.best_start), np.asarray(host.best_start)
    )
    np.testing.assert_allclose(
        np.asarray(pers.best_dist), np.asarray(host.best_dist), rtol=1e-6
    )


def test_hbm_tier_ref_budget_parity():
    """A reference over the VMEM budget DMA-streams with identical results."""
    from repro.kernels import ops

    ref, queries = _series()
    qn = _znorm(np.asarray(queries))
    mu, sigma = window_stats(ref, LENGTH)
    starts = jnp.asarray([[0, 60, 120, 180, 240, 300, 350]] * 2, jnp.int32)
    mu_l = mu[starts]                      # ops layer takes per-lane stats
    sg_l = clamp_sigma(sigma)[starts]      # pre-clamped by contract
    ub = jnp.full((2, 7), BIG, jnp.float32)
    kw = dict(window=WINDOW, length=LENGTH, block_k=4, interpret=True)
    d_vmem = ops.dtw_ea_multi_fused(qn, ref, starts, mu_l, sg_l, ub, **kw)
    d_hbm = ops.dtw_ea_multi_fused(
        qn, ref, starts, mu_l, sg_l, ub, ref_budget=256, **kw
    )
    assert np.array_equal(np.asarray(d_vmem), np.asarray(d_hbm))

    lb = jnp.asarray([[0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7]] * 2, jnp.float32)
    ub0 = jnp.full((2,), BIG, jnp.float32)
    p_vmem = ops.dtw_ea_persistent_fused(
        qn, ref, lb, starts, mu_l, sg_l, ub0, **kw
    )
    p_hbm = ops.dtw_ea_persistent_fused(
        qn, ref, lb, starts, mu_l, sg_l, ub0, ref_budget=256, **kw
    )
    for a, b in zip(p_vmem, p_hbm):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_golden_scenario_slab_arm_matches_fused():
    """The pipeline golden scenario's slab arms equal the fused default.

    ``test_pipeline_parity`` pins all five frontends on the (now fused)
    default; this cross-check pins the retired slab form against the same
    golden incumbents for the frontends that expose the knob.
    """
    import test_pipeline_parity as golden

    ref, queries = golden._scenario()
    g_starts, g_dists, g_quar = golden._golden("jax")

    res = multi_query_search(
        ref, queries, length=golden.LENGTH, window=golden.WINDOW, batch=64,
        backend="jax", gather="slab",
    )
    assert np.array_equal(np.asarray(res.best_start, np.int64), g_starts)
    np.testing.assert_allclose(
        np.asarray(res.best_dist, np.float64), g_dists,
        rtol=golden.DIST_RTOL,
    )
    assert int(res.quarantined) == g_quar

    one = subsequence_search(
        ref, queries[0], length=golden.LENGTH, window=golden.WINDOW,
        batch=64, backend="jax", gather="slab",
    )
    assert int(one.best_start) == int(g_starts[0])


def test_fused_is_default_and_validated():
    plan = make_plan(length=LENGTH, window=WINDOW)
    assert plan.gather == "fused"
    assert plan.slab_budget is None
    with pytest.raises(guards.SearchInputError):
        make_plan(length=LENGTH, window=WINDOW, gather="eager")
    with pytest.raises(guards.SearchInputError):
        make_plan(length=LENGTH, window=WINDOW, slab_budget=0)
