"""Sharding rules + HLO stats analyzer + multi-device placement subprocess."""
import os
import subprocess
import sys

import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.distributed.sharding import (
    make_batch_specs,
    make_cache_specs,
    make_param_specs,
    make_state_specs,
)
from repro.models.registry import build
from repro.roofline.hlo_stats import analyze_hlo


def _tree_specs_match(shapes, specs):
    sl = jax.tree.leaves(shapes)
    pl = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(sl) == len(pl)
    for leaf, spec in zip(sl, pl):
        assert len(tuple(spec)) <= len(leaf.shape), (leaf.shape, spec)


def _fake_mesh():
    """16x16 mesh over one repeated device — fine for spec math."""
    import numpy as np
    devs = np.array([jax.devices()[0]] * 256).reshape(16, 16)
    return jax.sharding.Mesh(devs, ("data", "model"))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_specs_structure(name):
    """Specs exist for every param of the FULL config and dims divide."""
    cfg = ARCHS[name]
    model = build(cfg)
    mesh = _fake_mesh()
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = make_param_specs(model, mesh)
    _tree_specs_match(shapes, specs)
    for leaf, spec in zip(
        jax.tree.leaves(shapes), jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    ):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            tot = 1
            for a in axes:
                tot *= mesh.shape[a]
            assert dim % tot == 0, (name, leaf.shape, spec)


def test_state_and_cache_specs():
    from repro.train.train_step import init_state

    mesh = _fake_mesh()
    model = build(ARCHS["kimi-k2-1t-a32b"])
    sspecs = make_state_specs(model, mesh)
    sshapes = jax.eval_shape(lambda k: init_state(model, k), jax.random.PRNGKey(0))
    _tree_specs_match(sshapes.params, sspecs.params)
    cshapes = jax.eval_shape(lambda: model.init_cache(128, 1024))
    cspecs = make_cache_specs(model, mesh, 128, 1024)
    _tree_specs_match(cshapes, cspecs)


def test_batch_specs_uneven_batch_replicates():
    mesh = _fake_mesh()
    specs = make_batch_specs(
        {"tokens": jax.ShapeDtypeStruct((1, 128), jnp.int32)}, mesh
    )
    assert tuple(specs["tokens"])[0] is None  # batch=1 cannot shard


def test_hlo_stats_trip_counts():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c.sum()

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, 64), jnp.float32),
        jax.ShapeDtypeStruct((5, 64, 64), jnp.float32),
    ).compile()
    st = analyze_hlo(comp.as_text())
    assert st["dot_flops"] == 2 * 8 * 64 * 64 * 5
    assert st["mem_bytes"] > 0


def test_sharded_training_subprocess():
    """Real 8-device run: placement, FSDP+TP train steps, loss finite."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, numpy as np
from repro.configs import ARCHS
from repro.models.registry import build
from repro.data.lm import TokenStream
from repro.distributed.sharding import make_state_specs, make_batch_specs, named
from repro.train.train_step import init_state, make_train_step

cfg = ARCHS["mistral-nemo-12b"].reduced()
model = build(cfg)
mesh = jax.make_mesh((4, 2), ("data", "model"))
sspecs = make_state_specs(model, mesh)
state = jax.device_put(init_state(model, jax.random.PRNGKey(0)), named(mesh, sspecs))
stream = TokenStream(cfg.vocab, 8, 32, seed=0)
step = jax.jit(make_train_step(model), in_shardings=(named(mesh, sspecs), None),
               out_shardings=(named(mesh, sspecs), None))
for i in range(3):
    batch = stream.batch_at(i)
    bspecs = make_batch_specs({k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}, mesh)
    batch = {k: jax.device_put(v, named(mesh, bspecs[k])) for k, v in batch.items()}
    state, m = step(state, batch)
print("LOSS", float(m["loss"]))
assert np.isfinite(float(m["loss"]))
# verify a param is actually sharded across devices
leaf = state.params["layers"]["attn"]["wq"]
assert len(leaf.sharding.device_set) > 1, leaf.sharding
print("SHARDED OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=420,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED OK" in out.stdout
