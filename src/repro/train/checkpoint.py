"""Sharded, atomic, async-capable checkpointing (no external deps).

Layout: ``<dir>/step_<N>/`` containing one ``.npy`` per pytree leaf (named by
its flattened key path — the per-shard file layout a multi-host deployment
writes per process) plus ``manifest.json`` (step, leaf index, tree structure).
Commit protocol: write into ``step_<N>.tmp`` then atomic ``rename`` — a
half-written checkpoint is never visible, so restart-after-failure always
finds a consistent one.

``AsyncCheckpointer`` moves serialization off the training thread (device
arrays are snapshotted synchronously via ``jax.device_get`` — cheap relative
to a step — and written by a worker thread).
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        named.append((name.replace("/", "."), leaf))
    return named, treedef


def save(ckpt_dir: str, tree, step: int) -> str:
    """Synchronous atomic checkpoint. Returns the committed directory."""
    named, _ = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for name, leaf in named:
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{name}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({"name": name, "file": fname})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def steps(ckpt_dir: str) -> list[int]:
    """All committed checkpoint steps under ``ckpt_dir``, ascending.

    Only fully-renamed ``step_<N>`` directories appear (the commit protocol
    hides ``.tmp`` writes), but a *committed* checkpoint can still be
    damaged after the fact (disk fault, partial copy) — callers that must
    survive that walk this list newest-first and fall back on restore
    failure (``serve.supervisor.SearchSupervisor.resume``).
    """
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", d))
    )


def latest_step(ckpt_dir: str) -> int | None:
    all_steps = steps(ckpt_dir)
    return all_steps[-1] if all_steps else None


def restore(ckpt_dir: str, template, step: int | None = None):
    """Restore into the structure of ``template``; returns (tree, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    named, treedef = _flatten(template)
    by_name = {e["name"]: e["file"] for e in manifest["leaves"]}
    leaves = []
    for name, leaf in named:
        arr = np.load(os.path.join(d, by_name[name]))
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def prune_old(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", d))
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Off-thread checkpoint writer with a bounded queue (backpressure).

    ``wait()`` is the write barrier: it blocks until every submitted
    checkpoint is committed (or has recorded its error). Supervisors call it
    before any restore/rollback so replay never races an in-flight write —
    without it, ``latest_step`` can report a step older than one already
    submitted, and a resume would silently rewind past committed progress.

    ``write_hook`` is a test-only injection point: when set, it is called
    with ``(tree, step)`` on the worker thread immediately before the
    atomic ``save`` — a sleeping hook widens the in-flight window so
    barrier races become deterministic in tests.
    """

    def __init__(self, ckpt_dir: str, keep: int = 3, write_hook=None):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._write_hook = write_hook
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._err: Exception | None = None
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def _run(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                tree, step = item
                try:
                    if self._write_hook is not None:
                        self._write_hook(tree, step)
                    save(self.ckpt_dir, tree, step)
                    prune_old(self.ckpt_dir, self.keep)
                except Exception as e:  # surfaced on next submit/wait/close
                    self._err = e
            finally:
                self._q.task_done()

    def submit(self, tree, step: int) -> None:
        if self._err:
            raise self._err
        snapshot = jax.device_get(tree)  # synchronous, consistent snapshot
        self._q.put((snapshot, int(step)))

    def wait(self) -> None:
        """Barrier: block until every submitted checkpoint is on disk."""
        self._q.join()
        if self._err:
            raise self._err

    def close(self) -> None:
        self._q.put(None)
        self._worker.join()
        if self._err:
            raise self._err
