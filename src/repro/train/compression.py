"""Gradient compression with error feedback (1-bit-Adam / PowerSGD lineage).

Params and grads are already bf16 on the wire; for cross-pod DCI links the
next 2x comes from int8 quantization. Per-tensor symmetric scales, with an
fp32 error-feedback accumulator so quantization noise is *recycled* into the
next step instead of lost — the standard trick that keeps convergence
(Seide et al. 2014; Tang et al. 2021).

Used by ``make_train_step(grad_compression="int8")``: gradients are
quantized after microbatch accumulation (i.e., what would cross the slow
inter-pod links in the hierarchical reduce), dequantized for the optimizer,
and the residual is carried.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedback(NamedTuple):
    residual: Any  # fp32, same structure as grads


def init_error_feedback(params) -> ErrorFeedback:
    return ErrorFeedback(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


class _QPair(NamedTuple):
    """Distinct type so tree.map's is_leaf can't collide with model pytrees
    (which legitimately contain plain tuples, e.g. RG-LRU group stacks)."""

    deq: Any
    res: Any


def _quantize_one(g: jax.Array, r: jax.Array) -> _QPair:
    """int8-quantize (g + residual); return (dequantized, new residual)."""
    x = g.astype(jnp.float32) + r
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return _QPair(deq, x - deq)


def compress_grads(grads, ef: ErrorFeedback) -> tuple[Any, ErrorFeedback]:
    """Quantize every gradient tensor to int8 (simulated wire format) with
    error feedback. Returns (dequantized grads, updated feedback state)."""
    out = jax.tree.map(_quantize_one, grads, ef.residual)
    is_pair = lambda x: isinstance(x, _QPair)
    deq = jax.tree.map(lambda o: o.deq, out, is_leaf=is_pair)
    res = jax.tree.map(lambda o: o.res, out, is_leaf=is_pair)
    return deq, ErrorFeedback(residual=res)
