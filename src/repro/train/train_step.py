"""Train-step builder: microbatched gradient accumulation + optimizer apply.

Gradients accumulate in float32 across ``cfg.num_microbatches`` sequential
microbatches (a ``lax.scan``), which bounds peak activation memory for the
large configs (the MoE dispatch buffer in particular scales with tokens per
microbatch). Parameters/activations are bf16, so the gradient reduce-scatter
traffic GSPMD emits is already 2-byte compressed on the wire; fp32 master
accumulation lives only in the (sharded) optimizer state.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.train.optimizer import (
    apply_opt,
    clip_by_global_norm,
    cosine_schedule,
    init_opt,
)


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array
    ef: Any = None  # ErrorFeedback residuals when grad compression is on


def init_state(model, key, grad_compression: str | None = None) -> TrainState:
    from repro.train.compression import init_error_feedback

    params = model.init(key)
    return TrainState(
        params=params,
        opt=init_opt(model.cfg, params),
        step=jnp.zeros((), jnp.int32),
        ef=init_error_feedback(params) if grad_compression else None,
    )


def make_train_step(
    model,
    base_lr: float = 3e-4,
    warmup: int = 2000,
    total_steps: int = 100_000,
    max_grad_norm: float = 1.0,
    grad_compression: str | None = None,
):
    """Build ``train_step(state, batch) -> (state, metrics)``.

    ``batch`` leaves have leading dim ``global_batch``; it is split into
    ``cfg.num_microbatches`` microbatches scanned sequentially.
    """
    cfg = model.cfg
    n_micro = max(cfg.num_microbatches, 1)

    def split_micro(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])

    def train_step(state: TrainState, batch: dict):
        micro = jax.tree.map(split_micro, batch)

        def micro_step(acc, mb):
            loss, grads = jax.value_and_grad(model.loss_fn)(state.params, mb)
            grads32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            acc_g, acc_loss = acc
            return (jax.tree.map(jnp.add, acc_g, grads32), acc_loss + loss), None

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params
        )
        (gsum, lsum), _ = jax.lax.scan(micro_step, (zero, jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        loss = lsum / n_micro

        new_ef = state.ef
        if grad_compression == "int8":
            # int8 wire format for the cross-pod reduce, with error feedback
            from repro.train.compression import compress_grads

            grads, new_ef = compress_grads(grads, state.ef)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = cosine_schedule(state.step, base_lr, warmup, total_steps)
        new_params, new_opt = apply_opt(cfg, state.params, grads, state.opt, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return TrainState(new_params, new_opt, state.step + 1, new_ef), metrics

    return train_step
