"""Optimizers: AdamW and Adafactor (factored second moments).

Pure-pytree implementations (no optax dependency). Adafactor is selected for
the 1T-parameter Kimi-K2 config: factored row/column second-moment statistics
cost O(rows + cols) instead of O(rows * cols) and no fp32 master copy is
kept — the difference between fitting in HBM and not (EXPERIMENTS.md §Memory).

All state tensors inherit the parameter's sharding (same shape), so ZeRO-style
optimizer-state sharding falls out of the param PartitionSpecs for free.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


class _Upd(NamedTuple):
    """Per-leaf update bundle — a distinct type so tree.map's is_leaf can
    stop exactly here (model params may legitimately contain plain tuples,
    e.g. the RG-LRU group stacks)."""

    p: Any
    a: Any
    b: Any


class AdafactorState(NamedTuple):
    vr: Any     # row statistics (or full v for <2D params)
    vc: Any     # col statistics (or None-like zeros)
    step: jax.Array


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def cosine_schedule(step, base_lr: float, warmup: int, total: int) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = base_lr * step / max(warmup, 1)
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, cos)


# ------------------------------- AdamW -------------------------------------


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        update = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return _Upd(p_new, m_new, v_new)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    is_upd = lambda x: isinstance(x, _Upd)
    new_params = jax.tree.map(lambda o: o.p, out, is_leaf=is_upd)
    new_m = jax.tree.map(lambda o: o.a, out, is_leaf=is_upd)
    new_v = jax.tree.map(lambda o: o.b, out, is_leaf=is_upd)
    return new_params, AdamWState(m=new_m, v=new_v, step=step)


# ------------------------------ Adafactor ----------------------------------


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params) -> AdafactorState:
    def vr(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vc(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return AdafactorState(
        vr=jax.tree.map(vr, params),
        vc=jax.tree.map(vc, params),
        step=jnp.zeros((), jnp.int32),
    )


def adafactor_update(
    params,
    grads,
    state: AdafactorState,
    lr,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
):
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** -decay  # increasing decay schedule (Shazeer & Stern)

    def upd(p, g, vr, vc):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if _factored(p):
            vr_new = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
            vc_new = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
            r = vr_new / jnp.maximum(jnp.mean(vr_new, axis=-1, keepdims=True), eps)
            precond = g32 / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc_new)[..., None, :])
        else:
            vr_new = beta * vr + (1 - beta) * g2
            vc_new = vc
            precond = g32 / jnp.sqrt(vr_new)
        # update clipping (RMS <= clip_threshold)
        rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + 1e-12)
        precond = precond / jnp.maximum(1.0, rms / clip_threshold)
        p_new = (p.astype(jnp.float32) - lr * precond).astype(p.dtype)
        return _Upd(p_new, vr_new, vc_new)

    out = jax.tree.map(upd, params, grads, state.vr, state.vc)
    is_upd = lambda x: isinstance(x, _Upd)
    new_params = jax.tree.map(lambda o: o.p, out, is_leaf=is_upd)
    new_vr = jax.tree.map(lambda o: o.a, out, is_leaf=is_upd)
    new_vc = jax.tree.map(lambda o: o.b, out, is_leaf=is_upd)
    return new_params, AdafactorState(vr=new_vr, vc=new_vc, step=step)


def init_opt(cfg, params):
    if cfg.optimizer == "adafactor":
        return adafactor_init(params)
    return adamw_init(params)


def apply_opt(cfg, params, grads, state, lr):
    if cfg.optimizer == "adafactor":
        return adafactor_update(params, grads, state, lr)
    return adamw_update(params, grads, state, lr)
