"""SearchSupervisor: crash-recoverable serving around a StreamSearchEngine.

The serving-side sibling of ``distributed.fault_tolerance.TrainingSupervisor``
(same supervision shape, same ``StragglerMonitor``, same ``train.checkpoint``
store): wrap a ``StreamSearchEngine`` and feed arrivals through
``supervisor.ingest(chunk)`` instead of ``engine.ingest(chunk)``. In return:

  * **Periodic checkpoints** — every ``ckpt_every`` arrivals the engine's
    full carried state (``save_state()``) is committed atomically under
    ``ckpt_dir`` via ``train.checkpoint`` (write-then-rename: a crash never
    leaves a half-written checkpoint visible).
  * **Bounded retry with backoff** — a *transient* dispatch failure
    (``RuntimeError`` / ``ValueError`` / ``OSError``: a device falling over,
    a flaky allocator) rolls the engine back to the last checkpointed state,
    replays the arrivals since (kept in a bounded in-memory buffer — at most
    ``ckpt_every`` chunks), sleeps an exponential backoff, and retries. The
    typed guard errors (``SearchInputError``, ``StreamStateError``) are
    *caller bugs*, re-raised immediately — retrying malformed input can only
    fail again. After ``max_retries`` consecutive failures the original
    error propagates.
  * **Restore-and-replay after a crash** — a fresh process builds the same
    engine + supervisor and calls ``resume()``: the latest *readable*
    checkpoint is restored bit-exactly and the number of arrivals already
    absorbed is returned, so the caller re-feeds its source from that index.
    A checkpoint damaged after commit (truncated leaf file, lost manifest —
    the atomic rename protects against half-writes, not against disk faults)
    is skipped and the next-older one restores instead; only when *no*
    checkpoint is readable does ``resume()`` start the stream from scratch.
    Incumbents, counters, tail, and the monitoring ring all come back;
    results are identical to the uninterrupted run (pinned by
    ``tests/test_robustness`` / ``tests/test_resilient``).
  * **Async checkpoints** (``async_ckpt=True``) — serialization moves off
    the ingest thread onto ``train.checkpoint.AsyncCheckpointer``; the
    ingest path pays only the ``device_get`` snapshot. Every path that
    restores state (``resume()``, the retry ``_rollback()``) takes the
    writer's ``wait()`` barrier first, so replay never races an in-flight
    write: without the barrier a resume could rewind past a submitted-but-
    uncommitted step, and a rollback's subsequent checkpoint could collide
    with the in-flight write of the same step.

Rollback correctness note: a failure can strike mid-arrival (after some
``stream_chunk`` pieces of a large arrival already committed), leaving the
engine partially advanced — which is why retry restores the last snapshot
and replays, rather than naively re-calling ``ingest`` on a maybe-half-eaten
engine.
"""
from __future__ import annotations

import time
from typing import Callable

import numpy as np

from repro.core import guards
from repro.distributed.fault_tolerance import (
    GUARD_ERRORS,
    TRANSIENT,
    DecorrelatedJitterBackoff,
    StragglerMonitor,
    WorkerHealth,
)
from repro.train import checkpoint as ckpt_lib


class SearchSupervisor:
    """Checkpoint/retry/replay wrapper around a ``StreamSearchEngine``.

    Args:
      engine: the (freshly constructed) engine to supervise.
      ckpt_dir: checkpoint directory (``train.checkpoint`` layout).
      ckpt_every: arrivals between checkpoints; also bounds the replay
        buffer.
      max_retries: consecutive transient failures tolerated per arrival.
      backoff: base retry sleep in seconds (doubles per consecutive retry).
      jitter: decorrelate retry sleeps (``DecorrelatedJitterBackoff``,
        seeded via ``$REPRO_FAULT_SEED``). Off by default — a single
        supervised engine has no fleet to decorrelate from, and the
        deterministic schedule keeps replay tests exact; turn it on when
        many supervisors share a backend.
      keep: checkpoints retained on disk (older ones pruned).
      sleep: injection point for the backoff sleep (tests pass a recorder).
      clock: injection point for latency measurement (tests pass a fake).
      breaker_threshold, breaker_cooldown: the engine's dispatch circuit
        breaker (``fault_tolerance.WorkerHealth``; DESIGN.md §2.9). With a
        single engine there is nowhere to route *away* to, so an open
        breaker sheds load in time instead of space: after the breaker
        trips, the retry path waits out ``breaker_cooldown`` before the
        half-open probe. ``health`` on the supervisor snapshots the state
        for operators.
      async_ckpt: move checkpoint serialization off the ingest thread
        (``train.checkpoint.AsyncCheckpointer``); restore paths barrier on
        in-flight writes first. Call ``close()`` at shutdown to flush.
    """

    def __init__(
        self,
        engine,
        ckpt_dir: str,
        ckpt_every: int = 16,
        max_retries: int = 3,
        backoff: float = 0.05,
        jitter: bool = False,
        keep: int = 3,
        sleep: Callable[[float], None] = time.sleep,
        clock: Callable[[], float] = time.time,
        breaker_threshold: int = 3,
        breaker_cooldown: float = 1.0,
        async_ckpt: bool = False,
    ):
        if ckpt_every < 1:
            raise ValueError("ckpt_every must be >= 1")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        self.engine = engine
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = int(ckpt_every)
        self.max_retries = int(max_retries)
        self.backoff = float(backoff)
        self.jitter = bool(jitter)
        self.keep = int(keep)
        self._sleep = sleep
        self._clock = clock
        self.monitor = StragglerMonitor()
        self.health = WorkerHealth(
            breaker_threshold=breaker_threshold,
            breaker_cooldown=breaker_cooldown, clock=clock,
        )
        self._backoffs = DecorrelatedJitterBackoff(self.backoff)
        self.restarts = 0
        self.chunks_done = 0          # arrivals fully absorbed
        self._pending: list = []      # arrivals since the last snapshot
        self._snapshot = engine.save_state()
        self._async = (
            ckpt_lib.AsyncCheckpointer(ckpt_dir, keep=keep)
            if async_ckpt
            else None
        )

    # -- persistence ------------------------------------------------------
    def _barrier(self) -> None:
        """Wait out in-flight async checkpoint writes (no-op when sync)."""
        if self._async is not None:
            self._async.wait()

    def resume(self) -> int:
        """Restore the newest readable checkpoint, if any; returns the
        number of arrivals already absorbed (the index to re-feed the
        source from).

        Walks committed checkpoints newest-first: one damaged after commit
        (truncated/garbled leaf file, unreadable manifest — possible when
        ``prune_old`` races a crash on a failing disk, or the filesystem
        loses a just-renamed directory's contents) is skipped, and the
        next-older checkpoint restores instead. Replay from an older index
        is always safe — the caller re-feeds from the returned index and
        the engine recomputes exactly what the lost checkpoints held.
        """
        self._barrier()
        for step in reversed(ckpt_lib.steps(self.ckpt_dir)):
            try:
                state, step = ckpt_lib.restore(
                    self.ckpt_dir, self.engine.save_state(), step=step
                )
                self.engine.restore_state(state)
            except (guards.StreamStateError, OSError, ValueError, KeyError,
                    EOFError):
                continue  # damaged checkpoint: fall back to the next older
            self.chunks_done = int(step)
            self._pending = []
            self._snapshot = self.engine.save_state()
            return self.chunks_done
        return 0

    def checkpoint(self) -> None:
        """Commit the engine state now (also called every ``ckpt_every``)."""
        state = self.engine.save_state()
        if self._async is not None:
            self._async.submit(state, self.chunks_done)
        else:
            ckpt_lib.save(self.ckpt_dir, state, self.chunks_done)
            ckpt_lib.prune_old(self.ckpt_dir, self.keep)
        self._snapshot = state
        self._pending = []

    def close(self) -> None:
        """Flush and stop the async writer (no-op for sync checkpoints)."""
        if self._async is not None:
            self._async.close()
            self._async = None

    def _rollback(self) -> None:
        """Back to the last snapshot, replay the arrivals since.

        Barriers on in-flight checkpoint writes first: the snapshot being
        restored may be the very tree an async writer is still committing,
        and the replayed arrivals will re-reach the same ``chunks_done``
        boundary — checkpointing there must not overlap the in-flight write
        of the same step.
        """
        self._barrier()
        self.engine.restore_state(self._snapshot)
        for c in self._pending:
            self.engine.ingest(c)

    # -- serving ----------------------------------------------------------
    def ingest(self, chunk, fail_injector: Callable[[int], None] | None = None):
        """Feed one arrival with retry/checkpoint semantics.

        Returns ``engine.best()``. ``fail_injector(arrival_index)`` may raise
        to simulate a failure (tests); it runs before the dispatch, like the
        training supervisor's.
        """
        chunk = np.asarray(chunk)
        retries = 0
        while True:
            try:
                if fail_injector is not None:
                    fail_injector(self.chunks_done)
                self.health.acquire()
                t0 = self._clock()
                out = self.engine.ingest(chunk)
                dt = self._clock() - t0
                self.monitor.observe(self.chunks_done, dt)
                self.health.observe(dt)
                self._backoffs.reset()
                break
            except GUARD_ERRORS:
                raise  # caller bug: retrying identical bad input cannot help
            except TRANSIENT as e:
                self.health.fail()
                self.restarts += 1
                retries += 1
                if retries > self.max_retries:
                    raise RuntimeError(
                        f"exceeded {self.max_retries} retries at arrival "
                        f"{self.chunks_done}"
                    ) from e
                if self.jitter:
                    self._sleep(self._backoffs.next())
                else:
                    self._sleep(self.backoff * (2 ** (retries - 1)))
                if not self.health.ready():
                    # Tripped breaker, single engine: shed load in time —
                    # wait out the cooldown before the half-open probe.
                    self._sleep(self.health.breaker.cooldown)
                self._rollback()
        self._pending.append(chunk)
        self.chunks_done += 1
        if self.chunks_done % self.ckpt_every == 0:
            self.checkpoint()
        return out
