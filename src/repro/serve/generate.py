"""Batched autoregressive generation: prefill + greedy/temperature decode.

The serving loop every decode-shape dry-run cell corresponds to: one prefill
over the prompt (filling the sequence-sharded KV / SSM / rolling-SWA cache),
then ``decode_step`` per token. Works for every registered architecture that
exposes ``prefill`` (transformer family, mamba2, whisper-after-encoder).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def generate(
    model,
    params,
    prompt_tokens: jax.Array,
    max_new_tokens: int,
    max_len: int | None = None,
    temperature: float = 0.0,
    key: jax.Array | None = None,
) -> jax.Array:
    """Generate ``max_new_tokens`` continuations for a (B, S) prompt batch.

    Greedy when ``temperature == 0``; otherwise softmax sampling. Returns
    (B, S + max_new_tokens) tokens.
    """
    b, s = prompt_tokens.shape
    total = max_len or (s + max_new_tokens)
    cache = model.init_cache(b, total)
    if model.prefill is None:
        raise ValueError(f"{model.cfg.name} has no prefill path")
    logits, cache = model.prefill(params, cache, tokens=prompt_tokens)

    def sample(logits_1, k):
        if temperature == 0.0:
            return jnp.argmax(logits_1, axis=-1).astype(prompt_tokens.dtype)
        probs = jax.nn.softmax(logits_1.astype(jnp.float32) / temperature, axis=-1)
        return jax.random.categorical(k, jnp.log(probs), axis=-1).astype(
            prompt_tokens.dtype
        )

    key = key if key is not None else jax.random.PRNGKey(0)
    toks = [sample(logits[:, 0], key)]
    out = prompt_tokens
    for i in range(max_new_tokens - 1):
        key, sub = jax.random.split(key)
        nxt = toks[-1][:, None]
        logits, cache = model.decode_step(params, cache, nxt, s + i)
        toks.append(sample(logits[:, 0], sub))
    return jnp.concatenate([out] + [t[:, None] for t in toks], axis=1)
