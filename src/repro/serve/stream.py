"""StreamSearchEngine: standing-query similarity search over a live stream.

The serving front-end of ``search/streaming.py``. Construct it with Q
standing queries, then feed reference chunks as they arrive::

    eng = StreamSearchEngine(queries, length=256, window=25)
    for chunk in source:
        best_start, best_dist = eng.ingest(chunk)

Each ``ingest`` is one jitted dispatch that (1) extends the window-stats
table by exactly the newly-valid windows via the appendable prefix-sum form
(O(chunk), not O(stream)), (2) runs the LB cascade over those windows only —
including the ``length - 1`` windows straddling the previous chunk boundary
— and (3) drives best-first EAPrunedDTW rounds through the per-lane-``ub``
multi-query batch, **warm-started with each query's incumbent carried over
from all previous chunks**. That carried upper bound is the paper's
tightening trick rotated into the time axis: the best match seen since the
stream began makes every new candidate abandon earlier, so per-chunk work
*decreases* as the stream ages (until a better match region arrives).

Memory is O(length + Q) regardless of stream length: the engine keeps only
the ``length - 1`` boundary tail plus per-query incumbent scalars.
``ring_capacity=W`` adds a bounded monitoring ring over the last W raw
samples (``recent()``), e.g. to snapshot the neighbourhood of a fresh match;
eviction is oldest-first and never affects search results.

Exactness: for any chunking of a reference series, the final per-query
``(best_dist, best_start)`` equals offline ``multi_query_search`` /
``subsequence_search`` over the concatenated stream (every window is scanned
exactly once, against a monotone incumbent). The one caveat is an *exact*
distance tie between windows in different chunks: both drivers keep the
first strict improvement they encounter, and their scan orders differ, so
the reported start may be the other cominimizer (the distance is identical).
Incumbents are monotone non-increasing across ingests —
``tests/test_streaming.py`` pins both properties on both backends.

Hardening (DESIGN.md §2.6): non-finite stream samples are *quarantined*, not
fatal — every window overlapping one is excluded from search (dead-lane
sentinel), everything else stays exact, and the engine keeps serving while
counting what it dropped (``quarantined_windows`` / ``quarantined_samples``).
Malformed inputs raise the typed ``core.guards`` taxonomy before any device
work. ``save_state()`` / ``restore_state()`` expose the full carried state as
a flat dict of arrays — ``train.checkpoint`` can persist it, and
``serve.supervisor.SearchSupervisor`` drives periodic checkpoints plus
restore-and-replay on crash.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import guards
from repro.core.lower_bounds import envelope
from repro.search.incumbents import QuarantineLedger
from repro.search.pipeline import MULTI_VARIANTS
from repro.search.streaming import (
    StreamIngestExecutor,
    initial_incumbents,
    rescore_windows,
)
from repro.search.znorm import znorm


class _Ring:
    """Fixed-capacity ring over the last W stream samples, oldest-first."""

    def __init__(self, capacity: int, dtype):
        self.capacity = int(capacity)
        self.buf = np.zeros((self.capacity,), dtype)
        self.count = 0
        self.pos = 0  # next write slot

    def extend(self, x: np.ndarray) -> None:
        x = np.asarray(x).reshape(-1)
        if x.shape[0] >= self.capacity:
            self.buf[:] = x[-self.capacity:]
            self.pos = 0
            self.count = self.capacity
            return
        first = min(x.shape[0], self.capacity - self.pos)
        self.buf[self.pos : self.pos + first] = x[:first]
        rest = x.shape[0] - first
        if rest:
            self.buf[:rest] = x[first:]
        self.pos = (self.pos + x.shape[0]) % self.capacity
        self.count = min(self.count + x.shape[0], self.capacity)

    def view(self) -> np.ndarray:
        if self.count < self.capacity:
            return self.buf[: self.count].copy()
        return np.concatenate([self.buf[self.pos :], self.buf[: self.pos]])

    def _phys(self, logical: int) -> int:
        """Physical slot of the ``logical``-th oldest retained sample."""
        if self.count < self.capacity:
            return logical  # never wrapped: data occupies [0, count)
        return (self.pos + logical) % self.capacity

    def get(self, logical: int):
        return self.buf[self._phys(logical)]

    def patch(self, logical: int, value) -> None:
        """Overwrite one retained sample in place (re-admission repair)."""
        self.buf[self._phys(logical)] = value


class StreamSearchEngine:
    """Incremental nearest-window search for Q standing queries.

    Args:
      queries: ``(Q, l)`` (or ``(l,)``) raw queries; z-normalized once here.
      length: window/query length; ``l == length``.
      window: Sakoe-Chiba warping window in samples.
      variant: ``"eapruned"`` (LB cascade + cb tightening) or
        ``"eapruned_nolb"`` (stream-order rounds, no cascade).
      batch: candidate lanes per query per round — each round dispatches one
        flattened ``(Q × batch)`` lane set.
      band_width, rows_per_step, block_k, row_block: DTW batch knobs, as in
        ``multi_query_search``.
      chunk_lb: LB-cascade materialization chunk (memory bound, not stream
        chunking).
      backend: DTW batch backend; resolved (incl. ``$REPRO_DTW_BACKEND``) on
        every ``ingest``, like the offline un-jitted wrappers.
      ub_init: optional per-query incumbent seeds (scalar or ``(Q,)``) — warm
        starts from a previous stream segment or a served cache.
      ring_capacity: keep the last W raw samples for ``recent()`` monitoring
        (bounded memory); ``None`` keeps no sample history at all.
      stream_chunk: fixed ingest shape. ``None`` (legacy) traces per distinct
        chunk shape — a fixed-size source settles into one steady-state
        trace, but every ragged chunk (the short final one included) costs a
        fresh compile. With ``stream_chunk=W`` the engine pads every ingest
        to a static ``W``-sample buffer (splitting bigger arrivals into
        ``W``-sized pieces first), so ONE compiled trace serves the whole
        stream regardless of how the source chunks it.
      quarantine: exclude windows overlapping non-finite samples instead of
        letting a NaN poison the incumbents (default on; DESIGN.md §2.6).
        Counts surface as ``quarantined_windows`` / ``quarantined_samples``.
      gather, slab_budget: candidate materialization policy per DESIGN.md
        §2.10 — ``"fused"`` (default) slices + z-normalizes candidates from
        the resident context inside the batch primitive; ``"slab"`` keeps
        the pre-gathered O(K·l) comparison form, guarded by ``slab_budget``
        bytes when set.
      debug_checks: verify after every ingest that no NaN reached the
        carried incumbents, raising ``NonFiniteInputError`` instead of
        serving poisoned results. ``None`` defers to ``$REPRO_DEBUG_CHECKS``.
        Synchronous (forces a device sync per ingest) — keep it off in
        production. For checkify-compatible pieces there is also
        ``core.guards.checked_call`` (the DTW round loop itself is outside
        checkify's support; see ``core.guards`` docstring).
      executor: the ingest dispatch seam (DESIGN.md §2.8/§2.9). ``None``
        builds the plain ``search.streaming.StreamIngestExecutor`` bound
        to this engine's knobs. Pass an object with ``run_ingest`` (e.g. a
        ``search.pipeline.HedgedExecutor`` wrapping several ingest
        executors) to substitute it, or a callable — it receives the
        default executor and returns the one to use, so a wrapper does not
        need to re-derive the engine's bound statics.
    """

    def __init__(
        self,
        queries: jax.Array,
        length: int,
        window: int,
        variant: str = "eapruned",
        batch: int = 64,
        band_width: int | None = None,
        chunk_lb: int = 4096,
        backend: str | None = None,
        rows_per_step: int = 1,
        block_k: int = 8,
        row_block: int = 128,
        ub_init: jax.Array | None = None,
        ring_capacity: int | None = None,
        stream_chunk: int | None = None,
        quarantine: bool = True,
        debug_checks: bool | None = None,
        executor=None,
        gather: str = "fused",
        slab_budget: int | None = None,
    ):
        if variant not in MULTI_VARIANTS:
            raise ValueError(f"variant must be one of {MULTI_VARIANTS}")
        if ring_capacity is not None and ring_capacity < 1:
            raise ValueError("ring_capacity must be >= 1")
        if stream_chunk is not None and stream_chunk < 1:
            raise ValueError("stream_chunk must be >= 1")
        q = jnp.atleast_2d(jnp.asarray(queries))
        guards.ensure_series(q, "queries", ndim=2, min_len=length)
        guards.ensure_finite(q, "queries")
        guards.ensure_knobs(
            length=length, window=window, batch=batch, band_width=band_width,
            block_k=block_k, row_block=row_block, rows_per_step=rows_per_step,
        )
        self.length = int(length)
        self.window = int(window)
        self.variant = variant
        self.batch = int(batch)
        self.band_width = band_width
        self.chunk_lb = int(chunk_lb)
        self.backend = backend
        self.rows_per_step = int(rows_per_step)
        self.block_k = int(block_k)
        self.row_block = int(row_block)
        self.stream_chunk = None if stream_chunk is None else int(stream_chunk)
        self.gather = gather
        self.slab_budget = None if slab_budget is None else int(slab_budget)
        self.queries_n = znorm(q[:, : self.length])
        self.u, self.low = jax.vmap(envelope, in_axes=(0, None))(
            self.queries_n, self.window
        )
        self._dtype = self.queries_n.dtype
        self._ub, self._best = initial_incumbents(
            self.queries_n.shape[0], self._dtype, ub_init
        )
        self._tail = jnp.zeros((0,), self._dtype)
        self._n_seen = 0
        self._n_chunks = 0
        self._rounds = jnp.asarray(0, jnp.int32)
        self._lanes = jnp.asarray(0, jnp.int32)
        self.quarantine = bool(quarantine)
        self.debug_checks = guards.debug_checks_enabled(debug_checks)
        # One source of truth for the §2.6 counters — shared semantics with
        # IngestResult accounting (search.incumbents.QuarantineLedger).
        self._ledger = QuarantineLedger()
        self._pending_rescore: list[tuple[np.ndarray, np.ndarray]] = []
        self._ring = (
            _Ring(ring_capacity, np.dtype(self._dtype))
            if ring_capacity is not None
            else None
        )
        # The ingest dispatch seam: every round of device work the engine
        # issues goes through self._executor.run_ingest (see the executor
        # arg in the class docstring).
        default_executor = StreamIngestExecutor(
            self.queries_n, self.u, self.low,
            length=self.length, window=self.window, variant=self.variant,
            batch=self.batch, band_width=self.band_width,
            chunk_lb=self.chunk_lb, backend=self.backend,
            rows_per_step=self.rows_per_step, block_k=self.block_k,
            row_block=self.row_block, quarantine=self.quarantine,
            gather=self.gather, slab_budget=self.slab_budget,
        )
        if executor is None:
            executor = default_executor
        elif callable(executor) and not hasattr(executor, "run_ingest"):
            executor = executor(default_executor)
        if not hasattr(executor, "run_ingest"):
            raise guards.SearchInputError(
                "executor must expose run_ingest (or be a factory that "
                "returns one when called with the default executor)"
            )
        self._executor = executor

    # -- state ------------------------------------------------------------
    @property
    def n_queries(self) -> int:
        return int(self.queries_n.shape[0])

    @property
    def n_seen(self) -> int:
        """Raw samples ingested since the stream began."""
        return self._n_seen

    @property
    def n_windows(self) -> int:
        """Candidate windows scanned so far (== offline window count)."""
        return max(0, self._n_seen - self.length + 1)

    @property
    def rounds(self) -> int:
        """Total batch rounds spent across all ingests (work accounting)."""
        return int(self._rounds)

    @property
    def lanes(self) -> int:
        """Total candidate lanes submitted across all ingests."""
        return int(self._lanes)

    @property
    def quarantined_windows(self) -> int:
        """Windows excluded from search by the non-finite quarantine."""
        return int(self._ledger.windows)

    @property
    def quarantined_samples(self) -> int:
        """Non-finite raw samples seen on the stream so far."""
        return int(self._ledger.samples)

    @property
    def readmitted_windows(self) -> int:
        """Quarantined windows re-admitted (rescored) after ``correct``."""
        return self._ledger.readmitted

    @property
    def pending_rescore(self) -> int:
        """Re-admitted windows queued but not yet rescored (flushes on the
        next ``ingest`` / ``save_state``)."""
        return sum(s.shape[0] for s, _ in self._pending_rescore)

    def best(self) -> tuple[jax.Array, jax.Array]:
        """Current ``(best_start, best_dist)`` per query, ``(Q,)`` each.

        ``best_start`` is in stream coordinates (-1 while no window has been
        scanned or an ``ub_init`` seed is still unbeaten); ``best_dist`` is
        the incumbent DTW distance.
        """
        return self._best, self._ub

    def recent(self) -> np.ndarray:
        """The last ``ring_capacity`` raw samples, oldest first."""
        if self._ring is None:
            raise ValueError("engine built without ring_capacity")
        return self._ring.view()

    # -- re-admission ------------------------------------------------------
    def correct(self, position: int, values) -> int:
        """Patch previously non-finite samples; re-admit the windows they
        poisoned (DESIGN.md §2.7).

        A sensor that emitted NaN/Inf and later backfills real values calls
        ``correct(position, values)`` with ``position`` in stream
        coordinates. The samples are patched wherever the engine still
        retains them (the carried tail, the monitoring ring), and every
        *fully-past* window that becomes all-finite again is queued for
        rescoring against the carried incumbents — the rescore itself runs
        as one extra dispatch on the next ``ingest`` (or ``save_state``),
        through ``search.streaming.rescore_windows``. Windows still
        straddling the stream frontier need no queue: the next ingest scans
        them through the (now patched) tail as usual.

        Only re-admission is supported — every targeted sample must
        currently be non-finite (``StreamStateError`` otherwise: rewriting
        already-searched finite history would silently invalidate served
        incumbents). Replacement ``values`` must be finite
        (``NonFiniteInputError``), within the ingested stream
        (``StreamStateError`` with ``n_seen`` otherwise), and within
        retained history — without a ring that is just the ``length - 1``
        tail, so fully-past windows are only recoverable when the engine
        was built with ``ring_capacity >= length``.

        Returns the number of windows queued for rescoring (0 is normal:
        e.g. the patched region still overlaps other bad samples, or no
        retained fully-past window covers it).
        """
        if not self.quarantine:
            raise guards.StreamStateError(
                "correct() is the quarantine re-admission path; this engine "
                "was built with quarantine=False"
            )
        values = np.asarray(values, np.dtype(self._dtype)).reshape(-1)
        k = int(values.shape[0])
        if k == 0:
            raise guards.SearchInputError("correct() needs >= 1 value")
        if not np.all(np.isfinite(values)):
            raise guards.NonFiniteInputError(
                "replacement values must be finite — correct() re-admits "
                "quarantined samples, it does not re-poison them"
            )
        position = int(position)
        if position < 0:
            raise guards.SearchInputError("position must be >= 0")
        n_seen = self._n_seen
        if position + k > n_seen:
            raise guards.StreamStateError(
                f"correct() targets [{position}, {position + k}) but only "
                f"{n_seen} samples have arrived — cannot correct the future",
                n_seen=n_seen, chunk_index=self._n_chunks,
            )
        tail_np = np.array(self._tail)  # mutable copy
        tail_len = int(tail_np.shape[0])
        ring_count = self._ring.count if self._ring is not None else 0
        horizon = max(tail_len, ring_count)
        if position < n_seen - horizon:
            raise guards.StreamStateError(
                f"correct() targets position {position} but retained "
                f"history starts at {n_seen - horizon} (tail {tail_len}, "
                f"ring {ring_count}) — the samples are gone",
                n_seen=n_seen, chunk_index=self._n_chunks,
            )
        tail_base = n_seen - tail_len
        ring_base = n_seen - ring_count
        for i in range(k):
            p = position + i
            cur = (
                tail_np[p - tail_base]
                if p >= tail_base
                else self._ring.get(p - ring_base)
            )
            if np.isfinite(cur):
                raise guards.StreamStateError(
                    f"sample at stream position {p} is already finite — "
                    "correct() only re-admits quarantined samples",
                    n_seen=n_seen, chunk_index=self._n_chunks,
                )
        for i in range(k):
            p = position + i
            if p >= tail_base:
                tail_np[p - tail_base] = values[i]
            if self._ring is not None and p >= ring_base:
                self._ring.patch(p - ring_base, values[i])
        self._tail = jnp.asarray(tail_np, self._dtype)
        self._ledger.correct_samples(k)

        # Fully-past windows revived by this patch: starts overlapping the
        # corrected region whose whole [s, s + length) is retained in the
        # ring and is now all-finite. Each one overlaps a patched sample,
        # so each was counted quarantined when it was scanned.
        queued = 0
        if self._ring is not None and ring_count >= self.length:
            hist = self._ring.view()  # post-patch, covers [ring_base, n_seen)
            s_lo = max(position - self.length + 1, ring_base, 0)
            s_hi = min(position + k - 1, n_seen - self.length)
            starts, wins = [], []
            for s in range(s_lo, s_hi + 1):
                w = hist[s - ring_base : s - ring_base + self.length]
                if np.all(np.isfinite(w)):
                    starts.append(s)
                    wins.append(w.copy())
            if starts:
                self._pending_rescore.append(
                    (np.asarray(starts, np.int64), np.stack(wins))
                )
                queued = len(starts)
        return queued

    def _flush_rescore(self) -> None:
        """Rescore queued re-admitted windows against the incumbents."""
        if not self._pending_rescore:
            return
        starts = np.concatenate([s for s, _ in self._pending_rescore])
        wins = np.concatenate([w for _, w in self._pending_rescore])
        self._pending_rescore = []
        self._ub, self._best = rescore_windows(
            jnp.asarray(wins, self._dtype), jnp.asarray(starts, jnp.int32),
            self.queries_n, self.u, self.low, self._ub, self._best,
            window=self.window, variant=self.variant,
            band_width=self.band_width, backend=self.backend,
            rows_per_step=self.rows_per_step, block_k=self.block_k,
            row_block=self.row_block,
        )
        self._ledger.readmit(int(starts.shape[0]))

    # -- checkpoint -------------------------------------------------------
    def save_state(self) -> dict:
        """Snapshot the full carried state as a flat dict of numpy arrays.

        Everything the engine threads between ingests: boundary tail, per-
        query incumbents, counters, and the monitoring ring (when built with
        one). Every leaf is an array — the dict is a valid
        ``train.checkpoint`` tree, so ``checkpoint.save(dir, state, step)``
        persists it atomically and ``restore_state(checkpoint.restore(dir,
        template))`` resumes a crashed stream bit-exactly. The standing
        queries and knobs are *not* captured: they are construction-time
        configuration, and restore validates against the live engine's.
        """
        self._flush_rescore()  # snapshot consistent incumbents, empty queue
        state = {
            "tail": np.asarray(self._tail),
            "ub": np.asarray(self._ub),
            "best": np.asarray(self._best),
            "n_seen": np.asarray(self._n_seen, np.int64),
            "n_chunks": np.asarray(self._n_chunks, np.int64),
            "rounds": np.asarray(self._rounds, np.int32),
            "lanes": np.asarray(self._lanes, np.int32),
        }
        state.update(self._ledger.state_dict())
        if self._ring is not None:
            state["ring_buf"] = self._ring.buf.copy()
            state["ring_count"] = np.asarray(self._ring.count, np.int64)
            state["ring_pos"] = np.asarray(self._ring.pos, np.int64)
        return state

    def restore_state(self, state: dict) -> None:
        """Adopt a ``save_state()`` snapshot; raises ``StreamStateError`` on
        a snapshot inconsistent with this engine's configuration."""
        required = ("tail", "ub", "best", "n_seen", "n_chunks",
                    "rounds", "lanes", "quarantined", "bad_samples")
        missing = [k for k in required if k not in state]
        if missing:
            raise guards.StreamStateError(
                f"checkpoint missing state keys {missing}"
            )
        nq = self.n_queries
        ub = np.asarray(state["ub"])
        if ub.shape != (nq,):
            raise guards.StreamStateError(
                f"checkpoint incumbents have shape {ub.shape}, engine has "
                f"{nq} standing queries — wrong stream?"
            )
        tail = np.asarray(state["tail"])
        if tail.ndim != 1 or tail.shape[0] > self.length - 1:
            raise guards.StreamStateError(
                f"checkpoint tail shape {tail.shape} overflows the "
                f"(length - 1,) = ({self.length - 1},) boundary context",
                n_seen=int(state["n_seen"]),
            )
        if (self._ring is not None) != ("ring_buf" in state):
            raise guards.StreamStateError(
                "checkpoint and engine disagree on ring_capacity monitoring"
            )
        self._tail = jnp.asarray(tail, self._dtype)
        self._ub = jnp.asarray(ub, self._dtype)
        self._best = jnp.asarray(state["best"], jnp.int32)
        self._n_seen = int(state["n_seen"])
        self._n_chunks = int(state["n_chunks"])
        self._rounds = jnp.asarray(state["rounds"], jnp.int32)
        self._lanes = jnp.asarray(state["lanes"], jnp.int32)
        # The ledger owns the quarantine keys (including the older-checkpoint
        # fallback for snapshots that predate re-admission); snapshots never
        # carry a pending queue (save_state flushes first).
        self._ledger.load_state_dict(state)
        self._pending_rescore = []
        if self._ring is not None:
            buf = np.asarray(state["ring_buf"])
            if buf.shape != self._ring.buf.shape:
                raise guards.StreamStateError(
                    f"checkpoint ring capacity {buf.shape[0]} != engine "
                    f"ring capacity {self._ring.capacity}"
                )
            self._ring.buf = buf.astype(self._ring.buf.dtype, copy=True)
            self._ring.count = int(state["ring_count"])
            self._ring.pos = int(state["ring_pos"])

    # -- ingest -----------------------------------------------------------
    def ingest(self, chunk: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Feed one chunk of reference samples; returns ``self.best()``.

        Scans every window whose last sample arrives with this chunk. Chunks
        may have any (nonzero) length; windows straddling chunk boundaries
        are handled via the carried tail. With ``stream_chunk`` set, arrivals
        bigger than the fixed ingest shape are split into ``stream_chunk``-
        sized pieces (one dispatch each) and every piece is padded to the
        one static shape — no retrace, whatever the source's chunking.
        """
        self._flush_rescore()  # re-admitted windows score before new ones
        chunk = jnp.asarray(chunk, self._dtype).reshape(-1)
        if chunk.shape[0] == 0:
            return self.best()
        if self.quarantine:
            # Lazy device accumulation, like the work counters below.
            self._ledger.note_samples(
                jnp.sum(~jnp.isfinite(chunk), dtype=jnp.int32)
            )
        if self._ring is not None:
            self._ring.extend(np.asarray(chunk))
        if self.stream_chunk is None:
            self._ingest_piece(chunk, pad_to=None)
        else:
            for pos in range(0, int(chunk.shape[0]), self.stream_chunk):
                self._ingest_piece(
                    chunk[pos : pos + self.stream_chunk],
                    pad_to=self.stream_chunk,
                )
        return self.best()

    def _ingest_piece(self, chunk: jax.Array, pad_to: int | None) -> None:
        tail_len = int(self._tail.shape[0])
        if tail_len + int(chunk.shape[0]) < self.length:
            # Not a full window yet: extend the boundary context only.
            self._tail = jnp.concatenate([self._tail, chunk])
            self._n_seen += int(chunk.shape[0])
            self._n_chunks += 1
            return
        offset = self._n_seen - tail_len  # stream coordinate of tail[0]
        self._tail, res = self._executor.run_ingest(
            self._tail, chunk, self._ub, self._best, offset,
            pad_to=pad_to, chunk_index=self._n_chunks,
        )
        if self.debug_checks:
            # Synchronous tripwire: a NaN must never reach the carried
            # incumbents (the quarantine exists to guarantee exactly this).
            # Full-program checkify cannot discharge through the vmapped
            # while-loop DTW (see guards.checked_call), so debug mode checks
            # the one invariant that matters at the one place it can.
            if bool(jnp.any(jnp.isnan(res.ub))):
                raise guards.NonFiniteInputError(
                    f"debug-mode tripwire: NaN reached the incumbents "
                    f"(n_seen={self._n_seen}, chunk_index={self._n_chunks})"
                )
        self._ub, self._best = res.ub, res.best
        # Accumulate work counters as device values: reading them eagerly
        # would sync on every ingest and forbid overlapping the next chunk's
        # arrival with this dispatch.
        self._rounds = self._rounds + jnp.max(res.rounds)
        self._lanes = self._lanes + jnp.sum(res.lanes)
        self._ledger.note_windows(res.quarantined)
        self._n_seen += int(chunk.shape[0])
        self._n_chunks += 1
