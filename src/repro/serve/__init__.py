"""Serving: batched prefill + decode generation, streaming similarity search."""
from repro.serve.generate import generate
from repro.serve.stream import StreamSearchEngine

__all__ = ["StreamSearchEngine", "generate"]
