"""Serving: batched prefill + decode generation, streaming similarity search."""
from repro.serve.generate import generate
from repro.serve.stream import StreamSearchEngine
from repro.serve.supervisor import SearchSupervisor

__all__ = ["SearchSupervisor", "StreamSearchEngine", "generate"]
