"""Serving: batched prefill + decode generation loop."""
from repro.serve.generate import generate

__all__ = ["generate"]
