"""Partitioning rules: Megatron-style TP on "model", FSDP on "data", DP on "pod".

Specs are derived from abstract shape trees (``jax.eval_shape``) with
name-based rules, so they track the real parameter structure of every
architecture without duplication. A mesh axis is only applied to a dimension
it divides exactly; otherwise that dimension stays replicated (GSPMD would
pad uneven shards — we prefer the waste to be explicit in the roofline table,
so the rule is conservative and the §Perf log revisits the hot cases).

Axis roles:
  pod    — pure data parallelism across pods (gradient all-reduce crosses DCI
           once per step, on already reduce-scattered shards)
  data   — batch sharding + ZeRO-3-style parameter/optimizer sharding
  model  — tensor parallelism: attention heads / ffn hidden / vocab / experts
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.train.optimizer import AdafactorState, AdamWState
from repro.train.train_step import TrainState

NORM_NAMES = {
    "ln", "ln1", "ln2", "ln3", "final_norm", "enc_norm", "dec_norm", "out_ln",
    "a_param", "d_skip", "dt_bias", "a_log",
}
# (d_model, hidden)-shaped projections: FSDP on dim0, TP on dim1
IN_PROJ = {"wq", "w_gate", "w_up", "w_in", "w_x", "w_gate_in", "a_gate", "i_gate"}
# (hidden, d_model)-shaped projections: TP on dim0, FSDP on dim1
OUT_PROJ = {"wo", "w_down", "w_out"}
KV_PROJ = {"wk", "wv"}
BIASES = {"bq", "bk", "bv"}

STACKED_MARKERS = ("layers", "groups", "enc_layers", "dec_layers")


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        key = getattr(p, "key", None)
        if key is None:
            key = getattr(p, "idx", None)
        out.append(str(key))
    return out


def _axes(mesh) -> tuple[str | None, str, str]:
    names = mesh.axis_names
    pod = "pod" if "pod" in names else None
    return pod, "data", "model"


def spec_for_param(path, shape, mesh, fsdp_shard: bool = True) -> P:
    """Rule-based PartitionSpec for one parameter leaf.

    ``fsdp_shard=False`` drops the "data"-axis parameter sharding — used for
    decode when the TP-sharded weights fit HBM outright, eliminating the
    per-layer FSDP all-gathers (§Perf-D4; inference has no optimizer state
    to amortize them against)."""
    pod, fsdp, tp = _axes(mesh)
    if not fsdp_shard:
        fsdp = None
    names = _path_names(path)
    name = names[-1]
    stacked = any(m in names for m in STACKED_MARKERS)
    dims = tuple(shape[1:]) if stacked else tuple(shape)

    def ax(a: str | None, size: int):
        if a is None:
            return None
        return a if size % mesh.shape[a] == 0 else None

    nd = len(dims)
    if name in NORM_NAMES or nd == 0:
        spec: tuple = (None,) * nd
    elif name == "embed":
        spec = (ax(tp, dims[0]), ax(fsdp, dims[1]))
    elif name == "unembed":
        spec = (ax(fsdp, dims[0]), ax(tp, dims[1]))
    elif name == "router":
        spec = (ax(fsdp, dims[0]), None)
    elif name == "conv_w":
        spec = (None, ax(tp, dims[1]))
    elif name in BIASES:
        spec = (ax(tp, dims[0]),)
    elif name in IN_PROJ:
        if nd == 3:  # MoE expert weights (E, D, FF): experts on TP
            spec = (ax(tp, dims[0]), ax(fsdp, dims[1]), None)
        else:
            spec = (ax(fsdp, dims[0]), ax(tp, dims[1]))
    elif name in OUT_PROJ:
        if nd == 3:  # (E, FF, D)
            spec = (ax(tp, dims[0]), None, ax(fsdp, dims[2]))
        else:
            spec = (ax(tp, dims[0]), ax(fsdp, dims[1]))
    elif name in KV_PROJ:
        spec = (ax(fsdp, dims[0]), ax(tp, dims[1]))
    else:
        spec = (None,) * nd
    if stacked:
        spec = (None,) + spec
    return P(*spec)


def make_param_specs(model, mesh, fsdp_shard: bool = True) -> Any:
    """PartitionSpec tree matching ``model.init`` (no allocation)."""
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_param(path, leaf.shape, mesh, fsdp_shard),
        shapes,
    )


def _drop_last(spec: P) -> P:
    return P(*tuple(spec)[:-1]) if len(tuple(spec)) else spec


def _factored_col(spec: P) -> P:
    t = tuple(spec)
    if len(t) >= 2:
        return P(*t[:-2], t[-1])
    return P()


def make_state_specs(model, mesh) -> TrainState:
    pspecs = make_param_specs(model, mesh)
    if model.cfg.optimizer == "adafactor":
        opt = AdafactorState(
            vr=jax.tree.map(_drop_last, pspecs),
            vc=jax.tree.map(_factored_col, pspecs),
            step=P(),
        )
    else:
        opt = AdamWState(m=pspecs, v=pspecs, step=P())
    return TrainState(params=pspecs, opt=opt, step=P())


def batch_axes(mesh) -> tuple:
    pod, fsdp, _ = _axes(mesh)
    return (pod, fsdp) if pod else (fsdp,)


def make_batch_specs(batch_shapes: dict, mesh) -> dict:
    """Batch leaves shard their leading (global batch) dim on (pod, data).

    When the batch doesn't divide the axes (long_500k has batch=1) the leading
    dim stays replicated and capacity rides on the sequence-sharded caches.
    """
    ba = batch_axes(mesh)
    total = 1
    for a in ba:
        total *= mesh.shape[a]

    def spec(v):
        lead = ba if v.shape[0] % total == 0 else None
        return P(lead, *([None] * (len(v.shape) - 1)))

    return {k: spec(v) for k, v in batch_shapes.items()}


def spec_for_cache(path, shape, mesh) -> P:
    """KV caches: batch on (pod,data); cache length on "model" (the baseline
    sequence-sharded layout — see EXPERIMENTS.md §Perf for the flash-decode
    alternative); SSM/LRU states: batch on (pod,data), width/heads on model."""
    pod, fsdp, tp = _axes(mesh)
    ba = (pod, fsdp) if pod else fsdp
    names = _path_names(path)
    name = names[-1].rstrip("0123456789")
    nd = len(shape)

    def ax(a, size):
        if a is None:
            return None
        if isinstance(a, tuple):
            tot = 1
            for x in a:
                tot *= mesh.shape[x]
            return a if size % tot == 0 else None
        return a if size % mesh.shape[a] == 0 else None

    if name in ("k", "v", "ek", "ev"):
        if nd == 5:  # (L, B, T, K, hd)
            return P(None, ax(ba, shape[1]), ax(tp, shape[2]), None, None)
        if nd == 4:  # (B, T, K, hd)
            return P(ax(ba, shape[0]), ax(tp, shape[1]), None, None)
    if name == "state":  # (L, B, H, P, N)
        return P(None, ax(ba, shape[1]), ax(tp, shape[2]), None, None)
    if name == "tail":
        if nd == 4:  # (L, B, k-1, C)
            return P(None, ax(ba, shape[1]), None, ax(tp, shape[3]))
        return P(ax(ba, shape[0]), None, ax(tp, shape[2]))
    if name == "h":  # (G, B, W) rg-lru state
        if nd == 3:
            return P(None, ax(ba, shape[1]), ax(tp, shape[2]))
        return P(ax(ba, shape[0]), ax(tp, shape[1]))
    return P(*([None] * nd))


def make_cache_specs(model, mesh, batch: int, max_len: int) -> Any:
    shapes = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_cache(path, leaf.shape, mesh), shapes
    )


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)
