"""Activation-sharding anchors (§Perf-A1).

Without explicit activation constraints, GSPMD propagates shardings from the
vocab-sharded embedding into the batch-sharded token stream and resolves the
conflict with "involuntary full rematerialization" (replicate-then-reshard) —
multi-GB activation tensors per microbatch in the 72B/1T train cells.

Model code calls ``constrain_tokens_like`` at three anchor points (after
embedding, after each block, at the logits); the launcher/dry-run sets the
batch axes before tracing. Defaults to no-op so CPU tests and single-device
runs are untouched. This is the MaxText-style pattern, kept minimal.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_BATCH_AXES: tuple | None = None
_TP_AXIS: str | None = None
_SEQ_PARALLEL: bool = False
_MESH = None


def set_axes(
    batch_axes: tuple | None,
    tp_axis: str | None = "model",
    seq_parallel: bool = False,
    mesh=None,
) -> None:
    global _BATCH_AXES, _TP_AXIS, _SEQ_PARALLEL, _MESH
    _BATCH_AXES = batch_axes
    _TP_AXIS = tp_axis
    _SEQ_PARALLEL = seq_parallel
    _MESH = mesh


def clear() -> None:
    set_axes(None, None)


def mesh_info():
    """(mesh, batch_axes, tp_axis) when set — used by shard_map layers."""
    if _MESH is None or _BATCH_AXES is None:
        return None
    return _MESH, _BATCH_AXES, _TP_AXIS


def _wsc(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that works with or without a mesh context:
    when a mesh was registered via ``set_axes``, bind the spec to it."""
    if _MESH is not None:
        return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_acts(x: jax.Array) -> jax.Array:
    """(B, S, D) activations: batch on (pod, data); with sequence
    parallelism (§Perf-B2) the sequence dim additionally shards on the TP
    axis at block boundaries, turning per-layer all-reduces into
    reduce-scatter + all-gather pairs (half the ring traffic)."""
    if _BATCH_AXES is None:
        return x
    if _SEQ_PARALLEL and x.ndim >= 3:
        spec = P(_BATCH_AXES, _TP_AXIS, *([None] * (x.ndim - 2)))
    else:
        spec = P(_BATCH_AXES, *([None] * (x.ndim - 1)))
    return _wsc(x, spec)


def constrain_logits(x: jax.Array) -> jax.Array:
    """(B, S, V) logits: batch on (pod, data), vocab on the TP axis."""
    if _BATCH_AXES is None:
        return x
    spec = P(_BATCH_AXES, *([None] * (x.ndim - 2)), _TP_AXIS)
    return _wsc(x, spec)


def constrain_decode_scores(scores: jax.Array) -> jax.Array:
    """Flash-decode sharding (§Perf-D3): during single-token decode the KV
    cache is sequence-sharded on the TP axis; keeping the score tensor's T
    dim sharded makes GSPMD compute partial softmax locally and psum only
    the (tiny) output/normalizer, instead of all-gathering the whole cache
    every layer. scores: (B, K, G, 1, T)."""
    if _BATCH_AXES is None:
        return scores
    spec = P(_BATCH_AXES, None, None, None, _TP_AXIS)
    return _wsc(scores, spec)
