"""Fault tolerance: checkpoint/restart supervision, stragglers, elasticity.

The pieces a 1000+-node deployment needs, exercised end-to-end on CPU in the
tests:

  * ``TrainingSupervisor`` — wraps the step loop: periodic async checkpoints,
    automatic restore-and-replay after a step failure (the single-controller
    JAX model means a dead host surfaces as an exception on the controller),
    bounded retry budget, and deterministic data replay (the TokenStream is
    indexed by step, so a restarted run consumes exactly the batches it
    would have).
  * ``StragglerMonitor`` — per-step wall-time EWMA + threshold; on a real pod
    the flagged hook triggers re-scheduling, here it records and reports.
    Lockstep designs (search rounds, microbatch scans) bound a straggler's
    blast radius to one round, see search/distributed.py.
  * ``CircuitBreaker`` / ``WorkerHealth`` — the per-worker health model the
    hedged scheduling layer (DESIGN.md §2.9) routes on: an EWMA latency
    estimate (a ``StragglerMonitor`` per worker) composed with a
    consecutive-failure breaker (closed → open → half-open → closed).
  * ``DecorrelatedJitterBackoff`` — retry sleeps drawn from
    ``uniform(base, 3 * prev)`` capped at ``cap`` (the AWS "decorrelated
    jitter" schedule), so simultaneously-failed workers do not retry in
    lockstep; seeded from ``$REPRO_FAULT_SEED`` by default so the fault
    suites stay reproducible.
  * ``hedge_race`` — the deterministic host emulation of racing backup
    attempts against a straggling primary (DESIGN.md §2.9).

The serving tier mirrors this shape: ``serve.supervisor.SearchSupervisor``
wraps ``StreamSearchEngine`` with the same checkpoint/retry/replay
semantics (and reuses ``StragglerMonitor`` per ingest) — one supervision
idiom across training and serving.
  * ``elastic_reshard`` — rebuilds train state for a smaller/larger "data"
    axis: with parameter/optimizer sharding expressed as PartitionSpecs,
    resharding is ``jax.device_put`` onto the new mesh — the runtime moves
    shards; no format conversion. Batch size per shard is re-derived from the
    new mesh.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple

import numpy as np

import jax

from repro.core import guards
from repro.train import checkpoint as ckpt_lib

# The transient/guard split shared by every supervisor in the repo: these
# retry (a device falling over, a flaky allocator, an RPC deadline —
# TimeoutError is an OSError); the typed guard errors (SearchInputError,
# StreamStateError) are caller bugs and must re-raise immediately. Guard
# errors subclass ValueError/RuntimeError, so catch them FIRST.
TRANSIENT = (RuntimeError, ValueError, OSError)
GUARD_ERRORS = (guards.SearchInputError, guards.StreamStateError)


@dataclass
class StragglerMonitor:
    threshold: float = 3.0          # x EWMA before flagging
    alpha: float = 0.2
    ewma: float | None = None
    flagged: list = field(default_factory=list)
    on_straggler: Callable[[int, float, float], None] | None = None

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = dt > self.threshold * self.ewma
        if is_straggler:
            self.flagged.append((step, dt, self.ewma))
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
        # stragglers don't poison the baseline estimate
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * min(
            dt, self.ewma * self.threshold
        )
        return is_straggler


class CircuitBreaker:
    """Consecutive-failure circuit breaker (DESIGN.md §2.9).

    State machine: **closed** (normal) → **open** after ``threshold``
    consecutive failures (the worker sheds load for ``cooldown`` seconds)
    → **half_open** once the cooldown elapses and a scheduler *acquires*
    the one probe slot → **closed** on probe success, back to **open**
    (cooldown restarted) on probe failure.

    ``ready()`` is a pure read — schedulers may call it on every candidate
    while routing without consuming anything; ``acquire()`` is called only
    on the worker actually picked, and is what converts an elapsed cooldown
    into the single half-open probe.
    """

    def __init__(
        self,
        threshold: int = 3,
        cooldown: float = 1.0,
        clock: Callable[[], float] = time.time,
    ):
        if threshold < 1:
            raise guards.SearchInputError("breaker threshold must be >= 1")
        if cooldown < 0:
            raise guards.SearchInputError("breaker cooldown must be >= 0")
        self.threshold = int(threshold)
        self.cooldown = float(cooldown)
        self._clock = clock
        self.state = "closed"
        self.consecutive_failures = 0
        self.failures = 0
        self.trips = 0
        self.opened_at: float | None = None

    def ready(self) -> bool:
        """May an attempt be routed here? (Pure; consumes nothing.)"""
        if self.state == "closed":
            return True
        if self.state == "open":
            return (
                self.opened_at is not None
                and self._clock() - self.opened_at >= self.cooldown
            )
        return False  # half_open: the one probe is already outstanding

    def acquire(self) -> None:
        """An attempt is about to run here; claim the half-open probe slot
        when the cooldown has elapsed."""
        if self.state == "open" and self.ready():
            self.state = "half_open"

    def record_success(self) -> None:
        self.consecutive_failures = 0
        self.state = "closed"
        self.opened_at = None

    def record_failure(self) -> None:
        self.failures += 1
        self.consecutive_failures += 1
        if (
            self.state == "half_open"
            or self.consecutive_failures >= self.threshold
        ):
            if self.state != "open":
                self.trips += 1
            self.state = "open"
            self.opened_at = self._clock()


class HealthSnapshot(NamedTuple):
    """Read-only view of one worker's health, surfaced on results."""
    state: str               # breaker state: closed | open | half_open
    ewma: float | None       # EWMA attempt latency (None: never observed)
    attempts: int            # completed attempts observed
    failures: int            # total failures recorded
    consecutive_failures: int
    trips: int               # times the breaker opened


class WorkerHealth:
    """Per-worker health: EWMA latency + circuit breaker (DESIGN.md §2.9).

    The unit the hedged scheduling layer routes on — one per shard in
    ``search.resilient.resilient_search``, one per wrapped executor in
    ``search.pipeline.HedgedExecutor``. Composes a per-worker
    ``StragglerMonitor`` (the latency estimate that derives hedge delays
    and classifies a worker as degraded) with a ``CircuitBreaker`` (the
    availability gate that routes load off a repeatedly-failing worker).
    """

    def __init__(
        self,
        *,
        threshold: float = 3.0,
        alpha: float = 0.2,
        breaker_threshold: int = 3,
        breaker_cooldown: float = 1.0,
        clock: Callable[[], float] = time.time,
    ):
        self.monitor = StragglerMonitor(threshold=threshold, alpha=alpha)
        self.breaker = CircuitBreaker(
            breaker_threshold, breaker_cooldown, clock
        )
        self.attempts = 0

    @property
    def ewma(self) -> float | None:
        return self.monitor.ewma

    def observe(self, dt: float) -> bool:
        """A completed attempt took ``dt`` seconds (closes the breaker)."""
        self.attempts += 1
        flagged = self.monitor.observe(self.attempts - 1, dt)
        self.breaker.record_success()
        return flagged

    def fail(self) -> None:
        self.breaker.record_failure()

    def ready(self) -> bool:
        return self.breaker.ready()

    def acquire(self) -> None:
        self.breaker.acquire()

    def snapshot(self) -> HealthSnapshot:
        return HealthSnapshot(
            state=self.breaker.state,
            ewma=self.monitor.ewma,
            attempts=self.attempts,
            failures=self.breaker.failures,
            consecutive_failures=self.breaker.consecutive_failures,
            trips=self.breaker.trips,
        )


class DecorrelatedJitterBackoff:
    """Retry sleeps with decorrelated jitter: ``uniform(base, 3 * prev)``.

    The plain exponential schedule (``base * 2**k``) retries every
    simultaneously-failed worker in lockstep — exactly the synchronized
    burst that knocked them over in the first place. The decorrelated form
    (Brooker, "Exponential Backoff and Jitter") keeps the exponential
    envelope in expectation while spreading retries over the interval.

    Deterministic given its seed; ``seed=None`` reads ``$REPRO_FAULT_SEED``
    (default 0) so the seeded check.sh fault pass varies the draw while any
    single run stays reproducible. ``reset()`` starts a fresh retry
    sequence (call it when a new failure episode begins).
    """

    def __init__(
        self,
        base: float,
        cap: float | None = None,
        seed: int | None = None,
    ):
        if base < 0:
            raise guards.SearchInputError("backoff base must be >= 0")
        self.base = float(base)
        self.cap = float(cap) if cap is not None else self.base * 16.0
        if seed is None:
            seed = int(os.environ.get("REPRO_FAULT_SEED", 0))
        self._rng = np.random.default_rng(seed)
        self._prev = self.base

    def reset(self) -> None:
        self._prev = self.base

    def next(self) -> float:
        if self.base == 0.0:
            return 0.0
        lo, hi = self.base, max(self._prev * 3.0, self.base)
        self._prev = min(self.cap, float(self._rng.uniform(lo, hi)))
        return self._prev


class HedgeOutcome(NamedTuple):
    """One hedged attempt's adjudication (all times in ``clock`` units)."""
    launched: int        # backup attempts actually launched
    won: bool            # a backup (virtually) finished before the primary
    effective_dt: float  # min over completions of their virtual finish time
    completions: tuple   # ((tag, result, backup_dt), ...) completed backups


def hedge_race(
    primary_dt: float,
    delay: float,
    backups,
    *,
    clock: Callable[[], float] = time.time,
    max_inflight: int = 2,
    on_failure: Callable[[Any, BaseException], None] | None = None,
) -> HedgeOutcome:
    """Race backup attempts against a primary that took ``primary_dt``.

    The deterministic host emulation of hedged dispatch (DESIGN.md §2.9):
    the host runs attempts sequentially, so the primary has already
    *completed* (in ``primary_dt`` seconds of the injectable clock) by the
    time this adjudicator runs. The race is replayed on the virtual
    timeline a concurrent deployment would see: backup ``k`` (1-based)
    launches at ``k * delay`` — but only if nothing has virtually finished
    by then — runs for its measured ``dt_k``, and finishes at
    ``k * delay + dt_k``. ``effective_dt`` is the latency a client would
    have observed: the min finish time over the primary and every
    completed backup. ``max_inflight`` caps how many backups may race one
    straggling primary (the ladder depth).

    ``backups`` yields ``(tag, thunk)`` lazily so the caller can pick each
    next-healthiest worker *at launch time*. A backup raising a transient
    error is reported to ``on_failure`` and contributes nothing; guard
    errors re-raise (caller bugs are never hedged away).
    """
    launched = 0
    best_eff = primary_dt
    completions = []
    for k, (tag, thunk) in enumerate(backups, start=1):
        if launched >= max_inflight:
            break
        launch_t = k * delay
        if best_eff <= launch_t:
            break  # someone already (virtually) finished; no more hedges
        launched += 1
        t0 = clock()
        try:
            result = thunk()
        except GUARD_ERRORS:
            raise
        except TRANSIENT as e:
            if on_failure is not None:
                on_failure(tag, e)
            continue
        dt_k = clock() - t0
        completions.append((tag, result, dt_k))
        best_eff = min(best_eff, launch_t + dt_k)
    return HedgeOutcome(
        launched=launched,
        won=best_eff < primary_dt,
        effective_dt=best_eff,
        completions=tuple(completions),
    )


class TrainingSupervisor:
    """Checkpoint/restart wrapper around a jitted train step."""

    def __init__(
        self,
        train_step: Callable,
        data_at: Callable[[int], Any],
        ckpt_dir: str,
        ckpt_every: int = 50,
        max_retries: int = 3,
        async_ckpt: bool = True,
        keep: int = 3,
    ):
        self.train_step = train_step
        self.data_at = data_at
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.monitor = StragglerMonitor()
        self.restarts = 0
        self._async = (
            ckpt_lib.AsyncCheckpointer(ckpt_dir, keep=keep) if async_ckpt else None
        )
        self.keep = keep

    def _save(self, state, step: int):
        if self._async is not None:
            self._async.submit(state, step)
        else:
            ckpt_lib.save(self.ckpt_dir, state, step)
            ckpt_lib.prune_old(self.ckpt_dir, self.keep)

    def resume_or(self, state):
        """Restore the latest checkpoint if one exists."""
        if self._async is not None:
            # Write barrier: without it, latest_step can miss a submitted-
            # but-uncommitted step and replay would rewind past real
            # progress (same rule as SearchSupervisor._barrier).
            self._async.wait()
        step = ckpt_lib.latest_step(self.ckpt_dir)
        if step is None:
            return state, 0
        restored, step = ckpt_lib.restore(self.ckpt_dir, state)
        return restored, step

    def run(self, state, n_steps: int, fail_injector: Callable[[int], None] | None = None):
        """Run to ``n_steps`` total steps with checkpoint/restart semantics.

        ``fail_injector(step)`` may raise to simulate node failure; the
        supervisor restores the last checkpoint and replays deterministically.
        """
        state, step = self.resume_or(state)
        metrics_log = []
        retries = 0
        while step < n_steps:
            try:
                if fail_injector is not None:
                    fail_injector(step)
                t0 = time.time()
                batch = self.data_at(step)
                state, metrics = self.train_step(state, batch)
                jax.block_until_ready(metrics["loss"])
                self.monitor.observe(step, time.time() - t0)
                step += 1
                retries = 0
                metrics_log.append({k: float(v) for k, v in metrics.items()})
                if step % self.ckpt_every == 0:
                    self._save(state, step)
            except (RuntimeError, ValueError, OSError) as e:
                self.restarts += 1
                retries += 1
                if retries > self.max_retries:
                    raise RuntimeError(
                        f"exceeded {self.max_retries} retries at step {step}"
                    ) from e
                state, step = self.resume_or(state)
        self._save(state, step)
        if self._async is not None:
            self._async.close()
            self._async = ckpt_lib.AsyncCheckpointer(self.ckpt_dir, keep=self.keep)
        return state, metrics_log


def elastic_reshard(state, old_mesh, new_mesh, make_specs: Callable):
    """Re-place train state onto a new mesh (shrunk/grown "data" axis).

    ``make_specs(mesh)`` returns the PartitionSpec tree for the state. All
    movement happens inside ``device_put`` (shard redistribution); values are
    bit-identical.
    """
    from repro.distributed.sharding import named

    new_specs = make_specs(new_mesh)
    return jax.device_put(state, named(new_mesh, new_specs))
