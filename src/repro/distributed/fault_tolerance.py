"""Fault tolerance: checkpoint/restart supervision, stragglers, elasticity.

The pieces a 1000+-node deployment needs, exercised end-to-end on CPU in the
tests:

  * ``TrainingSupervisor`` — wraps the step loop: periodic async checkpoints,
    automatic restore-and-replay after a step failure (the single-controller
    JAX model means a dead host surfaces as an exception on the controller),
    bounded retry budget, and deterministic data replay (the TokenStream is
    indexed by step, so a restarted run consumes exactly the batches it
    would have).
  * ``StragglerMonitor`` — per-step wall-time EWMA + threshold; on a real pod
    the flagged hook triggers re-scheduling, here it records and reports.
    Lockstep designs (search rounds, microbatch scans) bound a straggler's
    blast radius to one round, see search/distributed.py.

The serving tier mirrors this shape: ``serve.supervisor.SearchSupervisor``
wraps ``StreamSearchEngine`` with the same checkpoint/retry/replay
semantics (and reuses ``StragglerMonitor`` per ingest) — one supervision
idiom across training and serving.
  * ``elastic_reshard`` — rebuilds train state for a smaller/larger "data"
    axis: with parameter/optimizer sharding expressed as PartitionSpecs,
    resharding is ``jax.device_put`` onto the new mesh — the runtime moves
    shards; no format conversion. Batch size per shard is re-derived from the
    new mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from repro.train import checkpoint as ckpt_lib


@dataclass
class StragglerMonitor:
    threshold: float = 3.0          # x EWMA before flagging
    alpha: float = 0.2
    ewma: float | None = None
    flagged: list = field(default_factory=list)
    on_straggler: Callable[[int, float, float], None] | None = None

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = dt > self.threshold * self.ewma
        if is_straggler:
            self.flagged.append((step, dt, self.ewma))
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
        # stragglers don't poison the baseline estimate
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * min(
            dt, self.ewma * self.threshold
        )
        return is_straggler


class TrainingSupervisor:
    """Checkpoint/restart wrapper around a jitted train step."""

    def __init__(
        self,
        train_step: Callable,
        data_at: Callable[[int], Any],
        ckpt_dir: str,
        ckpt_every: int = 50,
        max_retries: int = 3,
        async_ckpt: bool = True,
        keep: int = 3,
    ):
        self.train_step = train_step
        self.data_at = data_at
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.monitor = StragglerMonitor()
        self.restarts = 0
        self._async = (
            ckpt_lib.AsyncCheckpointer(ckpt_dir, keep=keep) if async_ckpt else None
        )
        self.keep = keep

    def _save(self, state, step: int):
        if self._async is not None:
            self._async.submit(state, step)
        else:
            ckpt_lib.save(self.ckpt_dir, state, step)
            ckpt_lib.prune_old(self.ckpt_dir, self.keep)

    def resume_or(self, state):
        """Restore the latest checkpoint if one exists."""
        if self._async is not None:
            # Write barrier: without it, latest_step can miss a submitted-
            # but-uncommitted step and replay would rewind past real
            # progress (same rule as SearchSupervisor._barrier).
            self._async.wait()
        step = ckpt_lib.latest_step(self.ckpt_dir)
        if step is None:
            return state, 0
        restored, step = ckpt_lib.restore(self.ckpt_dir, state)
        return restored, step

    def run(self, state, n_steps: int, fail_injector: Callable[[int], None] | None = None):
        """Run to ``n_steps`` total steps with checkpoint/restart semantics.

        ``fail_injector(step)`` may raise to simulate node failure; the
        supervisor restores the last checkpoint and replays deterministically.
        """
        state, step = self.resume_or(state)
        metrics_log = []
        retries = 0
        while step < n_steps:
            try:
                if fail_injector is not None:
                    fail_injector(step)
                t0 = time.time()
                batch = self.data_at(step)
                state, metrics = self.train_step(state, batch)
                jax.block_until_ready(metrics["loss"])
                self.monitor.observe(step, time.time() - t0)
                step += 1
                retries = 0
                metrics_log.append({k: float(v) for k, v in metrics.items()})
                if step % self.ckpt_every == 0:
                    self._save(state, step)
            except (RuntimeError, ValueError, OSError) as e:
                self.restarts += 1
                retries += 1
                if retries > self.max_retries:
                    raise RuntimeError(
                        f"exceeded {self.max_retries} retries at step {step}"
                    ) from e
                state, step = self.resume_or(state)
        self._save(state, step)
        if self._async is not None:
            self._async.close()
            self._async = ckpt_lib.AsyncCheckpointer(self.ckpt_dir, keep=self.keep)
        return state, metrics_log


def elastic_reshard(state, old_mesh, new_mesh, make_specs: Callable):
    """Re-place train state onto a new mesh (shrunk/grown "data" axis).

    ``make_specs(mesh)`` returns the PartitionSpec tree for the state. All
    movement happens inside ``device_put`` (shard redistribution); values are
    bit-identical.
    """
    from repro.distributed.sharding import named

    new_specs = make_specs(new_mesh)
    return jax.device_put(state, named(new_mesh, new_specs))
