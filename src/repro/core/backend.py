"""Backend dispatch for the batched EAPrunedDTW hot path.

One question, answered in one place: *which implementation evaluates a batch
of candidates?* Two real backends exist:

  ``pallas`` — the TPU kernel (``kernels.ops.dtw_ea``): a banded
      ``(candidate_blocks, row_blocks)`` grid with the DP carry in VMEM and a
      block-level early-exit flag. Rows advance in lockstep across the lanes
      of a block, so abandon granularity is the block — coarser than the JAX
      path but with none of vmap's per-lane while_loop degradation. On
      non-TPU platforms the same kernel runs in interpret mode (Python
      execution of the kernel body) — correct everywhere, fast only on TPU.

  ``jax`` — ``core.ea_pruned_dtw.ea_pruned_dtw_banded`` under ``vmap``: a
      per-lane banded ``lax.while_loop``. Under vmap every lane steps until
      the slowest lane of the whole batch finishes, with per-lane
      dynamic-slice realignment each row. This is the portable CPU/GPU
      fallback and the float64 reference (the kernel is float32).

Selection order:

  1. explicit ``backend=`` argument (``"pallas"``, ``"pallas_interpret"``,
     ``"jax"``, ``"auto"``),
  2. the ``REPRO_DTW_BACKEND`` environment variable (same values) when the
     argument is ``None`` / ``"auto"`` is passed through it,
  3. platform default: ``pallas`` on TPU, ``jax`` elsewhere.

``pallas_interpret`` forces interpret mode on any platform — the CI path
that exercises the kernel's exact program on CPU. Multivariate queries
(``query.ndim > 1``) always take the ``jax`` backend; the kernel is
univariate (the paper's workload).

Every public entry point (``ea_pruned_dtw_batch``, ``ea_search_round``,
``subsequence_search``, ``multi_query_search``) resolves the environment
variable in its un-jitted wrapper, so the resolved name becomes the static
``backend`` argument of the jitted program: changing ``REPRO_DTW_BACKEND``
between calls correctly retraces. Only ``make_distributed_search`` /
``make_distributed_multi_search`` pin the backend once, at closure-build
time.
"""
from __future__ import annotations

import os

import jax

BACKENDS = ("auto", "pallas", "pallas_interpret", "jax")
ENV_VAR = "REPRO_DTW_BACKEND"


def resolve_backend(backend: str | None = None) -> str:
    """Resolve a backend request to a concrete backend name.

    ``None`` defers to ``$REPRO_DTW_BACKEND`` (default ``auto``); ``auto``
    picks ``pallas`` on TPU and ``jax`` elsewhere. Returns one of
    ``("pallas", "pallas_interpret", "jax")``.
    """
    b = backend if backend is not None else os.environ.get(ENV_VAR, "auto")
    if b not in BACKENDS:
        raise ValueError(f"backend {b!r} not in {BACKENDS}")
    if b == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jax"
    return b
