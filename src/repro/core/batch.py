"""Batched EAPrunedDTW — the TPU-native unit of similarity-search work.

The UCR suite streams candidates one at a time, tightening ``ub`` after each.
A TPU wants thousands of independent lanes in flight, so the unit of work here
is a *batch* of K candidates evaluated under one shared ``ub`` (DESIGN.md
§2.4). Each lane early-abandons independently (its banded while_loop predicate
goes false); the batch completes when every lane has abandoned or finished;
``ub`` is then tightened with the batch minimum before the next batch.

Best-first ordering by lower bound (see search/cascade.py) restores most of
the sequential tightening power the paper gets for free.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.ea_pruned_dtw import ea_pruned_dtw_banded


@partial(jax.jit, static_argnames=("window", "band_width", "rows_per_step"))
def ea_pruned_dtw_batch(
    query: jax.Array,
    candidates: jax.Array,
    ub: jax.Array,
    window: int,
    band_width: int | None = None,
    cb: jax.Array | None = None,
    rows_per_step: int = 1,
) -> jax.Array:
    """Banded EAPrunedDTW of one query against K candidates, shared ``ub``.

    Args:
      query: ``(m,)`` or ``(m, dims)``.
      candidates: ``(K, m[, dims])``.
      ub: scalar upper bound shared by the whole batch.
      window: Sakoe-Chiba window.
      band_width: static band columns per row (defaults to lane-aligned
        ``2*window+1``).
      cb: optional ``(K, m)`` per-candidate cumulative LB_Keogh suffix sums
        for UCR-style threshold tightening.

    Returns: ``(K,)`` distances; ``+inf`` where abandoned.
    """
    if cb is None:
        fn = lambda c: ea_pruned_dtw_banded(
            query, c, ub, window=window, band_width=band_width,
            rows_per_step=rows_per_step,
        )
        return jax.vmap(fn)(candidates)
    fn = lambda c, cbv: ea_pruned_dtw_banded(
        query, c, ub, window=window, band_width=band_width, cb=cbv,
        rows_per_step=rows_per_step,
    )
    return jax.vmap(fn)(candidates, cb)


@partial(jax.jit, static_argnames=("window", "band_width"))
def ea_search_round(
    query: jax.Array,
    candidates: jax.Array,
    ub: jax.Array,
    best_idx: jax.Array,
    cand_idx: jax.Array,
    window: int,
    band_width: int | None = None,
    cb: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """One search round: batch EAPrunedDTW + incumbent update.

    ``cand_idx`` carries the global index of each candidate (for argmin
    bookkeeping across rounds). Returns updated ``(ub, best_idx)``. Ties keep
    the incumbent (strict improvement only), matching the paper's strictness
    rule for early abandoning.
    """
    d = ea_pruned_dtw_batch(query, candidates, ub, window, band_width, cb)
    k = jnp.argmin(d)
    dmin = d[k]
    improved = dmin < ub
    new_ub = jnp.where(improved, dmin, ub)
    new_best = jnp.where(improved, cand_idx[k], best_idx)
    return new_ub, new_best
