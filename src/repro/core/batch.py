"""Batched EAPrunedDTW — the TPU-native unit of similarity-search work.

The UCR suite streams candidates one at a time, tightening ``ub`` after each.
A TPU wants thousands of independent lanes in flight, so the unit of work
here is a *batch* of lanes evaluated in one dispatch (DESIGN.md §2.4). Each
lane early-abandons independently against **its own** upper bound; the batch
completes when every lane has abandoned or finished; incumbents are then
tightened with the batch minima before the next batch. Best-first ordering
by lower bound (see search/cascade.py) restores most of the sequential
tightening power the paper gets for free.

Two batch shapes share one kernel program:

  * ``ea_pruned_dtw_batch`` — one query against ``K`` candidates. ``ub`` may
    be a scalar (shared, the PR-1 behaviour) or a ``(K,)`` per-lane vector.
  * ``ea_pruned_dtw_multi_batch`` — ``Q`` queries against their own
    ``(Q, K, m)`` candidate rounds, flattened to a ``(Q × K)`` lane set and
    evaluated in **one** launch with a ``(Q, K)`` per-lane ``ub``. This is
    the multi-query serving primitive: no per-query launches, no per-query
    recompilation, and finished queries ride along as dead lanes (negative
    ``ub`` sentinel) that abandon on row 0.

Backend dispatch (see ``core.backend``): both entry points route to one of
two implementations:

  * ``backend="pallas"`` / ``"pallas_interpret"`` — the banded Pallas kernel
    (``kernels.ops.dtw_ea`` / ``dtw_ea_multi``). Tuning knobs: ``band_width``
    (columns per row, lane-aligned default), ``block_k`` (candidate lanes per
    grid block — the early-exit granularity), ``row_block`` (DP rows per
    sequential grid step). ``pallas`` lowers through Mosaic on TPU and falls
    back to interpret mode elsewhere; ``pallas_interpret`` forces interpret
    mode (the CPU test path for the kernel program).
  * ``backend="jax"`` — per-lane banded ``lax.while_loop`` under ``vmap``
    (CPU/GPU fallback, float64-capable reference), with ``ub`` vmapped per
    lane so the semantics match the kernel exactly. Tuning knobs:
    ``band_width``, ``rows_per_step`` (rows per loop iteration — amortizes
    vmap'd loop-control overhead).

``backend=None`` defers to ``$REPRO_DTW_BACKEND``, then the platform default
(``pallas`` on TPU, ``jax`` elsewhere); the env var is re-read on every
(un-jitted) call, so changing it between calls takes effect. Multivariate
queries always take the ``jax`` path. ``with_info=True`` additionally
returns per-lane ``EAInfo`` pruning counters; the default is counter-free —
search fast rounds pay no bookkeeping.

Fused-gather primitives (DESIGN.md §2.10, ``gather="fused"`` — the search
default): ``ea_pruned_dtw_multi_batch_fused`` and
``ea_pruned_dtw_persistent_fused`` take the raw reference series plus
per-lane starts and the O(N) ``(mu, sigma)`` stats tables instead of a
pre-gathered ``(Q, K, m)`` window slab. On the Pallas backends the slicing
and z-normalization happen inside the kernel; on the jax backend the same
fusion is a vmapped ``dynamic_slice`` + normalize inlined into the round
body (and, for persistent mode, into each ``while_loop`` block step — an
O(N + block_k·m) working set matching the kernel, where the slab form
materialized all O(K·m) up front). Values are bit-identical to the slab
form: same copies, same ``clamp_sigma``, same op order.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import guards
from repro.core.backend import resolve_backend
from repro.core.common import (
    DEAD_LANE_UB,
    clamp_sigma,
    pad_lanes_to_blocks,
)
from repro.core.ea_pruned_dtw import EAInfo, ea_pruned_dtw_banded
from repro.core.lower_bounds import cascade_keogh_cumulative


def _slice_norm(ref, starts, length, mu_l, sg_l):
    """Fused normalize-on-slice of one lane set (``(K, length)``).

    ``mu_l``/``sg_l`` are per-lane (already indexed by start, sigma
    pre-clamped) — the trace-inlined form of ``common.norm_window_slice``
    used inside round and while_loop bodies, where the stats lookups have
    already been hoisted.
    """
    win = jax.vmap(
        lambda s: jax.lax.dynamic_slice(ref, (s,), (length,))
    )(starts)
    return (win - mu_l[:, None]) / sg_l[:, None]


def _kernel_ops():
    """Deferred ``repro.kernels.ops`` import, resolved at dispatch time.

    ``repro.kernels`` imports ``repro.core.common``, which triggers this
    package's ``__init__`` — a module-level import here would close a
    ``kernels → core → kernels`` cycle and crash any kernels-first entry
    point (``import repro.kernels`` before ``repro.core``). Python caches
    the module after the first call, so the per-dispatch cost is a dict hit.
    """
    from repro.kernels import ops

    return ops


@partial(
    jax.jit,
    static_argnames=("window", "band_width", "rows_per_step", "with_info"),
)
def _batch_jax(
    query, candidates, ub, window, band_width, cb, rows_per_step, with_info
):
    """vmapped banded-while_loop backend (CPU/GPU fallback), per-lane ub."""
    ub_lanes = jnp.broadcast_to(jnp.asarray(ub), candidates.shape[:1])
    if cb is None:
        fn = lambda c, u: ea_pruned_dtw_banded(
            query, c, u, window=window, band_width=band_width,
            rows_per_step=rows_per_step, with_info=with_info,
        )
        return jax.vmap(fn)(candidates, ub_lanes)
    fn = lambda c, u, cbv: ea_pruned_dtw_banded(
        query, c, u, window=window, band_width=band_width, cb=cbv,
        rows_per_step=rows_per_step, with_info=with_info,
    )
    return jax.vmap(fn)(candidates, ub_lanes, cb)


@partial(
    jax.jit,
    static_argnames=("window", "band_width", "rows_per_step", "with_info"),
)
def _multi_jax(
    queries, candidates, ub, window, band_width, cb, rows_per_step, with_info
):
    """Multi-query jax backend: per-lane batches over the query axis.

    On CPU the query axis runs under ``lax.map`` so each query's lanes get
    their *own* while_loop trip count — under a fused ``vmap`` every lane
    would step until the slowest lane of the slowest query (measured ~20%
    inflation on mixed-tightness workloads), and a finished query's dead
    lanes would be re-masked every iteration instead of exiting after one.
    On accelerators the fused vmap keeps all ``Q × K`` lanes in flight (the
    lockstep cost is what the hardware wants; the Pallas backend is the
    preferred path there anyway).
    """
    ub_lanes = jnp.broadcast_to(jnp.asarray(ub), candidates.shape[:2])

    def _mapped(fn, ops):
        # lax.cond skips the whole while_loop for an all-dead query — the
        # finished-query fast path the round loop relies on. Counter rounds
        # always run for real: a dead lane issues its abandoning row
        # (EAInfo semantics), which the skipped branch could not report.
        if with_info:
            return jax.lax.map(lambda t: fn(*t), ops)
        out_sd = jax.eval_shape(fn, *jax.tree.map(lambda x: x[0], ops))

        def dead():
            return jax.tree.map(
                lambda sd: jnp.full(sd.shape, jnp.inf, sd.dtype), out_sd
            )

        return jax.lax.map(
            lambda t: jax.lax.cond(
                jnp.any(t[2] >= 0), lambda: fn(*t), dead
            ),
            ops,
        )

    if cb is None:
        fn = lambda q, cs, us: _batch_jax(
            q, cs, us, window, band_width, None, rows_per_step, with_info
        )
        if jax.default_backend() == "cpu":
            return _mapped(fn, (queries, candidates, ub_lanes))
        return jax.vmap(fn)(queries, candidates, ub_lanes)
    fn = lambda q, cs, us, cbs: _batch_jax(
        q, cs, us, window, band_width, cbs, rows_per_step, with_info
    )
    if jax.default_backend() == "cpu":
        return _mapped(fn, (queries, candidates, ub_lanes, cb))
    return jax.vmap(fn)(queries, candidates, ub_lanes, cb)


def ea_pruned_dtw_batch(
    query: jax.Array,
    candidates: jax.Array,
    ub: jax.Array,
    window: int,
    band_width: int | None = None,
    cb: jax.Array | None = None,
    rows_per_step: int = 1,
    backend: str | None = None,
    block_k: int = 8,
    row_block: int = 128,
    with_info: bool = False,
):
    """Banded EAPrunedDTW of one query against K candidates.

    Args:
      query: ``(m,)`` or ``(m, dims)``.
      candidates: ``(K, m[, dims])``.
      ub: scalar upper bound shared by the whole batch, or ``(K,)`` per-lane
        upper bounds (each lane abandons against its own).
      window: Sakoe-Chiba window.
      band_width: static band columns per row (defaults to lane-aligned
        ``2*window+1``).
      cb: optional ``(K, m)`` per-candidate cumulative LB_Keogh suffix sums
        for UCR-style threshold tightening.
      rows_per_step: rows per while_loop iteration (``jax`` backend knob).
      backend: ``"pallas"`` / ``"pallas_interpret"`` / ``"jax"`` / ``"auto"``;
        ``None`` defers to ``$REPRO_DTW_BACKEND`` then the platform default.
      block_k, row_block: Pallas grid tiling knobs.
      with_info: also return per-lane ``EAInfo`` pruning counters.

    Returns: ``(K,)`` distances (``+inf`` where abandoned); with ``with_info``
      a ``(distances, EAInfo)`` tuple of per-lane arrays.

    Raises ``core.guards.SearchInputError`` on malformed shapes/knobs and
    ``NonFiniteInputError`` on a non-finite query (value checks run only on
    concrete arrays — trace-safe when called from jitted drivers).
    """
    guards.check_batch_args(query, candidates, ub, window, cb=cb)
    resolved = resolve_backend(backend)
    if resolved != "jax" and jnp.ndim(query) != 1:
        resolved = "jax"  # kernel is univariate; see core.backend docstring
    if resolved == "jax":
        out = _batch_jax(
            query, candidates, ub, window, band_width, cb, rows_per_step,
            with_info,
        )
        return out
    interpret = True if resolved == "pallas_interpret" else None
    out = _kernel_ops().dtw_ea(
        query, candidates, ub, window, cb=cb, band_width=band_width,
        block_k=block_k, row_block=row_block, interpret=interpret,
        with_info=with_info,
    )
    if with_info:
        d, rows, cells = out
        return d, EAInfo(rows=rows, cells=cells)
    return out


def ea_pruned_dtw_multi_batch(
    queries: jax.Array,
    candidates: jax.Array,
    ub: jax.Array,
    window: int,
    band_width: int | None = None,
    cb: jax.Array | None = None,
    rows_per_step: int = 1,
    backend: str | None = None,
    block_k: int = 8,
    row_block: int = 128,
    with_info: bool = False,
):
    """Banded EAPrunedDTW of Q queries against their own candidate rounds.

    The flattened ``(Q × K)`` lane set is evaluated in one dispatch: one
    Pallas launch with a query-block grid dimension, or one nested-vmap JAX
    program — no per-query launches or recompiles.

    Args:
      queries: ``(Q, m)`` z-normalized queries (multivariate multi-query is
        not supported — route per query through ``ea_pruned_dtw_batch``).
      candidates: ``(Q, K, m)`` candidate windows per query.
      ub: per-lane upper bounds — scalar, ``(Q, 1)`` or ``(Q, K)``
        (broadcast to ``(Q, K)``). Negative entries are dead-lane sentinels:
        those lanes abandon on row 0 (how finished queries ride along).
      window, band_width, cb, rows_per_step, backend, block_k, row_block,
        with_info: as in ``ea_pruned_dtw_batch`` (``cb`` is ``(Q, K, m)``).

    Returns: ``(Q, K)`` distances (``+inf`` where abandoned); with
      ``with_info`` a ``(distances, EAInfo)`` tuple of ``(Q, K)`` arrays.
    """
    guards.check_batch_args(queries, candidates, ub, window, cb=cb, multi=True)
    resolved = resolve_backend(backend)
    if resolved == "jax":
        return _multi_jax(
            queries, candidates, ub, window, band_width, cb, rows_per_step,
            with_info,
        )
    interpret = True if resolved == "pallas_interpret" else None
    out = _kernel_ops().dtw_ea_multi(
        queries, candidates, ub, window, cb=cb, band_width=band_width,
        block_k=block_k, row_block=row_block, interpret=interpret,
        with_info=with_info,
    )
    if with_info:
        d, rows, cells = out
        return d, EAInfo(rows=rows, cells=cells)
    return out


@partial(
    jax.jit,
    static_argnames=(
        "window", "length", "band_width", "rows_per_step", "with_info",
        "use_cb",
    ),
)
def _multi_jax_fused(
    queries, ref, starts, mu_l, sg_l, ub, u, low, window, length,
    band_width, rows_per_step, with_info, use_cb,
):
    """Fused-gather ``_multi_jax``: slice + normalize inside the round body.

    Per query, the candidate tile is built by vmapped ``dynamic_slice`` of
    the resident reference and normalized in place of arriving as a
    pre-gathered operand; with ``use_cb`` the cb suffix is computed from the
    just-built tile (per-lane sequential cumsum — bit-identical to the
    gathered jax path). The CPU ``lax.map`` + dead-query ``cond`` structure
    mirrors ``_multi_jax``, so a finished query skips its gather too.
    """
    ub_lanes = jnp.broadcast_to(jnp.asarray(ub), starts.shape)

    def _mapped(fn, ops):
        if with_info:
            return jax.lax.map(lambda t: fn(*t), ops)
        out_sd = jax.eval_shape(fn, *jax.tree.map(lambda x: x[0], ops))

        def dead():
            return jax.tree.map(
                lambda sd: jnp.full(sd.shape, jnp.inf, sd.dtype), out_sd
            )

        return jax.lax.map(
            lambda t: jax.lax.cond(
                jnp.any(t[4] >= 0), lambda: fn(*t), dead
            ),
            ops,
        )

    def fn(q, sq, muq, sgq, us, uq, lowq):
        c = _slice_norm(ref, sq, length, muq, sgq)
        cb = None
        if use_cb:
            cb = jax.vmap(
                lambda cc: cascade_keogh_cumulative(cc, uq, lowq)
            )(c)
        return _batch_jax(
            q, c, us, window, band_width, cb, rows_per_step, with_info
        )

    ops_t = (queries, starts, mu_l, sg_l, ub_lanes, u, low)
    if jax.default_backend() == "cpu":
        return _mapped(fn, ops_t)
    return jax.vmap(fn)(*ops_t)


def ea_pruned_dtw_multi_batch_fused(
    queries: jax.Array,
    ref: jax.Array,
    starts: jax.Array,
    ub: jax.Array,
    window: int,
    mu: jax.Array,
    sigma: jax.Array,
    envelopes: tuple[jax.Array, jax.Array] | None = None,
    band_width: int | None = None,
    rows_per_step: int = 1,
    backend: str | None = None,
    block_k: int = 8,
    row_block: int = 128,
    with_info: bool = False,
    ref_budget: int | None = None,
):
    """Fused-gather ``ea_pruned_dtw_multi_batch``: no candidate slab.

    Candidate windows are described, not materialized: the raw (sanitized)
    reference rides in once per dispatch and each lane carries
    ``(start, mu, sigma)``. Slicing + z-normalization happen inside the
    kernel (Pallas) or inside the jitted round body (jax) — results are
    bit-identical to gathering with ``gather_norm_windows`` first, because
    the copies, ``clamp_sigma``, and op order are the same.

    Args (where they differ from ``ea_pruned_dtw_multi_batch``):
      ref: ``(N,)`` raw (sanitized) reference series.
      starts: ``(Q, K)`` int32 window start per lane.
      mu, sigma: full ``(N_win,)`` per-window stats tables
        (``znorm.window_stats``); indexed by ``starts`` here — ``sigma`` is
        raw, the clamp is applied at this boundary.
      envelopes: optional ``(u, low)`` pair of ``(Q, m)`` query envelopes —
        enables UCR ``cb`` tightening, computed from the fused tile (the
        Pallas round kernel builds it in-kernel with a tree-order suffix
        sum: the documented O(1)-ulp reformulation; the jax path is
        bit-identical to the gathered jax path).
      ref_budget: Pallas-only — VMEM byte budget for the reference operand
        (above it the kernel DMA-streams windows from HBM).

    Returns: as ``ea_pruned_dtw_multi_batch``.
    """
    if jnp.ndim(queries) != 2:
        raise guards.SearchInputError(
            "fused multi batch requires (Q, m) univariate queries"
        )
    length = int(queries.shape[1])
    starts = jnp.asarray(starts, jnp.int32)
    mu_l = jnp.asarray(mu)[starts]
    sg_l = clamp_sigma(jnp.asarray(sigma))[starts]
    use_cb = envelopes is not None
    u, low = envelopes if use_cb else (None, None)
    resolved = resolve_backend(backend)
    if resolved == "jax":
        nq, m = queries.shape
        dt = queries.dtype
        if u is None:
            u_arr = jnp.zeros((nq, m), dt)
            low_arr = jnp.zeros((nq, m), dt)
        else:
            u_arr, low_arr = jnp.asarray(u, dt), jnp.asarray(low, dt)
        return _multi_jax_fused(
            queries, ref, starts, mu_l, sg_l, ub, u_arr, low_arr, window,
            length, band_width, rows_per_step, with_info, use_cb,
        )
    interpret = True if resolved == "pallas_interpret" else None
    out = _kernel_ops().dtw_ea_multi_fused(
        queries, ref, starts, mu_l, sg_l, ub, window, length,
        u=u, low=low, use_cb=use_cb, band_width=band_width,
        block_k=block_k, row_block=row_block, interpret=interpret,
        with_info=with_info, ref_budget=ref_budget,
    )
    if with_info:
        d, rows, cells = out
        return d, EAInfo(rows=rows, cells=cells)
    return out


def block_sweep(cand, lb, starts, ub0, block_k, block_fn):
    """Best-first sweep over ``block_k``-lane candidate blocks, carried ub.

    The host-side equivalent of the persistent kernel's sequential candidate
    grid dimension (DESIGN.md §2.5), shared by every driver that needs the
    block-granular loop: carried incumbent as loop state, the on-device
    cascade stop as the loop condition. Because lower bounds arrive sorted
    and the incumbent is non-increasing, the first gated block implies every
    later block is gated too, so exiting there visits exactly the blocks
    the kernel runs (a gated block on the kernel side is a no-op, here it
    is the loop exit). Incumbent updates are strict-improvement with
    first-lane tie-breaking — the one copy of that rule on the host side.

    Args:
      cand: ``(K_pad, m)`` candidate windows, ascending-``lb`` order,
        ``K_pad`` a multiple of ``block_k``.
      lb: ``(K_pad,)`` sorted lower bounds (``+inf`` padding lanes).
      starts: ``(K_pad,)`` global start per lane.
      ub0: scalar initial incumbent.
      block_fn: ``(cand_block, lb_block, ub) -> (block_k,)`` distances for
        one block (``+inf`` = abandoned; padding lanes are masked here).

    Returns ``(ub, best, blocks)`` scalars.
    """
    k_pad, m = cand.shape
    n_blocks = k_pad // block_k

    class St(NamedTuple):
        b: jax.Array     # next block index
        ub: jax.Array    # carried incumbent
        best: jax.Array  # carried best start

    def cond(st: St) -> jax.Array:
        head = jax.lax.dynamic_slice(
            lb, (jnp.minimum(st.b, n_blocks - 1) * block_k,), (1,)
        )[0]
        return jnp.logical_and(st.b < n_blocks, head < st.ub)

    def body(st: St) -> St:
        o = st.b * block_k
        c = jax.lax.dynamic_slice(cand, (o, jnp.zeros_like(o)), (block_k, m))
        lbb = jax.lax.dynamic_slice(lb, (o,), (block_k,))
        ss = jax.lax.dynamic_slice(starts, (o,), (block_k,))
        d = block_fn(c, lbb, st.ub)
        d = jnp.where(jnp.isfinite(lbb), d, jnp.inf)  # padding lanes
        j = jnp.argmin(d)
        dmin = d[j]
        improved = dmin < st.ub  # strict: ties keep the incumbent
        return St(
            b=st.b + 1,
            ub=jnp.where(improved, dmin, st.ub),
            best=jnp.where(improved, ss[j], st.best),
        )

    st0 = St(
        b=jnp.asarray(0, jnp.int32),
        ub=jnp.asarray(ub0),
        best=jnp.asarray(-1, starts.dtype),
    )
    st = jax.lax.while_loop(cond, body, st0)
    return st.ub, st.best, st.b


@partial(
    jax.jit,
    static_argnames=(
        "window", "band_width", "rows_per_step", "block_k", "use_cb"
    ),
)
def _persistent_jax(
    queries, candidates, lb, starts, ub_init, u, low, window, band_width,
    rows_per_step, block_k, use_cb,
):
    """JAX-backend persistent sweep: ``block_sweep`` per query.

    Per-lane arithmetic is ``_batch_jax`` — identical to the host round
    driver's jax backend, so surviving distances are bit-equal.
    """

    def one(q, cand, lbq, sq, ub0, uq, lowq):
        def block_fn(c, lbb, ub):
            cb = None
            if use_cb:
                cb = cascade_keogh_cumulative(c, uq, lowq)
            # Lane gating: a lane whose own bound reaches the incumbent is
            # submitted dead (same sentinel the kernel writes).
            ubl = jnp.where(lbb < ub, ub, DEAD_LANE_UB)
            return _batch_jax(
                q, c, ubl, window, band_width, cb, rows_per_step, False
            )

        return block_sweep(
            cand, lbq, sq, jnp.asarray(ub0, queries.dtype), block_k, block_fn
        )

    ops = (queries, candidates, lb, starts, ub_init, u, low)
    if jax.default_backend() == "cpu":
        # Per-query trip counts (see _multi_jax on why lax.map here).
        return jax.lax.map(lambda t: one(*t), ops)
    return jax.vmap(one)(*ops)


def ea_pruned_dtw_persistent(
    queries: jax.Array,
    candidates: jax.Array,
    lb: jax.Array,
    starts: jax.Array,
    ub_init: jax.Array,
    window: int,
    band_width: int | None = None,
    envelopes: tuple[jax.Array, jax.Array] | None = None,
    rows_per_step: int = 1,
    backend: str | None = None,
    block_k: int = 8,
    row_block: int = 128,
):
    """Persistent best-first EAPrunedDTW: the whole sweep in one dispatch.

    The round primitives (``ea_pruned_dtw_batch`` / ``_multi_batch``) leave
    incumbent tightening to their caller — one argmin + ``ub`` update per
    dispatched round. This primitive internalizes the loop: candidates for
    the *entire* best-first order come in at once, and the incumbent is
    carried across ``block_k``-lane candidate blocks inside a single
    dispatch (the Pallas kernel's sequential grid dimension with ``ub`` in
    SMEM, or one jitted while_loop on the jax backend). Tightening happens
    every ``block_k`` lanes instead of every ``batch`` lanes, and blocks
    whose lower bounds cannot beat the carried incumbent never run.

    Args:
      queries: ``(Q, m)`` z-normalized queries.
      candidates: ``(Q, K, m)`` windows in ascending-``lb`` order per query.
      lb: ``(Q, K)`` sorted lower bounds; ``+inf`` marks padding lanes. Pass
        zeros (with ``+inf`` padding) for the no-cascade variant — gating
        then never skips a live block, and the sweep visits all of them.
      starts: ``(Q, K)`` global window start per lane.
      ub_init: ``(Q,)`` incumbent seeds (``BIG`` cold).
      envelopes: optional ``(u, low)`` pair of ``(Q, m)`` query envelopes —
        enables UCR ``cb`` threshold tightening, computed per block inside
        the sweep (no precomputed ``(Q, K, m)`` cb slab exists anywhere).
      window, band_width, rows_per_step, backend, block_k, row_block: as in
        ``ea_pruned_dtw_multi_batch``.

    Returns: ``(best_dist, best_start, blocks)`` — ``(Q,)`` each; ``blocks``
      counts candidate blocks actually evaluated (the work metric; the
      dispatch count is 1 by construction).
    """
    if jnp.ndim(queries) != 2:
        raise ValueError("persistent sweep requires (Q, m) univariate queries")
    use_cb = envelopes is not None
    u, low = envelopes if use_cb else (None, None)
    resolved = resolve_backend(backend)
    if resolved == "jax":
        nq, m = queries.shape
        dt = queries.dtype
        lb_arr, starts_arr, candidates = pad_lanes_to_blocks(
            block_k, jnp.asarray(lb, dt), jnp.asarray(starts), candidates
        )
        if u is None:
            u_arr = jnp.zeros((nq, m), dt)
            low_arr = jnp.zeros((nq, m), dt)
        else:
            u_arr, low_arr = jnp.asarray(u, dt), jnp.asarray(low, dt)
        return _persistent_jax(
            queries, candidates, lb_arr, starts_arr,
            jnp.asarray(ub_init, dt), u_arr, low_arr,
            window, band_width, rows_per_step, block_k, use_cb,
        )
    interpret = True if resolved == "pallas_interpret" else None
    return _kernel_ops().dtw_ea_persistent(
        queries, candidates, lb, starts, ub_init, window, u=u, low=low,
        use_cb=use_cb, band_width=band_width, block_k=block_k,
        row_block=row_block, interpret=interpret,
    )


def block_sweep_fused(lb, starts, mu_l, sg_l, ub0, block_k, block_fn):
    """``block_sweep`` without the candidate matrix: lanes are descriptors.

    The while_loop state and stop condition are identical to
    ``block_sweep``; the body slices the per-block ``(starts, mu, sigma)``
    descriptors instead of a ``(K_pad, m)`` window matrix and hands them to
    ``block_fn(starts_b, mu_b, sg_b, lb_b, ub)``, which materializes the
    O(block_k · length) tile itself — the jax-backend analogue of the
    persistent kernel's in-kernel gather. Nothing O(K·m) exists at any
    point of the sweep.
    """
    k_pad = lb.shape[0]
    n_blocks = k_pad // block_k

    class St(NamedTuple):
        b: jax.Array     # next block index
        ub: jax.Array    # carried incumbent
        best: jax.Array  # carried best start

    def cond(st: St) -> jax.Array:
        head = jax.lax.dynamic_slice(
            lb, (jnp.minimum(st.b, n_blocks - 1) * block_k,), (1,)
        )[0]
        return jnp.logical_and(st.b < n_blocks, head < st.ub)

    def body(st: St) -> St:
        o = st.b * block_k
        lbb = jax.lax.dynamic_slice(lb, (o,), (block_k,))
        sb = jax.lax.dynamic_slice(starts, (o,), (block_k,))
        mub = jax.lax.dynamic_slice(mu_l, (o,), (block_k,))
        sgb = jax.lax.dynamic_slice(sg_l, (o,), (block_k,))
        d = block_fn(sb, mub, sgb, lbb, st.ub)
        d = jnp.where(jnp.isfinite(lbb), d, jnp.inf)  # padding lanes
        j = jnp.argmin(d)
        dmin = d[j]
        improved = dmin < st.ub  # strict: ties keep the incumbent
        return St(
            b=st.b + 1,
            ub=jnp.where(improved, dmin, st.ub),
            best=jnp.where(improved, sb[j], st.best),
        )

    st0 = St(
        b=jnp.asarray(0, jnp.int32),
        ub=jnp.asarray(ub0),
        best=jnp.asarray(-1, starts.dtype),
    )
    st = jax.lax.while_loop(cond, body, st0)
    return st.ub, st.best, st.b


@partial(
    jax.jit,
    static_argnames=(
        "window", "length", "band_width", "rows_per_step", "block_k",
        "use_cb",
    ),
)
def _persistent_jax_fused(
    queries, ref, lb, starts, mu_l, sg_l, ub_init, u, low, window, length,
    band_width, rows_per_step, block_k, use_cb,
):
    """JAX-backend fused persistent sweep: gather per block, in the loop.

    The slab form (``_persistent_jax``) receives the full candidate matrix
    even though the sweep visits blocks sequentially; here each while_loop
    step slices + normalizes only its own ``block_k`` windows out of the
    resident reference — O(N + block_k·m) live at any point, matching the
    fused kernel. Per-lane arithmetic is still ``_batch_jax``, so surviving
    distances stay bit-equal to the slab form.
    """

    def one(q, lbq, sq, muq, sgq, ub0, uq, lowq):
        def block_fn(sb, mub, sgb, lbb, ub):
            c = _slice_norm(ref, sb, length, mub, sgb)
            cb = None
            if use_cb:
                cb = cascade_keogh_cumulative(c, uq, lowq)
            # Lane gating: a lane whose own bound reaches the incumbent is
            # submitted dead (same sentinel the kernel writes).
            ubl = jnp.where(lbb < ub, ub, DEAD_LANE_UB)
            return _batch_jax(
                q, c, ubl, window, band_width, cb, rows_per_step, False
            )

        return block_sweep_fused(
            lbq, sq, muq, sgq, jnp.asarray(ub0, queries.dtype), block_k,
            block_fn,
        )

    ops = (queries, lb, starts, mu_l, sg_l, ub_init, u, low)
    if jax.default_backend() == "cpu":
        # Per-query trip counts (see _multi_jax on why lax.map here).
        return jax.lax.map(lambda t: one(*t), ops)
    return jax.vmap(one)(*ops)


def ea_pruned_dtw_persistent_fused(
    queries: jax.Array,
    ref: jax.Array,
    lb: jax.Array,
    starts: jax.Array,
    ub_init: jax.Array,
    window: int,
    mu: jax.Array,
    sigma: jax.Array,
    envelopes: tuple[jax.Array, jax.Array] | None = None,
    band_width: int | None = None,
    rows_per_step: int = 1,
    backend: str | None = None,
    block_k: int = 8,
    row_block: int = 128,
    ref_budget: int | None = None,
):
    """Fused-gather persistent sweep: whole search, O(N + K) operands.

    ``ea_pruned_dtw_persistent`` without the O(K·m) best-first window
    matrix: lanes arrive as ``(start, lb)`` descriptors plus the O(N)
    stats tables, and each visited block's tile is materialized inside the
    sweep (in-kernel on Pallas, inside the while_loop body on jax). This is
    the form that completes sweeps over references whose window slab could
    never be allocated.

    Args (where they differ from ``ea_pruned_dtw_persistent``):
      ref: ``(N,)`` raw (sanitized) reference series.
      mu, sigma: full ``(N_win,)`` per-window stats tables (``sigma`` raw;
        clamped at this boundary).
      ref_budget: Pallas-only VMEM byte budget for the reference operand.

    Returns: ``(best_dist, best_start, blocks)`` — as the slab form.
    """
    if jnp.ndim(queries) != 2:
        raise ValueError("persistent sweep requires (Q, m) univariate queries")
    length = int(queries.shape[1])
    use_cb = envelopes is not None
    u, low = envelopes if use_cb else (None, None)
    dt = queries.dtype
    lb_arr, starts_arr, _ = pad_lanes_to_blocks(
        block_k, jnp.asarray(lb, dt), jnp.asarray(starts, jnp.int32)
    )
    mu_l = jnp.asarray(mu, dt)[starts_arr]
    sg_l = clamp_sigma(jnp.asarray(sigma, dt))[starts_arr]
    resolved = resolve_backend(backend)
    if resolved == "jax":
        nq, m = queries.shape
        if u is None:
            u_arr = jnp.zeros((nq, m), dt)
            low_arr = jnp.zeros((nq, m), dt)
        else:
            u_arr, low_arr = jnp.asarray(u, dt), jnp.asarray(low, dt)
        return _persistent_jax_fused(
            queries, ref, lb_arr, starts_arr, mu_l, sg_l,
            jnp.asarray(ub_init, dt), u_arr, low_arr,
            window, length, band_width, rows_per_step, block_k, use_cb,
        )
    interpret = True if resolved == "pallas_interpret" else None
    return _kernel_ops().dtw_ea_persistent_fused(
        queries, ref, lb_arr, starts_arr, mu_l, sg_l, ub_init, window,
        length, u=u, low=low, use_cb=use_cb, band_width=band_width,
        block_k=block_k, row_block=row_block, interpret=interpret,
        ref_budget=ref_budget,
    )


@partial(
    jax.jit,
    static_argnames=(
        "window", "band_width", "rows_per_step", "backend", "block_k",
        "row_block",
    ),
)
def _ea_search_round_impl(
    query, candidates, ub, best_idx, cand_idx, window, band_width, cb,
    rows_per_step, backend, block_k, row_block,
):
    d = ea_pruned_dtw_batch(
        query, candidates, ub, window, band_width, cb,
        rows_per_step=rows_per_step, backend=backend, block_k=block_k,
        row_block=row_block,
    )
    k = jnp.argmin(d)
    dmin = d[k]
    improved = dmin < ub
    new_ub = jnp.where(improved, dmin, ub)
    new_best = jnp.where(improved, cand_idx[k], best_idx)
    return new_ub, new_best


def ea_search_round(
    query: jax.Array,
    candidates: jax.Array,
    ub: jax.Array,
    best_idx: jax.Array,
    cand_idx: jax.Array,
    window: int,
    band_width: int | None = None,
    cb: jax.Array | None = None,
    rows_per_step: int = 1,
    backend: str | None = None,
    block_k: int = 8,
    row_block: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """One search round: batch EAPrunedDTW + incumbent update.

    ``cand_idx`` carries the global index of each candidate (for argmin
    bookkeeping across rounds). Returns updated ``(ub, best_idx)``. Ties keep
    the incumbent (strict improvement only), matching the paper's strictness
    rule for early abandoning.

    The backend is resolved here, outside jit, so ``$REPRO_DTW_BACKEND`` is
    re-read on every call and becomes the static ``backend`` argument of the
    jitted round (changing the env var between calls correctly retraces).
    """
    return _ea_search_round_impl(
        query, candidates, ub, best_idx, cand_idx, window, band_width, cb,
        rows_per_step, resolve_backend(backend), block_k, row_block,
    )
