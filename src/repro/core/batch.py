"""Batched EAPrunedDTW — the TPU-native unit of similarity-search work.

The UCR suite streams candidates one at a time, tightening ``ub`` after each.
A TPU wants thousands of independent lanes in flight, so the unit of work here
is a *batch* of K candidates evaluated under one shared ``ub`` (DESIGN.md
§2.4). Each lane early-abandons independently; the batch completes when every
lane has abandoned or finished; ``ub`` is then tightened with the batch
minimum before the next batch. Best-first ordering by lower bound (see
search/cascade.py) restores most of the sequential tightening power the paper
gets for free.

Backend dispatch (see ``core.backend``): ``ea_pruned_dtw_batch`` is the
single entry point every search path goes through, and it routes a batch to
one of two implementations:

  * ``backend="pallas"`` / ``"pallas_interpret"`` — the banded Pallas kernel
    (``kernels.ops.dtw_ea``). Tuning knobs: ``band_width`` (columns per row,
    lane-aligned default), ``block_k`` (candidate lanes per grid block — the
    early-exit granularity), ``row_block`` (DP rows per sequential grid
    step). ``pallas`` lowers through Mosaic on TPU and falls back to
    interpret mode elsewhere; ``pallas_interpret`` forces interpret mode
    (the CPU test path for the kernel program).
  * ``backend="jax"`` — per-lane banded ``lax.while_loop`` under ``vmap``
    (CPU/GPU fallback, float64-capable reference). Tuning knobs:
    ``band_width``, ``rows_per_step`` (rows per loop iteration — amortizes
    vmap'd loop-control overhead).

``backend=None`` defers to ``$REPRO_DTW_BACKEND``, then the platform default
(``pallas`` on TPU, ``jax`` elsewhere). Multivariate queries always take the
``jax`` path. ``with_info=True`` additionally returns per-lane ``EAInfo``
pruning counters; the default is counter-free — search fast rounds pay no
bookkeeping.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.backend import resolve_backend
from repro.core.ea_pruned_dtw import EAInfo, ea_pruned_dtw_banded
from repro.kernels.ops import dtw_ea


@partial(
    jax.jit,
    static_argnames=("window", "band_width", "rows_per_step", "with_info"),
)
def _batch_jax(
    query, candidates, ub, window, band_width, cb, rows_per_step, with_info
):
    """vmapped banded-while_loop backend (CPU/GPU fallback)."""
    if cb is None:
        fn = lambda c: ea_pruned_dtw_banded(
            query, c, ub, window=window, band_width=band_width,
            rows_per_step=rows_per_step, with_info=with_info,
        )
        return jax.vmap(fn)(candidates)
    fn = lambda c, cbv: ea_pruned_dtw_banded(
        query, c, ub, window=window, band_width=band_width, cb=cbv,
        rows_per_step=rows_per_step, with_info=with_info,
    )
    return jax.vmap(fn)(candidates, cb)


def ea_pruned_dtw_batch(
    query: jax.Array,
    candidates: jax.Array,
    ub: jax.Array,
    window: int,
    band_width: int | None = None,
    cb: jax.Array | None = None,
    rows_per_step: int = 1,
    backend: str | None = None,
    block_k: int = 8,
    row_block: int = 128,
    with_info: bool = False,
):
    """Banded EAPrunedDTW of one query against K candidates, shared ``ub``.

    Args:
      query: ``(m,)`` or ``(m, dims)``.
      candidates: ``(K, m[, dims])``.
      ub: scalar upper bound shared by the whole batch.
      window: Sakoe-Chiba window.
      band_width: static band columns per row (defaults to lane-aligned
        ``2*window+1``).
      cb: optional ``(K, m)`` per-candidate cumulative LB_Keogh suffix sums
        for UCR-style threshold tightening.
      rows_per_step: rows per while_loop iteration (``jax`` backend knob).
      backend: ``"pallas"`` / ``"pallas_interpret"`` / ``"jax"`` / ``"auto"``;
        ``None`` defers to ``$REPRO_DTW_BACKEND`` then the platform default.
      block_k, row_block: Pallas grid tiling knobs.
      with_info: also return per-lane ``EAInfo`` pruning counters.

    Returns: ``(K,)`` distances (``+inf`` where abandoned); with ``with_info``
      a ``(distances, EAInfo)`` tuple of per-lane arrays.
    """
    resolved = resolve_backend(backend)
    if resolved != "jax" and jnp.ndim(query) != 1:
        resolved = "jax"  # kernel is univariate; see core.backend docstring
    if resolved == "jax":
        out = _batch_jax(
            query, candidates, ub, window, band_width, cb, rows_per_step,
            with_info,
        )
        return out
    interpret = True if resolved == "pallas_interpret" else None
    out = dtw_ea(
        query, candidates, ub, window, cb=cb, band_width=band_width,
        block_k=block_k, row_block=row_block, interpret=interpret,
        with_info=with_info,
    )
    if with_info:
        d, rows, cells = out
        return d, EAInfo(rows=rows, cells=cells)
    return out


@partial(
    jax.jit,
    static_argnames=(
        "window", "band_width", "rows_per_step", "backend", "block_k",
        "row_block",
    ),
)
def ea_search_round(
    query: jax.Array,
    candidates: jax.Array,
    ub: jax.Array,
    best_idx: jax.Array,
    cand_idx: jax.Array,
    window: int,
    band_width: int | None = None,
    cb: jax.Array | None = None,
    rows_per_step: int = 1,
    backend: str | None = None,
    block_k: int = 8,
    row_block: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """One search round: batch EAPrunedDTW + incumbent update.

    ``cand_idx`` carries the global index of each candidate (for argmin
    bookkeeping across rounds). Returns updated ``(ub, best_idx)``. Ties keep
    the incumbent (strict improvement only), matching the paper's strictness
    rule for early abandoning.
    """
    d = ea_pruned_dtw_batch(
        query, candidates, ub, window, band_width, cb,
        rows_per_step=rows_per_step, backend=backend, block_k=block_k,
        row_block=row_block,
    )
    k = jnp.argmin(d)
    dmin = d[k]
    improved = dmin < ub
    new_ub = jnp.where(improved, dmin, ub)
    new_best = jnp.where(improved, cand_idx[k], best_idx)
    return new_ub, new_best
