"""Exact DTW in JAX via the min-plus prefix-scan row recurrence.

This is the vectorized *unpruned* reference the paper's technique accelerates
(and the oracle the Pallas kernels are tested against). One `lax.scan` step per
row; within a row the sequential left-neighbour chain

    curr[j] = min(d[j], c[j] + curr[j-1])

is solved in closed form by ``row_scan`` (prefix sum + cumulative min), giving
log-depth vector ops instead of a scalar loop — the TPU-native shape of the
computation (DESIGN.md §2.1).

Supports univariate ``(n,)`` and multivariate ``(n, dims)`` series with the
squared-Euclidean cost, and a Sakoe-Chiba window for equal-length inputs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.common import BIG, row_scan, to_inf


def _cost_row(x_i: jax.Array, t: jax.Array) -> jax.Array:
    """Squared Euclidean cost of one point of S against every point of T."""
    diff = x_i - t  # (m,) or (m, dims)
    if diff.ndim == 1:
        return diff * diff
    return jnp.sum(diff * diff, axis=-1)


@partial(jax.jit, static_argnames=("window",))
def dtw(s: jax.Array, t: jax.Array, window: int | None = None) -> jax.Array:
    """Exact DTW distance between ``s`` and ``t`` (squared-Euclidean cost).

    Args:
      s: ``(n,)`` or ``(n, dims)`` series (the "line" series — scanned rows).
      t: ``(m,)`` or ``(m, dims)`` series.
      window: optional Sakoe-Chiba warping window (requires ``n == m``).

    Returns: scalar DTW cost; ``+inf`` if the window admits no path.
    """
    s = jnp.asarray(s)
    t = jnp.asarray(t)
    n = s.shape[0]
    m = t.shape[0]
    if window is not None and n != m:
        raise ValueError("windowed DTW requires equal lengths")
    if window is not None and window >= m:
        window = None

    cols = jnp.arange(m)

    def step(prev: jax.Array, xs) -> tuple[jax.Array, None]:
        x_i, i = xs
        c = _cost_row(x_i, t).astype(prev.dtype)
        # d[j] = c[j] + min(prev[j], prev[j-1]); prev has a border cell at [0].
        d = c + jnp.minimum(prev[1:], prev[:-1])
        if window is not None:
            in_win = jnp.abs(cols - i) <= window
            d = jnp.where(in_win, d, BIG)
        curr = row_scan(d, c)
        if window is not None:
            curr = jnp.where(in_win, curr, BIG)
        curr = jnp.minimum(curr, BIG)  # keep sentinel arithmetic bounded
        return jnp.concatenate([jnp.full((1,), BIG, prev.dtype), curr]), None

    dtype = jnp.result_type(s.dtype, t.dtype, jnp.float32)
    prev0 = jnp.full((m + 1,), BIG, dtype)
    prev0 = prev0.at[0].set(0.0)  # the (0,0) corner border cell
    final, _ = jax.lax.scan(step, prev0, (s.astype(dtype), jnp.arange(n)))
    return to_inf(final[m])


@partial(jax.jit, static_argnames=("window",))
def dtw_batch(
    queries: jax.Array, candidates: jax.Array, window: int | None = None
) -> jax.Array:
    """Pairwise-batched exact DTW: ``queries`` ``(B, n[, d])`` vs
    ``candidates`` ``(B, m[, d])`` → ``(B,)`` distances."""
    return jax.vmap(lambda q, c: dtw(q, c, window=window))(queries, candidates)


def dtw_matrix(s: jax.Array, t: jax.Array) -> jax.Array:
    """Full (n+1, m+1) DTW matrix (paper Fig. 2a) — for tests/visualization."""
    s = jnp.asarray(s)
    t = jnp.asarray(t)
    n, m = s.shape[0], t.shape[0]

    def step(prev, x_i):
        c = _cost_row(x_i, t).astype(prev.dtype)
        d = c + jnp.minimum(prev[1:], prev[:-1])
        curr = row_scan(d, c)
        nxt = jnp.concatenate([jnp.full((1,), BIG, prev.dtype), curr])
        return nxt, nxt

    prev0 = jnp.full((m + 1,), BIG, jnp.float64 if s.dtype == jnp.float64 else jnp.float32)
    prev0 = prev0.at[0].set(0.0)
    _, rows = jax.lax.scan(step, prev0, s.astype(prev0.dtype))
    return to_inf(jnp.concatenate([prev0[None], rows], axis=0))
