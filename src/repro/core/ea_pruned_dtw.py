"""EAPrunedDTW in JAX — the paper's contribution, adapted to TPU.

Two implementations of Herrmann & Webb's Algorithm 3, both making *identical
pruning decisions* to the paper at row granularity (tested against the literal
NumPy transcription in ``ea_pruned_dtw_np.py``):

``ea_pruned_dtw``  — full-width rows inside a ``lax.while_loop``: each row is
    one fused vector op (min-plus prefix scan), the band pointers
    (``next_start`` / pruning point) are extracted with vectorized mask
    reductions, and the loop exits on border collision (early abandon). Work is
    O(n·m) per row-vector but rows after abandon are never issued — this is the
    semantically-faithful mid-level reference.

``ea_pruned_dtw_banded`` — the performance shape: only a static ``band_width``
    slice of each row is computed (``band_width >= 2*window+1`` covers every
    admissible cell), the previous row's band is realigned with a dynamic
    slice, giving O(n · band) work with early abandon. This is what the Pallas
    kernel (kernels/dtw_band.py) mirrors block-by-block, and what batched
    similarity search calls.

Correctness contract (same as the paper's): the returned value equals exact
DTW whenever exact DTW <= ub, and is ``+inf`` (abandoned) whenever exact
DTW > ub. Ties (== ub) are never abandoned — up to reformulation rounding:
the prefix-scan form ``P[j] + (d[k] - P[k])`` rounds differently from the
sequential chain by O(1) ulp, so an *exact* tie with ``ub`` can resolve either
way (measured ~1e-15 relative on f64). Search correctness is unaffected: ``ub``
is always a true upper bound, and a 1-ulp tie merely keeps the incumbent.

Why the pointer extraction is faithful (DESIGN.md §2.2): right of the previous
row's pruning point the only dependency is the left neighbour and costs are
>= 0, so values along that suffix are non-decreasing; the vectorized row
therefore computes values > ub for every cell the paper prunes and exactly the
paper's values for every cell the paper computes. Abandon ⇔ no cell in the row
is <= ub ⇔ the paper's border collision.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.common import BIG, default_band_width, row_scan, to_inf


class EAInfo(NamedTuple):
    """Pruning-effectiveness counters (paper §5 reports cell ratios)."""

    rows: jax.Array   # rows actually issued before abandon/completion
    cells: jax.Array  # admissible cells across issued rows (band area)


def _cost_row(x_i: jax.Array, t: jax.Array) -> jax.Array:
    diff = x_i - t
    if diff.ndim == 1:
        return diff * diff
    return jnp.sum(diff * diff, axis=-1)


# ---------------------------------------------------------------------------
# Full-row variant
# ---------------------------------------------------------------------------


def _row_threshold(ub, cb, i, window, m):
    """UCR-suite upper-bound tightening: any path cell in row ``i`` sits in
    columns <= i + w, so the remaining columns contribute at least
    ``cb[i + w + 1]`` (cumulative LB_Keogh suffix). Row threshold becomes
    ``ub - cb[i+w+1]`` — identical to the UCR/UCR-MON ``cb`` mechanism."""
    if cb is None:
        return ub
    w = 0 if window is None else window
    idx = jnp.minimum(i + w + 1, m - 1)
    tail = jnp.where(i + w + 1 <= m - 1, cb[idx], 0.0)
    return ub - tail


@partial(jax.jit, static_argnames=("window", "with_info"))
def ea_pruned_dtw(
    s: jax.Array,
    t: jax.Array,
    ub: jax.Array,
    window: int | None = None,
    with_info: bool = False,
    cb: jax.Array | None = None,
):
    """EAPrunedDTW (full-row vectorized). See module docstring.

    Args:
      s: ``(n,)`` or ``(n, dims)`` "line" series (rows).
      t: ``(m,)`` or ``(m, dims)`` series (columns).
      ub: scalar upper bound; computation abandons once provably above it.
      window: optional Sakoe-Chiba window (requires ``n == m``).
      with_info: also return ``EAInfo`` counters.
      cb: optional ``(m,)`` cumulative LB_Keogh suffix sums — tightens the
        abandon threshold per row (UCR-suite upper-bound tightening).
    """
    s = jnp.asarray(s)
    t = jnp.asarray(t)
    n, m = s.shape[0], t.shape[0]
    if window is not None and n != m:
        raise ValueError("windowed EAPrunedDTW requires equal lengths")
    if window is not None and window >= m:
        window = None

    dtype = jnp.result_type(s.dtype, t.dtype, jnp.float32)
    ub = jnp.asarray(ub, dtype)
    cols = jnp.arange(m)

    class State(NamedTuple):
        i: jax.Array
        prev: jax.Array        # (m+1,): [border, row values]; pruned = BIG
        next_start: jax.Array  # 0-based first admissible column
        ok_last: jax.Array     # was the last column <= ub in the latest row?
        abandoned: jax.Array
        rows: jax.Array
        cells: jax.Array

    def cond(st: State) -> jax.Array:
        return jnp.logical_and(st.i < n, jnp.logical_not(st.abandoned))

    def body(st: State) -> State:
        i = st.i
        # Window clipping acts like permanent discard points on the left.
        if window is None:
            ns = st.next_start
            in_win = jnp.ones((m,), bool)
        else:
            ns = jnp.maximum(st.next_start, i - window)
            in_win = jnp.abs(cols - i) <= window
        exists = jnp.logical_and(cols >= ns, in_win)

        c = _cost_row(s[i], t).astype(dtype)
        d = c + jnp.minimum(st.prev[1:], st.prev[:-1])
        d = jnp.where(exists, d, BIG)
        curr = jnp.minimum(row_scan(d, c), BIG)
        curr = jnp.where(exists, curr, BIG)

        thr = _row_threshold(ub, cb, i, window, m)
        le = jnp.logical_and(curr <= thr, exists)
        any_le = jnp.any(le)
        # next_start' = first column <= thr (the discard-point prefix rule).
        ns_new = jnp.argmax(le).astype(ns.dtype)
        prev_new = jnp.concatenate([jnp.full((1,), BIG, dtype), curr])
        return State(
            i=i + 1,
            prev=jnp.where(any_le, prev_new, st.prev),
            next_start=jnp.where(any_le, ns_new, ns),
            ok_last=le[m - 1],
            abandoned=jnp.logical_not(any_le),
            rows=st.rows + 1,
            cells=st.cells + jnp.sum(exists),
        )

    prev0 = jnp.full((m + 1,), BIG, dtype).at[0].set(0.0)
    st0 = State(
        i=jnp.asarray(0),
        prev=prev0,
        next_start=jnp.asarray(0),
        ok_last=jnp.asarray(False),
        abandoned=jnp.asarray(False),
        rows=jnp.asarray(0),
        cells=jnp.asarray(0),
    )
    st = jax.lax.while_loop(cond, body, st0)
    # Paper final check: the last row's last column must have been <= ub
    # (pruning_point > l_co), otherwise the result is proven > ub.
    good = jnp.logical_and(jnp.logical_not(st.abandoned), st.ok_last)
    result = jnp.where(good, to_inf(st.prev[m]), jnp.inf)
    if with_info:
        return result, EAInfo(rows=st.rows, cells=st.cells)
    return result


# ---------------------------------------------------------------------------
# Banded variant — O(n * band) work, the serving hot path
# ---------------------------------------------------------------------------


@partial(
    jax.jit, static_argnames=("window", "band_width", "with_info", "rows_per_step")
)
def ea_pruned_dtw_banded(
    s: jax.Array,
    t: jax.Array,
    ub: jax.Array,
    window: int,
    band_width: int | None = None,
    with_info: bool = False,
    cb: jax.Array | None = None,
    rows_per_step: int = 1,
):
    """Banded EAPrunedDTW: compute only ``band_width`` columns per row.

    Requires equal lengths and a warping window. ``band_width`` defaults to
    the smallest lane-aligned width covering ``2*window + 1`` columns — the
    band always contains *every* admissible cell of the row, so results and
    abandon decisions are identical to ``ea_pruned_dtw``.
    """
    s = jnp.asarray(s)
    t = jnp.asarray(t)
    n, m = s.shape[0], t.shape[0]
    if n != m:
        raise ValueError("banded EAPrunedDTW requires equal lengths")
    window = min(window, m - 1)
    full = min(2 * window + 1, m)
    if band_width is None:
        # §Perf-C2: align the band to the vector unit, not beyond. On TPU,
        # XLA pads the trailing dim to 128 lanes regardless, so any multiple
        # of 8 costs the same there; on CPU, rounding up to 128 quadrupled
        # the row work for w=12 (measured 131ms -> 27ms at the right width).
        band_width = default_band_width(window, m)
    bw = int(band_width)
    if bw < full:
        raise ValueError(f"band_width {bw} < 2*window+1 = {full}")

    dtype = jnp.result_type(s.dtype, t.dtype, jnp.float32)
    ub = jnp.asarray(ub, dtype)
    rel = jnp.arange(bw)
    # Columns are gathered with a dynamic slice; pad t on the right.
    if s.ndim == 1:
        t_pad = jnp.concatenate([t, jnp.zeros((bw,), t.dtype)])
    else:
        t_pad = jnp.concatenate([t, jnp.zeros((bw, t.shape[1]), t.dtype)])

    class State(NamedTuple):
        i: jax.Array
        band: jax.Array        # (bw,) previous-row values at cols [lo, lo+bw)
        lo: jax.Array          # 0-based column of band[0] in the previous row
        next_start: jax.Array
        ok_last: jax.Array
        abandoned: jax.Array
        rows: jax.Array
        cells: jax.Array

    def cond(st: State) -> jax.Array:
        return jnp.logical_and(st.i < n, jnp.logical_not(st.abandoned))

    def row_update(st: State) -> State:
        """One band row, masked to a no-op once done/abandoned."""
        active = jnp.logical_and(st.i < n, jnp.logical_not(st.abandoned))
        i = jnp.minimum(st.i, n - 1)
        ns = jnp.maximum(st.next_start, i - window)
        lo = ns  # band starts at the first admissible column
        hi = jnp.minimum(m - 1, i + window)
        cols = lo + rel
        exists = cols <= hi  # cols >= lo == ns by construction; cols < m via hi

        # Realign previous band: aligned[r] = prev[lo - 1 + r].
        shift = lo - st.lo  # >= 0: next_start and the window edge only advance
        padded = jnp.concatenate(
            [jnp.full((1,), BIG, dtype), st.band, jnp.full((bw + 1,), BIG, dtype)]
        )
        aligned = jax.lax.dynamic_slice(padded, (shift,), (bw + 1,))
        # Columns past the previous band's right edge were never computed.
        aligned = jnp.where(jnp.arange(bw + 1) <= bw - shift, aligned, BIG)

        if s.ndim == 1:
            tc = jax.lax.dynamic_slice(t_pad, (lo,), (bw,))
        else:
            tc = jax.lax.dynamic_slice(t_pad, (lo, 0), (bw, t.shape[1]))
        c = _cost_row(s[i], tc).astype(dtype)
        d = c + jnp.minimum(aligned[1:], aligned[:-1])
        d = jnp.where(exists, d, BIG)
        curr = jnp.minimum(row_scan(d, c), BIG)
        curr = jnp.where(exists, curr, BIG)

        thr = _row_threshold(ub, cb, i, window, m)
        le = jnp.logical_and(curr <= thr, exists)
        any_le = jnp.any(le)
        upd = jnp.logical_and(active, any_le)
        ns_new = lo + jnp.argmax(le).astype(lo.dtype)
        return State(
            i=st.i + active.astype(st.i.dtype),
            band=jnp.where(upd, curr, st.band),
            lo=jnp.where(upd, lo, st.lo),
            next_start=jnp.where(upd, ns_new, jnp.where(active, ns, st.next_start)),
            ok_last=jnp.where(
                active, jnp.any(jnp.logical_and(le, cols == m - 1)), st.ok_last
            ),
            abandoned=jnp.logical_or(
                st.abandoned, jnp.logical_and(active, jnp.logical_not(any_le))
            ),
            rows=st.rows + active.astype(st.rows.dtype),
            cells=st.cells + jnp.where(active, jnp.sum(exists), 0),
        )

    def body(st: State) -> State:
        # rows_per_step > 1 amortizes loop-control overhead (§Perf-C):
        # abandon granularity coarsens to the block, trailing rows no-op.
        for _ in range(rows_per_step):
            st = row_update(st)
        return st

    band0 = jnp.full((bw,), BIG, dtype).at[0].set(0.0)  # corner at col -1
    st0 = State(
        i=jnp.asarray(0),
        band=band0,
        lo=jnp.asarray(-1),
        next_start=jnp.asarray(0),
        ok_last=jnp.asarray(False),
        abandoned=jnp.asarray(False),
        rows=jnp.asarray(0),
        cells=jnp.asarray(0),
    )
    st = jax.lax.while_loop(cond, body, st0)
    good = jnp.logical_and(jnp.logical_not(st.abandoned), st.ok_last)
    last_val = st.band[(m - 1) - st.lo]
    result = jnp.where(good, to_inf(last_val), jnp.inf)
    if with_info:
        return result, EAInfo(rows=st.rows, cells=st.cells)
    return result
