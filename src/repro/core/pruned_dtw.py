"""PrunedDTW baseline (Silva & Batista 2016 / UCR-USP 2018) in JAX.

The algorithm EAPrunedDTW improves upon. Differences from EAPrunedDTW:
  * prunes from the left the same way (advancing ``next_start``),
  * early abandons on the *row minimum* exceeding ``ub`` — it does NOT use
    the border-collision trick, so it abandons one mechanism later and keeps
    row-minimum bookkeeping (the overhead the paper eliminates),
  * always evaluates the 3-way min for every in-band cell.

Vectorized at row granularity exactly like ``ea_pruned_dtw`` so benchmark
comparisons isolate the *algorithmic* difference (abandon rule), not
implementation style.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.common import BIG, row_scan, to_inf
from repro.core.ea_pruned_dtw import EAInfo


def _cost_row(x_i: jax.Array, t: jax.Array) -> jax.Array:
    diff = x_i - t
    if diff.ndim == 1:
        return diff * diff
    return jnp.sum(diff * diff, axis=-1)


@partial(jax.jit, static_argnames=("window", "with_info"))
def pruned_dtw(
    s: jax.Array,
    t: jax.Array,
    ub: jax.Array,
    window: int | None = None,
    with_info: bool = False,
):
    """PrunedDTW: left pruning + row-minimum early abandon."""
    s = jnp.asarray(s)
    t = jnp.asarray(t)
    n, m = s.shape[0], t.shape[0]
    if window is not None and n != m:
        raise ValueError("windowed PrunedDTW requires equal lengths")
    if window is not None and window >= m:
        window = None

    dtype = jnp.result_type(s.dtype, t.dtype, jnp.float32)
    ub = jnp.asarray(ub, dtype)
    cols = jnp.arange(m)

    class State(NamedTuple):
        i: jax.Array
        prev: jax.Array
        next_start: jax.Array
        abandoned: jax.Array
        rows: jax.Array
        cells: jax.Array

    def cond(st: State) -> jax.Array:
        return jnp.logical_and(st.i < n, jnp.logical_not(st.abandoned))

    def body(st: State) -> State:
        i = st.i
        if window is None:
            ns = st.next_start
            in_win = jnp.ones((m,), bool)
        else:
            ns = jnp.maximum(st.next_start, i - window)
            in_win = jnp.abs(cols - i) <= window
        exists = jnp.logical_and(cols >= ns, in_win)

        c = _cost_row(s[i], t).astype(dtype)
        d = c + jnp.minimum(st.prev[1:], st.prev[:-1])
        d = jnp.where(exists, d, BIG)
        curr = jnp.minimum(row_scan(d, c), BIG)
        curr = jnp.where(exists, curr, BIG)

        le = jnp.logical_and(curr <= ub, exists)
        # PrunedDTW rule: abandon iff the row minimum exceeds ub. (With full
        # in-band evaluation this coincides with "no cell <= ub".)
        row_min = jnp.min(jnp.where(exists, curr, BIG))
        abandoned = row_min > ub
        ns_new = jnp.argmax(le).astype(ns.dtype)
        prev_new = jnp.concatenate([jnp.full((1,), BIG, dtype), curr])
        return State(
            i=i + 1,
            prev=jnp.where(abandoned, st.prev, prev_new),
            next_start=jnp.where(abandoned, ns, ns_new),
            abandoned=abandoned,
            rows=st.rows + 1,
            cells=st.cells + jnp.sum(exists),
        )

    prev0 = jnp.full((m + 1,), BIG, dtype).at[0].set(0.0)
    st0 = State(
        i=jnp.asarray(0),
        prev=prev0,
        next_start=jnp.asarray(0),
        abandoned=jnp.asarray(False),
        rows=jnp.asarray(0),
        cells=jnp.asarray(0),
    )
    st = jax.lax.while_loop(cond, body, st0)
    val = to_inf(st.prev[m])
    result = jnp.where(
        jnp.logical_or(st.abandoned, val > ub), jnp.inf, val
    )
    if with_info:
        return result, EAInfo(rows=st.rows, cells=st.cells)
    return result
