"""DTW lower bounds (LB_Kim, LB_Keogh) — vectorized, batched forms.

The UCR suite uses a cascade of cheap lower bounds to skip full DTW
computations (paper §2.2). On TPU these become *batched* single-pass ops over
thousands of candidates at once, which is why the paper's "are lower bounds
dispensable?" question gets re-examined in our benchmarks: here an LB pass is
one fused vector op, nearly free relative to its CPU cost.

All bounds are valid for the squared-Euclidean cost used throughout:
``lb(Q, C) <= DTW(Q, C)`` for any warping window.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("window",))
def envelope(q: jax.Array, window: int) -> tuple[jax.Array, jax.Array]:
    """Keogh envelope: ``U[i] = max(q[i-w : i+w+1])``, ``L[i] = min(...)``.

    Log-depth sparse-table construction (doubling), so it vectorizes for any
    window size; works batched over leading dims.
    """
    # Sparse-table (doubling) sliding min/max over the window [i-w, i+w],
    # computed on a neutrally-padded array so edge windows clamp exactly.
    w = int(window)
    length = 2 * w + 1
    n = q.shape[-1]
    batch = q.shape[:-1]
    hi = jnp.concatenate(
        [jnp.full(batch + (w,), -jnp.inf, q.dtype), q, jnp.full(batch + (w,), -jnp.inf, q.dtype)],
        axis=-1,
    )
    lo = jnp.concatenate(
        [jnp.full(batch + (w,), jnp.inf, q.dtype), q, jnp.full(batch + (w,), jnp.inf, q.dtype)],
        axis=-1,
    )
    # T_k[i] = reduce(padded[i : i+k]); grow k to the largest pow2 <= length.
    k = 1
    while 2 * k <= length:
        fill_hi = jnp.full(batch + (k,), -jnp.inf, q.dtype)
        fill_lo = jnp.full(batch + (k,), jnp.inf, q.dtype)
        hi = jnp.maximum(hi, jnp.concatenate([hi[..., k:], fill_hi], axis=-1))
        lo = jnp.minimum(lo, jnp.concatenate([lo[..., k:], fill_lo], axis=-1))
        k *= 2
    # Window [i-w, i+w] = padded [i, i+length); two overlapping k-blocks.
    idx = jnp.arange(n)
    a = idx
    b = idx + length - k
    u = jnp.maximum(jnp.take(hi, a, axis=-1), jnp.take(hi, b, axis=-1))
    low = jnp.minimum(jnp.take(lo, a, axis=-1), jnp.take(lo, b, axis=-1))
    return u, low


def _lb_keogh_terms(c: jax.Array, u: jax.Array, low: jax.Array) -> jax.Array:
    over = jnp.where(c > u, c - u, 0.0)
    under = jnp.where(c < low, low - c, 0.0)
    return over * over + under * under


@jax.jit
def lb_keogh(c: jax.Array, u: jax.Array, low: jax.Array) -> jax.Array:
    """LB_Keogh of candidate(s) ``c`` against a query envelope ``(u, low)``.

    ``c`` may be ``(m,)`` or batched ``(B, m)``; envelope broadcast applies.
    """
    return jnp.sum(_lb_keogh_terms(c, u, low), axis=-1)


@partial(jax.jit, static_argnames=("window",))
def lb_keogh_pair(q: jax.Array, c: jax.Array, window: int) -> jax.Array:
    """LB_Keogh(Q, C) building the envelope on the fly (pairwise form)."""
    u, low = envelope(q, window)
    return lb_keogh(c, u, low)


@jax.jit
def lb_kim_fl(q: jax.Array, c: jax.Array) -> jax.Array:
    """Simplified LB_Kim on z-normalized series (UCR suite form):
    first + last aligned point costs. Batched over leading dims of ``c``."""
    d0 = (c[..., 0] - q[..., 0]) ** 2
    d1 = (c[..., -1] - q[..., -1]) ** 2
    return d0 + d1


@jax.jit
def cascade_keogh_cumulative(c: jax.Array, u: jax.Array, low: jax.Array) -> jax.Array:
    """Per-position cumulative LB_Keogh partial sums (UCR 'cb' array).

    ``cb[j] = sum_{i >= j} clamp_cost(i)`` — used by EAPrunedDTW-with-LB to
    tighten the abandon threshold as rows advance (ub - cb[row]).
    """
    terms = _lb_keogh_terms(c, u, low)
    rev = jnp.flip(terms, axis=-1)
    return jnp.flip(jnp.cumsum(rev, axis=-1), axis=-1)
