"""Shared numerics for the DTW core.

TPU-side convention: "pruned / not computed / border" cells hold the large
finite sentinel ``BIG`` instead of ``+inf``. The min-plus prefix-scan row
recurrence (see ``row_scan``) computes ``d[k] - P[k]`` differences, and
``inf - inf = nan`` would poison the scan; ``BIG`` keeps everything finite.
``BIG`` is chosen so that summing ~1e4 of them stays below float32 max.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BIG = 1.0e30  # pruned-cell sentinel (finite stand-in for +inf)

# Per-lane ub sentinel for padding / LB-gated / finished-query lanes: any
# negative threshold kills the lane on row 0 (DTW costs are >= 0). The ONE
# definition — the Pallas kernels, the jax backends, and every search
# driver must agree on it, or lane gating diverges between backends.
DEAD_LANE_UB = -1.0

# Sigma floor for z-normalization of flat (constant) windows. Lives here —
# not in search.znorm — because the fused gather path normalizes inside
# ``core.batch`` / the kernels, and core must not import search. The search
# layer re-exports both names from ``search.znorm``.
EPS = 1e-8


def clamp_sigma(sigma: jax.Array) -> jax.Array:
    """The one sanctioned sigma clamp: keeps flat windows finite under
    normalization (they become all-zero, their true z-normal form limit)."""
    return jnp.maximum(sigma, EPS)


def norm_window_slice(
    ref: jax.Array, starts: jax.Array, length: int, mu: jax.Array,
    sigma: jax.Array,
) -> jax.Array:
    """Fused normalize-on-slice: ``(K, length)`` z-normalized windows.

    The sanctioned replacement for ``search.znorm.gather_norm_windows``:
    per-lane contiguous ``dynamic_slice`` of the raw reference plus the
    ``(mu, sigma)`` table lookups, normalized in one vectorized step —
    identical values (same copies, same op order, same ``clamp_sigma``), but
    expressed as window *slices* of the O(N)-resident series rather than an
    arbitrary-index gather, which is what the jax fused backends inline into
    their round/while_loop bodies and what the Pallas fused kernels mirror
    on device. ``mu``/``sigma`` are the full per-window stats tables indexed
    by start; ``sigma`` is raw (clamped here).
    """
    win = jax.vmap(
        lambda s: jax.lax.dynamic_slice(ref, (s,), (length,))
    )(starts)
    m = mu[starts][:, None]
    s = clamp_sigma(sigma[starts])[:, None]
    return (win - m) / s


def pad_lanes_to_blocks(block_k: int, lb, starts, candidates=None):
    """Pad the lane axis to a ``block_k`` multiple, the one shared rule.

    Padding lanes get ``+inf`` lower bounds — the marker that block gating,
    lane gating, and the padding-lane distance mask all key on — and zero
    starts/windows. ``lb``/``starts`` are ``(..., K)``; ``candidates``
    optional ``(..., K, m)``. Returns the (possibly unchanged) triple.
    """
    k = lb.shape[-1]
    k_pad = -(-k // block_k) * block_k
    if k_pad == k:
        return lb, starts, candidates
    pw = [(0, 0)] * (lb.ndim - 1) + [(0, k_pad - k)]
    lb = jnp.pad(lb, pw, constant_values=jnp.inf)
    starts = jnp.pad(starts, pw)
    if candidates is not None:
        candidates = jnp.pad(candidates, pw + [(0, 0)])
    return lb, starts, candidates


def default_band_width(window: int, m: int) -> int:
    """Smallest lane-aligned band covering ``2*window + 1`` columns.

    §Perf-C2: align the band to the vector unit (128 lanes on TPU, 8 on
    CPU), never past ``m``. Shared by the banded JAX path and the Pallas
    wrapper so ``backend="auto"`` dispatch picks the same default band for
    the same call.
    """
    full = min(2 * int(window) + 1, int(m))
    mult = 128 if jax.default_backend() == "tpu" else 8
    return min(int(m), -(-full // mult) * mult)


def is_pruned(x: jax.Array) -> jax.Array:
    """Cells >= BIG/2 are considered pruned/infinite."""
    return x >= jnp.asarray(BIG / 2, dtype=x.dtype)


def to_inf(x: jax.Array) -> jax.Array:
    """Map BIG sentinels back to +inf for user-facing results."""
    return jnp.where(is_pruned(x), jnp.inf, x)


def cummin(x: jax.Array, axis: int = -1) -> jax.Array:
    """Cumulative minimum along ``axis`` (log-depth associative scan)."""
    return jax.lax.associative_scan(jnp.minimum, x, axis=axis)


def row_scan(d: jax.Array, c: jax.Array) -> jax.Array:
    """Solve the DTW row recurrence in closed form.

    Given per-cell ``d[j] = c[j] + min(prev[j], prev[j-1])`` (the contribution
    that does NOT involve the current row's left neighbour) and the cost row
    ``c``, solve

        curr[j] = min(d[j], c[j] + curr[j-1])
                = P[j] + cummin_{k<=j}(d[k] - P[k]),   P = exclusive prefix sum of c

    which replaces the sequential left-to-right chain with one prefix sum and
    one cumulative min — both vectorizable. Shapes: ``d`` and ``c`` are
    ``(..., m)``; returns ``curr`` of the same shape.

    Note ``P`` is the *inclusive* prefix sum shifted so that ``P[j]`` equals
    ``sum(c[..j])``; the ``k = j`` term reproduces ``d[j]`` exactly.
    """
    P = jnp.cumsum(c, axis=-1)
    return P + cummin(d - P, axis=-1)
