"""Literal NumPy transcription of the paper's algorithms (cell-level oracles).

These are *not* the TPU implementations — they are faithful, loop-per-cell
transcriptions of Algorithm 1 (O(n) space DTW), Algorithm 2 (pruning from the
left) and Algorithm 3 (EAPrunedDTW) from Herrmann & Webb 2020, used as the
ground-truth oracles the vectorized JAX/Pallas versions are tested against.

Conventions follow the paper: 1-based series indexing inside the DP, `co` is
the shorter series, `li` the longer, `cost` is the squared difference.
All functions also expose per-row band traces (``next_start`` /
``pruning_point`` per row) so tests can assert the vectorized versions make
*identical* pruning decisions, not merely identical results.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

INF = math.inf


def _split(s: np.ndarray, t: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Return (co, li) = (shorter, longer); ties keep ``s`` as the line series
    (rows), matching the paper's figures."""
    if len(s) >= len(t):
        return np.asarray(t, dtype=np.float64), np.asarray(s, dtype=np.float64)
    return np.asarray(s, dtype=np.float64), np.asarray(t, dtype=np.float64)


def dtw_naive(s: np.ndarray, t: np.ndarray, window: int | None = None) -> float:
    """O(n*m) full-matrix DTW (Figure 1 equations). Reference of references."""
    s = np.asarray(s, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    n, m = len(s), len(t)
    if window is not None and n != m:
        raise ValueError("windowed DTW requires equal lengths here")
    M = np.full((n + 1, m + 1), INF)
    M[0, 0] = 0.0
    for i in range(1, n + 1):
        lo, hi = 1, m
        if window is not None:
            lo, hi = max(1, i - window), min(m, i + window)
        for j in range(lo, hi + 1):
            c = (s[i - 1] - t[j - 1]) ** 2
            M[i, j] = c + min(M[i - 1, j], M[i, j - 1], M[i - 1, j - 1])
    return float(M[n, m])


def dtw_rows(s: np.ndarray, t: np.ndarray) -> float:
    """Algorithm 1: O(n) space DTW, literal transcription."""
    co, li = _split(s, t)
    lco, lli = len(co), len(li)
    prev = np.full(lco + 1, INF)
    curr = np.full(lco + 1, INF)
    curr[0] = 0.0
    for i in range(1, lli + 1):
        prev, curr = curr, prev
        curr[0] = INF
        for j in range(1, lco + 1):
            c = (li[i - 1] - co[j - 1]) ** 2
            curr[j] = c + min(curr[j - 1], prev[j], prev[j - 1])
    return float(curr[lco])


def pruned_left(s: np.ndarray, t: np.ndarray, ub: float) -> float:
    """Algorithm 2: pruning from the left, literal transcription."""
    co, li = _split(s, t)
    lco, lli = len(co), len(li)
    prev = np.full(lco + 1, INF)
    curr = np.full(lco + 1, INF)
    curr[0] = 0.0
    next_start = 1
    for i in range(1, lli + 1):
        prev, curr = curr, prev
        j = next_start
        curr[j - 1] = INF
        # stage 1: successive discard points (no left dependency)
        while j == next_start and j <= lco:
            c = (li[i - 1] - co[j - 1]) ** 2
            curr[j] = c + min(prev[j], prev[j - 1])
            if curr[j] > ub:
                next_start += 1
            j += 1
        # Paper line 15 reads ``if j > l_co then return inf``; taken literally
        # it also abandons when the one sub-ub cell sits exactly in the last
        # column (j == next_start + 1 == l_co + 1), which over-prunes. We keep
        # the intended semantics: abandon iff the whole row was discard points.
        if j == next_start:  # implies next_start > lco
            return INF
        # stage 2: normal DTW computation
        while j <= lco:
            c = (li[i - 1] - co[j - 1]) ** 2
            curr[j] = c + min(curr[j - 1], prev[j], prev[j - 1])
            j += 1
    return float(curr[lco])


@dataclass
class EATrace:
    """Row-level band decisions, for equivalence testing."""

    next_start: list[int] = field(default_factory=list)
    pruning_point: list[int] = field(default_factory=list)
    abandoned_at_row: int = -1  # -1 = completed all rows
    rows_computed: int = 0
    cells_computed: int = 0


def ea_pruned_dtw(
    s: np.ndarray,
    t: np.ndarray,
    ub: float,
    window: int | None = None,
    trace: EATrace | None = None,
    cb: np.ndarray | None = None,
) -> float:
    """Algorithm 3: EAPrunedDTW, literal transcription (+ optional window).

    The paper presents the algorithm without a warping window "for clarity's
    sake"; the experiments require one. The windowed extension (equal lengths
    only) clips each row's column range to ``[i-window, i+window]`` exactly as
    the UCR suites do, interacting with the band pointers as in the MonashTS
    reference implementation.
    """
    co, li = _split(s, t)
    lco, lli = len(co), len(li)
    if window is not None:
        if lco != lli:
            raise ValueError("windowed EAPrunedDTW requires equal lengths")
        if window >= lco:
            window = None
    # ub = +inf needs no special casing: no cell ever exceeds it, so the
    # algorithm degrades gracefully to the plain row-by-row DTW.

    prev = np.full(lco + 1, INF)
    curr = np.full(lco + 1, INF)
    curr[0] = 0.0
    next_start = 1
    prev_pruning_point = 1
    pruning_point = 0

    def cost(i: int, j: int) -> float:
        return (li[i - 1] - co[j - 1]) ** 2

    ub_base = ub
    for i in range(1, lli + 1):
        prev, curr = curr, prev
        # UCR-suite upper-bound tightening: remaining columns beyond i+w
        # contribute at least cb[i+w+1] (0-based), so tighten the threshold.
        if cb is not None:
            w = 0 if window is None else window
            nxt = i + w  # 0-based index of column (i + w + 1) in paper terms
            ub = ub_base - (cb[nxt] if nxt <= lco - 1 else 0.0)
        # window clipping of this row's admissible columns
        if window is None:
            wlo, whi = 1, lco
        else:
            wlo, whi = max(1, i - window), min(lco, i + window)
        if next_start < wlo:
            next_start = wlo  # the window border acts like discard points
        j = next_start
        curr[j - 1] = INF
        cells = 0

        # stage 1: while within the discard-point prefix (deps: top, diag)
        while j == next_start and j < prev_pruning_point:
            c = cost(i, j)
            curr[j] = c + min(prev[j], prev[j - 1])
            cells += 1
            if curr[j] <= ub:
                pruning_point = j + 1
            else:
                next_start += 1
            j += 1
        # stage 2: normal 3-way computation below previous pruning point
        while j < prev_pruning_point:
            c = cost(i, j)
            curr[j] = c + min(curr[j - 1], prev[j], prev[j - 1])
            cells += 1
            if curr[j] <= ub:
                pruning_point = j + 1
            j += 1
        # stage 3: at the previous pruning point column
        if j <= whi:
            c = cost(i, j)
            if j == next_start:
                curr[j] = c + prev[j - 1]
                cells += 1
                if curr[j] <= ub:
                    pruning_point = j + 1
                else:
                    if trace is not None:
                        trace.abandoned_at_row = i
                        trace.rows_computed = i
                        trace.cells_computed += cells
                    return INF  # border collision -> early abandon
            else:
                curr[j] = c + min(curr[j - 1], prev[j - 1])
                cells += 1
                if curr[j] <= ub:
                    pruning_point = j + 1
            j += 1
        else:
            if j == next_start:
                if trace is not None:
                    trace.abandoned_at_row = i
                    trace.rows_computed = i
                    trace.cells_computed += cells
                return INF  # whole row was discard points -> early abandon
        # stage 4: past the previous pruning point (dep: left only)
        while j == pruning_point and j <= whi:
            c = cost(i, j)
            curr[j] = c + curr[j - 1]
            cells += 1
            if curr[j] <= ub:
                pruning_point = j + 1
            j += 1

        prev_pruning_point = pruning_point
        if trace is not None:
            trace.next_start.append(next_start)
            trace.pruning_point.append(pruning_point)
            trace.rows_computed = i
            trace.cells_computed += cells

    if prev_pruning_point > lco:
        return float(curr[lco])
    return INF


def pruned_dtw_usp(
    s: np.ndarray, t: np.ndarray, ub: float, window: int | None = None
) -> float:
    """PrunedDTW as used in the UCR-USP suite (Silva et al. 2018) — baseline.

    Prunes from the left like Algorithm 2 and early abandons on the *row
    minimum* exceeding ``ub`` (the strategy EAPrunedDTW's border collision
    replaces). Cell values match exact DTW whenever the result is <= ub.
    """
    co, li = _split(s, t)
    lco, lli = len(co), len(li)
    if window is not None:
        if lco != lli:
            raise ValueError("windowed PrunedDTW requires equal lengths")
        if window >= lco:
            window = None
    prev = np.full(lco + 1, INF)
    curr = np.full(lco + 1, INF)
    curr[0] = 0.0
    next_start = 1
    for i in range(1, lli + 1):
        prev, curr = curr, prev
        if window is None:
            wlo, whi = 1, lco
        else:
            wlo, whi = max(1, i - window), min(lco, i + window)
        next_start = max(next_start, wlo)
        j = next_start
        curr[j - 1] = INF
        row_min = INF
        advancing = True
        while j <= whi:
            c = (li[i - 1] - co[j - 1]) ** 2
            curr[j] = c + min(curr[j - 1], prev[j], prev[j - 1])
            if curr[j] > ub:
                if advancing:
                    next_start += 1
            else:
                advancing = False
                row_min = min(row_min, curr[j])
            j += 1
        if row_min > ub:
            return INF
    if curr[lco] > ub:
        return INF
    return float(curr[lco])
