"""The paper's contribution: EAPrunedDTW and its supporting DTW stack.

Public API:
  dtw, dtw_batch                — exact DTW (scan formulation)
  ea_pruned_dtw                 — EAPrunedDTW, full-row vectorized
  ea_pruned_dtw_banded          — EAPrunedDTW, O(n·band) banded hot path
  ea_pruned_dtw_batch           — batched banded EA (search unit of work),
                                  backend-dispatched (see core.backend),
                                  scalar or per-lane ub
  ea_pruned_dtw_multi_batch     — Q queries' rounds flattened to one
                                  (Q x K)-lane dispatch, per-lane ub vector
  ea_pruned_dtw_persistent      — the whole best-first sweep in ONE dispatch
                                  (incumbent carried across candidate blocks
                                  on device; backend-dispatched)
  resolve_backend, BACKENDS     — Pallas-vs-JAX backend selection
  pruned_dtw                    — PrunedDTW baseline (row-min abandon)
  envelope, lb_keogh, lb_kim_fl — lower bounds
  SearchInputError, NonFiniteInputError, StreamStateError
                                — typed guard taxonomy (core.guards)
"""
from repro.core.backend import BACKENDS, resolve_backend
from repro.core.guards import (
    NonFiniteInputError,
    SearchInputError,
    StreamStateError,
)
from repro.core.batch import (
    ea_pruned_dtw_batch,
    ea_pruned_dtw_multi_batch,
    ea_pruned_dtw_persistent,
    ea_search_round,
)
from repro.core.common import BIG
from repro.core.dtw import dtw, dtw_batch, dtw_matrix
from repro.core.ea_pruned_dtw import EAInfo, ea_pruned_dtw, ea_pruned_dtw_banded
from repro.core.lower_bounds import (
    cascade_keogh_cumulative,
    envelope,
    lb_keogh,
    lb_keogh_pair,
    lb_kim_fl,
)
from repro.core.pruned_dtw import pruned_dtw

__all__ = [
    "BACKENDS",
    "BIG",
    "EAInfo",
    "cascade_keogh_cumulative",
    "dtw",
    "dtw_batch",
    "dtw_matrix",
    "ea_pruned_dtw",
    "ea_pruned_dtw_banded",
    "ea_pruned_dtw_batch",
    "ea_pruned_dtw_multi_batch",
    "ea_pruned_dtw_persistent",
    "ea_search_round",
    "envelope",
    "lb_keogh",
    "lb_keogh_pair",
    "lb_kim_fl",
    "NonFiniteInputError",
    "SearchInputError",
    "StreamStateError",
    "pruned_dtw",
    "resolve_backend",
]
