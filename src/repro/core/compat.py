"""Version-compat shims over moving JAX APIs.

One place to absorb upstream API churn so feature modules stay clean. The
only current inhabitant is ``shard_map``: new JAX releases expose
``jax.shard_map`` with a ``check_vma`` flag, older releases only have
``jax.experimental.shard_map.shard_map`` with ``check_rep``. Both callers
(the distributed search drivers and the expert-parallel MoE) want the
replication check disabled — their per-device loops mix device-varying and
replicated values — so the shim bakes that in.
"""
from __future__ import annotations

import jax


def shard_map(fn, mesh, in_specs, out_specs):
    """``shard_map`` with the replication/VMA check disabled, on any JAX.

    New jax: ``jax.shard_map(..., check_vma=False)``. Older releases:
    ``jax.experimental.shard_map.shard_map(..., check_rep=False)``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
