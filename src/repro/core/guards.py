"""Typed input guards for the search serving surface (DESIGN.md §2.6).

The speed layers (kernel, multi-query, streaming, persistent sweep) assume
well-formed inputs; before this module, a malformed call died deep inside a
jitted program with a shape-error traceback, and a non-finite query silently
poisoned every distance it touched. This module is the one validation
chokepoint every public entry point calls:

  * ``ea_pruned_dtw_batch`` / ``ea_pruned_dtw_multi_batch`` — batch shapes,
    dtypes, knob sanity, ``cb >= 0``.
  * ``subsequence_search`` / ``multi_query_search`` — reference/query shape
    and dtype, length-vs-window sanity, query finiteness.
  * ``ingest_chunk`` / ``StreamSearchEngine`` — chunk dtype/ndim up front
    (instead of failing inside jit), stream-state errors carrying the stream
    position.

Exception taxonomy
------------------
``SearchInputError``      — malformed arguments (shape/dtype/ndim/knobs).
                            Subclasses ``ValueError``: existing callers that
                            catch ``ValueError`` keep working.
``NonFiniteInputError``   — a *query side* array contains NaN/Inf. Reference
                            side non-finites are NOT an error: they are
                            quarantined (``search.znorm.window_finite_mask``)
                            and the engine keeps serving.
``StreamStateError``      — a streaming call is inconsistent with the
                            engine's carried state (chunk bigger than the
                            fixed ingest shape, tail overflow, restoring a
                            mismatched checkpoint). Carries ``n_seen`` /
                            ``chunk_index`` context when known. Subclasses
                            ``RuntimeError`` so retry loops that treat
                            ``ValueError`` as transient do not retry a
                            caller bug — ``serve.supervisor`` explicitly
                            re-raises it instead of retrying.

Trace safety: shape/dtype/ndim checks read only static metadata and are safe
(and free) inside jit; *value* checks (finiteness, ``cb >= 0``) run only on
concrete arrays and are skipped for tracers — the drivers call this
chokepoint both from their un-jitted wrappers (concrete: full validation)
and from inside jitted round loops (tracers: static validation only).

Debug mode (``jax.experimental.checkify``)
------------------------------------------
``checked_call(fn, *args)`` wraps a jitted function with checkify NaN
checks: any primitive that *produces* a NaN on device raises a
``NonFiniteInputError`` on the host with the failing check's location,
instead of the NaN riding silently into an incumbent. Two scope limits:
checkify does not discharge through the Pallas kernels, and it rejects
vmapped while-loops (checkify-of-vmap-of-while) — which the batched DTW
round loop is on every backend. So ``checked_call`` serves the
checkify-compatible pieces (stats, cascade, plain jitted math), while the
engines' ``debug_checks=True`` opt-in (or ``REPRO_DEBUG_CHECKS``) enforces
the invariant that actually matters at the boundary it can see: no NaN ever
reaches the carried incumbents, checked synchronously after every ingest.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

DEBUG_ENV_VAR = "REPRO_DEBUG_CHECKS"


class SearchInputError(ValueError):
    """Malformed search input: shape, dtype, ndim, or knob out of contract."""


class NonFiniteInputError(SearchInputError):
    """A query-side array contains NaN/Inf (reference non-finites are
    quarantined, not rejected — see DESIGN.md §2.6)."""


class StreamStateError(RuntimeError):
    """A streaming call is inconsistent with the engine's carried state.

    ``n_seen`` (stream samples ingested so far) and ``chunk_index`` ride
    along when the caller knows them, so an operator can locate the failing
    ingest in a long-lived stream.
    """

    def __init__(self, message: str, n_seen=None, chunk_index=None):
        ctx = []
        if n_seen is not None:
            ctx.append(f"n_seen={int(n_seen)}")
        if chunk_index is not None:
            ctx.append(f"chunk_index={int(chunk_index)}")
        if ctx:
            message = f"{message} [{', '.join(ctx)}]"
        super().__init__(message)
        self.n_seen = None if n_seen is None else int(n_seen)
        self.chunk_index = None if chunk_index is None else int(chunk_index)


def is_concrete(x) -> bool:
    """True when ``x`` holds real values (not a jit/vmap tracer)."""
    return not isinstance(x, jax.core.Tracer)


def _ndim(x) -> int:
    return np.ndim(x) if not hasattr(x, "ndim") else int(x.ndim)


def ensure_series(x, name: str, ndim: int = 1, min_len: int | None = None):
    """Static checks on one array argument: ndim, inexact dtype, length."""
    if _ndim(x) != ndim:
        raise SearchInputError(
            f"{name} must be {ndim}-D, got shape {jnp.shape(x)}"
        )
    dt = jnp.result_type(x)
    if not jnp.issubdtype(dt, jnp.inexact):
        raise SearchInputError(
            f"{name} must have a floating dtype, got {dt}"
        )
    if min_len is not None and jnp.shape(x)[-1] < min_len:
        raise SearchInputError(
            f"{name} last-axis length {jnp.shape(x)[-1]} < required "
            f"{min_len} (shape {jnp.shape(x)})"
        )
    return x


def ensure_finite(x, name: str):
    """Value check: reject NaN/Inf. Skipped on tracers (trace-safe)."""
    if is_concrete(x) and not bool(jnp.all(jnp.isfinite(x))):
        bad = int(jnp.sum(~jnp.isfinite(x)))
        raise NonFiniteInputError(
            f"{name} contains {bad} non-finite value(s); queries must be "
            "finite (reference-side non-finites are quarantined instead)"
        )
    return x


def ensure_knobs(
    length: int | None = None,
    window: int | None = None,
    batch: int | None = None,
    band_width: int | None = None,
    block_k: int | None = None,
    row_block: int | None = None,
    rows_per_step: int | None = None,
):
    """Knob sanity shared by every driver; raises ``SearchInputError``."""
    if length is not None and int(length) < 2:
        raise SearchInputError(f"length must be >= 2, got {length}")
    if window is not None and int(window) < 0:
        raise SearchInputError(f"window must be >= 0, got {window}")
    if length is not None and window is not None and int(window) >= int(length):
        raise SearchInputError(
            f"window {window} must be < length {length} (a Sakoe-Chiba band "
            "wider than the series is the full DP — pass length - 1 at most)"
        )
    for knob, val in (
        ("batch", batch), ("band_width", band_width), ("block_k", block_k),
        ("row_block", row_block), ("rows_per_step", rows_per_step),
    ):
        if val is not None and int(val) < 1:
            raise SearchInputError(f"{knob} must be >= 1, got {val}")


def check_batch_args(query, candidates, ub, window, cb=None, multi=False):
    """Chokepoint for the batch primitives (core.batch entry points).

    Static shape/dtype/knob checks always run (trace-safe); value checks
    (query finiteness, ``cb >= 0``) run only on concrete arrays. ``multi``
    selects the ``(Q, m)`` x ``(Q, K, m)`` contract, else ``(m[, d])`` x
    ``(K, m[, d])``.
    """
    qnd = _ndim(query)
    cnd = _ndim(candidates)
    if multi:
        if qnd != 2:
            raise SearchInputError(
                "multi-query batch requires (Q, m) univariate queries, got "
                f"shape {jnp.shape(query)}"
            )
        if cnd != 3:
            raise SearchInputError(
                f"multi-query candidates must be (Q, K, m), got shape "
                f"{jnp.shape(candidates)}"
            )
        if jnp.shape(candidates)[0] != jnp.shape(query)[0]:
            raise SearchInputError(
                f"candidates Q={jnp.shape(candidates)[0]} != queries "
                f"Q={jnp.shape(query)[0]}"
            )
    else:
        if qnd not in (1, 2):
            raise SearchInputError(
                f"query must be (m,) or (m, dims), got shape {jnp.shape(query)}"
            )
        if cnd != qnd + 1:
            raise SearchInputError(
                f"candidates must be (K,) + query shape {jnp.shape(query)}, "
                f"got shape {jnp.shape(candidates)}"
            )
    m = jnp.shape(query)[1 if multi else 0]
    cm = jnp.shape(candidates)[2 if multi else 1]
    if cm != m:
        raise SearchInputError(
            f"candidate length {cm} != query length {m}"
        )
    ensure_knobs(window=window)
    if cb is not None:
        if jnp.shape(cb)[-1] != m:
            raise SearchInputError(
                f"cb last-axis length {jnp.shape(cb)[-1]} != query length {m}"
            )
        if is_concrete(cb) and not bool(jnp.all(jnp.asarray(cb) >= 0)):
            raise SearchInputError(
                "cb must be non-negative (cumulative LB_Keogh suffix sums)"
            )
    ensure_finite(query, "query" if not multi else "queries")
    if is_concrete(ub) and bool(jnp.any(jnp.isnan(jnp.asarray(ub)))):
        raise NonFiniteInputError("ub contains NaN (use +inf / BIG for cold)")


def debug_checks_enabled(flag: bool | None = None) -> bool:
    """Resolve the debug-checks opt-in: explicit flag, else env var."""
    if flag is not None:
        return bool(flag)
    return os.environ.get(DEBUG_ENV_VAR, "").strip().lower() in (
        "1", "true", "yes", "on"
    )


def checked_call(fn, *args, **kwargs):
    """Run ``fn`` under checkify NaN checks; raise on any device-side NaN.

    ``fn`` may be jitted (checkify discharges through jit). Any primitive
    producing a NaN raises ``NonFiniteInputError`` with the check's source
    location — the on-device finiteness tripwire for debug mode. Not
    applicable to the batched DTW dispatches themselves (their vmapped
    while-loops are outside checkify's support; see module docstring).
    """
    from jax.experimental import checkify

    err, out = checkify.checkify(fn, errors=checkify.nan_checks)(
        *args, **kwargs
    )
    try:
        err.throw()
    except checkify.JaxRuntimeError as e:
        raise NonFiniteInputError(
            f"debug-mode NaN check tripped on device: {e}"
        ) from e
    return out
