"""Streaming z-normalization of subsequence windows via prefix sums.

The UCR suite z-normalizes every candidate window of the long reference
series. Doing that one window at a time is O(N·l); with prefix sums every
window's mean/std comes from two table lookups, and the normalized window is
materialized lazily only for the candidates that survive the LB cascade.

Two forms of the stats table:

  * ``window_stats`` — offline: one prefix-sum pass over the whole reference.
  * ``append_window_stats`` — appendable: given the ``length - 1`` tail of
    samples already seen and a new chunk, produce the stats of exactly the
    windows that *become valid* with that chunk (including the windows
    straddling the tail/chunk boundary) in O(tail + chunk) work. A stream of
    appends therefore builds the same table as one offline pass over the
    concatenated series — without ever touching more than the boundary
    context. ``search/streaming.py`` drives this per ingest.

Sigma handling (flat-segment audit): ``window_stats`` returns the *raw*
standard deviation — zero for a constant window — because pruning statistics
want the true value. Every normalization site must clamp with
``clamp_sigma`` (``max(sigma, EPS)``) before dividing; a constant window then
normalizes to exactly zero (``win - mu == 0``), so the LB cascade and DTW
stay finite on flat reference segments instead of producing inf/NaN.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

EPS = 1e-8


@partial(jax.jit, static_argnames=("length",))
def window_stats(ref: jax.Array, length: int) -> tuple[jax.Array, jax.Array]:
    """Mean and std of every window ``ref[s : s+length]``.

    Returns ``(mu, sigma)`` of shape ``(N - length + 1,)`` each. ``sigma`` is
    raw (unclamped): exactly zero on a constant window. Divide only through
    ``clamp_sigma``.
    """
    n = ref.shape[0]
    p = jnp.concatenate([jnp.zeros((1,), ref.dtype), jnp.cumsum(ref)])
    q = jnp.concatenate([jnp.zeros((1,), ref.dtype), jnp.cumsum(ref * ref)])
    starts = jnp.arange(n - length + 1)
    s1 = p[starts + length] - p[starts]
    s2 = q[starts + length] - q[starts]
    mu = s1 / length
    var = jnp.maximum(s2 / length - mu * mu, 0.0)
    return mu, jnp.sqrt(var)


def append_window_stats(
    tail: jax.Array, chunk: jax.Array, length: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Stats of the windows that become valid when ``chunk`` is appended.

    ``tail`` holds the last ``min(seen, length - 1)`` samples of the stream
    so far (empty at stream start). Returns ``(new_tail, mu_new, sigma_new)``
    where the stats cover window starts ``seen - len(tail)`` …
    ``seen + len(chunk) - length`` in stream coordinates — i.e. every window
    ending inside the new chunk, including the ``length - 1`` windows
    straddling the tail/chunk boundary — and ``new_tail`` is the context to
    carry into the next append. Cost is O(tail + chunk) regardless of how
    long the stream already is; the boundary-local prefix sums also avoid the
    precision loss of differencing a billion-sample running cumsum.

    Zero windows may be valid yet (stream shorter than ``length``): then the
    stats arrays are empty and ``new_tail`` is the whole stream so far.
    """
    ctx = jnp.concatenate([jnp.asarray(tail), jnp.asarray(chunk)])
    keep = min(ctx.shape[0], length - 1)
    new_tail = ctx[ctx.shape[0] - keep :]
    if ctx.shape[0] < length:
        empty = jnp.zeros((0,), ctx.dtype)
        return new_tail, empty, empty
    mu, sigma = window_stats(ctx, length)
    return new_tail, mu, sigma


def clamp_sigma(sigma: jax.Array) -> jax.Array:
    """The one sanctioned sigma clamp: keeps flat windows finite under
    normalization (they become all-zero, their true z-normal form limit)."""
    return jnp.maximum(sigma, EPS)


@jax.jit
def znorm(x: jax.Array) -> jax.Array:
    """Z-normalize along the last axis (whole-series, for queries)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    sd = jnp.std(x, axis=-1, keepdims=True)
    return (x - mu) / clamp_sigma(sd)


@partial(jax.jit, static_argnames=("length",))
def gather_norm_windows(
    ref: jax.Array,
    starts: jax.Array,
    length: int,
    mu: jax.Array,
    sigma: jax.Array,
) -> jax.Array:
    """Materialize z-normalized windows ``(K, length)`` for given starts.

    ``mu``/``sigma`` are the precomputed per-window stats indexed by start.
    """
    idx = starts[:, None] + jnp.arange(length)[None, :]
    win = ref[idx]
    m = mu[starts][:, None]
    s = clamp_sigma(sigma[starts])[:, None]
    return (win - m) / s
