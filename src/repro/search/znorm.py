"""Streaming z-normalization of subsequence windows via prefix sums.

The UCR suite z-normalizes every candidate window of the long reference
series. Doing that one window at a time is O(N·l); with prefix sums every
window's mean/std comes from two table lookups, and the normalized window is
materialized lazily only for the candidates that survive the LB cascade.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

EPS = 1e-8


@partial(jax.jit, static_argnames=("length",))
def window_stats(ref: jax.Array, length: int) -> tuple[jax.Array, jax.Array]:
    """Mean and std of every window ``ref[s : s+length]``.

    Returns ``(mu, sigma)`` of shape ``(N - length + 1,)`` each.
    """
    n = ref.shape[0]
    p = jnp.concatenate([jnp.zeros((1,), ref.dtype), jnp.cumsum(ref)])
    q = jnp.concatenate([jnp.zeros((1,), ref.dtype), jnp.cumsum(ref * ref)])
    starts = jnp.arange(n - length + 1)
    s1 = p[starts + length] - p[starts]
    s2 = q[starts + length] - q[starts]
    mu = s1 / length
    var = jnp.maximum(s2 / length - mu * mu, 0.0)
    return mu, jnp.sqrt(var)


@jax.jit
def znorm(x: jax.Array) -> jax.Array:
    """Z-normalize along the last axis (whole-series, for queries)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    sd = jnp.std(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.maximum(sd, EPS)


@partial(jax.jit, static_argnames=("length",))
def gather_norm_windows(
    ref: jax.Array,
    starts: jax.Array,
    length: int,
    mu: jax.Array,
    sigma: jax.Array,
) -> jax.Array:
    """Materialize z-normalized windows ``(K, length)`` for given starts.

    ``mu``/``sigma`` are the precomputed per-window stats indexed by start.
    """
    idx = starts[:, None] + jnp.arange(length)[None, :]
    win = ref[idx]
    m = mu[starts][:, None]
    s = jnp.maximum(sigma[starts][:, None], EPS)
    return (win - m) / s
