"""Streaming z-normalization of subsequence windows via prefix sums.

The UCR suite z-normalizes every candidate window of the long reference
series. Doing that one window at a time is O(N·l); with prefix sums every
window's mean/std comes from two table lookups, and the normalized window is
materialized lazily only for the candidates that survive the LB cascade.

Two forms of the stats table:

  * ``window_stats`` — offline: one prefix-sum pass over the whole reference.
  * ``append_window_stats`` — appendable: given the ``length - 1`` tail of
    samples already seen and a new chunk, produce the stats of exactly the
    windows that *become valid* with that chunk (including the windows
    straddling the tail/chunk boundary) in O(tail + chunk) work. A stream of
    appends therefore builds the same table as one offline pass over the
    concatenated series — without ever touching more than the boundary
    context. ``search/streaming.py`` drives this per ingest.

Sigma handling (flat-segment audit): ``window_stats`` returns the *raw*
standard deviation — zero for a constant window — because pruning statistics
want the true value. Every normalization site must clamp with
``clamp_sigma`` (``max(sigma, EPS)``) before dividing; a constant window then
normalizes to exactly zero (``win - mu == 0``), so the LB cascade and DTW
stay finite on flat reference segments instead of producing inf/NaN.

Non-finite quarantine (DESIGN.md §2.6): the prefix sums above are the reason
a single NaN sample is catastrophic without a prepass — ``cumsum`` carries
it into the stats of *every* later window. The quarantine contract is
implemented right here at the stats layer: ``window_finite_mask`` marks the
windows overlapping any non-finite sample (one more prefix-sum pass, same
O(N) shape as the stats themselves), and ``sanitize_series`` zero-fills the
bad samples so the stats/cascade arithmetic of the *surviving* windows is
untouched by them. Drivers kill the masked windows through the dead-lane
sentinel (``+inf`` lower bound) and report the count; everything outside a
quarantined window stays exact.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

# The sigma floor and the two normalization entry points live in core.common
# (the fused gather path normalizes inside core.batch and the kernels, and
# core must not import search); re-exported here so the search layer keeps
# one import site for all z-normalization.
from repro.core.common import EPS, clamp_sigma, norm_window_slice

__all__ = [
    "EPS",
    "append_window_stats",
    "clamp_sigma",
    "gather_norm_windows",
    "norm_window_slice",
    "sanitize_series",
    "window_finite_mask",
    "window_stats",
    "znorm",
]


@partial(jax.jit, static_argnames=("length",))
def window_stats(ref: jax.Array, length: int) -> tuple[jax.Array, jax.Array]:
    """Mean and std of every window ``ref[s : s+length]``.

    Returns ``(mu, sigma)`` of shape ``(N - length + 1,)`` each. ``sigma`` is
    raw (unclamped): exactly zero on a constant window. Divide only through
    ``clamp_sigma``.
    """
    n = ref.shape[0]
    p = jnp.concatenate([jnp.zeros((1,), ref.dtype), jnp.cumsum(ref)])
    q = jnp.concatenate([jnp.zeros((1,), ref.dtype), jnp.cumsum(ref * ref)])
    starts = jnp.arange(n - length + 1)
    s1 = p[starts + length] - p[starts]
    s2 = q[starts + length] - q[starts]
    mu = s1 / length
    var = jnp.maximum(s2 / length - mu * mu, 0.0)
    return mu, jnp.sqrt(var)


def append_window_stats(
    tail: jax.Array, chunk: jax.Array, length: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Stats of the windows that become valid when ``chunk`` is appended.

    ``tail`` holds the last ``min(seen, length - 1)`` samples of the stream
    so far (empty at stream start). Returns ``(new_tail, mu_new, sigma_new)``
    where the stats cover window starts ``seen - len(tail)`` …
    ``seen + len(chunk) - length`` in stream coordinates — i.e. every window
    ending inside the new chunk, including the ``length - 1`` windows
    straddling the tail/chunk boundary — and ``new_tail`` is the context to
    carry into the next append. Cost is O(tail + chunk) regardless of how
    long the stream already is; the boundary-local prefix sums also avoid the
    precision loss of differencing a billion-sample running cumsum.

    Zero windows may be valid yet (stream shorter than ``length``): then the
    stats arrays are empty and ``new_tail`` is the whole stream so far.
    """
    ctx = jnp.concatenate([jnp.asarray(tail), jnp.asarray(chunk)])
    keep = min(ctx.shape[0], length - 1)
    new_tail = ctx[ctx.shape[0] - keep :]
    if ctx.shape[0] < length:
        empty = jnp.zeros((0,), ctx.dtype)
        return new_tail, empty, empty
    mu, sigma = window_stats(ctx, length)
    return new_tail, mu, sigma


@partial(jax.jit, static_argnames=("length",))
def window_finite_mask(ref: jax.Array, length: int) -> jax.Array:
    """``(N - length + 1,)`` bool mask: True where the window is NaN/Inf-free.

    The quarantine prepass: a window overlapping *any* non-finite sample is
    excluded from search (mask False); every other window stays exact. One
    prefix-sum pass over a non-finite indicator — the same O(N) shape as
    ``window_stats``, so the clean-data overhead is one extra cumsum.
    """
    bad = (~jnp.isfinite(ref)).astype(jnp.int32)
    p = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(bad)])
    starts = jnp.arange(ref.shape[0] - length + 1)
    return (p[starts + length] - p[starts]) == 0


@jax.jit
def sanitize_series(ref: jax.Array) -> jax.Array:
    """Zero-fill non-finite samples so prefix sums stay finite.

    Only windows already condemned by ``window_finite_mask`` contain the
    zero-filled samples; the fill exists so the shared ``cumsum`` does not
    carry a NaN into the table entries of the *surviving* windows. On a
    fully finite series this is the identity.
    """
    return jnp.where(jnp.isfinite(ref), ref, jnp.zeros_like(ref))


@jax.jit
def znorm(x: jax.Array) -> jax.Array:
    """Z-normalize along the last axis (whole-series, for queries)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    sd = jnp.std(x, axis=-1, keepdims=True)
    return (x - mu) / clamp_sigma(sd)


@partial(jax.jit, static_argnames=("length",))
def gather_norm_windows(
    ref: jax.Array,
    starts: jax.Array,
    length: int,
    mu: jax.Array,
    sigma: jax.Array,
) -> jax.Array:
    """Materialize z-normalized windows ``(K, length)`` for given starts.

    ``mu``/``sigma`` are the precomputed per-window stats indexed by start.

    This is the O(K·l) **slab** baseline (``gather="slab"``): an arbitrary
    index gather that re-copies every overlapping window. The default search
    paths use the fused normalize-on-slice form instead
    (``core.common.norm_window_slice`` on the jax backend, in-kernel
    slicing on Pallas) with an O(N + K) working set; sanctioned callers of
    this function are the full/pruned baseline cores in
    ``search.pipeline._baseline_search_impl`` and the explicit
    ``gather="slab"`` comparison arms — ``scripts/lint_layers.py`` enforces
    the import surface.
    """
    idx = starts[:, None] + jnp.arange(length)[None, :]
    win = ref[idx]
    m = mu[starts][:, None]
    s = clamp_sigma(sigma[starts])[:, None]
    return (win - m) / s
