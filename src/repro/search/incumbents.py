"""The per-query incumbent store and quarantine ledger.

Every search frontend in this repo carries the same two pieces of state:

  * the **incumbent vector** — per-query upper bound ``ub[q]`` plus the
    window start ``best[q]`` that achieved it (``-1`` while a seed is
    unbeaten). Updates are *strict improvement only* (``d < ub``, never
    ``<=``): the first achiever of a distance keeps its start, which is
    what makes carried seeds admissible (a rerun of a range seeded with a
    bound achieved inside that range can still re-adopt the achieving
    window only because the seed rode in *with* its start).
  * the **quarantine counters** (DESIGN.md §2.6/§2.7) — windows excluded
    by the non-finite quarantine, raw bad samples seen, and windows later
    re-admitted by ``correct()``.

Before the pipeline refactor each frontend hand-rolled both (five copies
of the argmin/strict-improvement fold, two copies of the counter
bookkeeping, with subtle drift). This module is now the single owner:

  * ``IncumbentState`` / ``initial_state`` — the carried ``(ub, best)``.
  * ``fold_min`` — one ``(Q, K)`` round of distances folded into the
    state (device-side, used inside every jitted round loop).
  * ``fold_np`` — the same rule on host numpy arrays (the resilient
    executor folds completed ranges on the host).
  * ``merge_states`` — two full incumbent snapshots merged under the same
    strict-improvement rule. This is what makes hedged dispatch
    (DESIGN.md §2.9) *provably idempotent*: duplicate completions of the
    same range, seeded with the same incumbents, return identical
    ``(start, dist)`` pairs, and folding the same pair twice is a no-op
    under strict improvement (``d < ub`` is false the second time) — so a
    hedge can change latency but never the answer.
  * ``DEAD_LANE_UB`` re-export — the negative sentinel that kills a lane
    on row 0; any lane whose lower bound is non-finite (padding,
    quarantined, inactive query) must be submitted with it.
  * ``QuarantineLedger`` — the counter triple with checkpoint-stable
    ``state_dict()`` keys (``quarantined`` / ``bad_samples`` /
    ``readmitted``), shared by ``IngestResult`` accounting and
    ``serve.stream.StreamSearchEngine``.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.common import BIG, DEAD_LANE_UB  # noqa: F401  (re-export)


class IncumbentState(NamedTuple):
    """Carried per-query incumbents: ``(Q,)`` upper bounds + best starts."""
    ub: jax.Array    # (Q,) upper bound; == seed while unbeaten
    best: jax.Array  # (Q,) achieving window start; -1 while unbeaten


def initial_state(
    nq: int, dtype=jnp.float32, ub_init=None, best_dtype=jnp.int32
) -> IncumbentState:
    """Fresh incumbents for Q queries; ``ub_init`` warm-seeds (scalar/(Q,))."""
    if ub_init is None:
        ub = jnp.full((nq,), BIG, dtype)
    else:
        ub = jnp.broadcast_to(jnp.asarray(ub_init, dtype), (nq,))
    return IncumbentState(ub=ub, best=jnp.full((nq,), -1, best_dtype))


def fold_min(
    state: IncumbentState, starts: jax.Array, d: jax.Array, offset=0
) -> tuple[IncumbentState, jax.Array]:
    """Fold one round of distances into the incumbents (strict improvement).

    ``d`` is ``(Q, K)`` with dead/padding lanes already at ``+inf``;
    ``starts`` the matching ``(Q, K)`` window starts. ``offset`` maps local
    starts into caller coordinates (stream offset, range ``lo``). Returns
    the new state and the per-query ``improved`` mask.
    """
    k = jnp.argmin(d, axis=1)
    dmin = jnp.take_along_axis(d, k[:, None], axis=1)[:, 0]
    improved = dmin < state.ub
    starts_k = jnp.take_along_axis(starts, k[:, None], axis=1)[:, 0]
    return IncumbentState(
        ub=jnp.where(improved, dmin, state.ub),
        best=jnp.where(
            improved, offset + starts_k.astype(state.best.dtype), state.best
        ),
    ), improved


def fold_np(ub: np.ndarray, best: np.ndarray, starts, dists):
    """Host-side fold of achieved ``(start, dist)`` pairs (resilient path).

    Same strict-improvement rule as ``fold_min``; additionally requires a
    real achieving start (``>= 0``) — a bare bound with no achieving window
    is never folded (see ``search.resilient`` module docstring).
    """
    s = np.asarray(starts, np.int64)
    d = np.asarray(dists, np.float64)
    improved = np.logical_and(s >= 0, d < ub)
    return np.where(improved, d, ub), np.where(improved, s, best)


def merge_states(a: IncumbentState, b: IncumbentState) -> IncumbentState:
    """Merge two incumbent snapshots under strict improvement.

    Used by the hedged executor (DESIGN.md §2.9) to fold a backup
    completion into the primary's: per query, ``b`` wins only where its
    bound is *strictly* tighter, so merging a duplicate completion (same
    range, same seed → identical arrays) reproduces ``a`` bit-exactly —
    duplicate completions are idempotent. On an exact distance tie the
    first argument's achiever is kept (the same first-strict-improvement
    rule every fold in this repo applies).
    """
    take_b = b.ub < a.ub
    return IncumbentState(
        ub=jnp.where(take_b, b.ub, a.ub),
        best=jnp.where(take_b, b.best, a.best),
    )


class QuarantineLedger:
    """One source of truth for §2.6 quarantine accounting.

    ``windows`` / ``samples`` accumulate lazily as device scalars so an
    ingest never forces a sync just to keep counters (the serving engine
    overlaps chunk arrival with the in-flight dispatch); ``readmitted`` is
    host-driven (the re-admission queue lives on the host). The
    ``state_dict`` keys match the engine's historical checkpoint layout, so
    snapshots taken before the ledger existed restore unchanged.
    """

    def __init__(self):
        self.windows = jnp.asarray(0, jnp.int32)
        self.samples = jnp.asarray(0, jnp.int32)
        self.readmitted = 0

    def note_windows(self, n) -> None:
        """Count newly quarantined windows (device scalar ok)."""
        self.windows = self.windows + jnp.asarray(n, jnp.int32)

    def note_samples(self, n) -> None:
        """Count newly seen non-finite raw samples (device scalar ok)."""
        self.samples = self.samples + jnp.asarray(n, jnp.int32)

    def correct_samples(self, k: int) -> None:
        """``k`` bad samples were patched with finite values."""
        self.samples = self.samples - jnp.asarray(int(k), jnp.int32)

    def readmit(self, n: int) -> None:
        """``n`` previously quarantined windows were rescored back in."""
        n = int(n)
        self.windows = self.windows - jnp.asarray(n, jnp.int32)
        self.readmitted += n

    def state_dict(self) -> dict:
        return {
            "quarantined": np.asarray(self.windows, np.int32),
            "bad_samples": np.asarray(self.samples, np.int32),
            "readmitted": np.asarray(self.readmitted, np.int64),
        }

    def load_state_dict(self, state: dict) -> None:
        self.windows = jnp.asarray(state["quarantined"], jnp.int32)
        self.samples = jnp.asarray(state["bad_samples"], jnp.int32)
        # Older checkpoints predate re-admission.
        self.readmitted = int(state.get("readmitted", 0))
