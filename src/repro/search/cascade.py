"""Lower-bound cascade over all candidate windows (UCR-suite stage 1).

One fused, batched pass computes LB_Kim and LB_Keogh for *every* window —
the TPU-native replacement for the UCR suite's per-candidate cascade. The
output is a best-first candidate ordering plus per-window lower bounds, which
stage 2 (batched EAPrunedDTW, search/subsequence.py) consumes.

Chunked over windows so the materialized ``(chunk, l)`` window matrix stays
within a fixed memory budget regardless of reference length.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.common import norm_window_slice
from repro.core.lower_bounds import envelope, lb_keogh, lb_kim_fl


class CascadeOut(NamedTuple):
    order: jax.Array    # (N,) window starts sorted by ascending lower bound
    lb_sorted: jax.Array  # (N,) the lower bound per sorted window
    n_windows: int


@partial(jax.jit, static_argnames=("length", "window", "use_kim", "use_keogh", "chunk"))
def cascade_lower_bounds(
    ref: jax.Array,
    query_n: jax.Array,
    mu: jax.Array,
    sigma: jax.Array,
    length: int,
    window: int,
    use_kim: bool = True,
    use_keogh: bool = True,
    chunk: int = 4096,
) -> jax.Array:
    """Lower bound for every candidate window start. Returns ``(N,)``.

    ``query_n`` must already be z-normalized. When both bounds are enabled the
    result is their max (both are valid DTW lower bounds).
    """
    n_win = ref.shape[0] - length + 1
    u, low = envelope(query_n, window)

    n_chunks = -(-n_win // chunk)
    pad_total = n_chunks * chunk

    def one_chunk(c0: jax.Array) -> jax.Array:
        starts = c0 + jnp.arange(chunk)
        valid = starts < n_win
        safe = jnp.minimum(starts, n_win - 1)
        cand = norm_window_slice(ref, safe, length, mu, sigma)
        lb = jnp.zeros((chunk,), cand.dtype)
        if use_kim:
            lb = jnp.maximum(lb, lb_kim_fl(query_n, cand))
        if use_keogh:
            lb = jnp.maximum(lb, lb_keogh(cand, u, low))
        return jnp.where(valid, lb, jnp.inf)

    chunk_starts = jnp.arange(n_chunks) * chunk
    lbs = jax.lax.map(one_chunk, chunk_starts).reshape(pad_total)
    return lbs[:n_win]


@partial(jax.jit, static_argnames=("length", "window", "use_kim", "use_keogh", "chunk"))
def cascade(
    ref: jax.Array,
    query_n: jax.Array,
    mu: jax.Array,
    sigma: jax.Array,
    length: int,
    window: int,
    use_kim: bool = True,
    use_keogh: bool = True,
    chunk: int = 4096,
) -> tuple[jax.Array, jax.Array]:
    """Best-first ordering of window starts by lower bound.

    Returns ``(order, lb_sorted)``; both ``(N,)`` with N = #windows.
    """
    lbs = cascade_lower_bounds(
        ref, query_n, mu, sigma, length, window, use_kim, use_keogh, chunk
    )
    order = jnp.argsort(lbs)
    return order, lbs[order]
