"""Multi-query subsequence search: Q queries amortized over one launch path.

The serving shape of the paper's pipeline. ``subsequence_search`` answers
one query; a serving tier answers a *workload* of queries against the same
reference. Running Q sequential searches wastes exactly what the batched
EAPrunedDTW primitive is good at: lanes. This frontend flattens the Q
queries' candidate rounds into a single ``(Q × batch)`` lane set per
dispatch and keeps one incumbent **per query**.

The machinery lives in ``search.pipeline`` (DESIGN.md §2.8): this module
validates, builds the ``SearchPlan``, and runs the shared offline core
(``pipeline._offline_search_impl`` → ``run_host_rounds`` /
``run_persistent``); the mesh closure below binds the sharded executor
(``pipeline.make_sharded_search``).

(query × candidate) lane layout
-------------------------------
Each round builds a ``(Q, batch, length)`` candidate tensor — row ``q`` is
the next best-first batch of query ``q``'s own LB-ordered candidates — and
evaluates it in one call to ``core.batch.ea_pruned_dtw_multi_batch``. On the
Pallas backend that is literally one kernel launch whose grid carries a
query-block dimension: lanes are flattened query-major (lane ``q * batch +
j`` is candidate ``j`` of query ``q``), every ``block_k`` lane tile shares
one query/envelope, and ``ub`` rides along as a per-lane VMEM vector holding
each query's incumbent broadcast over its lanes. On the ``jax`` backend the
same semantics run as a nested vmap. Either way there is exactly one
dispatch per round for the whole workload — no per-query launches and no
per-query recompilation (one trace serves every Q of the same shape).

Per-query incumbents and drop-out
---------------------------------
State is vectorized over queries: incumbent ``ub[q]``, best start
``best[q]``, and a best-first round pointer ``r[q]`` that advances only
while query ``q`` is *active* (it still has rounds left and its next batch's
smallest lower bound can beat its incumbent). The loop runs while any query
is active; a finished query drops out by having its lanes submitted with the
negative dead-lane sentinel, so the kernel abandons them on row 0 — they
cost one masked row, not a DP.

Amortized stage 1: ``window_stats`` runs once for the workload, and the LB
cascade runs as one vmapped pass over all Q queries (one fused kernel
program instead of Q sequential ones).

``ub_init`` seeds the per-query incumbents (warm starts from a cache or a
previous shard). A query whose seed is already below every candidate's
reachable distance abandons its entire round-0 batch and drops out with
``best_start == -1`` — the serving analogue of the paper's "ub from a
previous query" trick.

``rounds="persistent"`` (DESIGN.md §2.5) replaces the per-round dispatches
with ONE launch for the whole workload: every query's full best-first
candidate order is gathered once, the kernel grid keeps the query dimension
parallel, and each query's incumbent is carried in SMEM across the now
*sequential* candidate-block dimension — tightened every ``block_k`` lanes
and gating LB-pruned blocks on device. Same per-query results, O(1)
dispatches. With the default ``gather="fused"`` the sweep *addresses* the
best-first order instead of materializing a ``(Q, N, l)`` window tensor:
each block's candidates are sliced + z-normalized from the resident
reference on demand (DESIGN.md §2.10). ``warm_start`` works here too: the same prepass dispatch seeds the
sweep's SMEM incumbents and the prepass winner keeps its start when the
sweep cannot beat it (pre-refactor the knob was silently dropped).

The distributed variant (``make_distributed_multi_search``) shards the
(query, candidate-range) work items across the mesh: candidate ranges are
sharded contiguously (each device owns a slice of every query's windows, so
a device's round is Q work items — one (query, local-range) pair per query),
queries ride in the lane dimension, and the per-query incumbent *vector* is
reconciled with one vectorized ``lax.pmin`` all-reduce per round — the
multi-query generalization of ``search/distributed.py``'s scalar ``pmin``
pattern. Devices iterate in lockstep until the global continue flag
(``pmax`` over any-device-any-query-active) clears.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import guards
from repro.search.pipeline import (
    MULTI_VARIANTS,
    ROUND_DRIVERS,
    _offline_search_impl,
    make_plan,
    make_sharded_search,
)

__all__ = [
    "MULTI_VARIANTS",
    "DistMultiSearchResult",
    "MultiSearchResult",
    "make_distributed_multi_search",
    "multi_query_search",
]


class MultiSearchResult(NamedTuple):
    best_start: jax.Array  # (Q,) window start of each query's neighbour (-1: none)
    best_dist: jax.Array   # (Q,) its DTW distance (== ub_init when unbeaten)
    rounds: jax.Array      # (Q,) batch rounds each query stayed active
    lanes: jax.Array       # (Q,) candidate lanes each query submitted
    lb_pruned: jax.Array   # (Q,) candidates never evaluated thanks to LB ordering
    rows: jax.Array        # (Q,) DTW rows issued (-1: fast rounds)
    cells: jax.Array       # (Q,) admissible DTW cells (-1: fast rounds)
    quarantined: jax.Array  # windows excluded by the non-finite quarantine


class DistMultiSearchResult(NamedTuple):
    best_start: jax.Array  # (Q,)
    best_dist: jax.Array   # (Q,)
    rounds: jax.Array      # max rounds any device spent on the workload
    quarantined: jax.Array  # windows excluded by the non-finite quarantine
    #   (scalar: windows are query-independent; psum over shards == the
    #   single-device count)


def multi_query_search(
    ref: jax.Array,
    queries: jax.Array,
    length: int,
    window: int,
    variant: str = "eapruned",
    batch: int = 64,
    band_width: int | None = None,
    chunk: int = 4096,
    with_info: bool = False,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
    ub_init: jax.Array | None = None,
    warm_start: int = 0,
    rounds: str = "host",
    quarantine: bool = True,
    gather: str = "fused",
    slab_budget: int | None = None,
) -> MultiSearchResult:
    """Nearest z-normalized window of ``ref`` for each of Q queries.

    Equivalent to Q independent ``subsequence_search`` calls (same
    ``best_start`` / ``best_dist`` per query) but amortized: one
    ``window_stats`` pass, one vmapped LB cascade, and one flattened
    ``(Q × batch)``-lane EAPrunedDTW dispatch per round with a per-query
    incumbent vector (see module docstring for the lane layout).

    Args:
      ref: ``(N,)`` long reference series shared by the workload.
      queries: ``(Q, l)`` raw queries (z-normalized internally).
      length: window/query length (static); ``l == length``.
      window: Sakoe-Chiba warping window in samples (static).
      variant: ``"eapruned"`` or ``"eapruned_nolb"`` (the EA batch is the
        primitive being amortized; use ``subsequence_search`` for the
        ``full`` / ``pruned`` baselines).
      batch: candidates per query per round (static) — each round dispatches
        ``Q * batch`` lanes.
      with_info: collect per-query rows/cells pruning counters (stats
        rounds); fast rounds leave them at ``-1``.
      backend: DTW batch backend (see ``core.backend``); resolved here, in
        the un-jitted wrapper, so ``$REPRO_DTW_BACKEND`` is re-read every
        call.
      ub_init: optional per-query initial incumbents — scalar or ``(Q,)``
        (warm starts from a cache or a previous shard). A query that cannot
        beat its seed returns ``best_start == -1`` and
        ``best_dist == ub_init[q]``.
      warm_start: number of best-LB candidates per query to full-DP in a
        tiny prepass dispatch that seeds the incumbents, so no round ever
        runs with an unbounded ``ub`` (0 disables, the default). Changes
        work, not results: it helps the Pallas backend's block-level early
        exit (round-0 blocks can die early instead of running full DPs) but
        adds prepass lanes the vmap backend cannot recoup — leave it off on
        CPU. With the persistent driver the prepass bound seeds the SMEM
        incumbents (and the prepass winner keeps its start when the sweep
        cannot beat it), so ``rounds`` reports 2 dispatches.
      rounds: ``"host"`` (per-round dispatches, the default) or
        ``"persistent"`` — the whole Q-query sweep in one launch with
        per-query incumbents carried in SMEM across candidate blocks (see
        ``search.subsequence`` module docstring for the trade-offs).
        Counter-free: combine with ``with_info`` is rejected.
      quarantine: exclude windows overlapping a non-finite reference sample
        (DESIGN.md §2.6); the excluded count is reported in
        ``result.quarantined``. On (default) even for clean data — the
        prepass is one extra prefix-sum pass.

    Returns: ``MultiSearchResult`` of per-query ``(Q,)`` arrays.
    """
    if rounds not in ROUND_DRIVERS:
        raise ValueError(f"rounds {rounds!r} not in {ROUND_DRIVERS}")
    if rounds == "persistent" and with_info:
        raise ValueError(
            "rounds='persistent' is counter-free; use the host driver for "
            "with_info stats rounds"
        )
    guards.ensure_series(ref, "ref", ndim=1, min_len=length)
    guards.ensure_series(queries, "queries", ndim=2, min_len=length)
    guards.ensure_finite(queries, "queries")
    if ub_init is not None and guards.is_concrete(ub_init):
        if bool(jnp.any(jnp.isnan(jnp.asarray(ub_init)))):
            raise guards.NonFiniteInputError(
                "ub_init contains NaN (use +inf / BIG for a cold start)"
            )
    plan = make_plan(
        length=length, window=window, variant=variant, batch=batch,
        band_width=band_width, chunk=chunk, backend=backend,
        rows_per_step=rows_per_step, block_k=block_k, row_block=row_block,
        rounds=rounds, quarantine=quarantine, warm_start=warm_start,
        gather=gather, slab_budget=slab_budget,
        with_info=with_info, allowed_variants=MULTI_VARIANTS,
    )
    state, stats, n_quar = _offline_search_impl(
        ref, queries, ub_init, plan, with_info
    )
    return MultiSearchResult(
        best_start=state.best,
        best_dist=state.ub,
        rounds=stats.rounds,
        lanes=stats.lanes,
        lb_pruned=stats.lb_pruned,
        rows=stats.rows,
        cells=stats.cells,
        quarantined=n_quar,
    )


def make_distributed_multi_search(
    mesh: jax.sharding.Mesh,
    axis_names: tuple[str, ...],
    length: int,
    window: int,
    batch: int = 64,
    band_width: int | None = None,
    chunk: int = 2048,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
    quarantine: bool = True,
    gather: str = "fused",
    slab_budget: int | None = None,
):
    """Build a jitted distributed multi-query search fn for a mesh config.

    Returns ``search_fn(ref, queries) -> DistMultiSearchResult`` with
    per-query ``(Q,)`` results — the sharded executor of the pipeline
    (``pipeline.make_sharded_search``). Work items are (query,
    candidate-range) pairs: candidate window starts are sharded contiguously
    across the mesh axes (each device owns a range of every query's
    windows), queries are flattened into the lane dimension of the
    per-device multi-query batch, and after every round the per-query
    incumbent vector is reconciled with one vectorized ``pmin`` all-reduce.
    Devices iterate in lockstep until no device has an active (query, range)
    item left (``pmax`` continue flag); a device whose query finished early
    submits dead lanes for it, so stragglers cost masked rows, not DPs.

    ``backend`` is resolved once, here at closure-build time.

    ``quarantine`` (default on) threads ``znorm.window_finite_mask`` through
    every shard's per-query cascade: poisoned windows are condemned on the
    shard that owns them (``+inf`` LB → dead-lane sentinel, query-
    independent), counts are ``psum``-reduced into
    ``DistMultiSearchResult.quarantined``, and the sanitized reference keeps
    the shared prefix sums finite for survivors — exactly the single-device
    contract of ``multi_query_search`` (DESIGN.md §2.6/§2.7).
    """
    plan = make_plan(
        length=length, window=window, variant="eapruned", batch=batch,
        band_width=band_width, chunk=chunk, backend=backend,
        rows_per_step=rows_per_step, block_k=block_k, row_block=row_block,
        quarantine=quarantine, gather=gather, slab_budget=slab_budget,
        allowed_variants=MULTI_VARIANTS,
    )
    sharded = make_sharded_search(mesh, axis_names, plan)

    def search_fn(ref: jax.Array, queries: jax.Array) -> DistMultiSearchResult:
        best_d, best_s, rounds, n_quar = sharded(ref, queries)
        return DistMultiSearchResult(
            best_start=best_s, best_dist=best_d, rounds=rounds,
            quarantined=n_quar,
        )

    return search_fn
