"""Multi-query subsequence search: Q queries amortized over one launch path.

The serving shape of the paper's pipeline. ``subsequence_search`` answers
one query; a serving tier answers a *workload* of queries against the same
reference. Running Q sequential searches wastes exactly what the batched
EAPrunedDTW primitive is good at: lanes. This driver flattens the Q queries'
candidate rounds into a single ``(Q × batch)`` lane set per dispatch and
keeps one incumbent **per query**.

(query × candidate) lane layout
-------------------------------
Each round builds a ``(Q, batch, length)`` candidate tensor — row ``q`` is
the next best-first batch of query ``q``'s own LB-ordered candidates — and
evaluates it in one call to ``core.batch.ea_pruned_dtw_multi_batch``. On the
Pallas backend that is literally one kernel launch whose grid carries a
query-block dimension: lanes are flattened query-major (lane ``q * batch +
j`` is candidate ``j`` of query ``q``), every ``block_k`` lane tile shares
one query/envelope, and ``ub`` rides along as a per-lane VMEM vector holding
each query's incumbent broadcast over its lanes. On the ``jax`` backend the
same semantics run as a nested vmap. Either way there is exactly one
dispatch per round for the whole workload — no per-query launches and no
per-query recompilation (one trace serves every Q of the same shape).

Per-query incumbents and drop-out
---------------------------------
State is vectorized over queries: incumbent ``ub[q]``, best start
``best[q]``, and a best-first round pointer ``r[q]`` that advances only
while query ``q`` is *active* (it still has rounds left and its next batch's
smallest lower bound can beat its incumbent). The loop runs while any query
is active; a finished query drops out by having its lanes submitted with the
negative dead-lane sentinel, so the kernel abandons them on row 0 — they
cost one masked row, not a DP.

Amortized stage 1: ``window_stats`` runs once for the workload, and the LB
cascade runs as one vmapped pass over all Q queries (one fused kernel
program instead of Q sequential ones).

``ub_init`` seeds the per-query incumbents (warm starts from a cache or a
previous shard). A query whose seed is already below every candidate's
reachable distance abandons its entire round-0 batch and drops out with
``best_start == -1`` — the serving analogue of the paper's "ub from a
previous query" trick.

``rounds="persistent"`` (DESIGN.md §2.5) replaces the per-round dispatches
with ONE launch for the whole workload: every query's full best-first
candidate order is gathered once, the kernel grid keeps the query dimension
parallel, and each query's incumbent is carried in SMEM across the now
*sequential* candidate-block dimension — tightened every ``block_k`` lanes
and gating LB-pruned blocks on device. Same per-query results, O(1)
dispatches, at the cost of materializing the ``(Q, N, l)`` window tensor up
front.

The distributed variant (``make_distributed_multi_search``) shards the
(query, candidate-range) work items across the mesh: candidate ranges are
sharded contiguously (each device owns a slice of every query's windows, so
a device's round is Q work items — one (query, local-range) pair per query),
queries ride in the lane dimension, and the per-query incumbent *vector* is
reconciled with one vectorized ``lax.pmin`` all-reduce per round — the
multi-query generalization of ``search/distributed.py``'s scalar ``pmin``
pattern. Devices iterate in lockstep until the global continue flag
(``pmax`` over any-device-any-query-active) clears.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import guards
from repro.core.backend import resolve_backend
from repro.core.batch import ea_pruned_dtw_multi_batch, ea_pruned_dtw_persistent
from repro.core.common import BIG, DEAD_LANE_UB, pad_lanes_to_blocks
from repro.core.lower_bounds import cascade_keogh_cumulative, envelope
from repro.search.cascade import cascade_lower_bounds
from repro.core.compat import shard_map as _shard_map
from repro.search.distributed import _local_lbs
from repro.search.subsequence import ROUND_DRIVERS
from repro.search.znorm import (
    gather_norm_windows,
    sanitize_series,
    window_finite_mask,
    window_stats,
    znorm,
)

MULTI_VARIANTS = ("eapruned", "eapruned_nolb")


class MultiSearchResult(NamedTuple):
    best_start: jax.Array  # (Q,) window start of each query's neighbour (-1: none)
    best_dist: jax.Array   # (Q,) its DTW distance (== ub_init when unbeaten)
    rounds: jax.Array      # (Q,) batch rounds each query stayed active
    lanes: jax.Array       # (Q,) candidate lanes each query submitted
    lb_pruned: jax.Array   # (Q,) candidates never evaluated thanks to LB ordering
    rows: jax.Array        # (Q,) DTW rows issued (-1: fast rounds)
    cells: jax.Array       # (Q,) admissible DTW cells (-1: fast rounds)
    quarantined: jax.Array  # windows excluded by the non-finite quarantine


class DistMultiSearchResult(NamedTuple):
    best_start: jax.Array  # (Q,)
    best_dist: jax.Array   # (Q,)
    rounds: jax.Array      # max rounds any device spent on the workload
    quarantined: jax.Array  # windows excluded by the non-finite quarantine
    #   (scalar: windows are query-independent; psum over shards == the
    #   single-device count)


def _round_slicers(batch: int):
    """Vmapped per-query round slicing, shared by both drivers.

    Returns ``(slice_round, peek_lb)``: ``slice_round(rows, ptrs)`` pulls
    each query's current ``batch``-wide round from its padded row,
    ``peek_lb(rows, ptrs)`` reads the head (smallest) lower bound of that
    round.
    """
    slice_round = jax.vmap(
        lambda row, r: jax.lax.dynamic_slice(row, (r * batch,), (batch,)),
        in_axes=(0, 0),
    )
    peek_lb = jax.vmap(
        lambda row, r: jax.lax.dynamic_slice(row, (r * batch,), (1,))[0],
        in_axes=(0, 0),
    )
    return slice_round, peek_lb


@partial(
    jax.jit,
    static_argnames=(
        "length", "window", "variant", "batch", "band_width", "chunk",
        "with_info", "backend", "rows_per_step", "block_k", "row_block",
        "warm_start", "rounds", "quarantine",
    ),
)
def _multi_query_search_impl(
    ref,
    queries,
    ub_init,
    length,
    window,
    variant,
    batch,
    band_width,
    chunk,
    with_info,
    backend,
    rows_per_step,
    block_k,
    row_block,
    warm_start,
    rounds,
    quarantine,
):
    assert variant in MULTI_VARIANTS, variant
    knobs = dict(
        rows_per_step=rows_per_step, backend=backend, block_k=block_k,
        row_block=row_block,
    )
    ref = jnp.asarray(ref)
    queries_n = znorm(jnp.asarray(queries)[:, :length])  # (Q, l)
    nq = queries_n.shape[0]
    n_win = ref.shape[0] - length + 1
    use_lb = variant != "eapruned_nolb"
    use_cb = variant == "eapruned"

    if quarantine:
        finite_ok = window_finite_mask(ref, length)
        n_quar = jnp.sum(~finite_ok).astype(jnp.int32)
        ref = sanitize_series(ref)
    else:
        finite_ok = None
        n_quar = jnp.asarray(0, jnp.int32)

    # Stage 1, amortized: one stats pass, one vmapped cascade over all Q.
    mu, sigma = window_stats(ref, length)
    if use_lb:
        lbs = jax.vmap(
            lambda qn: cascade_lower_bounds(
                ref, qn, mu, sigma, length, window, chunk=chunk
            )
        )(queries_n)                                   # (Q, N)
        if quarantine:
            # Quarantined windows: +inf lower bound — sorted behind every
            # live candidate, never reached by the cascade stop, dead lanes
            # if a partially-live round straddles them (DESIGN.md §2.6).
            lbs = jnp.where(finite_ok[None, :], lbs, jnp.inf)
        order = jnp.argsort(lbs, axis=1)               # (Q, N)
        lb_sorted = jnp.take_along_axis(lbs, order, axis=1)
    elif quarantine:
        # No-cascade variant: stable argsort of the 0/+inf quarantine mask
        # keeps natural scan order among surviving windows and pushes
        # poisoned ones to the back.
        lbs = jnp.broadcast_to(
            jnp.where(finite_ok, 0.0, jnp.inf).astype(queries_n.dtype),
            (nq, n_win),
        )
        order = jnp.argsort(lbs, axis=1)
        lb_sorted = jnp.take_along_axis(lbs, order, axis=1)
    else:
        order = jnp.broadcast_to(jnp.arange(n_win), (nq, n_win))
        lb_sorted = jnp.zeros((nq, n_win), queries_n.dtype)

    u, low = jax.vmap(envelope, in_axes=(0, None))(queries_n, window)

    if rounds == "persistent":
        # One launch for the whole workload: grid (Q, cand_blocks,
        # row_blocks) with the query dimension parallel and a per-query
        # incumbent carried across the sequential candidate dimension
        # (SMEM on the Pallas backend, mapped while_loops on jax). The
        # query-major lane layout is unchanged from the host rounds.
        assert not with_info, "persistent mode is counter-free"
        if ub_init is None:
            ub0 = jnp.full((nq,), BIG, queries_n.dtype)
        else:
            ub0 = jnp.broadcast_to(
                jnp.asarray(ub_init, queries_n.dtype), (nq,)
            )
        lb_p, order_p, _ = pad_lanes_to_blocks(block_k, lb_sorted, order)
        cand_all = jax.vmap(
            lambda s: gather_norm_windows(ref, s, length, mu, sigma)
        )(order_p)                                     # (Q, k_pad, l)
        bd, bs, blocks = ea_pruned_dtw_persistent(
            queries_n, cand_all, lb_p, order_p, ub0, window=window,
            band_width=band_width,
            envelopes=(u, low) if use_cb else None, **knobs,
        )
        # visited blocks are a best-first prefix per query, so only the
        # final padded block can hold non-candidates — clamp to n_win
        lanes = jnp.minimum(blocks * block_k, n_win).astype(jnp.int32)
        no_info = jnp.full((nq,), -1)
        return MultiSearchResult(
            best_start=bs,
            best_dist=bd,
            rounds=jnp.ones((nq,), jnp.int32),  # dispatches: one launch
            lanes=lanes,
            lb_pruned=n_win - lanes,
            rows=no_info,
            cells=no_info,
            quarantined=n_quar,
        )

    n_rounds = -(-n_win // batch)
    pad = n_rounds * batch - n_win
    order_p = jnp.concatenate(
        [order, jnp.zeros((nq, pad), order.dtype)], axis=1
    )
    lb_p = jnp.concatenate(
        [lb_sorted, jnp.full((nq, pad), jnp.inf, lb_sorted.dtype)], axis=1
    )

    if ub_init is None:
        ub0 = jnp.full((nq,), BIG, queries_n.dtype)
    else:
        ub0 = jnp.broadcast_to(
            jnp.asarray(ub_init, queries_n.dtype), (nq,)
        )
    best0 = jnp.full((nq,), -1, order.dtype)

    # Warm-start prepass: full-DP each query's ``pre`` best-LB candidates in
    # one tiny (Q x pre)-lane dispatch so the round loop never runs a
    # BIG-ub round (in round 0 every lane of every query would otherwise do
    # the full DP — by far the most expensive round). The round loop
    # re-encounters these candidates with ``d == ub``; strict-improvement
    # keeps the prepass incumbent, so results are unchanged.
    pre = min(int(warm_start), batch)
    if pre > 0:
        pre_starts = order_p[:, :pre]
        pre_lbs = lb_p[:, :pre]
        cand0 = jax.vmap(
            lambda s: gather_norm_windows(ref, s, length, mu, sigma)
        )(pre_starts)
        ub_pre = jnp.where(
            jnp.logical_and(jnp.isfinite(pre_lbs), pre_lbs < ub0[:, None]),
            jnp.broadcast_to(ub0[:, None], (nq, pre)),
            DEAD_LANE_UB,
        )
        if with_info:
            d0, info0 = ea_pruned_dtw_multi_batch(
                queries_n, cand0, ub_pre, window=window,
                band_width=band_width, with_info=True, **knobs,
            )
            rows_pre = jnp.sum(info0.rows, axis=1, dtype=jnp.int32)
            cells_pre = jnp.sum(info0.cells, axis=1, dtype=jnp.int32)
        else:
            d0 = ea_pruned_dtw_multi_batch(
                queries_n, cand0, ub_pre, window=window,
                band_width=band_width, **knobs,
            )
            rows_pre = cells_pre = jnp.zeros((nq,), jnp.int32)
        d0 = jnp.where(jnp.isfinite(pre_lbs), d0, jnp.inf)
        k0 = jnp.argmin(d0, axis=1)
        d0min = jnp.take_along_axis(d0, k0[:, None], axis=1)[:, 0]
        seeded = d0min < ub0
        ub0 = jnp.where(seeded, d0min, ub0)
        best0 = jnp.where(
            seeded, jnp.take_along_axis(pre_starts, k0[:, None], axis=1)[:, 0],
            best0,
        )
    else:
        rows_pre = cells_pre = jnp.zeros((nq,), jnp.int32)

    # A query whose warm incumbent already beats its best remaining lower
    # bound never enters the round loop at all.
    active0 = jnp.ones((nq,), bool)
    if use_lb:
        active0 = lb_p[:, 0] < ub0

    slice_round, peek_lb = _round_slicers(batch)

    class St(NamedTuple):
        r: jax.Array        # (Q,) per-query round pointer
        ub: jax.Array       # (Q,) per-query incumbents
        best: jax.Array     # (Q,)
        active: jax.Array   # (Q,) still in the round loop?
        lanes: jax.Array    # (Q,)
        rows: jax.Array     # (Q,)
        cells: jax.Array    # (Q,)

    def cond(st: St) -> jax.Array:
        return jnp.any(st.active)

    def body(st: St) -> St:
        starts = slice_round(order_p, st.r)            # (Q, batch)
        lbs_b = slice_round(lb_p, st.r)                # (Q, batch)
        cand = jax.vmap(
            lambda s: gather_norm_windows(ref, s, length, mu, sigma)
        )(starts)                                      # (Q, batch, l)
        cb = None
        if use_cb:
            cb = jax.vmap(cascade_keogh_cumulative)(cand, u, low)
        # Flattened (Q x batch) lane set, per-lane ub. Three per-lane cases
        # the scalar-ub driver cannot express: finished queries submit dead
        # lanes; within an active query's batch, lanes whose own lower bound
        # already reaches the incumbent are submitted dead too (lane-level
        # LB gating — the batch-head check only gates the round); the rest
        # carry their query's incumbent.
        lane_live = jnp.logical_and(st.active[:, None], lbs_b < st.ub[:, None])
        ub_lanes = jnp.where(
            lane_live,
            jnp.broadcast_to(st.ub[:, None], (nq, batch)),
            DEAD_LANE_UB,
        )
        if with_info:
            d, info = ea_pruned_dtw_multi_batch(
                queries_n, cand, ub_lanes, window=window,
                band_width=band_width, cb=cb, with_info=True, **knobs,
            )
            rows_q = jnp.sum(info.rows, axis=1, dtype=jnp.int32)
            cells_q = jnp.sum(info.cells, axis=1, dtype=jnp.int32)
        else:
            d = ea_pruned_dtw_multi_batch(
                queries_n, cand, ub_lanes, window=window,
                band_width=band_width, cb=cb, **knobs,
            )
            rows_q = cells_q = jnp.zeros((nq,), st.rows.dtype)
        d = jnp.where(jnp.isfinite(lbs_b), d, jnp.inf)  # padding lanes
        d = jnp.where(st.active[:, None], d, jnp.inf)
        k = jnp.argmin(d, axis=1)                       # (Q,)
        dmin = jnp.take_along_axis(d, k[:, None], axis=1)[:, 0]
        improved = dmin < st.ub
        ub_new = jnp.where(improved, dmin, st.ub)
        best_new = jnp.where(
            improved, jnp.take_along_axis(starts, k[:, None], axis=1)[:, 0],
            st.best,
        )
        r_new = st.r + st.active.astype(st.r.dtype)
        # Drop-out: no rounds left, or the next batch's best lower bound
        # can no longer beat this query's incumbent.
        more = r_new < n_rounds
        if use_lb:
            nxt = peek_lb(lb_p, jnp.minimum(r_new, n_rounds - 1))
            more = jnp.logical_and(more, nxt < ub_new)
        return St(
            r=r_new,
            ub=ub_new,
            best=best_new,
            active=jnp.logical_and(st.active, more),
            lanes=st.lanes + st.active.astype(st.lanes.dtype) * batch,
            rows=st.rows + rows_q,
            cells=st.cells + cells_q,
        )

    # ``lanes`` counts distinct candidates examined: round 0 re-submits the
    # prepass candidates (they lead its best-first batch), so the prepass
    # only stands alone for a query that never enters the round loop.
    st0 = St(
        r=jnp.zeros((nq,), jnp.int32),
        ub=ub0,
        best=best0,
        active=active0,
        lanes=jnp.where(active0, 0, pre).astype(jnp.int32),
        rows=rows_pre,
        cells=cells_pre,
    )
    st = jax.lax.while_loop(cond, body, st0)
    no_info = jnp.full((nq,), -1)
    return MultiSearchResult(
        best_start=st.best,
        best_dist=st.ub,
        rounds=st.r,
        lanes=st.lanes,
        lb_pruned=n_win - jnp.minimum(st.lanes, n_win),
        rows=st.rows if with_info else no_info,
        cells=st.cells if with_info else no_info,
        quarantined=n_quar,
    )


def multi_query_search(
    ref: jax.Array,
    queries: jax.Array,
    length: int,
    window: int,
    variant: str = "eapruned",
    batch: int = 64,
    band_width: int | None = None,
    chunk: int = 4096,
    with_info: bool = False,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
    ub_init: jax.Array | None = None,
    warm_start: int = 0,
    rounds: str = "host",
    quarantine: bool = True,
) -> MultiSearchResult:
    """Nearest z-normalized window of ``ref`` for each of Q queries.

    Equivalent to Q independent ``subsequence_search`` calls (same
    ``best_start`` / ``best_dist`` per query) but amortized: one
    ``window_stats`` pass, one vmapped LB cascade, and one flattened
    ``(Q × batch)``-lane EAPrunedDTW dispatch per round with a per-query
    incumbent vector (see module docstring for the lane layout).

    Args:
      ref: ``(N,)`` long reference series shared by the workload.
      queries: ``(Q, l)`` raw queries (z-normalized internally).
      length: window/query length (static); ``l == length``.
      window: Sakoe-Chiba warping window in samples (static).
      variant: ``"eapruned"`` or ``"eapruned_nolb"`` (the EA batch is the
        primitive being amortized; use ``subsequence_search`` for the
        ``full`` / ``pruned`` baselines).
      batch: candidates per query per round (static) — each round dispatches
        ``Q * batch`` lanes.
      with_info: collect per-query rows/cells pruning counters (stats
        rounds); fast rounds leave them at ``-1``.
      backend: DTW batch backend (see ``core.backend``); resolved here, in
        the un-jitted wrapper, so ``$REPRO_DTW_BACKEND`` is re-read every
        call.
      ub_init: optional per-query initial incumbents — scalar or ``(Q,)``
        (warm starts from a cache or a previous shard). A query that cannot
        beat its seed returns ``best_start == -1`` and
        ``best_dist == ub_init[q]``.
      warm_start: number of best-LB candidates per query to full-DP in a
        tiny prepass dispatch that seeds the incumbents, so no round ever
        runs with an unbounded ``ub`` (0 disables, the default). Changes
        work, not results: it helps the Pallas backend's block-level early
        exit (round-0 blocks can die early instead of running full DPs) but
        adds prepass lanes the vmap backend cannot recoup — leave it off on
        CPU. A host-rounds knob: ignored by the persistent driver, whose
        incumbent already tightens every ``block_k`` lanes from block 0.
      rounds: ``"host"`` (per-round dispatches, the default) or
        ``"persistent"`` — the whole Q-query sweep in one launch with
        per-query incumbents carried in SMEM across candidate blocks (see
        ``search.subsequence`` module docstring for the trade-offs).
        Counter-free: combine with ``with_info`` is rejected.
      quarantine: exclude windows overlapping a non-finite reference sample
        (DESIGN.md §2.6); the excluded count is reported in
        ``result.quarantined``. On (default) even for clean data — the
        prepass is one extra prefix-sum pass.

    Returns: ``MultiSearchResult`` of per-query ``(Q,)`` arrays.
    """
    if rounds not in ROUND_DRIVERS:
        raise ValueError(f"rounds {rounds!r} not in {ROUND_DRIVERS}")
    if rounds == "persistent" and with_info:
        raise ValueError(
            "rounds='persistent' is counter-free; use the host driver for "
            "with_info stats rounds"
        )
    guards.ensure_series(ref, "ref", ndim=1, min_len=length)
    guards.ensure_series(queries, "queries", ndim=2, min_len=length)
    guards.ensure_finite(queries, "queries")
    guards.ensure_knobs(
        length=length, window=window, batch=batch, band_width=band_width,
        block_k=block_k, row_block=row_block, rows_per_step=rows_per_step,
    )
    if ub_init is not None and guards.is_concrete(ub_init):
        if bool(jnp.any(jnp.isnan(jnp.asarray(ub_init)))):
            raise guards.NonFiniteInputError(
                "ub_init contains NaN (use +inf / BIG for a cold start)"
            )
    return _multi_query_search_impl(
        ref, queries, ub_init, length=length, window=window, variant=variant,
        batch=batch, band_width=band_width, chunk=chunk, with_info=with_info,
        backend=resolve_backend(backend), rows_per_step=rows_per_step,
        block_k=block_k, row_block=row_block, warm_start=warm_start,
        rounds=rounds, quarantine=quarantine,
    )


def make_distributed_multi_search(
    mesh: jax.sharding.Mesh,
    axis_names: tuple[str, ...],
    length: int,
    window: int,
    batch: int = 64,
    band_width: int | None = None,
    chunk: int = 2048,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
    quarantine: bool = True,
):
    """Build a jitted distributed multi-query search fn for a mesh config.

    Returns ``search_fn(ref, queries) -> DistMultiSearchResult`` with
    per-query ``(Q,)`` results. Work items are (query, candidate-range)
    pairs: candidate window starts are sharded contiguously across the mesh
    axes (each device owns a range of every query's windows), queries are
    flattened into the lane dimension of the per-device multi-query batch,
    and after every round the per-query incumbent vector is reconciled with
    one vectorized ``pmin`` all-reduce. Devices iterate in lockstep until no
    device has an active (query, range) item left (``pmax`` continue flag);
    a device whose query finished early submits dead lanes for it, so
    stragglers cost masked rows, not DPs.

    ``backend`` is resolved once, here at closure-build time.

    ``quarantine`` (default on) threads ``znorm.window_finite_mask`` through
    every shard's per-query cascade: poisoned windows are condemned on the
    shard that owns them (``+inf`` LB → dead-lane sentinel, query-
    independent), counts are ``psum``-reduced into
    ``DistMultiSearchResult.quarantined``, and the sanitized reference keeps
    the shared prefix sums finite for survivors — exactly the single-device
    contract of ``multi_query_search`` (DESIGN.md §2.6/§2.7).
    """
    backend = resolve_backend(backend)
    n_shards = 1
    for a in axis_names:
        n_shards *= mesh.shape[a]
    spec_sharded = P(axis_names)
    spec_rep = P()

    def local_search(ref, queries_n, starts, valid, q_ok):
        nq = queries_n.shape[0]

        def psum_all(x):
            for a in axis_names:
                x = jax.lax.psum(x, a)
            return x

        n_quar = psum_all(
            jnp.sum(jnp.logical_and(valid, ~q_ok)).astype(jnp.int32)
        )
        valid = jnp.logical_and(valid, q_ok)
        mu, sigma = window_stats(ref, length)
        lbs = jax.vmap(
            lambda qn: _local_lbs(
                ref, qn, starts, valid, length, window, mu, sigma, chunk
            )
        )(queries_n)                                   # (Q, n_local)
        order = jnp.argsort(lbs, axis=1)
        starts_o = jnp.take_along_axis(
            jnp.broadcast_to(starts, lbs.shape), order, axis=1
        )
        lb_o = jnp.take_along_axis(lbs, order, axis=1)
        n_local = starts.shape[0]
        n_rounds = -(-n_local // batch)
        pad = n_rounds * batch - n_local
        starts_p = jnp.concatenate(
            [starts_o, jnp.zeros((nq, pad), starts_o.dtype)], axis=1
        )
        lb_p = jnp.concatenate(
            [lb_o, jnp.full((nq, pad), jnp.inf, lb_o.dtype)], axis=1
        )
        u, low = jax.vmap(envelope, in_axes=(0, None))(queries_n, window)

        def pmin_all(x):
            for a in axis_names:
                x = jax.lax.pmin(x, a)
            return x

        def pmax_all(x):
            for a in axis_names:
                x = jax.lax.pmax(x, a)
            return x

        slice_round, peek_lb = _round_slicers(batch)

        class St(NamedTuple):
            r: jax.Array        # (Q,) local per-query round pointer
            ub: jax.Array       # (Q,) globally reconciled incumbents
            best: jax.Array     # (Q,) local best start
            best_d: jax.Array   # (Q,) local best distance
            go: jax.Array       # global continue flag

        def cond(st: St) -> jax.Array:
            return st.go

        def body(st: St) -> St:
            s = slice_round(starts_p, st.r)            # (Q, batch)
            lb = slice_round(lb_p, st.r)
            head = peek_lb(lb_p, st.r)
            local_more = jnp.logical_and(st.r < n_rounds, head < st.ub)  # (Q,)
            cand = jax.vmap(
                lambda ss: gather_norm_windows(ref, ss, length, mu, sigma)
            )(s)
            cb = jax.vmap(cascade_keogh_cumulative)(cand, u, low)
            # Dead-lane sentinel for finished (query, range) items and for
            # lanes whose own lower bound already reaches the incumbent
            # (lane-level LB gating, as in the single-host driver).
            lane_live = jnp.logical_and(local_more[:, None], lb < st.ub[:, None])
            ub_lanes = jnp.where(
                lane_live,
                jnp.broadcast_to(st.ub[:, None], (nq, batch)),
                DEAD_LANE_UB,
            )
            d = ea_pruned_dtw_multi_batch(
                queries_n, cand, ub_lanes, window=window,
                band_width=band_width, cb=cb, rows_per_step=rows_per_step,
                backend=backend, block_k=block_k, row_block=row_block,
            )
            d = jnp.where(jnp.isfinite(lb), d, jnp.inf)  # padding lanes
            d = jnp.where(local_more[:, None], d, jnp.inf)
            k = jnp.argmin(d, axis=1)
            dmin = jnp.take_along_axis(d, k[:, None], axis=1)[:, 0]
            improved = dmin < st.best_d
            best = jnp.where(
                improved, jnp.take_along_axis(s, k[:, None], axis=1)[:, 0],
                st.best,
            )
            best_d = jnp.where(improved, dmin, st.best_d)
            # One vectorized pmin reconciles all Q incumbents per round.
            ub = pmin_all(jnp.minimum(st.ub, dmin))
            r = st.r + local_more.astype(st.r.dtype)
            nxt = peek_lb(lb_p, jnp.minimum(r, n_rounds - 1))
            local_next = jnp.logical_and(r < n_rounds, nxt < ub)
            return St(
                r=r, ub=ub, best=best, best_d=best_d,
                go=pmax_all(jnp.any(local_next)),
            )

        go0 = pmax_all(jnp.asarray(True))
        st0 = St(
            r=jnp.zeros((nq,), jnp.int32),
            ub=jnp.full((nq,), BIG, queries_n.dtype),
            best=jnp.full((nq,), -1, starts.dtype),
            best_d=jnp.full((nq,), BIG, queries_n.dtype),
            go=go0,
        )
        st = jax.lax.while_loop(cond, body, st0)
        # Per-query global argmin: vectorized lexicographic (distance, start).
        g_min = pmin_all(st.best_d)                    # (Q,)
        is_best = jnp.isclose(st.best_d, g_min)
        cand_start = jnp.where(is_best, st.best, jnp.iinfo(jnp.int32).max)
        g_start = pmin_all(cand_start.astype(jnp.int32))
        return g_min, g_start, pmax_all(jnp.max(st.r)), n_quar

    @jax.jit
    def search_fn(ref: jax.Array, queries: jax.Array) -> DistMultiSearchResult:
        ref = jnp.asarray(ref)
        queries_n = znorm(jnp.asarray(queries)[:, :length])
        n_win = ref.shape[0] - length + 1
        per = -(-n_win // n_shards)
        total = per * n_shards
        starts = jnp.arange(total, dtype=jnp.int32)
        valid = starts < n_win
        starts = jnp.minimum(starts, n_win - 1)
        if quarantine:
            finite_ok = window_finite_mask(ref, length)
            ref = sanitize_series(ref)
            q_ok = finite_ok[starts]
        else:
            q_ok = jnp.ones_like(valid)

        shard = _shard_map(
            local_search,
            mesh=mesh,
            in_specs=(
                spec_rep, spec_rep, spec_sharded, spec_sharded, spec_sharded,
            ),
            out_specs=(spec_rep, spec_rep, spec_rep, spec_rep),
        )
        best_d, best_s, rounds, n_quar = shard(
            ref, queries_n, starts, valid, q_ok
        )
        return DistMultiSearchResult(
            best_start=best_s, best_dist=best_d, rounds=rounds,
            quarantined=n_quar,
        )

    return search_fn
