"""Fault-tolerant sharded search: shard recovery with coverage accounting.

``search/distributed.py`` maps the paper's search onto a mesh as ONE SPMD
program: collectives run in lockstep, so a dead device kills the whole
search — the runtime model has no per-shard failure domain. This module is
the complementary host-side executor for deployments where shards *can*
fail independently (processes, pods, RPC workers): candidate window starts
are partitioned into per-shard work ranges, each range runs as an
independent dispatch (``multi_query_search`` over the range's slice of the
reference, seeded with the carried incumbents), and the host supervises
with the same transient/guard-error split and ``StragglerMonitor`` as
``serve.supervisor.SearchSupervisor``.

Failure story (DESIGN.md §2.7):

  * **Bounded retry with backoff** — a transient range failure
    (``RuntimeError`` / ``ValueError`` / ``OSError``, which includes
    ``TimeoutError``) sleeps a decorrelated-jitter backoff
    (``fault_tolerance.DecorrelatedJitterBackoff``: exponential envelope,
    but simultaneously-failed shards do not retry in lockstep; seeded via
    ``$REPRO_FAULT_SEED``, disable with ``jitter=False`` for the plain
    ``backoff * 2**k`` schedule) and retries on the same shard up to
    ``max_retries`` times. Typed guard errors (``SearchInputError``,
    ``StreamStateError``) are caller bugs and re-raise immediately — the
    same split as the serving supervisor.
  * **Reassignment** — a range that exhausts its retries marks its shard
    failed; the range moves to the next healthy shard with a fresh retry
    budget, and every later range still assigned to the failed shard skips
    straight to reassignment. Only when *no* healthy shard can complete a
    range does it become uncovered.
  * **Coverage accounting** — the result always says what it covers:
    ``coverage`` is the fraction of candidate windows actually searched and
    ``uncovered`` lists the window-start ranges that were not. Over the
    covered set the result is *exact* (every covered window was scanned
    against an admissible incumbent); degraded results are reported, never
    silently wrong. ``require_full_coverage=True`` raises ``CoverageError``
    instead of returning a degraded result.
  * **Incumbent carry across attempts** — the per-query upper-bound vector
    is carried across ranges, retries, and reassignments; a tighter bound
    from anywhere makes every later range abandon earlier (the paper's
    ub-tightening trick, rotated across shards). A *failed* attempt may
    also report partial progress by attaching ``partial_ub`` /
    ``partial_best`` arrays to its exception: because each entry is an
    *achieved* (start, distance) pair of a real window, folding it is a
    plain incumbent update — admissible even though the range that produced
    it will be re-run (the rerun needs only strict improvements; the
    incumbent already points at the achieving window). A bare bound with no
    achieving start is NOT folded: seeding a rerun of range R with a bound
    achieved *inside* R would make the rerun unable to re-adopt that very
    window (strict-improvement incumbents), losing its start.
  * **Soft timeout** — with ``timeout`` set, an attempt that *completes*
    but took longer than ``timeout`` seconds keeps its (correct) result,
    but strikes its shard; a shard that accumulates more than
    ``max_retries`` strikes is marked failed and its remaining ranges are
    reassigned. (A runner that wants hard timeouts raises
    ``TimeoutError`` itself — e.g. an RPC deadline — which takes the
    transient-retry path above.)
  * **Shard health & circuit breaking** (DESIGN.md §2.9) — every shard
    carries a ``WorkerHealth``: a latency EWMA plus a
    closed/open/half-open circuit breaker that opens after
    ``breaker_threshold`` *consecutive* failures. Fresh ranges and retry
    reroutes prefer breaker-ready, non-straggling shards (shard-id order
    as the tiebreak, so routing stays deterministic); a range popped for
    a shard whose breaker is open moves to a ready shard without
    touching the degraded one. Unlike ``failed_shards``, a breaker is a
    pause, not a verdict: after ``breaker_cooldown`` the shard earns one
    half-open probe, and a success puts it back in rotation.
    ``shard_health`` on the result snapshots all of this.
  * **Hedged dispatch** (``hedge=True``; DESIGN.md §2.9) — when a
    completed attempt exceeded the hedge delay (explicit ``hedge_delay``,
    or derived as ``threshold × EWMA`` from the fleet monitor), the same
    range is raced on up to ``hedge_max_inflight`` healthy backups and
    adjudicated on the virtual timeline of
    ``fault_tolerance.hedge_race``. Backups are seeded with the same
    *pre-fold* incumbents as the primary, so a duplicate completion
    returns identical ``(start, dist)`` pairs and the strict-improvement
    fold makes the merge a no-op — a hedge can change latency but never
    the answer. Quarantine and coverage are counted once (the primary's:
    both attempts scanned the same windows), and the soft-timeout strike
    is judged on the *effective* latency, so a won hedge also saves the
    straggler shard's range from burning the full ``timeout``.
    ``hedges_launched`` / ``hedges_won`` report the outcome.

The executor is deliberately sequential on the host: determinism makes the
fault recipes in ``tests/faults.py`` exactly reproducible, and the ranges
themselves are where the device time goes.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, NamedTuple

import numpy as np

import jax.numpy as jnp

from repro.core import guards
from repro.distributed.fault_tolerance import (
    GUARD_ERRORS,
    TRANSIENT,
    DecorrelatedJitterBackoff,
    StragglerMonitor,
    WorkerHealth,
    hedge_race,
)
from repro.search.incumbents import IncumbentState, fold_np
from repro.search.pipeline import MULTI_VARIANTS, HostRoundsExecutor, make_plan


class CoverageError(RuntimeError):
    """Raised by ``require_full_coverage=True`` when ranges stay uncovered."""

    def __init__(self, message: str, uncovered=()):
        super().__init__(message)
        self.uncovered = tuple(uncovered)


class ResilientSearchResult(NamedTuple):
    best_start: np.ndarray   # (Q,) start of each query's covered-set NN (-1: none)
    best_dist: np.ndarray    # (Q,) its DTW distance (== seed when unbeaten)
    coverage: float          # fraction of candidate windows searched
    uncovered: tuple         # ((lo, hi), ...) window-start ranges not searched
    quarantined: int         # non-finite-quarantined windows over the covered set
    attempts: int            # range attempts issued (including failures)
    reassignments: int       # ranges moved off a failed/degraded shard
    failed_shards: tuple     # shard ids marked failed
    hedges_launched: int = 0  # backup attempts raced against stragglers
    hedges_won: int = 0       # races a backup (virtually) finished first
    shard_health: tuple = ()  # per-shard HealthSnapshot, indexed by shard id
    latency: float = 0.0      # summed per-range effective latency (clock units)


def partition_ranges(n_win: int, n_shards: int) -> list[tuple[int, int]]:
    """Contiguous per-shard window-start ranges covering ``[0, n_win)``."""
    per = -(-n_win // n_shards) if n_win else 0
    out = []
    lo = 0
    while lo < n_win:
        out.append((lo, min(lo + per, n_win)))
        lo += per
    return out


def _merge_ranges(ranges) -> tuple:
    out = []
    for lo, hi in sorted(ranges):
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return tuple(out)


def resilient_search(
    ref,
    queries,
    length: int,
    window: int,
    *,
    n_shards: int = 4,
    variant: str = "eapruned",
    batch: int = 64,
    band_width: int | None = None,
    chunk: int = 4096,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
    ub_init=None,
    quarantine: bool = True,
    max_retries: int = 2,
    backoff: float = 0.05,
    jitter: bool = True,
    timeout: float | None = None,
    hedge: bool = False,
    hedge_delay: float | None = None,
    hedge_max_inflight: int = 2,
    breaker_threshold: int = 3,
    breaker_cooldown: float = 1.0,
    n_ranges: int | None = None,
    require_full_coverage: bool = False,
    runner: Callable | None = None,
    sleep: Callable[[float], None] = time.sleep,
    clock: Callable[[], float] = time.time,
    monitor: StragglerMonitor | None = None,
) -> ResilientSearchResult:
    """Nearest-window search executed as recoverable per-shard work ranges.

    Same answers as ``multi_query_search`` when every range completes
    (``coverage == 1.0``); exact over the covered set otherwise, with the
    degradation reported in ``coverage`` / ``uncovered``.

    Args:
      ref: ``(N,)`` reference series.
      queries: ``(Q, l)`` or ``(l,)`` raw queries.
      length, window: as in ``multi_query_search``.
      n_shards: work ranges (and conceptual failure domains) to partition
        the candidate starts into.
      variant, batch, band_width, chunk, backend, rows_per_step, block_k,
        row_block, ub_init, quarantine: forwarded to each range's
        ``multi_query_search`` dispatch.
      max_retries: transient failures tolerated per (range, shard) before
        the shard is marked failed and the range reassigned; also the
        soft-timeout strike budget per shard.
      backoff: base retry sleep in seconds (exponential envelope).
      jitter: decorrelate retry sleeps (module docstring); ``False``
        restores the deterministic ``backoff * 2**k`` schedule.
      timeout: soft per-attempt wall-clock budget in seconds (see module
        docstring); ``None`` disables. Judged on the *effective* latency,
        so a won hedge saves the strike.
      hedge: race straggling attempts on healthy backup shards (module
        docstring). Never changes the answer, only the latency.
      hedge_delay: explicit hedge delay in clock seconds; ``None`` derives
        ``threshold × EWMA`` from ``monitor`` (no hedging until the
        monitor has a baseline).
      hedge_max_inflight: max backups raced against one straggling attempt.
      breaker_threshold: consecutive failures before a shard's circuit
        breaker opens (routing avoids it without marking it failed).
      breaker_cooldown: seconds an open breaker sheds load before it earns
        one half-open probe.
      n_ranges: how many work ranges to partition the windows into
        (default ``n_shards``); more ranges than shards gives the breaker
        and the hedger something to re-route mid-search.
      require_full_coverage: raise ``CoverageError`` instead of returning a
        degraded result.
      runner: injection point for the per-range search:
        ``runner(shard_id, lo, hi, ub) -> (starts (Q,), dists (Q,),
        quarantined)`` with ``starts`` in *global* window coordinates
        (-1 where the seed was unbeaten). Defaults to the real dispatch;
        tests wrap it with ``tests.faults.ShardFaultInjector``.
      sleep, clock, monitor: injection points (tests pass recorders and a
        deterministic clock so timeout tests don't depend on wall time).

    Returns: ``ResilientSearchResult``.
    """
    if n_shards < 1:
        raise guards.SearchInputError("n_shards must be >= 1")
    if max_retries < 0:
        raise guards.SearchInputError("max_retries must be >= 0")
    if n_ranges is not None and n_ranges < 1:
        raise guards.SearchInputError("n_ranges must be >= 1")
    if hedge_max_inflight < 1:
        raise guards.SearchInputError("hedge_max_inflight must be >= 1")
    queries = jnp.atleast_2d(jnp.asarray(queries))
    guards.ensure_series(ref, "ref", ndim=1, min_len=length)
    guards.ensure_series(queries, "queries", ndim=2, min_len=length)
    guards.ensure_finite(queries, "queries")
    ref = jnp.asarray(ref)
    nq = int(queries.shape[0])
    n_win = int(ref.shape[0]) - length + 1
    monitor = monitor or StragglerMonitor()
    health = {
        s: WorkerHealth(
            threshold=monitor.threshold, alpha=monitor.alpha,
            breaker_threshold=breaker_threshold,
            breaker_cooldown=breaker_cooldown, clock=clock,
        )
        for s in range(n_shards)
    }
    backoffs = {s: DecorrelatedJitterBackoff(backoff) for s in range(n_shards)}

    if ub_init is None:
        ub = np.full((nq,), np.inf)
    else:
        ub = np.broadcast_to(np.asarray(ub_init, np.float64), (nq,)).copy()
    best = np.full((nq,), -1, np.int64)

    if runner is None:
        # The default range execution IS the pipeline's executor seam
        # (DESIGN.md §2.8): one HostRoundsExecutor bound to this workload,
        # each range a ``run_range`` call with the carried incumbents as the
        # seed state. The executor handles the global-coordinate mapping and
        # keeps seed-unbeaten starts at their incoming value (-1 here).
        plan = make_plan(
            length=length, window=window, variant=variant, batch=batch,
            band_width=band_width, chunk=chunk, backend=backend,
            rows_per_step=rows_per_step, block_k=block_k, row_block=row_block,
            quarantine=quarantine, allowed_variants=MULTI_VARIANTS,
        )
        executor = HostRoundsExecutor(ref, queries)

        def runner(shard_id, lo, hi, ub_now):
            state = IncumbentState(
                ub=jnp.asarray(ub_now, queries.dtype),
                best=jnp.full((nq,), -1, jnp.int64),
            )
            rr = executor.run_range(plan, state, int(lo), int(hi))
            return (
                np.asarray(rr.state.best, np.int64),
                np.asarray(rr.state.ub, np.float64),
                int(rr.quarantined),
            )

    work = deque(
        (lo, hi, i % n_shards, 0) for i, (lo, hi) in
        enumerate(partition_ranges(n_win, n_ranges or n_shards))
    )
    healthy = set(range(n_shards))
    strikes = {s: 0 for s in range(n_shards)}
    covered: list[tuple[int, int]] = []
    uncovered: list[tuple[int, int]] = []
    attempts = 0
    reassignments = 0
    quarantined = 0
    hedges_launched = 0
    hedges_won = 0
    latency = 0.0

    def _fold(starts, dists):
        nonlocal ub, best
        ub, best = fold_np(ub, best, starts, dists)

    def _order(exclude=frozenset()):
        # Healthiest first: breaker-ready before open, non-straggling
        # before straggling (EWMA > threshold x the fleet EWMA), shard id
        # as the tiebreak — id order whenever health is uniform, which
        # keeps routing deterministic and matches the pre-health behavior.
        fleet = monitor.ewma

        def key(s):
            h = health[s]
            slow = (
                h.ewma is not None and fleet is not None
                and h.ewma > monitor.threshold * fleet
            )
            return (0 if h.ready() else 1, 1 if slow else 0, s)

        return sorted((s for s in healthy if s not in exclude), key=key)

    def _reassign(lo, hi, off_shard):
        nonlocal reassignments
        for cand in _order(exclude={off_shard}):
            work.append((lo, hi, cand, 0))
            reassignments += 1
            return
        uncovered.append((lo, hi))

    while work:
        lo, hi, shard, tries = work.popleft()
        if shard not in healthy:
            _reassign(lo, hi, shard)
            continue
        if tries == 0 and not health[shard].ready():
            # Fresh range on a shard whose breaker is open: route it to a
            # ready shard instead (counted as a reassignment, but the
            # shard is NOT marked failed — the breaker may yet recover).
            alt = [s for s in _order(exclude={shard}) if health[s].ready()]
            if alt:
                work.append((lo, hi, alt[0], 0))
                reassignments += 1
                continue
        ub_pre = ub.copy()
        try:
            attempts += 1
            health[shard].acquire()
            t0 = clock()
            starts, dists, n_quar = runner(shard, lo, hi, ub)
            dt = clock() - t0
        except GUARD_ERRORS:
            raise  # caller bug: retrying identical bad input cannot help
        except TRANSIENT as e:
            health[shard].fail()
            # Admissible partial progress: achieved (start, distance) pairs
            # only — see the module docstring for why a bare bound is not.
            p_ub = getattr(e, "partial_ub", None)
            p_best = getattr(e, "partial_best", None)
            if p_ub is not None and p_best is not None:
                _fold(np.broadcast_to(np.asarray(p_best, np.int64), (nq,)),
                      np.broadcast_to(np.asarray(p_ub, np.float64), (nq,)))
            tries += 1
            if tries > max_retries:
                healthy.discard(shard)
                _reassign(lo, hi, shard)
                continue
            alt = [s for s in _order(exclude={shard}) if health[s].ready()]
            if not health[shard].ready() and alt:
                # The breaker just opened mid-retry: move the range rather
                # than hammer a shard the breaker took out of rotation.
                work.append((lo, hi, alt[0], 0))
                reassignments += 1
            else:
                if jitter:
                    sleep(backoffs[shard].next())
                else:
                    sleep(backoff * (2 ** (tries - 1)))
                work.appendleft((lo, hi, shard, tries))
            continue
        # Hedge-delay derivation must precede this attempt's observation —
        # a straggler should be judged against the baseline, not against a
        # baseline it already contaminated.
        delay = None
        if hedge:
            if hedge_delay is not None:
                delay = hedge_delay
            elif monitor.ewma is not None:
                delay = monitor.threshold * monitor.ewma
        health[shard].observe(dt)
        backoffs[shard].reset()
        _fold(starts, dists)
        effective = dt
        if delay is not None and dt > delay:
            used = {shard}

            def backups():
                while True:
                    cands = [
                        s for s in _order(exclude=used) if health[s].ready()
                    ]
                    if not cands:
                        return
                    s = cands[0]
                    used.add(s)

                    def thunk(s=s):
                        nonlocal attempts
                        attempts += 1
                        health[s].acquire()
                        return runner(s, lo, hi, ub_pre)

                    yield s, thunk

            race = hedge_race(
                dt, delay, backups(), clock=clock,
                max_inflight=hedge_max_inflight,
                on_failure=lambda tag, _e: health[tag].fail(),
            )
            hedges_launched += race.launched
            if race.won:
                hedges_won += 1
            effective = race.effective_dt
            for tag, res_b, dt_b in race.completions:
                health[tag].observe(dt_b)
                b_starts, b_dists, _b_quar = res_b
                # Idempotent under strict improvement; the backup's
                # quarantine count is deliberately dropped (the primary
                # already accounted these very windows).
                _fold(b_starts, b_dists)
        monitor.observe(attempts - 1, effective)
        latency += effective
        quarantined += int(n_quar)
        covered.append((lo, hi))
        if timeout is not None and effective > timeout:
            # The result stands (it is a completed, exact range) but the
            # shard is now suspect for *future* assignments.
            strikes[shard] += 1
            if strikes[shard] > max_retries:
                healthy.discard(shard)

    covered_n = sum(hi - lo for lo, hi in covered)
    coverage = covered_n / n_win if n_win else 1.0
    uncovered_m = _merge_ranges(uncovered)
    if require_full_coverage and uncovered_m:
        raise CoverageError(
            f"search degraded: {n_win - covered_n}/{n_win} candidate "
            f"windows uncovered after shard failures ({uncovered_m})",
            uncovered=uncovered_m,
        )
    return ResilientSearchResult(
        best_start=best,
        best_dist=ub,
        coverage=coverage,
        uncovered=uncovered_m,
        quarantined=quarantined,
        attempts=attempts,
        reassignments=reassignments,
        failed_shards=tuple(sorted(set(range(n_shards)) - healthy)),
        hedges_launched=hedges_launched,
        hedges_won=hedges_won,
        shard_health=tuple(health[s].snapshot() for s in range(n_shards)),
        latency=latency,
    )
