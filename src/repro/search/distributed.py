"""Distributed subsequence search: shard candidates, share the upper bound.

The multi-device mapping of the paper's technique (DESIGN.md §2.4):

  * candidate window starts are sharded contiguously across the mesh axes,
  * the reference series is replicated (a few MB — broadcast once),
  * every device runs its own LB cascade + best-first batched EAPrunedDTW,
  * after every round the incumbent ``ub`` is shared with ``lax.pmin`` —
    the distributed analogue of the UCR suite's upper-bound tightening. A
    tighter global ub makes *every* device abandon earlier, so sharing is
    super-linear in value,
  * devices iterate in lockstep (collectives must stay aligned); a device
    that exhausts its useful candidates keeps issuing no-op rounds until the
    global continue-flag (``pmax``) clears. This is also the straggler story:
    work per round is bounded and uniform, so a slow device delays at most
    one round of its peers.

Built on ``shard_map`` so the same code lowers for the 1-device CPU test,
the 256-chip pod, and the 512-chip multi-pod mesh.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.batch import ea_pruned_dtw_batch
from repro.core.compat import shard_map as _shard_map
from repro.core.common import BIG
from repro.core.lower_bounds import cascade_keogh_cumulative, envelope, lb_keogh, lb_kim_fl
from repro.search.znorm import (
    gather_norm_windows,
    sanitize_series,
    window_finite_mask,
    window_stats,
    znorm,
)


class DistSearchResult(NamedTuple):
    best_start: jax.Array
    best_dist: jax.Array
    rounds: jax.Array
    quarantined: jax.Array  # windows excluded by the non-finite quarantine


def _local_lbs(ref, query_n, starts, valid, length, window, mu, sigma, chunk):
    """Lower bounds for this device's candidate starts (chunked)."""
    u, low = envelope(query_n, window)
    n_local = starts.shape[0]
    n_chunks = -(-n_local // chunk)
    pad = n_chunks * chunk - n_local
    starts_p = jnp.concatenate([starts, jnp.zeros((pad,), starts.dtype)])
    valid_p = jnp.concatenate([valid, jnp.zeros((pad,), bool)])

    def one(i):
        s = jax.lax.dynamic_slice(starts_p, (i * chunk,), (chunk,))
        v = jax.lax.dynamic_slice(valid_p, (i * chunk,), (chunk,))
        cand = gather_norm_windows(ref, s, length, mu, sigma)
        lb = jnp.maximum(lb_kim_fl(query_n, cand), lb_keogh(cand, u, low))
        return jnp.where(v, lb, jnp.inf)

    lbs = jax.lax.map(one, jnp.arange(n_chunks)).reshape(-1)
    return lbs[:n_local]


def make_distributed_search(
    mesh: jax.sharding.Mesh,
    axis_names: tuple[str, ...],
    length: int,
    window: int,
    batch: int = 64,
    band_width: int | None = None,
    chunk: int = 2048,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
    quarantine: bool = True,
):
    """Build a jitted distributed search fn for a given mesh/shape config.

    Returns ``search_fn(ref, query) -> DistSearchResult``. ``ref`` must have
    static length; the number of windows is padded to the mesh size.

    ``backend`` / ``rows_per_step`` / ``block_k`` / ``row_block`` select and
    tune the per-device DTW batch implementation exactly as in
    ``core.batch.ea_pruned_dtw_batch`` — every device runs the same backend.

    ``quarantine`` (default on) threads the non-finite window mask through
    every shard's cascade (DESIGN.md §2.6/§2.7): the mask is computed once
    on the replicated raw reference, sharded alongside the candidate starts,
    and poisoned windows ride each shard's rounds as ``+inf``-LB dead lanes
    — the same sentinel machinery as the single-device drivers, no kernel
    change. Per-shard exclusion counts are ``psum``-reduced into
    ``DistSearchResult.quarantined``, which therefore equals the
    single-device ``subsequence_search(...).quarantined`` exactly.
    """
    n_shards = 1
    for a in axis_names:
        n_shards *= mesh.shape[a]
    spec_sharded = P(axis_names)
    spec_rep = P()

    def local_search(ref, query_n, starts, valid, q_ok):
        def psum_all(x):
            for a in axis_names:
                x = jax.lax.psum(x, a)
            return x

        # Quarantine accounting before the mask folds into ``valid``: each
        # shard counts its own real (non-padding) condemned windows, and the
        # psum reconciles them into the global count every shard reports.
        n_quar = psum_all(
            jnp.sum(jnp.logical_and(valid, ~q_ok)).astype(jnp.int32)
        )
        valid = jnp.logical_and(valid, q_ok)
        mu, sigma = window_stats(ref, length)
        lbs = _local_lbs(ref, query_n, starts, valid, length, window, mu, sigma, chunk)
        order = jnp.argsort(lbs)
        starts_o = starts[order]
        lb_o = lbs[order]
        n_local = starts.shape[0]
        n_rounds = -(-n_local // batch)
        pad = n_rounds * batch - n_local
        starts_p = jnp.concatenate([starts_o, jnp.zeros((pad,), starts_o.dtype)])
        lb_p = jnp.concatenate([lb_o, jnp.full((pad,), jnp.inf, lb_o.dtype)])
        u, low = envelope(query_n, window)

        def pmin_all(x):
            for a in axis_names:
                x = jax.lax.pmin(x, a)
            return x

        def pmax_all(x):
            for a in axis_names:
                x = jax.lax.pmax(x, a)
            return x

        class St(NamedTuple):
            r: jax.Array
            ub: jax.Array        # globally shared upper bound
            best: jax.Array      # local best start
            best_d: jax.Array    # local best distance
            go: jax.Array        # global continue flag

        def cond(st: St) -> jax.Array:
            return st.go

        def body(st: St) -> St:
            s = jax.lax.dynamic_slice(starts_p, (st.r * batch,), (batch,))
            lb = jax.lax.dynamic_slice(lb_p, (st.r * batch,), (batch,))
            local_more = jnp.logical_and(st.r < n_rounds, lb[0] < st.ub)
            cand = gather_norm_windows(ref, s, length, mu, sigma)
            cb = cascade_keogh_cumulative(cand, u, low)
            d = ea_pruned_dtw_batch(
                query_n, cand, st.ub, window=window, band_width=band_width,
                cb=cb, rows_per_step=rows_per_step, backend=backend,
                block_k=block_k, row_block=row_block,
            )
            # lanes that are padding, or rounds past this device's work,
            # must not contribute
            d = jnp.where(jnp.isfinite(lb), d, jnp.inf)
            d = jnp.where(local_more, d, jnp.inf)
            k = jnp.argmin(d)
            dmin = d[k]
            improved = dmin < st.best_d
            best = jnp.where(improved, s[k], st.best)
            best_d = jnp.where(improved, dmin, st.best_d)
            # share the upper bound; advance only devices that did real work
            ub = pmin_all(jnp.minimum(st.ub, dmin))
            r = st.r + local_more.astype(st.r.dtype)
            # a device continues while any device still has useful rounds
            nxt_lb = jax.lax.dynamic_slice(lb_p, (r * batch,), (1,))[0]
            local_next = jnp.logical_and(r < n_rounds, nxt_lb < ub)
            return St(r=r, ub=ub, best=best, best_d=best_d, go=pmax_all(local_next))

        # prime the global continue flag
        go0 = pmax_all(jnp.asarray(True))
        st0 = St(
            r=jnp.asarray(0),
            ub=jnp.asarray(BIG, query_n.dtype),
            best=jnp.asarray(-1, starts.dtype),
            best_d=jnp.asarray(BIG, query_n.dtype),
            go=go0,
        )
        st = jax.lax.while_loop(cond, body, st0)
        # global argmin: lexicographic (distance, start) via pmin on packed key
        ax_min = st.best_d
        g_min = pmin_all(ax_min)
        is_best = jnp.isclose(st.best_d, g_min)
        cand_start = jnp.where(is_best, st.best, jnp.iinfo(jnp.int32).max)
        g_start = pmin_all(cand_start.astype(jnp.int32))
        return g_min, g_start, pmax_all(st.r), n_quar

    @jax.jit
    def search_fn(ref: jax.Array, query: jax.Array) -> DistSearchResult:
        ref = jnp.asarray(ref)
        query_n = znorm(jnp.asarray(query)[:length])
        n_win = ref.shape[0] - length + 1
        per = -(-n_win // n_shards)
        total = per * n_shards
        starts = jnp.arange(total, dtype=jnp.int32)
        valid = starts < n_win
        starts = jnp.minimum(starts, n_win - 1)
        if quarantine:
            # Mask on the raw series, sanitize before replication so shared
            # prefix sums stay finite for the surviving windows (§2.6).
            finite_ok = window_finite_mask(ref, length)
            ref = sanitize_series(ref)
            q_ok = finite_ok[starts]
        else:
            q_ok = jnp.ones_like(valid)

        shard = _shard_map(
            local_search,
            mesh=mesh,
            in_specs=(
                spec_rep, spec_rep, spec_sharded, spec_sharded, spec_sharded,
            ),
            out_specs=(spec_rep, spec_rep, spec_rep, spec_rep),
        )
        best_d, best_s, rounds, n_quar = shard(ref, query_n, starts, valid, q_ok)
        return DistSearchResult(
            best_start=best_s, best_dist=best_d, rounds=rounds,
            quarantined=n_quar,
        )

    return search_fn
