"""Distributed subsequence search: shard candidates, share the upper bound.

The multi-device mapping of the paper's technique (DESIGN.md §2.4):

  * candidate window starts are sharded contiguously across the mesh axes,
  * the reference series is replicated (a few MB — broadcast once),
  * every device runs its own LB cascade + best-first batched EAPrunedDTW,
  * after every round the incumbent ``ub`` is shared with ``lax.pmin`` —
    the distributed analogue of the UCR suite's upper-bound tightening. A
    tighter global ub makes *every* device abandon earlier, so sharing is
    super-linear in value,
  * devices iterate in lockstep (collectives must stay aligned); a device
    that exhausts its useful candidates keeps issuing no-op rounds until the
    global continue-flag (``pmax``) clears. This is also the straggler story:
    work per round is bounded and uniform, so a slow device delays at most
    one round of its peers.

This module is the *scalar* (single-query) frontend of the mesh program
owned by ``search.pipeline.make_sharded_search`` (DESIGN.md §2.8): the SPMD
while_loop, the sharded quarantine accounting, and the lexicographic
``pmin`` reconcile live there, shared with ``make_distributed_multi_search``
and the ``ShardedExecutor`` range seam. Built on ``shard_map`` so the same
code lowers for the 1-device CPU test, the 256-chip pod, and the 512-chip
multi-pod mesh.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.search.pipeline import make_plan, make_sharded_search


class DistSearchResult(NamedTuple):
    best_start: jax.Array
    best_dist: jax.Array
    rounds: jax.Array
    quarantined: jax.Array  # windows excluded by the non-finite quarantine


def make_distributed_search(
    mesh: jax.sharding.Mesh,
    axis_names: tuple[str, ...],
    length: int,
    window: int,
    batch: int = 64,
    band_width: int | None = None,
    chunk: int = 2048,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
    quarantine: bool = True,
):
    """Build a jitted distributed search fn for a given mesh/shape config.

    Returns ``search_fn(ref, query) -> DistSearchResult``. ``ref`` must have
    static length; the number of windows is padded to the mesh size. The
    search runs as the Q=1 case of the pipeline's multi-query mesh program
    — one query lane, the same per-round ``pmin`` incumbent sharing.

    ``backend`` / ``rows_per_step`` / ``block_k`` / ``row_block`` select and
    tune the per-device DTW batch implementation exactly as in
    ``core.batch.ea_pruned_dtw_batch`` — every device runs the same backend.

    ``quarantine`` (default on) threads the non-finite window mask through
    every shard's cascade (DESIGN.md §2.6/§2.7): the mask is computed once
    on the replicated raw reference, sharded alongside the candidate starts,
    and poisoned windows ride each shard's rounds as ``+inf``-LB dead lanes
    — the same sentinel machinery as the single-device drivers, no kernel
    change. Per-shard exclusion counts are ``psum``-reduced into
    ``DistSearchResult.quarantined``, which therefore equals the
    single-device ``subsequence_search(...).quarantined`` exactly.
    """
    plan = make_plan(
        length=length, window=window, variant="eapruned", batch=batch,
        band_width=band_width, chunk=chunk, backend=backend,
        rows_per_step=rows_per_step, block_k=block_k, row_block=row_block,
        quarantine=quarantine,
    )
    sharded = make_sharded_search(mesh, axis_names, plan)

    def search_fn(ref: jax.Array, query: jax.Array) -> DistSearchResult:
        best_d, best_s, rounds, n_quar = sharded(
            jnp.asarray(ref), jnp.asarray(query)[None]
        )
        return DistSearchResult(
            best_start=best_s[0], best_dist=best_d[0], rounds=rounds,
            quarantined=n_quar,
        )

    return search_fn
