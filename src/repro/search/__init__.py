"""UCR-suite style similarity search built on EAPrunedDTW."""
from repro.search.cascade import cascade, cascade_lower_bounds
from repro.search.distributed import DistSearchResult, make_distributed_search
from repro.search.multi import (
    DistMultiSearchResult,
    MultiSearchResult,
    make_distributed_multi_search,
    multi_query_search,
)
from repro.search.subsequence import VARIANTS, SearchResult, subsequence_search
from repro.search.znorm import gather_norm_windows, window_stats, znorm

__all__ = [
    "DistMultiSearchResult",
    "DistSearchResult",
    "MultiSearchResult",
    "SearchResult",
    "VARIANTS",
    "cascade",
    "cascade_lower_bounds",
    "gather_norm_windows",
    "make_distributed_multi_search",
    "make_distributed_search",
    "multi_query_search",
    "subsequence_search",
    "window_stats",
    "znorm",
]
