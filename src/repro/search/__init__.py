"""UCR-suite style similarity search built on EAPrunedDTW."""
from repro.search.cascade import cascade, cascade_lower_bounds
from repro.search.distributed import DistSearchResult, make_distributed_search
from repro.search.multi import (
    DistMultiSearchResult,
    MultiSearchResult,
    make_distributed_multi_search,
    multi_query_search,
)
from repro.search.resilient import (
    CoverageError,
    ResilientSearchResult,
    resilient_search,
)
from repro.search.streaming import (
    IngestResult,
    ingest_chunk,
    initial_incumbents,
    rescore_windows,
)
from repro.search.subsequence import VARIANTS, SearchResult, subsequence_search
from repro.search.znorm import (
    append_window_stats,
    clamp_sigma,
    gather_norm_windows,
    sanitize_series,
    window_finite_mask,
    window_stats,
    znorm,
)

__all__ = [
    "CoverageError",
    "DistMultiSearchResult",
    "DistSearchResult",
    "IngestResult",
    "MultiSearchResult",
    "ResilientSearchResult",
    "SearchResult",
    "VARIANTS",
    "append_window_stats",
    "cascade",
    "cascade_lower_bounds",
    "clamp_sigma",
    "gather_norm_windows",
    "ingest_chunk",
    "initial_incumbents",
    "make_distributed_multi_search",
    "make_distributed_search",
    "multi_query_search",
    "rescore_windows",
    "resilient_search",
    "sanitize_series",
    "subsequence_search",
    "window_finite_mask",
    "window_stats",
    "znorm",
]
