"""UCR-suite style similarity search built on EAPrunedDTW.

Layering (DESIGN.md §2.8): ``pipeline`` owns the staged search program
(plan resolution, prepare, cascade, round drivers, executors); the five
frontends — ``subsequence``, ``multi``, ``streaming``, ``distributed``,
``resilient`` — are thin wrappers that validate inputs and adapt the
pipeline to their calling convention; ``incumbents`` owns the carried
per-query state and quarantine counters. ``scripts/lint_layers.py``
enforces that frontends never import each other or reach past the
pipeline into ``core.kernels``.

Note: ``cascade`` here is ``search.cascade.cascade`` (the LB operator
chain); the pipeline's *stage* of the same name is ``pipeline.cascade``
and is not re-exported to keep the historical binding.
"""
from repro.search.cascade import cascade, cascade_lower_bounds
from repro.search.distributed import DistSearchResult, make_distributed_search
from repro.search.incumbents import (
    IncumbentState,
    QuarantineLedger,
    fold_min,
    fold_np,
    initial_state,
    merge_states,
)
from repro.search.multi import (
    DistMultiSearchResult,
    MultiSearchResult,
    make_distributed_multi_search,
    multi_query_search,
)
from repro.search.pipeline import (
    Executor,
    HedgedExecutor,
    HostRoundsExecutor,
    PersistentExecutor,
    RangeResult,
    SearchPlan,
    ShardedExecutor,
    get_executor,
    make_plan,
)
from repro.search.resilient import (
    CoverageError,
    ResilientSearchResult,
    resilient_search,
)
from repro.search.streaming import (
    IngestResult,
    StreamIngestExecutor,
    ingest_chunk,
    initial_incumbents,
    rescore_windows,
)
from repro.search.subsequence import VARIANTS, SearchResult, subsequence_search
from repro.search.znorm import (
    append_window_stats,
    clamp_sigma,
    gather_norm_windows,
    sanitize_series,
    window_finite_mask,
    window_stats,
    znorm,
)

__all__ = [
    "CoverageError",
    "DistMultiSearchResult",
    "DistSearchResult",
    "Executor",
    "HedgedExecutor",
    "HostRoundsExecutor",
    "IncumbentState",
    "IngestResult",
    "MultiSearchResult",
    "PersistentExecutor",
    "QuarantineLedger",
    "RangeResult",
    "ResilientSearchResult",
    "SearchPlan",
    "SearchResult",
    "ShardedExecutor",
    "StreamIngestExecutor",
    "VARIANTS",
    "append_window_stats",
    "cascade",
    "cascade_lower_bounds",
    "clamp_sigma",
    "fold_min",
    "fold_np",
    "gather_norm_windows",
    "get_executor",
    "ingest_chunk",
    "initial_incumbents",
    "initial_state",
    "make_distributed_multi_search",
    "make_distributed_search",
    "make_plan",
    "merge_states",
    "multi_query_search",
    "rescore_windows",
    "resilient_search",
    "sanitize_series",
    "subsequence_search",
    "window_finite_mask",
    "window_stats",
    "znorm",
]
