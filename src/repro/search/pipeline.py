"""The one staged search pipeline: SearchPlan → prepare → cascade → execute.

The paper's pipeline is fixed (Herrmann & Webb 2020): z-norm window stats,
LB cascade, then EAPrunedDTW lanes folding into a shared incumbent. This
repo used to implement that skeleton five times — once per frontend
(``subsequence``, ``multi``, ``streaming``, ``distributed``,
``resilient``), each with its own quarantine prepass, cascade, round loop
and incumbent fold. This module is now the single implementation; the
frontends are thin wrappers that build a :class:`SearchPlan` and pick an
executor.

Stages
------
::

    SearchPlan (make_plan: resolved knobs, hashable → a jit static)
        │
        ├─ prepare_ref      window stats + §2.6 quarantine mask/sanitize
        ├─ prepare_queries  z-norm + LB_Keogh envelopes (per standing query)
        ├─ cascade          the one LB gate: LB_Kim/LB_Keogh per window,
        │                   +inf for quarantined/invalid, best-first argsort
        └─ execute          one of three range executors:
             host rounds        best-first (Q × batch)-lane dispatches in a
                                lax.while_loop (run_host_rounds)
             persistent sweep   the whole order in ONE launch, incumbent in
                                SMEM across candidate blocks (run_persistent)
             sharded            shard_map over candidate ranges, per-round
                                vectorized lax.pmin incumbent reconcile
                                (make_sharded_search / ShardedExecutor)

Incumbent state (``ub``/``best``, strict-improvement fold, dead-lane
sentinel) and quarantine counters live in ``search.incumbents``.

Executor seam
-------------
:class:`Executor` (``run_range(plan, state, lo, hi) -> RangeResult``) is the
unit the fault-tolerant layer schedules: ``resilient_search`` retries,
reassigns and coverage-accounts *ranges*, never caring which executor runs
them. Window starts ``[lo, hi)`` of the bound reference are searched
against the carried incumbents; results come back in global window
coordinates. :class:`HedgedExecutor` composes on the same seam: it wraps N
executors behind one ``run_range`` (and ``run_ingest``, for streaming
executors), races a straggling attempt on the next-healthiest wrapped
executor, and merges duplicate completions through the strict-improvement
fold — provably idempotent, see ``incumbents.merge_states`` and
DESIGN.md §2.9.

Frontend ↔ executor binding (public signatures unchanged):

  * ``subsequence_search``  — Q=1 of the multi host/persistent core for the
    univariate EA variants; the ``full``/``pruned`` baselines and
    multivariate queries run the dedicated single-query core here (their
    kernels take a scalar threshold and no (Q, K) lane form exists).
  * ``multi_query_search``  — host rounds or persistent sweep.
  * ``ingest_chunk``        — host rounds with a ``valid`` window mask and a
    stream-coordinate offset (the streaming wrappers own buffering only).
  * ``make_distributed_search`` / ``make_distributed_multi_search`` — the
    sharded executor (scalar search is Q=1 of the multi program).
  * ``resilient_search``    — a host-rounds executor per work range.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Protocol

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import guards
from repro.core.backend import resolve_backend
from repro.core.batch import (
    block_sweep,
    ea_pruned_dtw_batch,
    ea_pruned_dtw_multi_batch,
    ea_pruned_dtw_multi_batch_fused,
    ea_pruned_dtw_persistent,
    ea_pruned_dtw_persistent_fused,
)
from repro.core.common import (
    BIG,
    DEAD_LANE_UB,
    norm_window_slice,
    pad_lanes_to_blocks,
)
from repro.core.compat import shard_map as _shard_map
from repro.core.dtw import dtw
from repro.core.lower_bounds import (
    cascade_keogh_cumulative,
    envelope,
    lb_keogh,
    lb_kim_fl,
)
from repro.core.pruned_dtw import pruned_dtw
from repro.distributed.fault_tolerance import (
    GUARD_ERRORS,
    TRANSIENT,
    StragglerMonitor,
    WorkerHealth,
    hedge_race,
)
from repro.search.cascade import cascade_lower_bounds
from repro.search.incumbents import (
    IncumbentState,
    fold_min,
    initial_state,
    merge_states,
)
from repro.search.znorm import (
    gather_norm_windows,
    sanitize_series,
    window_finite_mask,
    window_stats,
    znorm,
)

VARIANTS = ("full", "pruned", "eapruned", "eapruned_nolb")
MULTI_VARIANTS = ("eapruned", "eapruned_nolb")
ROUND_DRIVERS = ("host", "persistent")
GATHER_MODES = ("fused", "slab")


# ---------------------------------------------------------------------------
# SearchPlan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SearchPlan:
    """Resolved, validated search knobs — hashable, so a jit static arg.

    Frontends build one per call via :func:`make_plan` (the single
    validation/resolution chokepoint: ``backend`` is always a *concrete*
    backend name here, never ``None``/``"auto"``), then hand it to the
    jitted cores where it replaces the dozen positional knob arguments the
    pre-refactor impls threaded through every layer.
    """
    length: int
    window: int
    variant: str = "eapruned"
    batch: int = 64
    band_width: int | None = None
    chunk: int = 4096
    backend: str = "jax"
    rows_per_step: int = 1
    block_k: int = 8
    row_block: int = 128
    rounds: str = "host"
    quarantine: bool = True
    warm_start: int = 0
    # Candidate materialization (DESIGN.md §2.10): "fused" (default) slices
    # + z-normalizes windows inside the kernel / round body from the O(N)
    # reference and stats tables; "slab" pre-gathers the O(K·l) normalized
    # window matrix on the host (the retired baseline, kept as the
    # comparison arm and for the full/pruned baseline cores, which have no
    # fused form). Results are identical (bit-for-bit on jax; to the
    # documented O(1)-ulp cb reformulation on the Pallas round path).
    gather: str = "fused"
    # Optional byte ceiling for any host-side candidate slab. "slab" paths
    # that would materialize more than this raise SearchInputError at trace
    # time; fused paths never build one, so they are exempt — the knob pins
    # the "persistent sweep too big to slab" regime in tests/benches.
    slab_budget: int | None = None

    @property
    def use_lb(self) -> bool:
        return self.variant != "eapruned_nolb"

    @property
    def use_cb(self) -> bool:
        return self.variant == "eapruned"

    def knobs(self) -> dict:
        """The batch-primitive keyword block (``core.batch`` tuning)."""
        return dict(
            rows_per_step=self.rows_per_step, backend=self.backend,
            block_k=self.block_k, row_block=self.row_block,
        )


def make_plan(
    *,
    length: int,
    window: int,
    variant: str = "eapruned",
    batch: int = 64,
    band_width: int | None = None,
    chunk: int = 4096,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
    rounds: str = "host",
    quarantine: bool = True,
    warm_start: int = 0,
    gather: str = "fused",
    slab_budget: int | None = None,
    with_info: bool = False,
    allowed_variants: tuple[str, ...] = VARIANTS,
) -> SearchPlan:
    """Validate knobs and resolve the backend into a :class:`SearchPlan`.

    Called from every un-jitted frontend wrapper, so ``$REPRO_DTW_BACKEND``
    is re-read on every call and rides into the jitted cores as a concrete
    static. Raises the ``core.guards`` taxonomy on bad knobs, matching the
    pre-refactor per-frontend checks.
    """
    if variant not in allowed_variants:
        raise guards.SearchInputError(
            f"variant {variant!r} not in {allowed_variants}"
        )
    if rounds not in ROUND_DRIVERS:
        raise ValueError(f"rounds {rounds!r} not in {ROUND_DRIVERS}")
    if gather not in GATHER_MODES:
        raise guards.SearchInputError(
            f"gather {gather!r} not in {GATHER_MODES}"
        )
    if slab_budget is not None and int(slab_budget) <= 0:
        raise guards.SearchInputError("slab_budget must be positive bytes")
    if rounds == "persistent" and with_info:
        raise ValueError(
            "rounds='persistent' is counter-free; use the host driver for "
            "with_info stats rounds"
        )
    guards.ensure_knobs(
        length=length, window=window, batch=batch, band_width=band_width,
        block_k=block_k, row_block=row_block, rows_per_step=rows_per_step,
    )
    return SearchPlan(
        length=int(length), window=int(window), variant=variant,
        batch=int(batch), band_width=band_width, chunk=int(chunk),
        backend=resolve_backend(backend), rows_per_step=int(rows_per_step),
        block_k=int(block_k), row_block=int(row_block), rounds=rounds,
        quarantine=bool(quarantine), warm_start=int(warm_start),
        gather=gather,
        slab_budget=None if slab_budget is None else int(slab_budget),
    )


def _ensure_slab_budget(plan: SearchPlan, n_lanes: int, what: str) -> None:
    """Trace-time guard: a host-side slab must fit ``plan.slab_budget``.

    ``n_lanes`` is static (shape-derived), so the check runs while tracing
    and raises before any O(K·l) allocation happens. Fused paths never call
    this — not materializing the slab is the point.
    """
    if plan.slab_budget is None:
        return
    need = int(n_lanes) * int(plan.length) * 4  # float32 windows
    if need > plan.slab_budget:
        raise guards.SearchInputError(
            f"{what}: gather='slab' would materialize {need} bytes of "
            f"candidate windows ({n_lanes} lanes x {plan.length} samples) "
            f"but slab_budget={plan.slab_budget}; use gather='fused' or "
            "raise the budget"
        )


# ---------------------------------------------------------------------------
# prepare — window stats + §2.6 quarantine + query envelopes
# ---------------------------------------------------------------------------

class PreparedRef(NamedTuple):
    """Reference-side stage-1 products shared by every executor."""
    ref: jax.Array           # sanitized series (raw when quarantine off)
    mu: jax.Array            # (n_win,) per-window means
    sigma: jax.Array         # (n_win,) per-window stds (clamped)
    valid: jax.Array | None  # (n_win,) surviving-window mask; None = all
    n_quar: jax.Array        # scalar int32: windows newly quarantined here


class PreparedQueries(NamedTuple):
    """Query-side stage-1 products (fixed for a workload / stream)."""
    qn: jax.Array   # (Q, l) z-normalized queries
    u: jax.Array    # (Q, l) upper LB_Keogh envelope
    low: jax.Array  # (Q, l) lower LB_Keogh envelope


def prepare_ref(plan: SearchPlan, ref, valid=None) -> PreparedRef:
    """Window stats + the one §2.6 quarantine prepass.

    ``valid`` optionally masks which window starts exist at all (the
    fixed-shape streaming buffers); quarantined windows are folded into it
    and only *previously-valid* windows count toward ``n_quar``. The series
    is zero-filled at the bad samples afterwards so the shared prefix sums
    stay finite for the surviving windows.
    """
    ref = jnp.asarray(ref)
    if plan.quarantine:
        finite_ok = window_finite_mask(ref, plan.length)
        if valid is None:
            n_quar = jnp.sum(~finite_ok).astype(jnp.int32)
            valid = finite_ok
        else:
            n_quar = jnp.sum(
                jnp.logical_and(valid, ~finite_ok)
            ).astype(jnp.int32)
            valid = jnp.logical_and(valid, finite_ok)
        ref = sanitize_series(ref)
    else:
        n_quar = jnp.asarray(0, jnp.int32)
    mu, sigma = window_stats(ref, plan.length)
    return PreparedRef(ref=ref, mu=mu, sigma=sigma, valid=valid, n_quar=n_quar)


def prepare_queries(plan: SearchPlan, queries) -> PreparedQueries:
    """Z-normalize the workload's queries and build their envelopes."""
    qn = znorm(jnp.asarray(queries)[:, : plan.length])
    u, low = jax.vmap(envelope, in_axes=(0, None))(qn, plan.window)
    return PreparedQueries(qn=qn, u=u, low=low)


# ---------------------------------------------------------------------------
# cascade — the one LB gate
# ---------------------------------------------------------------------------

def cascade(plan: SearchPlan, prep: PreparedRef, qn) -> tuple[jax.Array, jax.Array]:
    """Per-query lower bounds → best-first candidate order.

    Returns ``(order, lb_sorted)``, both ``(Q, n_win)``. Quarantined and
    invalid windows carry ``+inf`` lower bounds: the argsort pushes them
    behind every live candidate, the cascade stop never reaches them, and
    any that ride in a partially-live round are dead lanes (the same
    machinery as round padding, DESIGN.md §2.6). The no-cascade variant
    keeps natural scan order among surviving windows via a stable argsort
    of the 0/+inf mask.
    """
    n_win = prep.mu.shape[0]
    nq = qn.shape[0]
    if plan.use_lb:
        lbs = jax.vmap(
            lambda q: cascade_lower_bounds(
                prep.ref, q, prep.mu, prep.sigma, plan.length, plan.window,
                chunk=plan.chunk,
            )
        )(qn)                                          # (Q, n_win)
        if prep.valid is not None:
            lbs = jnp.where(prep.valid[None, :], lbs, jnp.inf)
        order = jnp.argsort(lbs, axis=1)
        return order, jnp.take_along_axis(lbs, order, axis=1)
    if prep.valid is not None:
        lbs = jnp.broadcast_to(
            jnp.where(prep.valid, 0.0, jnp.inf).astype(qn.dtype),
            (nq, n_win),
        )
        order = jnp.argsort(lbs, axis=1)
        return order, jnp.take_along_axis(lbs, order, axis=1)
    order = jnp.broadcast_to(jnp.arange(n_win), (nq, n_win))
    return order, jnp.zeros((nq, n_win), qn.dtype)


def local_cascade(
    plan: SearchPlan, prep: PreparedRef, qn, starts, valid
) -> jax.Array:
    """Per-shard lower bounds for an explicit (gathered) start set.

    The sharded executor's form of the gate: each device owns ``starts``
    (a slice of every query's windows) rather than the dense ``[0, n_win)``
    range, so the bounds are computed per gathered window, chunked through
    ``lax.map`` to bound materialization. Invalid/quarantined starts come
    back ``+inf`` exactly as in :func:`cascade`.
    """
    def one_query(query_n):
        u, low = envelope(query_n, plan.window)
        n_local = starts.shape[0]
        n_chunks = -(-n_local // plan.chunk)
        pad = n_chunks * plan.chunk - n_local
        starts_p = jnp.concatenate([starts, jnp.zeros((pad,), starts.dtype)])
        valid_p = jnp.concatenate([valid, jnp.zeros((pad,), bool)])

        def one(i):
            s = jax.lax.dynamic_slice(starts_p, (i * plan.chunk,), (plan.chunk,))
            v = jax.lax.dynamic_slice(valid_p, (i * plan.chunk,), (plan.chunk,))
            cand = norm_window_slice(
                prep.ref, s, plan.length, prep.mu, prep.sigma
            )
            lb = jnp.maximum(lb_kim_fl(query_n, cand), lb_keogh(cand, u, low))
            return jnp.where(v, lb, jnp.inf)

        lbs = jax.lax.map(one, jnp.arange(n_chunks)).reshape(-1)
        return lbs[:n_local]

    return jax.vmap(one_query)(qn)                     # (Q, n_local)


# ---------------------------------------------------------------------------
# host-rounds executor core
# ---------------------------------------------------------------------------

class SearchStats(NamedTuple):
    """Per-query work accounting of one execution."""
    rounds: jax.Array     # (Q,) batch rounds (persistent: dispatches)
    lanes: jax.Array      # (Q,) candidate lanes submitted
    lb_pruned: jax.Array  # (Q,) candidates never evaluated (LB ordering)
    rows: jax.Array       # (Q,) DTW rows issued (-1: fast rounds)
    cells: jax.Array      # (Q,) admissible DTW cells (-1: fast rounds)


def _round_slicers(batch: int):
    """Vmapped per-query round slicing, shared by both round drivers.

    Returns ``(slice_round, peek_lb)``: ``slice_round(rows, ptrs)`` pulls
    each query's current ``batch``-wide round from its padded row,
    ``peek_lb(rows, ptrs)`` reads the head (smallest) lower bound of that
    round.
    """
    slice_round = jax.vmap(
        lambda row, r: jax.lax.dynamic_slice(row, (r * batch,), (batch,)),
        in_axes=(0, 0),
    )
    peek_lb = jax.vmap(
        lambda row, r: jax.lax.dynamic_slice(row, (r * batch,), (1,))[0],
        in_axes=(0, 0),
    )
    return slice_round, peek_lb


def _dtw_round_fused(
    plan: SearchPlan, prep: PreparedRef, pq, starts, ub_lanes, *,
    use_cb: bool, with_info: bool,
):
    """One fused-gather EAPrunedDTW round over ``(Q, K)`` lane starts.

    Candidates are sliced and z-normalized from ``prep.ref`` inside the
    batch primitive (jax) or the Pallas kernel — no O(Q·K·l) slab is built
    host-side. Returns ``(d, info_or_None)``.
    """
    env = (pq.u, pq.low) if use_cb else None
    out = ea_pruned_dtw_multi_batch_fused(
        pq.qn, prep.ref, starts, ub_lanes, window=plan.window,
        mu=prep.mu, sigma=prep.sigma, envelopes=env,
        band_width=plan.band_width, with_info=with_info, **plan.knobs(),
    )
    if with_info:
        return out
    return out, None


def warm_prepass(
    plan: SearchPlan,
    prep: PreparedRef,
    pq: PreparedQueries,
    order,
    lb_sorted,
    state0: IncumbentState,
    with_info: bool = False,
    offset=0,
):
    """Full-DP each query's best-LB candidates to seed the incumbents.

    One tiny ``(Q × pre)``-lane dispatch (``pre = min(warm_start, batch)``)
    so no subsequent round or sweep ever runs with an unbounded ``ub``. The
    main pass re-encounters these candidates with ``d == ub``;
    strict-improvement keeps the prepass incumbent, so results are
    unchanged — both for the host round loop and for the persistent sweep
    (whose result is folded against this state by the caller).

    Returns ``(state, pre, rows_pre, cells_pre)``.
    """
    nq, n_win = order.shape
    pre = min(int(plan.warm_start), plan.batch)
    if pre <= 0:
        z = jnp.zeros((nq,), jnp.int32)
        return state0, 0, z, z
    if n_win < pre:
        order = jnp.concatenate(
            [order, jnp.zeros((nq, pre - n_win), order.dtype)], axis=1
        )
        lb_sorted = jnp.concatenate(
            [lb_sorted, jnp.full((nq, pre - n_win), jnp.inf, lb_sorted.dtype)],
            axis=1,
        )
    pre_starts = order[:, :pre]
    pre_lbs = lb_sorted[:, :pre]
    ub_pre = jnp.where(
        jnp.logical_and(jnp.isfinite(pre_lbs), pre_lbs < state0.ub[:, None]),
        jnp.broadcast_to(state0.ub[:, None], (nq, pre)),
        DEAD_LANE_UB,
    )
    if plan.gather == "fused":
        d0, info0 = _dtw_round_fused(
            plan, prep, pq, pre_starts, ub_pre,
            use_cb=False, with_info=with_info,
        )
    else:
        _ensure_slab_budget(plan, nq * pre, "warm_prepass")
        cand0 = jax.vmap(
            lambda s: gather_norm_windows(
                prep.ref, s, plan.length, prep.mu, prep.sigma
            )
        )(pre_starts)
        if with_info:
            d0, info0 = ea_pruned_dtw_multi_batch(
                pq.qn, cand0, ub_pre, window=plan.window,
                band_width=plan.band_width, with_info=True, **plan.knobs(),
            )
        else:
            d0 = ea_pruned_dtw_multi_batch(
                pq.qn, cand0, ub_pre, window=plan.window,
                band_width=plan.band_width, **plan.knobs(),
            )
            info0 = None
    if with_info:
        rows_pre = jnp.sum(info0.rows, axis=1, dtype=jnp.int32)
        cells_pre = jnp.sum(info0.cells, axis=1, dtype=jnp.int32)
    else:
        rows_pre = cells_pre = jnp.zeros((nq,), jnp.int32)
    d0 = jnp.where(jnp.isfinite(pre_lbs), d0, jnp.inf)
    state, _ = fold_min(state0, pre_starts, d0, offset=offset)
    return state, pre, rows_pre, cells_pre


def run_host_rounds(
    plan: SearchPlan,
    prep: PreparedRef,
    pq: PreparedQueries,
    order,
    lb_sorted,
    state0: IncumbentState,
    *,
    with_info: bool = False,
    offset=0,
) -> tuple[IncumbentState, SearchStats]:
    """The host round driver: best-first ``(Q × batch)``-lane dispatches.

    One ``lax.while_loop`` serves every host-rounds frontend — offline
    multi-query (``offset == 0``), Q=1 single-query, streaming ingest
    (``offset`` maps local window starts into stream coordinates) and each
    resilient work range (``offset == lo``). Per-query drop-out: a query
    leaves the loop when it has no rounds left or its next batch's smallest
    lower bound can no longer beat its incumbent; a finished query's lanes
    ride along with the dead-lane sentinel, costing one masked row each.
    ``plan.warm_start`` seeds the incumbents through :func:`warm_prepass`
    first (changes work, not results).
    """
    nq = pq.qn.shape[0]
    n_win = order.shape[1]
    batch = plan.batch
    use_lb, use_cb = plan.use_lb, plan.use_cb

    state0, pre, rows_pre, cells_pre = warm_prepass(
        plan, prep, pq, order, lb_sorted, state0, with_info=with_info,
        offset=offset,
    )

    n_rounds = -(-n_win // batch)
    pad = n_rounds * batch - n_win
    order_p = jnp.concatenate(
        [order, jnp.zeros((nq, pad), order.dtype)], axis=1
    )
    lb_p = jnp.concatenate(
        [lb_sorted, jnp.full((nq, pad), jnp.inf, lb_sorted.dtype)], axis=1
    )

    # A query whose (possibly warm) incumbent already beats its best
    # remaining lower bound never enters the round loop at all.
    active0 = jnp.ones((nq,), bool)
    if use_lb:
        active0 = lb_p[:, 0] < state0.ub

    slice_round, peek_lb = _round_slicers(batch)
    if plan.gather != "fused":
        _ensure_slab_budget(plan, nq * batch, "run_host_rounds")

    class St(NamedTuple):
        r: jax.Array        # (Q,) per-query round pointer
        inc: IncumbentState
        active: jax.Array   # (Q,) still in the round loop?
        lanes: jax.Array    # (Q,)
        rows: jax.Array     # (Q,)
        cells: jax.Array    # (Q,)

    def cond(st: St) -> jax.Array:
        return jnp.any(st.active)

    def body(st: St) -> St:
        starts = slice_round(order_p, st.r)            # (Q, batch)
        lbs_b = slice_round(lb_p, st.r)                # (Q, batch)
        # Flattened (Q x batch) lane set, per-lane ub. Three per-lane cases
        # the scalar-ub form cannot express: finished queries submit dead
        # lanes; within an active query's batch, lanes whose own lower bound
        # already reaches the incumbent are submitted dead too (lane-level
        # LB gating — the batch-head check only gates the round); the rest
        # carry their query's incumbent.
        lane_live = jnp.logical_and(
            st.active[:, None], lbs_b < st.inc.ub[:, None]
        )
        ub_lanes = jnp.where(
            lane_live,
            jnp.broadcast_to(st.inc.ub[:, None], (nq, batch)),
            DEAD_LANE_UB,
        )
        if plan.gather == "fused":
            d, info = _dtw_round_fused(
                plan, prep, pq, starts, ub_lanes,
                use_cb=use_cb, with_info=with_info,
            )
        else:
            cand = jax.vmap(
                lambda s: gather_norm_windows(
                    prep.ref, s, plan.length, prep.mu, prep.sigma
                )
            )(starts)                                  # (Q, batch, l)
            cb = None
            if use_cb:
                cb = jax.vmap(cascade_keogh_cumulative)(cand, pq.u, pq.low)
            if with_info:
                d, info = ea_pruned_dtw_multi_batch(
                    pq.qn, cand, ub_lanes, window=plan.window,
                    band_width=plan.band_width, cb=cb, with_info=True,
                    **plan.knobs(),
                )
            else:
                d = ea_pruned_dtw_multi_batch(
                    pq.qn, cand, ub_lanes, window=plan.window,
                    band_width=plan.band_width, cb=cb, **plan.knobs(),
                )
                info = None
        if with_info:
            rows_q = jnp.sum(info.rows, axis=1, dtype=jnp.int32)
            cells_q = jnp.sum(info.cells, axis=1, dtype=jnp.int32)
        else:
            rows_q = cells_q = jnp.zeros((nq,), st.rows.dtype)
        d = jnp.where(jnp.isfinite(lbs_b), d, jnp.inf)  # padding lanes
        d = jnp.where(st.active[:, None], d, jnp.inf)
        inc, _ = fold_min(st.inc, starts, d, offset=offset)
        r_new = st.r + st.active.astype(st.r.dtype)
        # Drop-out: no rounds left, or the next batch's best lower bound
        # can no longer beat this query's incumbent.
        more = r_new < n_rounds
        if use_lb:
            nxt = peek_lb(lb_p, jnp.minimum(r_new, n_rounds - 1))
            more = jnp.logical_and(more, nxt < inc.ub)
        return St(
            r=r_new,
            inc=inc,
            active=jnp.logical_and(st.active, more),
            lanes=st.lanes + st.active.astype(st.lanes.dtype) * batch,
            rows=st.rows + rows_q,
            cells=st.cells + cells_q,
        )

    # ``lanes`` counts distinct candidates examined: round 0 re-submits the
    # prepass candidates (they lead its best-first batch), so the prepass
    # only stands alone for a query that never enters the round loop.
    st0 = St(
        r=jnp.zeros((nq,), jnp.int32),
        inc=state0,
        active=active0,
        lanes=jnp.where(active0, 0, pre).astype(jnp.int32),
        rows=rows_pre,
        cells=cells_pre,
    )
    st = jax.lax.while_loop(cond, body, st0)
    no_info = jnp.full((nq,), -1)
    return st.inc, SearchStats(
        rounds=st.r,
        lanes=st.lanes,
        lb_pruned=n_win - jnp.minimum(st.lanes, n_win),
        rows=st.rows if with_info else no_info,
        cells=st.cells if with_info else no_info,
    )


# ---------------------------------------------------------------------------
# persistent-sweep executor core
# ---------------------------------------------------------------------------

def run_persistent(
    plan: SearchPlan,
    prep: PreparedRef,
    pq: PreparedQueries,
    order,
    lb_sorted,
    state0: IncumbentState,
) -> tuple[IncumbentState, SearchStats]:
    """One launch for the whole workload (DESIGN.md §2.5).

    Every query's full best-first candidate order is gathered once; the
    kernel grid keeps the query dimension parallel and carries each query's
    incumbent in SMEM across the *sequential* candidate-block dimension —
    tightened every ``block_k`` lanes, LB-gated per block on device.

    ``plan.warm_start > 0`` runs the same :func:`warm_prepass` as the host
    driver and seeds the sweep's ``ub`` with the prepass bounds; because the
    persistent kernel takes no ``best`` seed (strict improvement returns
    ``-1`` when the seed is unbeaten), the sweep's result is folded against
    the prepass state so a prepass winner keeps its start. Pre-refactor the
    knob was silently dropped here.
    """
    nq = pq.qn.shape[0]
    n_win = order.shape[1]

    state0, pre, _, _ = warm_prepass(
        plan, prep, pq, order, lb_sorted, state0
    )

    lb_p, order_p, _ = pad_lanes_to_blocks(plan.block_k, lb_sorted, order)
    if plan.gather == "fused":
        # The whole best-first order is *addressed*, never materialized:
        # each block of block_k lanes is sliced + normalized from the
        # resident reference on demand (O(N + block_k) working set).
        bd, bs, blocks = ea_pruned_dtw_persistent_fused(
            pq.qn, prep.ref, lb_p, order_p, state0.ub, window=plan.window,
            mu=prep.mu, sigma=prep.sigma, band_width=plan.band_width,
            envelopes=(pq.u, pq.low) if plan.use_cb else None,
            **plan.knobs(),
        )
    else:
        _ensure_slab_budget(plan, nq * order_p.shape[1], "run_persistent")
        cand_all = jax.vmap(
            lambda s: gather_norm_windows(
                prep.ref, s, plan.length, prep.mu, prep.sigma
            )
        )(order_p)                                     # (Q, k_pad, l)
        bd, bs, blocks = ea_pruned_dtw_persistent(
            pq.qn, cand_all, lb_p, order_p, state0.ub, window=plan.window,
            band_width=plan.band_width,
            envelopes=(pq.u, pq.low) if plan.use_cb else None, **plan.knobs(),
        )
    # Strict-improvement fold against the (possibly prepass-seeded) state:
    # unbeaten seeds keep their start, a tighter sweep result adopts its.
    improved = bd < state0.ub
    state = IncumbentState(
        ub=jnp.where(improved, bd, state0.ub),
        best=jnp.where(improved, bs, state0.best),
    )
    # visited blocks are a best-first prefix per query, so only the final
    # padded block can hold non-candidates — clamp to n_win
    lanes = jnp.minimum(blocks * plan.block_k, n_win).astype(jnp.int32)
    no_info = jnp.full((nq,), -1)
    return state, SearchStats(
        # dispatches, not batch rounds: one launch (+ the warm prepass)
        rounds=jnp.full((nq,), 2 if pre else 1, jnp.int32),
        lanes=lanes,
        lb_pruned=n_win - lanes,
        rows=no_info,
        cells=no_info,
    )


# ---------------------------------------------------------------------------
# jitted offline cores
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("plan", "with_info"))
def _offline_search_impl(ref, queries, ub_init, plan: SearchPlan, with_info):
    """prepare → cascade → host rounds / persistent sweep, one jitted program.

    The shared offline core behind ``multi_query_search``,
    ``subsequence_search`` (Q=1) and each resilient work range. Returns
    ``(IncumbentState, SearchStats, n_quar)``.
    """
    prep = prepare_ref(plan, ref)
    pq = prepare_queries(plan, queries)
    nq = pq.qn.shape[0]
    order, lb_sorted = cascade(plan, prep, pq.qn)
    state0 = initial_state(nq, pq.qn.dtype, ub_init, best_dtype=order.dtype)
    if plan.rounds == "persistent":
        state, stats = run_persistent(plan, prep, pq, order, lb_sorted, state0)
    else:
        state, stats = run_host_rounds(
            plan, prep, pq, order, lb_sorted, state0, with_info=with_info
        )
    return state, stats, prep.n_quar


@partial(jax.jit, static_argnames=("plan", "with_info"))
def _baseline_search_impl(ref, query, plan: SearchPlan, with_info):
    """Single-query core for the ``full``/``pruned`` baselines and
    multivariate queries.

    These paths have no ``(Q, K)`` lane form — ``dtw``/``pruned_dtw`` take a
    scalar threshold, and the multi batch is univariate-only — so the paper
    baselines keep a dedicated scalar-incumbent sweep here (the same
    prepare/cascade stages, a scalar round loop or ``block_sweep``).
    Returns scalar-field ``(IncumbentState, SearchStats, n_quar)`` shaped
    like Q=1 (length-1 arrays).
    """
    query_n = znorm(jnp.asarray(query)[: plan.length])
    prep = prepare_ref(plan, ref)
    n_win = prep.mu.shape[0]
    order, lb_sorted = cascade(plan, prep, query_n[None])
    order, lb_sorted = order[0], lb_sorted[0]
    u, low = envelope(query_n, plan.window)
    use_lb, use_cb = plan.use_lb, plan.use_cb
    knobs = plan.knobs()

    def batch_distances(cand, ub, cb):
        if plan.variant in ("eapruned", "eapruned_nolb"):
            return ea_pruned_dtw_batch(
                query_n, cand, ub, window=plan.window,
                band_width=plan.band_width, cb=cb, **knobs,
            )
        if plan.variant == "pruned":
            return jax.vmap(
                lambda c: pruned_dtw(query_n, c, ub, window=plan.window)
            )(cand)
        return jax.vmap(lambda c: dtw(query_n, c, window=plan.window))(cand)

    def batch_stats(cand, ub, cb):
        if plan.variant in ("eapruned", "eapruned_nolb"):
            d, info = ea_pruned_dtw_batch(
                query_n, cand, ub, window=plan.window,
                band_width=plan.band_width, cb=cb, with_info=True, **knobs,
            )
            return d, jnp.sum(info.rows), jnp.sum(info.cells)
        if plan.variant == "pruned":
            d, info = jax.vmap(
                lambda c: pruned_dtw(
                    query_n, c, ub, window=plan.window, with_info=True
                )
            )(cand)
            return d, jnp.sum(info.rows), jnp.sum(info.cells)
        d = batch_distances(cand, ub, cb)
        m = query_n.shape[-1]
        k = cand.shape[0]
        # full DTW issues every in-window cell
        win_cells = m * (2 * plan.window + 1) - plan.window * (plan.window + 1)
        return d, jnp.asarray(k * m), jnp.asarray(k * min(win_cells, m * m))

    if plan.rounds == "persistent":
        # One gather of the whole best-first order; the sweep itself is a
        # single dispatch (EA variants) or the shared block-granular host
        # sweep (full/pruned kernels take no per-lane threshold).
        lb_p, order_p, _ = pad_lanes_to_blocks(plan.block_k, lb_sorted, order)
        # Baseline cores take pre-gathered candidates by contract, so this
        # slab is sanctioned regardless of plan.gather — but it still has to
        # fit the configured budget.
        _ensure_slab_budget(plan, order_p.shape[0], "baseline persistent")
        cand_all = gather_norm_windows(
            prep.ref, order_p, plan.length, prep.mu, prep.sigma
        )
        if plan.variant in ("eapruned", "eapruned_nolb"):
            envs = (u[None], low[None]) if use_cb else None
            bd, bs, blocks = ea_pruned_dtw_persistent(
                query_n[None], cand_all[None], lb_p[None], order_p[None],
                jnp.full((1,), BIG, query_n.dtype), window=plan.window,
                band_width=plan.band_width, envelopes=envs, **knobs,
            )
            best, ub, blocks = bs[0], bd[0], blocks[0]
        else:
            ub, best, blocks = block_sweep(
                cand_all, lb_p, order_p, jnp.asarray(BIG, query_n.dtype),
                plan.block_k,
                lambda c, lbb, ub_cur: batch_distances(c, ub_cur, None),
            )
        lanes = jnp.minimum(blocks * plan.block_k, n_win).astype(jnp.int32)
        no_info = jnp.asarray(-1)
        state = IncumbentState(ub=ub[None], best=jnp.asarray(best)[None])
        stats = SearchStats(
            rounds=jnp.asarray(1)[None],  # dispatches: one launch per search
            lanes=lanes[None],
            lb_pruned=(jnp.asarray(n_win) - lanes)[None],
            rows=no_info[None],
            cells=no_info[None],
        )
        return state, stats, prep.n_quar

    batch = plan.batch
    n_rounds = -(-n_win // batch)
    pad = n_rounds * batch - n_win
    order_p = jnp.concatenate([order, jnp.zeros((pad,), order.dtype)])
    lb_p = jnp.concatenate(
        [lb_sorted, jnp.full((pad,), jnp.inf, lb_sorted.dtype)]
    )

    class St(NamedTuple):
        r: jax.Array
        ub: jax.Array
        best: jax.Array
        lanes: jax.Array
        rows: jax.Array
        cells: jax.Array

    def cond(st: St) -> jax.Array:
        more = st.r < n_rounds
        if not use_lb:
            return more
        next_lb = jax.lax.dynamic_slice(lb_p, (st.r * batch,), (1,))[0]
        return jnp.logical_and(more, next_lb < st.ub)

    def body(st: St) -> St:
        starts = jax.lax.dynamic_slice(order_p, (st.r * batch,), (batch,))
        lbs = jax.lax.dynamic_slice(lb_p, (st.r * batch,), (batch,))
        cand = gather_norm_windows(
            prep.ref, starts, plan.length, prep.mu, prep.sigma
        )
        cb = None
        if use_cb:
            cb = cascade_keogh_cumulative(cand, u, low)
        if plan.variant in ("eapruned", "eapruned_nolb"):
            # Per-lane ub: quarantined and round-padding lanes (both marked
            # by +inf lower bounds) ride as dead lanes — the kernel abandons
            # them on row 0 instead of running a DP over masked garbage.
            ub_b = jnp.where(jnp.isfinite(lbs), st.ub, DEAD_LANE_UB)
        else:
            ub_b = st.ub  # full/pruned kernels take a scalar threshold
        if with_info:
            d, rows, cells = batch_stats(cand, ub_b, cb)
        else:
            d = batch_distances(cand, ub_b, cb)
            rows = cells = jnp.asarray(0)
        d = jnp.where(jnp.isfinite(lbs), d, jnp.inf)  # padding lanes
        k = jnp.argmin(d)
        dmin = d[k]
        improved = dmin < st.ub
        return St(
            r=st.r + 1,
            ub=jnp.where(improved, dmin, st.ub),
            best=jnp.where(improved, starts[k], st.best),
            lanes=st.lanes + batch,
            rows=st.rows + rows,
            cells=st.cells + cells,
        )

    st0 = St(
        r=jnp.asarray(0),
        ub=jnp.asarray(BIG, query_n.dtype),
        best=jnp.asarray(-1, order.dtype),
        lanes=jnp.asarray(0),
        rows=jnp.asarray(0),
        cells=jnp.asarray(0),
    )
    st = jax.lax.while_loop(cond, body, st0)
    no_info = jnp.asarray(-1)
    state = IncumbentState(ub=st.ub[None], best=st.best[None])
    stats = SearchStats(
        rounds=st.r[None],
        lanes=st.lanes[None],
        lb_pruned=(jnp.asarray(n_win) - jnp.minimum(st.lanes, n_win))[None],
        rows=(st.rows if with_info else no_info)[None],
        cells=(st.cells if with_info else no_info)[None],
    )
    return state, stats, prep.n_quar


# ---------------------------------------------------------------------------
# streaming ingest core (traced; the streaming wrappers own buffering)
# ---------------------------------------------------------------------------

def run_stream_ingest(
    plan: SearchPlan, ctx, valid, pq: PreparedQueries, state0: IncumbentState,
    offset,
):
    """One ingest over the windows of ``ctx``: prepare → cascade → rounds.

    ``valid`` masks which of the ``len(ctx) - length + 1`` window starts
    really exist (fixed-shape buffers mask their garbage prefix/padding
    suffix); ``offset`` is the stream coordinate of ``ctx[0]``. The carried
    incumbents ride in as ``state0`` and gate round 0 exactly like a warm
    ``ub_init`` in the offline driver. Returns
    ``(IncumbentState, SearchStats, n_quar)`` with ``best`` in stream
    coordinates.
    """
    prep = prepare_ref(plan, ctx, valid=valid)
    order, lb_sorted = cascade(plan, prep, pq.qn)
    state, stats = run_host_rounds(
        plan, prep, pq, order, lb_sorted, state0, offset=offset
    )
    return state, stats, prep.n_quar


# ---------------------------------------------------------------------------
# sharded executor (shard_map + pmin reconcile)
# ---------------------------------------------------------------------------

def make_sharded_search(
    mesh: jax.sharding.Mesh, axis_names: tuple[str, ...], plan: SearchPlan
):
    """Build the jitted sharded search program for a mesh config.

    Returns ``search_fn(ref, queries) -> (best_dist (Q,), best_start (Q,),
    rounds, n_quar)``. Work items are (query, candidate-range) pairs:
    candidate window starts are sharded contiguously across the mesh axes
    (each device owns a slice of every query's windows), queries ride in
    the lane dimension of the per-device multi-query batch, and after every
    round the per-query incumbent vector is reconciled with one vectorized
    ``lax.pmin`` all-reduce. Devices iterate in lockstep until no device
    has an active (query, range) item left (``pmax`` continue flag); the
    scalar frontend is Q=1 of this same program.

    ``plan.quarantine`` threads the §2.6 mask per shard: poisoned windows
    are condemned on the shard that owns them (``+inf`` LB → dead-lane
    sentinel, query-independent), counts ``psum``-reduce to the
    single-device total, and the sanitized reference keeps the shared
    prefix sums finite for survivors.
    """
    n_shards = 1
    for a in axis_names:
        n_shards *= mesh.shape[a]
    spec_sharded = P(axis_names)
    spec_rep = P()
    batch = plan.batch

    def local_search(ref, queries_n, starts, valid, q_ok):
        nq = queries_n.shape[0]

        def psum_all(x):
            for a in axis_names:
                x = jax.lax.psum(x, a)
            return x

        # Quarantine accounting before the mask folds into ``valid``: each
        # shard counts its own real (non-padding) condemned windows, and
        # the psum reconciles them into the global count every shard
        # reports.
        n_quar = psum_all(
            jnp.sum(jnp.logical_and(valid, ~q_ok)).astype(jnp.int32)
        )
        valid = jnp.logical_and(valid, q_ok)
        mu, sigma = window_stats(ref, plan.length)
        prep = PreparedRef(
            ref=ref, mu=mu, sigma=sigma, valid=None, n_quar=n_quar
        )
        lbs = local_cascade(plan, prep, queries_n, starts, valid)
        order = jnp.argsort(lbs, axis=1)
        starts_o = jnp.take_along_axis(
            jnp.broadcast_to(starts, lbs.shape), order, axis=1
        )
        lb_o = jnp.take_along_axis(lbs, order, axis=1)
        n_local = starts.shape[0]
        n_rounds = -(-n_local // batch)
        pad = n_rounds * batch - n_local
        starts_p = jnp.concatenate(
            [starts_o, jnp.zeros((nq, pad), starts_o.dtype)], axis=1
        )
        lb_p = jnp.concatenate(
            [lb_o, jnp.full((nq, pad), jnp.inf, lb_o.dtype)], axis=1
        )
        u, low = jax.vmap(envelope, in_axes=(0, None))(
            queries_n, plan.window
        )

        def pmin_all(x):
            for a in axis_names:
                x = jax.lax.pmin(x, a)
            return x

        def pmax_all(x):
            for a in axis_names:
                x = jax.lax.pmax(x, a)
            return x

        slice_round, peek_lb = _round_slicers(batch)
        if plan.gather != "fused":
            _ensure_slab_budget(plan, nq * batch, "make_sharded_search")

        class St(NamedTuple):
            r: jax.Array        # (Q,) local per-query round pointer
            ub: jax.Array       # (Q,) globally reconciled incumbents
            loc: IncumbentState  # local best (start, dist per lane fold)
            go: jax.Array       # global continue flag

        def cond(st: St) -> jax.Array:
            return st.go

        def body(st: St) -> St:
            s = slice_round(starts_p, st.r)            # (Q, batch)
            lb = slice_round(lb_p, st.r)
            head = peek_lb(lb_p, st.r)
            local_more = jnp.logical_and(st.r < n_rounds, head < st.ub)
            # Dead-lane sentinel for finished (query, range) items and for
            # lanes whose own lower bound already reaches the incumbent
            # (lane-level LB gating, as in the host round driver).
            lane_live = jnp.logical_and(
                local_more[:, None], lb < st.ub[:, None]
            )
            ub_lanes = jnp.where(
                lane_live,
                jnp.broadcast_to(st.ub[:, None], (nq, batch)),
                DEAD_LANE_UB,
            )
            if plan.gather == "fused":
                d = ea_pruned_dtw_multi_batch_fused(
                    queries_n, ref, s, ub_lanes, window=plan.window,
                    mu=mu, sigma=sigma, envelopes=(u, low),
                    band_width=plan.band_width, **plan.knobs(),
                )
            else:
                cand = jax.vmap(
                    lambda ss: gather_norm_windows(
                        ref, ss, plan.length, mu, sigma
                    )
                )(s)
                cb = jax.vmap(cascade_keogh_cumulative)(cand, u, low)
                d = ea_pruned_dtw_multi_batch(
                    queries_n, cand, ub_lanes, window=plan.window,
                    band_width=plan.band_width, cb=cb, **plan.knobs(),
                )
            d = jnp.where(jnp.isfinite(lb), d, jnp.inf)  # padding lanes
            d = jnp.where(local_more[:, None], d, jnp.inf)
            # Local fold keeps this shard's best achieved pair; the global
            # incumbent only needs the bound, reconciled by one vectorized
            # pmin per round.
            loc, _ = fold_min(st.loc, s, d)
            ub = pmin_all(jnp.minimum(st.ub, loc.ub))
            r = st.r + local_more.astype(st.r.dtype)
            nxt = peek_lb(lb_p, jnp.minimum(r, n_rounds - 1))
            local_next = jnp.logical_and(r < n_rounds, nxt < ub)
            return St(
                r=r, ub=ub, loc=loc, go=pmax_all(jnp.any(local_next)),
            )

        go0 = pmax_all(jnp.asarray(True))
        st0 = St(
            r=jnp.zeros((nq,), jnp.int32),
            ub=jnp.full((nq,), BIG, queries_n.dtype),
            loc=IncumbentState(
                ub=jnp.full((nq,), BIG, queries_n.dtype),
                best=jnp.full((nq,), -1, starts.dtype),
            ),
            go=go0,
        )
        st = jax.lax.while_loop(cond, body, st0)
        # Per-query global argmin: vectorized lexicographic
        # (distance, start).
        g_min = pmin_all(st.loc.ub)                    # (Q,)
        is_best = jnp.isclose(st.loc.ub, g_min)
        cand_start = jnp.where(
            is_best, st.loc.best, jnp.iinfo(jnp.int32).max
        )
        g_start = pmin_all(cand_start.astype(jnp.int32))
        return g_min, g_start, pmax_all(jnp.max(st.r)), n_quar

    @jax.jit
    def search_fn(ref: jax.Array, queries: jax.Array):
        ref = jnp.asarray(ref)
        queries_n = znorm(jnp.asarray(queries)[:, : plan.length])
        n_win = ref.shape[0] - plan.length + 1
        per = -(-n_win // n_shards)
        total = per * n_shards
        starts = jnp.arange(total, dtype=jnp.int32)
        valid = starts < n_win
        starts = jnp.minimum(starts, n_win - 1)
        if plan.quarantine:
            # Mask on the raw series, sanitize before replication so shared
            # prefix sums stay finite for the surviving windows (§2.6).
            finite_ok = window_finite_mask(ref, plan.length)
            ref = sanitize_series(ref)
            q_ok = finite_ok[starts]
        else:
            q_ok = jnp.ones_like(valid)

        shard = _shard_map(
            local_search,
            mesh=mesh,
            in_specs=(
                spec_rep, spec_rep, spec_sharded, spec_sharded, spec_sharded,
            ),
            out_specs=(spec_rep, spec_rep, spec_rep, spec_rep),
        )
        return shard(ref, queries_n, starts, valid, q_ok)

    return search_fn


# ---------------------------------------------------------------------------
# Executor protocol — the range-execution seam
# ---------------------------------------------------------------------------

class RangeResult(NamedTuple):
    """Outcome of one work range: folded incumbents + accounting."""
    state: IncumbentState   # (Q,) incumbents, best in GLOBAL coordinates
    stats: SearchStats
    quarantined: jax.Array  # windows of this range excluded by §2.6


class Executor(Protocol):
    """``run_range(plan, state, lo, hi)``: search window starts [lo, hi).

    The seam the fault-tolerant layer schedules on: an executor is bound to
    one (reference, queries) workload at construction and searches any
    window-start range of it against carried incumbents, returning results
    in global window coordinates. Implementations: host rounds, persistent
    sweep, sharded mesh program.
    """

    def run_range(
        self, plan: SearchPlan, state: IncumbentState, lo: int, hi: int
    ) -> RangeResult:
        ...


class _OfflineRangeExecutor:
    """Shared range logic for the host-rounds/persistent executors.

    A range is searched as the offline core over its slice: windows
    ``[lo, hi)`` live in ``ref[lo : hi + length - 1]``, the carried
    incumbents ride in as warm ``ub_init`` seeds, and achieved starts map
    back by ``+ lo``. Distinct range lengths trace distinct programs (the
    usual static-shape rule); equal-length ranges share one trace.
    """

    _rounds: str

    def __init__(self, ref, queries):
        self.ref = jnp.asarray(ref)
        self.queries = jnp.atleast_2d(jnp.asarray(queries))

    def run_range(
        self, plan: SearchPlan, state: IncumbentState, lo: int, hi: int
    ) -> RangeResult:
        plan = dataclasses.replace(plan, rounds=self._rounds)
        seg = self.ref[lo : hi + plan.length - 1]
        res_state, stats, n_quar = _offline_search_impl(
            seg, self.queries, jnp.asarray(state.ub, self.queries.dtype),
            plan, False,
        )
        best = jnp.where(res_state.best >= 0, res_state.best + lo, -1)
        # Seed-unbeaten queries keep their incoming start (the seed's
        # achiever lives outside this range).
        best = jnp.where(
            res_state.ub < jnp.asarray(state.ub, res_state.ub.dtype),
            best, state.best,
        )
        return RangeResult(
            state=IncumbentState(ub=res_state.ub, best=best),
            stats=stats, quarantined=n_quar,
        )


class HostRoundsExecutor(_OfflineRangeExecutor):
    """Best-first host-round dispatches over the range (the default)."""
    _rounds = "host"


class PersistentExecutor(_OfflineRangeExecutor):
    """The range's whole best-first order in one launch (DESIGN.md §2.5)."""
    _rounds = "persistent"


class ShardedExecutor:
    """Mesh-parallel range execution: shard_map + ``pmin`` reconcile.

    Satisfies the same ``run_range`` contract as the host executors so the
    resilient layer can schedule mesh-sized ranges too; each distinct range
    length compiles its own program (cached per length). Incoming incumbent
    *bounds* seed nothing on the mesh path today (the SPMD program cold-
    starts at BIG) — the fold afterwards keeps whichever side is tighter.
    """

    def __init__(self, mesh, axis_names, ref, queries):
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        self.ref = jnp.asarray(ref)
        self.queries = jnp.atleast_2d(jnp.asarray(queries))
        self._fns: dict[SearchPlan, object] = {}

    def _fn(self, plan: SearchPlan):
        if plan not in self._fns:
            self._fns[plan] = make_sharded_search(
                self.mesh, self.axis_names, plan
            )
        return self._fns[plan]

    def run_range(
        self, plan: SearchPlan, state: IncumbentState, lo: int, hi: int
    ) -> RangeResult:
        seg = self.ref[lo : hi + plan.length - 1]
        best_d, best_s, rounds, n_quar = self._fn(plan)(seg, self.queries)
        improved = best_d < jnp.asarray(state.ub, best_d.dtype)
        merged = IncumbentState(
            ub=jnp.where(improved, best_d, state.ub),
            best=jnp.where(improved, best_s + lo, state.best),
        )
        nq = self.queries.shape[0]
        n_win = hi - lo
        no_info = jnp.full((nq,), -1)
        return RangeResult(
            state=merged,
            stats=SearchStats(
                rounds=jnp.broadcast_to(rounds, (nq,)),
                lanes=no_info, lb_pruned=no_info, rows=no_info,
                cells=no_info,
            ),
            quarantined=n_quar,
        )


def get_executor(
    plan: SearchPlan, ref, queries, *, mesh=None, axis_names=None
) -> Executor:
    """Bind the executor ``plan.rounds`` selects to one workload."""
    if mesh is not None:
        return ShardedExecutor(mesh, axis_names, ref, queries)
    if plan.rounds == "persistent":
        return PersistentExecutor(ref, queries)
    return HostRoundsExecutor(ref, queries)


def _merge_range_results(a: RangeResult, b: RangeResult) -> RangeResult:
    """Fold a duplicate completion into the primary's (idempotent).

    Incumbents merge under strict improvement; stats and the quarantine
    count stay the primary's — both attempts scanned the same windows, so
    counting the backup's quarantined windows again would double-count.
    """
    return a._replace(state=merge_states(a.state, b.state))


def _merge_ingest_results(a, b):
    """Same rule for ``run_ingest``'s ``(new_tail, IngestResult)`` pairs."""
    tail_a, res_a = a
    _tail_b, res_b = b
    merged = merge_states(
        IncumbentState(ub=res_a.ub, best=res_a.best),
        IncumbentState(ub=res_b.ub, best=res_b.best),
    )
    return tail_a, res_a._replace(ub=merged.ub, best=merged.best)


class HedgedExecutor:
    """Race a straggling attempt on the next-healthiest wrapped executor.

    Wraps N executors behind the same seam (``run_range``, and
    ``run_ingest`` when the wrapped executors are streaming ingest
    executors). Every attempt runs on the healthiest available executor;
    when it takes longer than the hedge delay — explicit ``hedge_delay``,
    or derived as ``threshold × EWMA`` of the fleet's attempt latency —
    the same work is raced on up to ``hedge_max_inflight`` backups and the
    race is adjudicated on the virtual timeline
    (``fault_tolerance.hedge_race``; DESIGN.md §2.9 spells out the
    host-serialized emulation vs a concurrent RPC deployment). Duplicate
    completions merge through the strict-improvement fold
    (``incumbents.merge_states``), so a hedge can never change the answer
    — only the latency.

    Health: one ``WorkerHealth`` (EWMA + circuit breaker) per wrapped
    executor. Routing prefers breaker-ready executors that are not
    straggling (EWMA ≤ ``threshold ×`` the fleet EWMA), in index order —
    deterministic whenever the clock is. A transient failure of the
    *primary* attempt records breaker state and re-raises: retry policy
    belongs to the layer above (``resilient_search``, the supervisor),
    composing instead of duplicating it. Backup failures are absorbed —
    the primary's completed result stands.

    Counters: ``hedges_launched`` / ``hedges_won`` (a backup virtually
    finished first) / ``last_effective_dt`` (the latency a client of the
    race would have seen, which is what callers should feed their own
    monitors). ``clock`` is injectable; with a fake clock every race is
    deterministic in tests.
    """

    def __init__(
        self,
        executors,
        *,
        hedge_delay: float | None = None,
        hedge_max_inflight: int = 2,
        threshold: float = 3.0,
        alpha: float = 0.2,
        breaker_threshold: int = 3,
        breaker_cooldown: float = 1.0,
        clock=time.time,
    ):
        self._executors = tuple(executors)
        if not self._executors:
            raise guards.SearchInputError(
                "HedgedExecutor needs at least one executor"
            )
        if hedge_max_inflight < 1:
            raise guards.SearchInputError("hedge_max_inflight must be >= 1")
        self.hedge_delay = hedge_delay
        self.hedge_max_inflight = int(hedge_max_inflight)
        self._clock = clock
        self.monitor = StragglerMonitor(threshold=threshold, alpha=alpha)
        self.health = tuple(
            WorkerHealth(
                threshold=threshold, alpha=alpha,
                breaker_threshold=breaker_threshold,
                breaker_cooldown=breaker_cooldown, clock=clock,
            )
            for _ in self._executors
        )
        self.hedges_launched = 0
        self.hedges_won = 0
        self.last_effective_dt: float | None = None
        self._steps = 0

    # -- routing ----------------------------------------------------------
    def _order(self) -> list[int]:
        """Executor indices, healthiest first: breaker-ready before open,
        non-straggling before straggling, index order as the tiebreak."""
        fleet = self.monitor.ewma

        def key(i: int):
            h = self.health[i]
            slow = (
                h.ewma is not None
                and fleet is not None
                and h.ewma > self.monitor.threshold * fleet
            )
            return (0 if h.ready() else 1, 1 if slow else 0, i)

        return sorted(range(len(self._executors)), key=key)

    def _delay(self) -> float | None:
        if self.hedge_delay is not None:
            return self.hedge_delay
        if self.monitor.ewma is None:
            return None  # no baseline yet: never hedge the first attempt
        return self.monitor.threshold * self.monitor.ewma

    def health_snapshots(self) -> tuple:
        return tuple(h.snapshot() for h in self.health)

    # -- the race ---------------------------------------------------------
    def _attempt(self, method: str, args, kwargs, merge):
        primary = self._order()[0]
        self.health[primary].acquire()
        t0 = self._clock()
        try:
            result = getattr(self._executors[primary], method)(
                *args, **kwargs
            )
        except GUARD_ERRORS:
            raise
        except TRANSIENT:
            self.health[primary].fail()
            raise
        dt_p = self._clock() - t0
        delay = self._delay()  # pre-observe: the baseline excludes this dt
        self.health[primary].observe(dt_p)
        effective = dt_p
        if delay is not None and dt_p > delay and len(self._executors) > 1:
            used = {primary}

            def backups():
                while True:
                    cands = [
                        i for i in self._order()
                        if i not in used and self.health[i].ready()
                    ]
                    if not cands:
                        return
                    i = cands[0]
                    used.add(i)

                    def thunk(i=i):
                        self.health[i].acquire()
                        return getattr(self._executors[i], method)(
                            *args, **kwargs
                        )

                    yield i, thunk

            race = hedge_race(
                dt_p, delay, backups(), clock=self._clock,
                max_inflight=self.hedge_max_inflight,
                on_failure=lambda tag, _e: self.health[tag].fail(),
            )
            self.hedges_launched += race.launched
            if race.won:
                self.hedges_won += 1
            for tag, res_b, dt_b in race.completions:
                self.health[tag].observe(dt_b)
                result = merge(result, res_b)
            effective = race.effective_dt
        self.monitor.observe(self._steps, effective)
        self._steps += 1
        self.last_effective_dt = effective
        return result

    # -- the seam ---------------------------------------------------------
    def run_range(
        self, plan: SearchPlan, state: IncumbentState, lo: int, hi: int
    ) -> RangeResult:
        return self._attempt(
            "run_range", (plan, state, lo, hi), {}, _merge_range_results
        )

    def run_ingest(self, *args, **kwargs):
        """Forward one streaming ingest through the race (duck-typed: the
        wrapped executors must expose ``run_ingest``, e.g.
        ``search.streaming.StreamIngestExecutor``)."""
        return self._attempt(
            "run_ingest", args, kwargs, _merge_ingest_results
        )
