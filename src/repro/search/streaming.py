"""Streaming similarity search: per-chunk incremental ingest primitives.

The offline drivers (``subsequence_search`` / ``multi_query_search``) see the
whole reference at once. A stream delivers it in chunks, and recomputing the
O(N) stats + cascade per chunk throws away everything the previous chunks
taught us. This module is the incremental core the serving front-end
(``serve/stream.py``) drives, one jitted dispatch per ingest:

  * **Boundary-local window stats** — one ``znorm.window_stats`` prefix-sum
    pass over the ``length - 1`` carried tail plus the new chunk yields the
    mu/sigma table of exactly the windows that become valid with this
    chunk, in O(chunk) work (the appendable form ``append_window_stats``
    wraps the same pass for callers that also want the carried tail). The
    ``length - 1`` windows straddling the tail/chunk boundary are
    first-class: they appear in the ingest in which their last sample
    arrives, so no chunking of the stream can hide a window.

  * **LB cascade over new windows only** — the same LB_Kim/LB_Keogh cascade
    as offline, vmapped over the Q standing queries, but over the O(chunk)
    newly-valid starts instead of all N windows seen so far.

  * **Carried-incumbent EAPrunedDTW rounds** — the paper's tightening trick
    applied *in time*: each query's incumbent ``ub[q]``, carried over from
    every previous chunk, seeds this ingest's best-first rounds through the
    per-lane-``ub`` machinery of ``ea_pruned_dtw_multi_batch``. A stream that
    found a good match early makes every later chunk abandon harder — the
    exact analogue of the UCR suite carrying ``ub`` across candidates, here
    carried across arrival time. Finished-for-this-ingest queries ride along
    as dead lanes (negative-``ub`` sentinel), so Q standing queries cost one
    flattened ``(Q × batch)``-lane dispatch per round regardless of how many
    still have live candidates.

Because every window is scanned exactly once (in the ingest where it becomes
valid) against a monotone non-increasing incumbent, the final per-query
``(distance, start)`` equals the offline search over the concatenated stream
— for *any* chunking. ``tests/test_streaming.py`` pins that parity on both
backends.

Fixed-shape ingest (``pad_to``): the raw form retraces per distinct
``(tail, chunk)`` shape — a ragged final chunk, or any mixed-size schedule,
costs a fresh compile. With ``pad_to`` set, every ingest is canonicalized to
one static shape: the carried tail rides in a right-aligned
``(length - 1,)`` buffer with a dynamic ``tail_len``, the chunk in a
``(pad_to,)`` buffer with a dynamic ``chunk_len``, and the windows that do
not really exist (garbage prefix of the tail buffer, padding suffix of the
chunk buffer) are masked with ``+inf`` lower bounds so they ride the rounds
as dead lanes. One trace then serves the whole stream — start-up, steady
state, and the short final chunk alike.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import guards
from repro.core.backend import resolve_backend
from repro.core.batch import ea_pruned_dtw_multi_batch
from repro.core.common import BIG, DEAD_LANE_UB
from repro.core.lower_bounds import cascade_keogh_cumulative
from repro.search.cascade import cascade_lower_bounds
from repro.search.multi import MULTI_VARIANTS, _round_slicers
from repro.search.znorm import (
    gather_norm_windows,
    sanitize_series,
    window_finite_mask,
    window_stats,
    znorm,
)


class IngestResult(NamedTuple):
    """Per-ingest outcome; ``(Q,)`` arrays over the standing queries except
    ``quarantined``, which is a scalar (windows are query-independent)."""
    ub: jax.Array      # incumbents after this ingest (non-increasing)
    best: jax.Array    # stream-coordinate start of each best-so-far (-1: none)
    rounds: jax.Array  # batch rounds spent on this ingest
    lanes: jax.Array   # candidate lanes submitted this ingest
    quarantined: jax.Array  # newly-valid windows excluded by the quarantine


def _ingest_core(
    ctx,
    valid,
    queries_n,
    u,
    low,
    ub0,
    best0,
    offset0,
    *,
    length,
    window,
    variant,
    batch,
    band_width,
    chunk_lb,
    quarantine,
    knobs,
):
    """Shared cascade + carried-ub round loop over the windows of ``ctx``.

    ``valid`` masks which of the ``len(ctx) - length + 1`` window starts
    really exist — all of them on the raw path; the fixed-shape path masks
    the tail-buffer garbage prefix and the chunk-buffer padding suffix.
    Invalid windows get ``+inf`` lower bounds and ride the rounds as dead
    lanes. ``offset0`` is the stream coordinate of ``ctx[0]`` (may be
    negative on the fixed-shape path while the tail buffer is not yet
    full — only invalid starts map below zero).

    With ``quarantine`` (DESIGN.md §2.6), windows overlapping a non-finite
    sample join the invalid set — same dead-lane machinery, and the count of
    *newly-valid* windows so excluded is reported. ``ctx`` is zero-filled at
    the bad samples afterwards so the shared prefix sums stay finite for the
    surviving windows; the caller's carried tail keeps the *raw* samples, so
    boundary-straddling windows of the next ingest are condemned too.
    """
    assert variant in MULTI_VARIANTS, variant
    use_lb = variant != "eapruned_nolb"
    use_cb = variant == "eapruned"
    nq = queries_n.shape[0]

    k_new = ctx.shape[0] - length + 1
    assert k_new >= 1, "ingest called with no newly-valid windows"

    if quarantine:
        finite_ok = window_finite_mask(ctx, length)
        quarantined = jnp.sum(
            jnp.logical_and(valid, ~finite_ok)
        ).astype(jnp.int32)
        valid = jnp.logical_and(valid, finite_ok)
        ctx = sanitize_series(ctx)
    else:
        quarantined = jnp.asarray(0, jnp.int32)

    mu, sigma = window_stats(ctx, length)

    if use_lb:
        lbs = jax.vmap(
            lambda qn: cascade_lower_bounds(
                ctx, qn, mu, sigma, length, window, chunk=chunk_lb
            )
        )(queries_n)                                   # (Q, k_new)
        lbs = jnp.where(valid[None, :], lbs, jnp.inf)
        order = jnp.argsort(lbs, axis=1)
        lb_sorted = jnp.take_along_axis(lbs, order, axis=1)
    else:
        order = jnp.broadcast_to(jnp.arange(k_new), (nq, k_new))
        lb_sorted = jnp.broadcast_to(
            jnp.where(valid, 0.0, jnp.inf).astype(queries_n.dtype),
            (nq, k_new),
        )

    n_rounds = -(-k_new // batch)
    pad = n_rounds * batch - k_new
    order_p = jnp.concatenate(
        [order, jnp.zeros((nq, pad), order.dtype)], axis=1
    )
    lb_p = jnp.concatenate(
        [lb_sorted, jnp.full((nq, pad), jnp.inf, lb_sorted.dtype)], axis=1
    )

    # The carried incumbent gates round 0 exactly like a warm ``ub_init`` in
    # the offline driver: a query whose best new lower bound cannot beat its
    # incumbent skips this ingest entirely.
    active0 = jnp.ones((nq,), bool)
    if use_lb:
        active0 = lb_p[:, 0] < ub0

    slice_round, peek_lb = _round_slicers(batch)

    class St(NamedTuple):
        r: jax.Array        # (Q,) per-query round pointer
        ub: jax.Array       # (Q,) carried incumbents
        best: jax.Array     # (Q,) stream-coordinate best starts
        active: jax.Array   # (Q,)
        lanes: jax.Array    # (Q,)

    def cond(st: St) -> jax.Array:
        return jnp.any(st.active)

    def body(st: St) -> St:
        starts = slice_round(order_p, st.r)            # (Q, batch) local
        lbs_b = slice_round(lb_p, st.r)
        cand = jax.vmap(
            lambda s: gather_norm_windows(ctx, s, length, mu, sigma)
        )(starts)
        cb = None
        if use_cb:
            cb = jax.vmap(cascade_keogh_cumulative)(cand, u, low)
        lane_live = jnp.logical_and(st.active[:, None], lbs_b < st.ub[:, None])
        ub_lanes = jnp.where(
            lane_live,
            jnp.broadcast_to(st.ub[:, None], (nq, batch)),
            DEAD_LANE_UB,
        )
        d = ea_pruned_dtw_multi_batch(
            queries_n, cand, ub_lanes, window=window,
            band_width=band_width, cb=cb, **knobs,
        )
        d = jnp.where(jnp.isfinite(lbs_b), d, jnp.inf)  # padding lanes
        d = jnp.where(st.active[:, None], d, jnp.inf)
        k = jnp.argmin(d, axis=1)
        dmin = jnp.take_along_axis(d, k[:, None], axis=1)[:, 0]
        improved = dmin < st.ub
        ub_new = jnp.where(improved, dmin, st.ub)
        starts_k = jnp.take_along_axis(starts, k[:, None], axis=1)[:, 0]
        best_new = jnp.where(
            improved, offset0 + starts_k.astype(st.best.dtype), st.best
        )
        r_new = st.r + st.active.astype(st.r.dtype)
        more = r_new < n_rounds
        if use_lb:
            nxt = peek_lb(lb_p, jnp.minimum(r_new, n_rounds - 1))
            more = jnp.logical_and(more, nxt < ub_new)
        return St(
            r=r_new,
            ub=ub_new,
            best=best_new,
            active=jnp.logical_and(st.active, more),
            lanes=st.lanes + st.active.astype(st.lanes.dtype) * batch,
        )

    st0 = St(
        r=jnp.zeros((nq,), jnp.int32),
        ub=ub0,
        best=best0,
        active=active0,
        lanes=jnp.zeros((nq,), jnp.int32),
    )
    st = jax.lax.while_loop(cond, body, st0)
    return IngestResult(
        ub=st.ub, best=st.best, rounds=st.r, lanes=st.lanes,
        quarantined=quarantined,
    )


_INGEST_STATICS = (
    "length", "window", "variant", "batch", "band_width", "chunk_lb",
    "backend", "rows_per_step", "block_k", "row_block", "quarantine",
)


@partial(jax.jit, static_argnames=_INGEST_STATICS)
def _ingest_impl(
    tail,
    chunk,
    queries_n,
    u,
    low,
    ub0,
    best0,
    offset,
    length,
    window,
    variant,
    batch,
    band_width,
    chunk_lb,
    backend,
    rows_per_step,
    block_k,
    row_block,
    quarantine,
):
    """One raw-shape ingest: stats + cascade + carried-ub rounds, jitted.

    ``tail`` is the carried ``length - 1`` boundary context, ``offset`` the
    stream coordinate of ``tail[0]`` (so local window start ``s`` in the
    context maps to stream start ``offset + s``). Retraces per distinct
    (tail, chunk) shape — a fixed chunk size settles into one trace, but a
    ragged final chunk costs a fresh compile; see ``pad_to`` on
    ``ingest_chunk`` for the fixed-shape form that never retraces.
    """
    knobs = dict(
        rows_per_step=rows_per_step, backend=backend, block_k=block_k,
        row_block=row_block,
    )
    ctx = jnp.concatenate([tail, chunk])
    keep = min(ctx.shape[0], length - 1)
    new_tail = ctx[ctx.shape[0] - keep :]
    k_new = ctx.shape[0] - length + 1
    res = _ingest_core(
        ctx, jnp.ones((k_new,), bool), queries_n, u, low, ub0, best0, offset,
        length=length, window=window, variant=variant, batch=batch,
        band_width=band_width, chunk_lb=chunk_lb, quarantine=quarantine,
        knobs=knobs,
    )
    return new_tail, res


@partial(jax.jit, static_argnames=_INGEST_STATICS)
def _ingest_impl_padded(
    tail_buf,
    tail_len,
    chunk_buf,
    chunk_len,
    queries_n,
    u,
    low,
    ub0,
    best0,
    offset0,
    length,
    window,
    variant,
    batch,
    band_width,
    chunk_lb,
    backend,
    rows_per_step,
    block_k,
    row_block,
    quarantine,
):
    """Fixed-shape ingest: one trace for any mix of real chunk lengths.

    ``tail_buf`` is a ``(length - 1,)`` buffer whose *last* ``tail_len``
    entries are the real carried samples (right-aligned so the real region
    ``[length - 1 - tail_len, length - 1 + chunk_len)`` of the concatenated
    context is contiguous); ``chunk_buf`` is a ``(pad_to,)`` buffer whose
    first ``chunk_len`` entries are the real chunk. ``tail_len``/
    ``chunk_len`` are *dynamic* scalars — shapes never change, so mixed
    chunk sizes (start-up, steady state, ragged final chunk) reuse one
    compiled program. Windows touching buffer padding are masked invalid.
    """
    knobs = dict(
        rows_per_step=rows_per_step, backend=backend, block_k=block_k,
        row_block=row_block,
    )
    ctx = jnp.concatenate([tail_buf, chunk_buf])
    k_buf = ctx.shape[0] - length + 1
    starts = jnp.arange(k_buf)
    lo = (length - 1) - tail_len
    valid = jnp.logical_and(
        starts >= lo, starts + length <= (length - 1) + chunk_len
    )
    return _ingest_core(
        ctx, valid, queries_n, u, low, ub0, best0, offset0,
        length=length, window=window, variant=variant, batch=batch,
        band_width=band_width, chunk_lb=chunk_lb, quarantine=quarantine,
        knobs=knobs,
    )


def ingest_chunk(
    tail: jax.Array,
    chunk: jax.Array,
    queries_n: jax.Array,
    u: jax.Array,
    low: jax.Array,
    ub: jax.Array,
    best: jax.Array,
    offset,
    length: int,
    window: int,
    variant: str = "eapruned",
    batch: int = 64,
    band_width: int | None = None,
    chunk_lb: int = 4096,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
    pad_to: int | None = None,
    quarantine: bool = True,
    chunk_index: int | None = None,
) -> tuple[jax.Array, IngestResult]:
    """Advance Q standing queries over one stream chunk.

    Functional core of ``serve.stream.StreamSearchEngine`` (which owns the
    state threading and ring buffer — use it unless you are building your
    own driver). ``backend`` is resolved here, in the un-jitted wrapper, so
    ``$REPRO_DTW_BACKEND`` is re-read on every ingest. ``tail``/``chunk`` are raw stream samples;
    ``queries_n``/``u``/``low`` the z-normalized queries and their envelopes
    (fixed for the stream's lifetime); ``ub``/``best`` the carried per-query
    incumbents; ``offset`` the stream coordinate of ``tail[0]``. A call with
    ``len(tail) + len(chunk) < length`` (no newly-valid window yet) is a
    cheap no-op: the tail is extended and the incumbents come back
    unchanged, with zero rounds/lanes — so a driver can feed arbitrarily
    small start-up chunks without special-casing.

    ``pad_to`` selects the fixed-shape form: the tail and chunk are packed
    into static ``(length - 1,)`` / ``(pad_to,)`` buffers with dynamic
    lengths, so *every* ingest of the stream — regardless of the real chunk
    size (``<= pad_to``) — reuses one compiled trace. ``None`` keeps the
    raw-shape form (one trace per distinct shape).

    ``quarantine`` (default on) excludes windows overlapping non-finite
    samples and reports the count in ``IngestResult.quarantined``
    (DESIGN.md §2.6). State-shape violations raise
    ``core.guards.StreamStateError`` with the stream position; malformed
    arrays raise ``SearchInputError`` before any device work.

    Returns ``(new_tail, IngestResult)``; feed ``new_tail`` and the updated
    incumbents into the next call.
    """
    guards.ensure_series(chunk, "chunk", ndim=1)
    guards.ensure_series(tail, "tail", ndim=1)
    t = int(tail.shape[0])
    c = int(chunk.shape[0])
    if t + c < length:
        # Zero newly-valid windows: extend the tail, touch nothing else.
        new_tail = jnp.concatenate([jnp.asarray(tail), jnp.asarray(chunk)])
        nq = queries_n.shape[0]
        zq = jnp.zeros((nq,), jnp.int32)
        return new_tail, IngestResult(
            ub=jnp.asarray(ub), best=jnp.asarray(best), rounds=zq, lanes=zq,
            quarantined=jnp.asarray(0, jnp.int32),
        )
    if pad_to is None:
        return _ingest_impl(
            tail, chunk, queries_n, u, low, ub, best, offset,
            length=length, window=window, variant=variant, batch=batch,
            band_width=band_width, chunk_lb=chunk_lb,
            backend=resolve_backend(backend),
            rows_per_step=rows_per_step, block_k=block_k, row_block=row_block,
            quarantine=quarantine,
        )
    if c > pad_to:
        raise guards.StreamStateError(
            f"chunk length {c} > pad_to {pad_to}; split the chunk before "
            "ingesting (the fixed-shape trace cannot grow)",
            n_seen=offset + t, chunk_index=chunk_index,
        )
    if t > length - 1:
        raise guards.StreamStateError(
            f"carried tail length {t} overflows length - 1 = {length - 1}; "
            "the stream state is corrupt (tail must never outgrow the "
            "boundary context)",
            n_seen=offset + t, chunk_index=chunk_index,
        )
    dt = chunk.dtype
    tail_buf = jnp.concatenate(
        [jnp.zeros((length - 1 - t,), dt), jnp.asarray(tail, dt)]
    )
    chunk_buf = jnp.concatenate([chunk, jnp.zeros((pad_to - c,), dt)])
    res = _ingest_impl_padded(
        tail_buf, jnp.asarray(t, jnp.int32), chunk_buf,
        jnp.asarray(c, jnp.int32), queries_n, u, low, ub, best,
        offset - (length - 1 - t),  # stream coordinate of tail_buf[0]
        length=length, window=window, variant=variant, batch=batch,
        band_width=band_width, chunk_lb=chunk_lb,
        backend=resolve_backend(backend),
        rows_per_step=rows_per_step, block_k=block_k, row_block=row_block,
        quarantine=quarantine,
    )
    keep = min(t + c, length - 1)
    new_tail = jnp.concatenate([jnp.asarray(tail, dt), chunk])[t + c - keep :]
    return new_tail, res


_RESCORE_STATICS = (
    "window", "variant", "band_width", "backend", "rows_per_step",
    "block_k", "row_block",
)


@partial(jax.jit, static_argnames=_RESCORE_STATICS)
def _rescore_impl(
    windows,
    starts,
    queries_n,
    u,
    low,
    ub0,
    best0,
    window,
    variant,
    band_width,
    backend,
    rows_per_step,
    block_k,
    row_block,
):
    nq = queries_n.shape[0]
    k = windows.shape[0]
    cand1 = jax.vmap(znorm)(windows)                       # (k, l)
    cand = jnp.broadcast_to(cand1[None], (nq, k, windows.shape[1]))
    cb = None
    if variant == "eapruned":
        cb = jax.vmap(cascade_keogh_cumulative)(cand, u, low)
    ub_lanes = jnp.broadcast_to(ub0[:, None], (nq, k))
    d = ea_pruned_dtw_multi_batch(
        queries_n, cand, ub_lanes, window=window, band_width=band_width,
        cb=cb, rows_per_step=rows_per_step, backend=backend,
        block_k=block_k, row_block=row_block,
    )
    kmin = jnp.argmin(d, axis=1)
    dmin = jnp.take_along_axis(d, kmin[:, None], axis=1)[:, 0]
    improved = dmin < ub0
    ub = jnp.where(improved, dmin, ub0)
    best = jnp.where(improved, starts[kmin].astype(best0.dtype), best0)
    return ub, best


def rescore_windows(
    windows: jax.Array,
    starts: jax.Array,
    queries_n: jax.Array,
    u: jax.Array,
    low: jax.Array,
    ub: jax.Array,
    best: jax.Array,
    *,
    window: int,
    variant: str = "eapruned",
    band_width: int | None = None,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Fold k explicitly-materialized windows into the carried incumbents.

    The re-admission dispatch (DESIGN.md §2.7): when a quarantined window
    becomes finite again after ``StreamSearchEngine.correct`` patches its bad
    samples, its raw samples are handed here as ``windows`` ``(k, length)``
    with ``starts`` ``(k,)`` in stream coordinates. Each window is
    z-normalized directly (same normalization the prefix-sum stats would
    have produced had the samples arrived clean) and scored against all Q
    standing queries through the same per-lane-``ub`` multi-query batch the
    ingest rounds use — the carried incumbents seed the abandon threshold,
    so an already-good incumbent makes re-admitted windows cheap.

    Returns the updated ``(ub, best)``; strict improvement only, like every
    other incumbent fold.
    """
    guards.ensure_series(windows, "windows", ndim=2)
    if variant not in MULTI_VARIANTS:
        raise guards.SearchInputError(
            f"variant must be one of {MULTI_VARIANTS}"
        )
    return _rescore_impl(
        jnp.asarray(windows), jnp.asarray(starts, jnp.int32),
        queries_n, u, low, jnp.asarray(ub), jnp.asarray(best),
        window=window, variant=variant, band_width=band_width,
        backend=resolve_backend(backend), rows_per_step=rows_per_step,
        block_k=block_k, row_block=row_block,
    )


def initial_incumbents(
    nq: int, dtype=jnp.float32, ub_init=None
) -> tuple[jax.Array, jax.Array]:
    """Fresh ``(ub, best)`` incumbent vectors for Q standing queries.

    ``ub_init`` optionally seeds the incumbents (scalar or ``(Q,)``) — the
    cross-stream analogue of ``multi_query_search``'s warm seeds.
    """
    if ub_init is None:
        ub = jnp.full((nq,), BIG, dtype)
    else:
        ub = jnp.broadcast_to(jnp.asarray(ub_init, dtype), (nq,))
    return ub, jnp.full((nq,), -1, jnp.int32)
