"""Streaming similarity search: per-chunk incremental ingest primitives.

The offline drivers (``subsequence_search`` / ``multi_query_search``) see the
whole reference at once. A stream delivers it in chunks, and recomputing the
O(N) stats + cascade per chunk throws away everything the previous chunks
taught us. This module is the incremental *frontend* the serving layer
(``serve/stream.py``) drives: it owns the buffering — the carried
``length - 1`` boundary tail, the fixed-shape padding, the stream-coordinate
offsets — and hands each ingest's context to the shared pipeline stage
program (``search.pipeline.run_stream_ingest``: prepare → cascade →
carried-incumbent host rounds), one jitted dispatch per ingest:

  * **Boundary-local window stats** — one prefix-sum pass over the
    ``length - 1`` carried tail plus the new chunk yields the mu/sigma table
    of exactly the windows that become valid with this chunk, in O(chunk)
    work. The ``length - 1`` windows straddling the tail/chunk boundary are
    first-class: they appear in the ingest in which their last sample
    arrives, so no chunking of the stream can hide a window.

  * **LB cascade over new windows only** — the same LB_Kim/LB_Keogh cascade
    as offline, vmapped over the Q standing queries, but over the O(chunk)
    newly-valid starts instead of all N windows seen so far.

  * **Carried-incumbent EAPrunedDTW rounds** — the paper's tightening trick
    applied *in time*: each query's incumbent ``ub[q]``, carried over from
    every previous chunk, seeds this ingest's best-first rounds through the
    per-lane-``ub`` machinery of ``ea_pruned_dtw_multi_batch``. A stream that
    found a good match early makes every later chunk abandon harder — the
    exact analogue of the UCR suite carrying ``ub`` across candidates, here
    carried across arrival time. Finished-for-this-ingest queries ride along
    as dead lanes (negative-``ub`` sentinel), so Q standing queries cost one
    flattened ``(Q × batch)``-lane dispatch per round regardless of how many
    still have live candidates.

Because every window is scanned exactly once (in the ingest where it becomes
valid) against a monotone non-increasing incumbent, the final per-query
``(distance, start)`` equals the offline search over the concatenated stream
— for *any* chunking. ``tests/test_streaming.py`` pins that parity on both
backends.

Fixed-shape ingest (``pad_to``): the raw form retraces per distinct
``(tail, chunk)`` shape — a ragged final chunk, or any mixed-size schedule,
costs a fresh compile. With ``pad_to`` set, every ingest is canonicalized to
one static shape: the carried tail rides in a right-aligned
``(length - 1,)`` buffer with a dynamic ``tail_len``, the chunk in a
``(pad_to,)`` buffer with a dynamic ``chunk_len``, and the windows that do
not really exist (garbage prefix of the tail buffer, padding suffix of the
chunk buffer) are masked with ``+inf`` lower bounds so they ride the rounds
as dead lanes. One trace then serves the whole stream — start-up, steady
state, and the short final chunk alike.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import guards
from repro.core.backend import resolve_backend
from repro.core.batch import ea_pruned_dtw_multi_batch
from repro.core.lower_bounds import cascade_keogh_cumulative
from repro.search.incumbents import IncumbentState, fold_min, initial_state
from repro.search.pipeline import (
    MULTI_VARIANTS,
    PreparedQueries,
    SearchPlan,
    run_stream_ingest,
)
from repro.search.znorm import znorm


class IngestResult(NamedTuple):
    """Per-ingest outcome; ``(Q,)`` arrays over the standing queries except
    ``quarantined``, which is a scalar (windows are query-independent)."""
    ub: jax.Array      # incumbents after this ingest (non-increasing)
    best: jax.Array    # stream-coordinate start of each best-so-far (-1: none)
    rounds: jax.Array  # batch rounds spent on this ingest
    lanes: jax.Array   # candidate lanes submitted this ingest
    quarantined: jax.Array  # newly-valid windows excluded by the quarantine


_INGEST_STATICS = (
    "length", "window", "variant", "batch", "band_width", "chunk_lb",
    "backend", "rows_per_step", "block_k", "row_block", "quarantine",
    "gather", "slab_budget",
)


def _ingest_plan(
    length, window, variant, batch, band_width, chunk_lb, backend,
    rows_per_step, block_k, row_block, quarantine, gather, slab_budget,
) -> SearchPlan:
    """Static ingest knobs → the pipeline plan (backend already concrete)."""
    return SearchPlan(
        length=length, window=window, variant=variant, batch=batch,
        band_width=band_width, chunk=chunk_lb, backend=backend,
        rows_per_step=rows_per_step, block_k=block_k, row_block=row_block,
        rounds="host", quarantine=quarantine, warm_start=0,
        gather=gather, slab_budget=slab_budget,
    )


@partial(jax.jit, static_argnames=_INGEST_STATICS)
def _ingest_impl(
    tail,
    chunk,
    queries_n,
    u,
    low,
    ub0,
    best0,
    offset,
    length,
    window,
    variant,
    batch,
    band_width,
    chunk_lb,
    backend,
    rows_per_step,
    block_k,
    row_block,
    quarantine,
    gather,
    slab_budget,
):
    """One raw-shape ingest: stats + cascade + carried-ub rounds, jitted.

    ``tail`` is the carried ``length - 1`` boundary context, ``offset`` the
    stream coordinate of ``tail[0]`` (so local window start ``s`` in the
    context maps to stream start ``offset + s``). Retraces per distinct
    (tail, chunk) shape — a fixed chunk size settles into one trace, but a
    ragged final chunk costs a fresh compile; see ``pad_to`` on
    ``ingest_chunk`` for the fixed-shape form that never retraces.
    """
    plan = _ingest_plan(
        length, window, variant, batch, band_width, chunk_lb, backend,
        rows_per_step, block_k, row_block, quarantine, gather, slab_budget,
    )
    ctx = jnp.concatenate([tail, chunk])
    keep = min(ctx.shape[0], length - 1)
    new_tail = ctx[ctx.shape[0] - keep :]
    k_new = ctx.shape[0] - length + 1
    state, stats, n_quar = run_stream_ingest(
        plan, ctx, jnp.ones((k_new,), bool),
        PreparedQueries(qn=queries_n, u=u, low=low),
        IncumbentState(ub=ub0, best=best0), offset,
    )
    return new_tail, IngestResult(
        ub=state.ub, best=state.best, rounds=stats.rounds, lanes=stats.lanes,
        quarantined=n_quar,
    )


@partial(jax.jit, static_argnames=_INGEST_STATICS)
def _ingest_impl_padded(
    tail_buf,
    tail_len,
    chunk_buf,
    chunk_len,
    queries_n,
    u,
    low,
    ub0,
    best0,
    offset0,
    length,
    window,
    variant,
    batch,
    band_width,
    chunk_lb,
    backend,
    rows_per_step,
    block_k,
    row_block,
    quarantine,
    gather,
    slab_budget,
):
    """Fixed-shape ingest: one trace for any mix of real chunk lengths.

    ``tail_buf`` is a ``(length - 1,)`` buffer whose *last* ``tail_len``
    entries are the real carried samples (right-aligned so the real region
    ``[length - 1 - tail_len, length - 1 + chunk_len)`` of the concatenated
    context is contiguous); ``chunk_buf`` is a ``(pad_to,)`` buffer whose
    first ``chunk_len`` entries are the real chunk. ``tail_len``/
    ``chunk_len`` are *dynamic* scalars — shapes never change, so mixed
    chunk sizes (start-up, steady state, ragged final chunk) reuse one
    compiled program. Windows touching buffer padding are masked invalid.
    """
    plan = _ingest_plan(
        length, window, variant, batch, band_width, chunk_lb, backend,
        rows_per_step, block_k, row_block, quarantine, gather, slab_budget,
    )
    ctx = jnp.concatenate([tail_buf, chunk_buf])
    k_buf = ctx.shape[0] - length + 1
    starts = jnp.arange(k_buf)
    lo = (length - 1) - tail_len
    valid = jnp.logical_and(
        starts >= lo, starts + length <= (length - 1) + chunk_len
    )
    state, stats, n_quar = run_stream_ingest(
        plan, ctx, valid, PreparedQueries(qn=queries_n, u=u, low=low),
        IncumbentState(ub=ub0, best=best0), offset0,
    )
    return IngestResult(
        ub=state.ub, best=state.best, rounds=stats.rounds, lanes=stats.lanes,
        quarantined=n_quar,
    )


def ingest_chunk(
    tail: jax.Array,
    chunk: jax.Array,
    queries_n: jax.Array,
    u: jax.Array,
    low: jax.Array,
    ub: jax.Array,
    best: jax.Array,
    offset,
    length: int,
    window: int,
    variant: str = "eapruned",
    batch: int = 64,
    band_width: int | None = None,
    chunk_lb: int = 4096,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
    pad_to: int | None = None,
    quarantine: bool = True,
    chunk_index: int | None = None,
    gather: str = "fused",
    slab_budget: int | None = None,
) -> tuple[jax.Array, IngestResult]:
    """Advance Q standing queries over one stream chunk.

    Functional core of ``serve.stream.StreamSearchEngine`` (which owns the
    state threading and ring buffer — use it unless you are building your
    own driver). ``backend`` is resolved here, in the un-jitted wrapper, so
    ``$REPRO_DTW_BACKEND`` is re-read on every ingest. ``tail``/``chunk`` are raw stream samples;
    ``queries_n``/``u``/``low`` the z-normalized queries and their envelopes
    (fixed for the stream's lifetime); ``ub``/``best`` the carried per-query
    incumbents; ``offset`` the stream coordinate of ``tail[0]``. A call with
    ``len(tail) + len(chunk) < length`` (no newly-valid window yet) is a
    cheap no-op: the tail is extended and the incumbents come back
    unchanged, with zero rounds/lanes — so a driver can feed arbitrarily
    small start-up chunks without special-casing.

    ``pad_to`` selects the fixed-shape form: the tail and chunk are packed
    into static ``(length - 1,)`` / ``(pad_to,)`` buffers with dynamic
    lengths, so *every* ingest of the stream — regardless of the real chunk
    size (``<= pad_to``) — reuses one compiled trace. ``None`` keeps the
    raw-shape form (one trace per distinct shape).

    ``quarantine`` (default on) excludes windows overlapping non-finite
    samples and reports the count in ``IngestResult.quarantined``
    (DESIGN.md §2.6). State-shape violations raise
    ``core.guards.StreamStateError`` with the stream position; malformed
    arrays raise ``SearchInputError`` before any device work.

    Returns ``(new_tail, IngestResult)``; feed ``new_tail`` and the updated
    incumbents into the next call.
    """
    guards.ensure_series(chunk, "chunk", ndim=1)
    guards.ensure_series(tail, "tail", ndim=1)
    t = int(tail.shape[0])
    c = int(chunk.shape[0])
    if t + c < length:
        # Zero newly-valid windows: extend the tail, touch nothing else.
        new_tail = jnp.concatenate([jnp.asarray(tail), jnp.asarray(chunk)])
        nq = queries_n.shape[0]
        zq = jnp.zeros((nq,), jnp.int32)
        return new_tail, IngestResult(
            ub=jnp.asarray(ub), best=jnp.asarray(best), rounds=zq, lanes=zq,
            quarantined=jnp.asarray(0, jnp.int32),
        )
    if pad_to is None:
        return _ingest_impl(
            tail, chunk, queries_n, u, low, ub, best, offset,
            length=length, window=window, variant=variant, batch=batch,
            band_width=band_width, chunk_lb=chunk_lb,
            backend=resolve_backend(backend),
            rows_per_step=rows_per_step, block_k=block_k, row_block=row_block,
            quarantine=quarantine, gather=gather, slab_budget=slab_budget,
        )
    if c > pad_to:
        raise guards.StreamStateError(
            f"chunk length {c} > pad_to {pad_to}; split the chunk before "
            "ingesting (the fixed-shape trace cannot grow)",
            n_seen=offset + t, chunk_index=chunk_index,
        )
    if t > length - 1:
        raise guards.StreamStateError(
            f"carried tail length {t} overflows length - 1 = {length - 1}; "
            "the stream state is corrupt (tail must never outgrow the "
            "boundary context)",
            n_seen=offset + t, chunk_index=chunk_index,
        )
    dt = chunk.dtype
    tail_buf = jnp.concatenate(
        [jnp.zeros((length - 1 - t,), dt), jnp.asarray(tail, dt)]
    )
    chunk_buf = jnp.concatenate([chunk, jnp.zeros((pad_to - c,), dt)])
    res = _ingest_impl_padded(
        tail_buf, jnp.asarray(t, jnp.int32), chunk_buf,
        jnp.asarray(c, jnp.int32), queries_n, u, low, ub, best,
        offset - (length - 1 - t),  # stream coordinate of tail_buf[0]
        length=length, window=window, variant=variant, batch=batch,
        band_width=band_width, chunk_lb=chunk_lb,
        backend=resolve_backend(backend),
        rows_per_step=rows_per_step, block_k=block_k, row_block=row_block,
        quarantine=quarantine, gather=gather, slab_budget=slab_budget,
    )
    keep = min(t + c, length - 1)
    new_tail = jnp.concatenate([jnp.asarray(tail, dt), chunk])[t + c - keep :]
    return new_tail, res


_RESCORE_STATICS = (
    "window", "variant", "band_width", "backend", "rows_per_step",
    "block_k", "row_block",
)


@partial(jax.jit, static_argnames=_RESCORE_STATICS)
def _rescore_impl(
    windows,
    starts,
    queries_n,
    u,
    low,
    ub0,
    best0,
    window,
    variant,
    band_width,
    backend,
    rows_per_step,
    block_k,
    row_block,
):
    nq = queries_n.shape[0]
    k = windows.shape[0]
    cand1 = jax.vmap(znorm)(windows)                       # (k, l)
    cand = jnp.broadcast_to(cand1[None], (nq, k, windows.shape[1]))
    cb = None
    if variant == "eapruned":
        cb = jax.vmap(cascade_keogh_cumulative)(cand, u, low)
    ub_lanes = jnp.broadcast_to(ub0[:, None], (nq, k))
    d = ea_pruned_dtw_multi_batch(
        queries_n, cand, ub_lanes, window=window, band_width=band_width,
        cb=cb, rows_per_step=rows_per_step, backend=backend,
        block_k=block_k, row_block=row_block,
    )
    state, _ = fold_min(
        IncumbentState(ub=ub0, best=best0),
        jnp.broadcast_to(starts[None], (nq, k)), d,
    )
    return state.ub, state.best


def rescore_windows(
    windows: jax.Array,
    starts: jax.Array,
    queries_n: jax.Array,
    u: jax.Array,
    low: jax.Array,
    ub: jax.Array,
    best: jax.Array,
    *,
    window: int,
    variant: str = "eapruned",
    band_width: int | None = None,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Fold k explicitly-materialized windows into the carried incumbents.

    The re-admission dispatch (DESIGN.md §2.7): when a quarantined window
    becomes finite again after ``StreamSearchEngine.correct`` patches its bad
    samples, its raw samples are handed here as ``windows`` ``(k, length)``
    with ``starts`` ``(k,)`` in stream coordinates. Each window is
    z-normalized directly (same normalization the prefix-sum stats would
    have produced had the samples arrived clean) and scored against all Q
    standing queries through the same per-lane-``ub`` multi-query batch the
    ingest rounds use — the carried incumbents seed the abandon threshold,
    so an already-good incumbent makes re-admitted windows cheap.

    Returns the updated ``(ub, best)``; strict improvement only
    (``incumbents.fold_min``), like every other incumbent fold.
    """
    guards.ensure_series(windows, "windows", ndim=2)
    if variant not in MULTI_VARIANTS:
        raise guards.SearchInputError(
            f"variant must be one of {MULTI_VARIANTS}"
        )
    return _rescore_impl(
        jnp.asarray(windows), jnp.asarray(starts, jnp.int32),
        queries_n, u, low, jnp.asarray(ub), jnp.asarray(best),
        window=window, variant=variant, band_width=band_width,
        backend=resolve_backend(backend), rows_per_step=rows_per_step,
        block_k=block_k, row_block=row_block,
    )


def initial_incumbents(
    nq: int, dtype=jnp.float32, ub_init=None
) -> tuple[jax.Array, jax.Array]:
    """Fresh ``(ub, best)`` incumbent vectors for Q standing queries.

    ``ub_init`` optionally seeds the incumbents (scalar or ``(Q,)``) — the
    cross-stream analogue of ``multi_query_search``'s warm seeds. Tuple form
    of ``incumbents.initial_state`` (kept for serving/checkpoint callers
    that thread ``ub``/``best`` as separate arrays).
    """
    state = initial_state(nq, dtype, ub_init)
    return state.ub, state.best


class StreamIngestExecutor:
    """One stream's ingest dispatch bound as an executor-seam worker.

    The streaming analogue of the offline ``run_range`` executors
    (DESIGN.md §2.8): the per-stream statics (normalized queries,
    envelopes, dispatch knobs) bind once at construction, and each call to
    ``run_ingest`` advances one chunk of carried state. The seam exists so
    ``serve.stream.StreamSearchEngine`` can be pointed at *any* object
    with this method — in particular ``search.pipeline.HedgedExecutor``
    wrapping several of these (DESIGN.md §2.9) — and gain hedging and
    health-aware routing with zero streaming-specific recovery code.

    ``run_ingest`` is a pure function of its arguments (all carried state
    rides in ``tail``/``ub``/``best``/``offset``), which is exactly what
    makes a duplicate hedged call safe: same inputs, same
    ``(new_tail, IngestResult)``, and the strict-improvement merge of a
    duplicate completion is a no-op.
    """

    def __init__(
        self,
        queries_n: jax.Array,
        u: jax.Array,
        low: jax.Array,
        *,
        length: int,
        window: int,
        variant: str = "eapruned",
        batch: int = 64,
        band_width: int | None = None,
        chunk_lb: int = 4096,
        backend: str | None = None,
        rows_per_step: int = 1,
        block_k: int = 8,
        row_block: int = 128,
        quarantine: bool = True,
        gather: str = "fused",
        slab_budget: int | None = None,
    ):
        self.queries_n = queries_n
        self.u = u
        self.low = low
        self.length = int(length)
        self.window = int(window)
        self.variant = variant
        self.batch = int(batch)
        self.band_width = band_width
        self.chunk_lb = int(chunk_lb)
        self.backend = backend
        self.rows_per_step = int(rows_per_step)
        self.block_k = int(block_k)
        self.row_block = int(row_block)
        self.quarantine = bool(quarantine)
        self.gather = gather
        self.slab_budget = None if slab_budget is None else int(slab_budget)

    def run_ingest(
        self,
        tail: jax.Array,
        chunk: jax.Array,
        ub: jax.Array,
        best: jax.Array,
        offset,
        *,
        pad_to: int | None = None,
        chunk_index: int | None = None,
    ) -> tuple[jax.Array, IngestResult]:
        """Advance the carried stream state over one chunk (the seam call)."""
        return ingest_chunk(
            tail, chunk, self.queries_n, self.u, self.low, ub, best, offset,
            length=self.length, window=self.window, variant=self.variant,
            batch=self.batch, band_width=self.band_width,
            chunk_lb=self.chunk_lb, backend=self.backend,
            rows_per_step=self.rows_per_step, block_k=self.block_k,
            row_block=self.row_block, pad_to=pad_to,
            quarantine=self.quarantine, chunk_index=chunk_index,
            gather=self.gather, slab_budget=self.slab_budget,
        )
