"""UCR-suite subsequence similarity search with EAPrunedDTW (single device).

Reproduces the paper's experimental pipeline: given a long reference series R
and a query Q, find the window of R (length = |Q|, z-normalized) with minimum
DTW distance to z-normalized Q, under a warping window.

Four variants, mirroring the paper's four suites (§5):

  ``full``           — UCR:      LB cascade + exact DTW (no in-DTW pruning)
  ``pruned``         — UCR-USP:  LB cascade + PrunedDTW (row-min abandon)
  ``eapruned``       — UCR-MON:  LB cascade + EAPrunedDTW + cb tightening
  ``eapruned_nolb``  — UCR-MON-nolb: EAPrunedDTW only, natural order

The search is one jitted program: cascade → best-first batches inside a
``lax.while_loop`` that stops when the next batch's smallest lower bound can
no longer beat the incumbent (``ub``). Batches share ``ub`` (DESIGN.md §2.4).

Round drivers (``rounds=``, DESIGN.md §2.5): the default ``"host"`` driver
loops best-first batches around the batch primitive as above — one dispatch
and one incumbent update per round, every lane of a round abandoning against
the round-entry ``ub``. ``rounds="persistent"`` collapses the sweep into a
*single* dispatch: all candidate windows are gathered/normalized once in
best-first order and handed to ``core.batch.ea_pruned_dtw_persistent``,
which carries the incumbent across ``block_k``-lane candidate blocks inside
the launch (SMEM scratch on the Pallas backend, one while_loop on the jax
backend) and skips LB-gated blocks on device. Same ``best_start``, and
``best_dist`` equal up to the O(1)-ulp reformulation rounding documented in
``core.ea_pruned_dtw`` (a tighter mid-sweep incumbent masks a different set
of *suboptimal* float paths inside the winner's DP — the same effect as
changing ``batch`` in the host driver; typically bitwise in practice). Two
caveats at that same ulp scale: an *exact* distance tie between candidates
can resolve to the other cominimizer's start, and on the Pallas backend the
in-kernel ``cb`` prologue suffix-sums in tree order while host rounds use a
sequential cumsum — abandon thresholds can differ by an ulp, which only
matters for that same measure-zero tie case (the winner's survival, §2.2 of
DESIGN.md, is independent of ``cb`` rounding). O(1)
dispatches instead of O(rounds); ``ub`` tightens every ``block_k`` lanes
instead of every ``batch``. The trade: the
full window matrix is materialized up front (O(N·l) memory traffic), where
the host driver gathers only the rounds it visits — prefer ``"host"`` when
memory is tight or the LB ordering routinely stops after a round or two.
The ``full``/``pruned`` baselines run the same block-granular sweep as a
jitted loop (their per-lane kernels ignore per-lane thresholds). Persistent
mode is counter-free; combine with ``with_info`` is rejected.

Rounds come in two flavours. The default is the *counter-free fast round*:
distances only, no pruning bookkeeping — the hot path pays nothing for stats
it isn't asked for. ``with_info=True`` switches every round to the *stats
round*, which also accumulates the paper's rows/cells pruning counters into
``SearchResult`` (counter fields are ``-1`` when not collected). The
EAPrunedDTW batches are routed through ``core.batch.ea_pruned_dtw_batch``,
so ``backend=`` (pallas kernel vs banded-vmap JAX) and the tuning knobs
(``rows_per_step``, ``block_k``, ``row_block``, ``band_width``) thread all
the way down; defaults for the paper workload live in
``configs/dtw_search.py``. The backend (and ``$REPRO_DTW_BACKEND``) is
resolved in the un-jitted wrapper on every call, so it is always a concrete
static argument of the jitted program.

Per-lane ``ub`` semantics: the batch primitive underneath accepts one upper
bound *per lane*, not one per batch. This single-query driver always passes
the scalar incumbent (every lane of a round shares it — the PR-1
behaviour), but the semantics it relies on are per-lane: each lane abandons
against its own threshold and a negative threshold kills a lane on row 0.
``search/multi.py`` exploits exactly that to flatten Q queries' rounds into
one ``(Q × batch)`` lane set per dispatch — see its docstring for the
(query × candidate) lane layout.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import guards
from repro.core.backend import resolve_backend
from repro.core.batch import (
    block_sweep,
    ea_pruned_dtw_batch,
    ea_pruned_dtw_persistent,
)
from repro.core.common import BIG, DEAD_LANE_UB, pad_lanes_to_blocks
from repro.core.dtw import dtw
from repro.core.lower_bounds import cascade_keogh_cumulative, envelope
from repro.core.pruned_dtw import pruned_dtw
from repro.search.cascade import cascade_lower_bounds
from repro.search.znorm import (
    gather_norm_windows,
    sanitize_series,
    window_finite_mask,
    window_stats,
    znorm,
)

VARIANTS = ("full", "pruned", "eapruned", "eapruned_nolb")
ROUND_DRIVERS = ("host", "persistent")


class SearchResult(NamedTuple):
    best_start: jax.Array   # window start of the nearest neighbour
    best_dist: jax.Array    # its DTW distance (z-normalized)
    rounds: jax.Array       # batch rounds executed
    lanes: jax.Array        # candidate lanes evaluated (rounds * batch)
    lb_pruned: jax.Array    # candidates never evaluated thanks to LB ordering
    rows: jax.Array         # DTW rows issued across all lanes (-1: fast round)
    cells: jax.Array        # admissible DTW cells across all lanes (-1: fast)
    quarantined: jax.Array  # windows excluded by the non-finite quarantine


def _batch_distances(
    variant, query_n, cand, ub, window, band_width, cb, knobs
):
    """Counter-free fast round: distances only, no pruning bookkeeping."""
    if variant == "eapruned" or variant == "eapruned_nolb":
        return ea_pruned_dtw_batch(
            query_n, cand, ub, window=window, band_width=band_width, cb=cb,
            **knobs,
        )
    if variant == "pruned":
        fn = lambda c: pruned_dtw(query_n, c, ub, window=window)
        return jax.vmap(fn)(cand)
    if variant == "full":
        fn = lambda c: dtw(query_n, c, window=window)
        return jax.vmap(fn)(cand)
    raise ValueError(f"unknown variant {variant!r}")


def _batch_stats(variant, query_n, cand, ub, window, band_width, cb, knobs):
    """Stats round: distances + (rows, cells) pruning counters."""
    if variant in ("eapruned", "eapruned_nolb"):
        d, info = ea_pruned_dtw_batch(
            query_n, cand, ub, window=window, band_width=band_width, cb=cb,
            with_info=True, **knobs,
        )
        return d, jnp.sum(info.rows), jnp.sum(info.cells)
    if variant == "pruned":
        d, info = jax.vmap(
            lambda c: pruned_dtw(query_n, c, ub, window=window, with_info=True)
        )(cand)
        return d, jnp.sum(info.rows), jnp.sum(info.cells)
    d = _batch_distances(variant, query_n, cand, ub, window, band_width, cb, knobs)
    m = query_n.shape[-1]
    k = cand.shape[0]
    # full DTW issues every in-window cell
    win_cells = m * (2 * window + 1) - window * (window + 1)
    return d, jnp.asarray(k * m), jnp.asarray(k * min(win_cells, m * m))


@partial(
    jax.jit,
    static_argnames=(
        "length", "window", "variant", "batch", "band_width", "chunk",
        "with_info", "backend", "rows_per_step", "block_k", "row_block",
        "rounds", "quarantine",
    ),
)
def _subsequence_search_impl(
    ref: jax.Array,
    query: jax.Array,
    length: int,
    window: int,
    variant: str = "eapruned",
    batch: int = 64,
    band_width: int | None = None,
    chunk: int = 4096,
    with_info: bool = False,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
    rounds: str = "host",
    quarantine: bool = True,
) -> SearchResult:
    """Locate the closest z-normalized window of ``ref`` to ``query``.

    Args:
      ref: ``(N,)`` long reference series.
      query: ``(l,)`` raw query (z-normalized internally); ``l == length``.
      length: window/query length (static).
      window: Sakoe-Chiba warping window in samples (static).
      variant: one of ``VARIANTS``.
      batch: candidates per shared-ub round (static; host driver only).
      with_info: collect rows/cells pruning counters (stats rounds). The
        default fast rounds leave ``SearchResult.rows``/``.cells`` at ``-1``.
      backend: DTW batch backend (see ``core.backend``); ``None`` = auto.
      rows_per_step: JAX-backend while_loop rows per iteration.
      block_k, row_block: Pallas-backend grid tiling.
      rounds: ``"host"`` (best-first rounds around the batch primitive) or
        ``"persistent"`` (whole sweep in one dispatch with a block-granular
        carried incumbent — see module docstring).
      quarantine: exclude windows overlapping non-finite reference samples
        (DESIGN.md §2.6); they ride the rounds as dead lanes and are counted
        in ``SearchResult.quarantined``. ``False`` skips the prepass (the
        caller then guarantees a finite reference).
    """
    assert variant in VARIANTS, variant
    assert rounds in ROUND_DRIVERS, rounds
    knobs = dict(
        rows_per_step=rows_per_step, backend=backend, block_k=block_k,
        row_block=row_block,
    )
    ref = jnp.asarray(ref)
    query_n = znorm(jnp.asarray(query)[:length])
    n_win = ref.shape[0] - length + 1
    use_lb = variant != "eapruned_nolb"
    use_cb = variant == "eapruned"

    if quarantine:
        finite_ok = window_finite_mask(ref, length)
        n_quar = jnp.sum(~finite_ok).astype(jnp.int32)
        ref = sanitize_series(ref)
    else:
        finite_ok = None
        n_quar = jnp.asarray(0, jnp.int32)

    mu, sigma = window_stats(ref, length)
    if use_lb:
        lbs = cascade_lower_bounds(
            ref, query_n, mu, sigma, length, window, chunk=chunk
        )
        if quarantine:
            # Quarantined windows get +inf lower bounds: the argsort pushes
            # them behind every live candidate, the cascade stop never
            # reaches them, and any that ride in a partially-live round are
            # dead lanes (the same machinery as round padding).
            lbs = jnp.where(finite_ok, lbs, jnp.inf)
        order = jnp.argsort(lbs)
        lb_sorted = lbs[order]
    elif quarantine:
        # No-cascade variant: natural scan order among surviving windows
        # (stable argsort of the 0/+inf mask), poisoned windows at the back.
        lbs = jnp.where(finite_ok, 0.0, jnp.inf).astype(query_n.dtype)
        order = jnp.argsort(lbs)
        lb_sorted = lbs[order]
    else:
        order = jnp.arange(n_win)
        lb_sorted = jnp.zeros((n_win,), query_n.dtype)

    u, low = envelope(query_n, window)

    if rounds == "persistent":
        assert not with_info, "persistent mode is counter-free"
        # One gather of the whole best-first order; the sweep itself is a
        # single dispatch with the incumbent carried across block_k-lane
        # candidate blocks (core.batch.ea_pruned_dtw_persistent).
        lb_p, order_p, _ = pad_lanes_to_blocks(block_k, lb_sorted, order)
        cand_all = gather_norm_windows(ref, order_p, length, mu, sigma)
        if variant in ("eapruned", "eapruned_nolb"):
            envs = (u[None], low[None]) if use_cb else None
            bd, bs, blocks = ea_pruned_dtw_persistent(
                query_n[None], cand_all[None], lb_p[None], order_p[None],
                jnp.full((1,), BIG, query_n.dtype), window=window,
                band_width=band_width, envelopes=envs, **knobs,
            )
            best, ub, blocks = bs[0], bd[0], blocks[0]
        else:
            # full / pruned baselines: the shared block-granular sweep as a
            # jitted loop (their per-lane kernels take no per-lane
            # threshold, so there is no single-launch kernel form to hand
            # off to; lane masking rides on the lb padding inside the sweep)
            ub, best, blocks = block_sweep(
                cand_all, lb_p, order_p, jnp.asarray(BIG, query_n.dtype),
                block_k,
                lambda c, lbb, ub_cur: _batch_distances(
                    variant, query_n, c, ub_cur, window, band_width, None,
                    knobs,
                ),
            )
        # visited blocks are a best-first prefix, so only the final padded
        # block can hold non-candidates — clamp to the real window count
        lanes = jnp.minimum(blocks * block_k, n_win).astype(jnp.int32)
        no_info = jnp.asarray(-1)
        return SearchResult(
            best_start=best,
            best_dist=ub,
            rounds=jnp.asarray(1),  # dispatches: one launch per search
            lanes=lanes,
            lb_pruned=jnp.asarray(n_win) - lanes,
            rows=no_info,
            cells=no_info,
            quarantined=n_quar,
        )

    n_rounds = -(-n_win // batch)
    pad = n_rounds * batch - n_win
    order_p = jnp.concatenate([order, jnp.zeros((pad,), order.dtype)])
    lb_p = jnp.concatenate([lb_sorted, jnp.full((pad,), jnp.inf, lb_sorted.dtype)])

    class St(NamedTuple):
        r: jax.Array
        ub: jax.Array
        best: jax.Array
        lanes: jax.Array
        rows: jax.Array
        cells: jax.Array

    def cond(st: St) -> jax.Array:
        more = st.r < n_rounds
        if not use_lb:
            return more
        next_lb = jax.lax.dynamic_slice(lb_p, (st.r * batch,), (1,))[0]
        return jnp.logical_and(more, next_lb < st.ub)

    def body(st: St) -> St:
        starts = jax.lax.dynamic_slice(order_p, (st.r * batch,), (batch,))
        lbs = jax.lax.dynamic_slice(lb_p, (st.r * batch,), (batch,))
        cand = gather_norm_windows(ref, starts, length, mu, sigma)
        cb = None
        if use_cb:
            cb = cascade_keogh_cumulative(cand, u, low)
        if variant in ("eapruned", "eapruned_nolb"):
            # Per-lane ub: quarantined and round-padding lanes (both marked
            # by +inf lower bounds) ride as dead lanes — the kernel abandons
            # them on row 0 instead of running a DP over masked garbage.
            ub_b = jnp.where(jnp.isfinite(lbs), st.ub, DEAD_LANE_UB)
        else:
            ub_b = st.ub  # full/pruned kernels take a scalar threshold
        if with_info:
            d, rows, cells = _batch_stats(
                variant, query_n, cand, ub_b, window, band_width, cb, knobs
            )
        else:
            d = _batch_distances(
                variant, query_n, cand, ub_b, window, band_width, cb, knobs
            )
            rows = cells = jnp.asarray(0)
        d = jnp.where(jnp.isfinite(lbs), d, jnp.inf)  # padding lanes
        k = jnp.argmin(d)
        dmin = d[k]
        improved = dmin < st.ub
        return St(
            r=st.r + 1,
            ub=jnp.where(improved, dmin, st.ub),
            best=jnp.where(improved, starts[k], st.best),
            lanes=st.lanes + batch,
            rows=st.rows + rows,
            cells=st.cells + cells,
        )

    st0 = St(
        r=jnp.asarray(0),
        ub=jnp.asarray(BIG, query_n.dtype),
        best=jnp.asarray(-1, order.dtype),
        lanes=jnp.asarray(0),
        rows=jnp.asarray(0),
        cells=jnp.asarray(0),
    )
    st = jax.lax.while_loop(cond, body, st0)
    no_info = jnp.asarray(-1)
    return SearchResult(
        best_start=st.best,
        best_dist=st.ub,
        rounds=st.r,
        lanes=st.lanes,
        lb_pruned=jnp.asarray(n_win) - jnp.minimum(st.lanes, n_win),
        rows=st.rows if with_info else no_info,
        cells=st.cells if with_info else no_info,
        quarantined=n_quar,
    )


def subsequence_search(
    ref: jax.Array,
    query: jax.Array,
    length: int,
    window: int,
    variant: str = "eapruned",
    batch: int = 64,
    band_width: int | None = None,
    chunk: int = 4096,
    with_info: bool = False,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
    rounds: str = "host",
    quarantine: bool = True,
) -> SearchResult:
    """Locate the closest z-normalized window of ``ref`` to ``query``.

    Un-jitted entry point: resolves ``backend`` (including the
    ``$REPRO_DTW_BACKEND`` env var, re-read every call) to a concrete name
    that becomes a static argument of the jitted search — see
    ``_subsequence_search_impl`` for the argument reference.
    ``rounds="persistent"`` runs the whole best-first sweep in one dispatch
    (module docstring); it is counter-free, so ``with_info`` is rejected.
    Input validation (``core.guards``): shapes/dtypes and knob sanity raise
    ``SearchInputError`` here, before tracing; a non-finite *query* raises
    ``NonFiniteInputError`` (non-finite *reference* samples are quarantined
    instead — their windows are excluded, counted in
    ``SearchResult.quarantined``, and the search over the remaining windows
    stays exact).
    """
    if rounds not in ROUND_DRIVERS:
        raise ValueError(f"rounds {rounds!r} not in {ROUND_DRIVERS}")
    if rounds == "persistent" and with_info:
        raise ValueError(
            "rounds='persistent' is counter-free; use the host driver for "
            "with_info stats rounds"
        )
    guards.ensure_series(ref, "ref", ndim=1, min_len=length)
    if jnp.ndim(query) == 1:
        guards.ensure_series(query, "query", ndim=1, min_len=length)
    else:
        guards.ensure_series(query, "query", ndim=2)  # (l, dims) multivariate
        if jnp.shape(query)[0] < length:
            raise guards.SearchInputError(
                f"query length {jnp.shape(query)[0]} < length {length}"
            )
    guards.ensure_finite(query, "query")
    guards.ensure_knobs(
        length=length, window=window, batch=batch, band_width=band_width,
        block_k=block_k, row_block=row_block, rows_per_step=rows_per_step,
    )
    return _subsequence_search_impl(
        ref, query, length=length, window=window, variant=variant,
        batch=batch, band_width=band_width, chunk=chunk, with_info=with_info,
        backend=resolve_backend(backend), rows_per_step=rows_per_step,
        block_k=block_k, row_block=row_block, rounds=rounds,
        quarantine=quarantine,
    )
