"""UCR-suite subsequence similarity search with EAPrunedDTW (single device).

Reproduces the paper's experimental pipeline: given a long reference series R
and a query Q, find the window of R (length = |Q|, z-normalized) with minimum
DTW distance to z-normalized Q, under a warping window.

Four variants, mirroring the paper's four suites (§5):

  ``full``           — UCR:      LB cascade + exact DTW (no in-DTW pruning)
  ``pruned``         — UCR-USP:  LB cascade + PrunedDTW (row-min abandon)
  ``eapruned``       — UCR-MON:  LB cascade + EAPrunedDTW + cb tightening
  ``eapruned_nolb``  — UCR-MON-nolb: EAPrunedDTW only, natural order

This module is a *frontend* of ``search.pipeline`` (DESIGN.md §2.8): the
wrapper validates inputs, resolves the knobs into a ``SearchPlan``, and runs
the shared prepare → cascade → execute program. The EA variants run as the
Q=1 case of the multi-query core (``pipeline._offline_search_impl``) — one
lane set, one incumbent, the same host-rounds / persistent-sweep executors
``multi_query_search`` uses. The ``full``/``pruned`` paper baselines and
multivariate queries run the pipeline's dedicated single-query core
(``pipeline._baseline_search_impl``): their kernels take a scalar abandon
threshold and no ``(Q, K)`` lane form exists.

Round drivers (``rounds=``, DESIGN.md §2.5): the default ``"host"`` driver
loops best-first batches around the batch primitive — one dispatch and one
incumbent update per round, every lane of a round abandoning against the
round-entry ``ub``. ``rounds="persistent"`` collapses the sweep into a
*single* dispatch: all candidate windows are gathered/normalized once in
best-first order and handed to ``core.batch.ea_pruned_dtw_persistent``,
which carries the incumbent across ``block_k``-lane candidate blocks inside
the launch (SMEM scratch on the Pallas backend, one while_loop on the jax
backend) and skips LB-gated blocks on device. Same ``best_start``, and
``best_dist`` equal up to the O(1)-ulp reformulation rounding documented in
``core.ea_pruned_dtw`` (a tighter mid-sweep incumbent masks a different set
of *suboptimal* float paths inside the winner's DP — the same effect as
changing ``batch`` in the host driver; typically bitwise in practice). Two
caveats at that same ulp scale: an *exact* distance tie between candidates
can resolve to the other cominimizer's start, and on the Pallas backend the
in-kernel ``cb`` prologue suffix-sums in tree order while host rounds use a
sequential cumsum — abandon thresholds can differ by an ulp, which only
matters for that same measure-zero tie case (the winner's survival, §2.2 of
DESIGN.md, is independent of ``cb`` rounding). O(1) dispatches instead of
O(rounds); ``ub`` tightens every ``block_k`` lanes instead of every
``batch``. The trade: the full window matrix is materialized up front
(O(N·l) memory traffic), where the host driver gathers only the rounds it
visits — prefer ``"host"`` when memory is tight or the LB ordering
routinely stops after a round or two. Persistent mode is counter-free;
combine with ``with_info`` is rejected.

Rounds come in two flavours. The default is the *counter-free fast round*:
distances only, no pruning bookkeeping — the hot path pays nothing for stats
it isn't asked for. ``with_info=True`` switches every round to the *stats
round*, which also accumulates the paper's rows/cells pruning counters into
``SearchResult`` (counter fields are ``-1`` when not collected). The
backend (and ``$REPRO_DTW_BACKEND``) is resolved in the un-jitted wrapper
on every call, so it is always a concrete static argument of the jitted
program; defaults for the paper workload live in ``configs/dtw_search.py``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import guards
from repro.search.pipeline import (
    MULTI_VARIANTS,
    ROUND_DRIVERS,
    VARIANTS,
    _baseline_search_impl,
    _offline_search_impl,
    make_plan,
)

__all__ = ["ROUND_DRIVERS", "VARIANTS", "SearchResult", "subsequence_search"]


class SearchResult(NamedTuple):
    best_start: jax.Array   # window start of the nearest neighbour
    best_dist: jax.Array    # its DTW distance (z-normalized)
    rounds: jax.Array       # batch rounds executed
    lanes: jax.Array        # candidate lanes evaluated (rounds * batch)
    lb_pruned: jax.Array    # candidates never evaluated thanks to LB ordering
    rows: jax.Array         # DTW rows issued across all lanes (-1: fast round)
    cells: jax.Array        # admissible DTW cells across all lanes (-1: fast)
    quarantined: jax.Array  # windows excluded by the non-finite quarantine


def subsequence_search(
    ref: jax.Array,
    query: jax.Array,
    length: int,
    window: int,
    variant: str = "eapruned",
    batch: int = 64,
    band_width: int | None = None,
    chunk: int = 4096,
    with_info: bool = False,
    backend: str | None = None,
    rows_per_step: int = 1,
    block_k: int = 8,
    row_block: int = 128,
    rounds: str = "host",
    quarantine: bool = True,
    gather: str = "fused",
    slab_budget: int | None = None,
) -> SearchResult:
    """Locate the closest z-normalized window of ``ref`` to ``query``.

    Un-jitted entry point: resolves ``backend`` (including the
    ``$REPRO_DTW_BACKEND`` env var, re-read every call) into the
    ``SearchPlan`` that becomes a static argument of the jitted pipeline.
    ``rounds="persistent"`` runs the whole best-first sweep in one dispatch
    (module docstring); it is counter-free, so ``with_info`` is rejected.
    Input validation (``core.guards``): shapes/dtypes and knob sanity raise
    ``SearchInputError`` here, before tracing; a non-finite *query* raises
    ``NonFiniteInputError`` (non-finite *reference* samples are quarantined
    instead — their windows are excluded, counted in
    ``SearchResult.quarantined``, and the search over the remaining windows
    stays exact).

    Args:
      ref: ``(N,)`` long reference series.
      query: ``(l,)`` raw query (z-normalized internally); ``l == length``.
      length: window/query length (static).
      window: Sakoe-Chiba warping window in samples (static).
      variant: one of ``VARIANTS``.
      batch: candidates per shared-ub round (static; host driver only).
      with_info: collect rows/cells pruning counters (stats rounds). The
        default fast rounds leave ``SearchResult.rows``/``.cells`` at ``-1``.
      backend: DTW batch backend (see ``core.backend``); ``None`` = auto.
      rows_per_step: JAX-backend while_loop rows per iteration.
      block_k, row_block: Pallas-backend grid tiling.
      rounds: ``"host"`` (best-first rounds around the batch primitive) or
        ``"persistent"`` (whole sweep in one dispatch with a block-granular
        carried incumbent — see module docstring).
      quarantine: exclude windows overlapping non-finite reference samples
        (DESIGN.md §2.6); they ride the rounds as dead lanes and are counted
        in ``SearchResult.quarantined``. ``False`` skips the prepass (the
        caller then guarantees a finite reference).
      gather: candidate materialization (DESIGN.md §2.10) — ``"fused"``
        (default) slices + z-normalizes candidates from the resident
        reference inside the DTW stage; ``"slab"`` pre-gathers the O(K·l)
        window matrix (comparison arm). Results are identical.
      slab_budget: optional byte cap on host-side candidate slabs; an
        over-budget ``"slab"`` dispatch raises ``SearchInputError`` at
        trace time.
    """
    if rounds not in ROUND_DRIVERS:
        raise ValueError(f"rounds {rounds!r} not in {ROUND_DRIVERS}")
    if rounds == "persistent" and with_info:
        raise ValueError(
            "rounds='persistent' is counter-free; use the host driver for "
            "with_info stats rounds"
        )
    guards.ensure_series(ref, "ref", ndim=1, min_len=length)
    univariate = jnp.ndim(query) == 1
    if univariate:
        guards.ensure_series(query, "query", ndim=1, min_len=length)
    else:
        guards.ensure_series(query, "query", ndim=2)  # (l, dims) multivariate
        if jnp.shape(query)[0] < length:
            raise guards.SearchInputError(
                f"query length {jnp.shape(query)[0]} < length {length}"
            )
    guards.ensure_finite(query, "query")
    plan = make_plan(
        length=length, window=window, variant=variant, batch=batch,
        band_width=band_width, chunk=chunk, backend=backend,
        rows_per_step=rows_per_step, block_k=block_k, row_block=row_block,
        rounds=rounds, quarantine=quarantine, gather=gather,
        slab_budget=slab_budget, with_info=with_info,
    )
    if univariate and variant in MULTI_VARIANTS:
        # Q=1 of the multi-query pipeline core: same executors, one lane set.
        state, stats, n_quar = _offline_search_impl(
            ref, jnp.asarray(query)[None, :], None, plan, with_info
        )
    else:
        # full/pruned baselines and multivariate queries: the pipeline's
        # dedicated single-query core (scalar-threshold kernels).
        state, stats, n_quar = _baseline_search_impl(
            ref, query, plan, with_info
        )
    return SearchResult(
        best_start=state.best[0],
        best_dist=state.ub[0],
        rounds=stats.rounds[0],
        lanes=stats.lanes[0],
        lb_pruned=stats.lb_pruned[0],
        rows=stats.rows[0],
        cells=stats.cells[0],
        quarantined=n_quar,
    )
