"""Pure-jnp oracles for the Pallas kernels.

These are the reference semantics each kernel must reproduce; tests sweep
shapes/dtypes and ``assert_allclose`` kernel-vs-ref in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.common import norm_window_slice
from repro.core.dtw import dtw
from repro.core.ea_pruned_dtw import ea_pruned_dtw
from repro.core.lower_bounds import envelope, lb_keogh, lb_kim_fl


def dtw_ea_ref(
    query: jax.Array,
    candidates: jax.Array,
    ub: jax.Array,
    window: int,
    cb: jax.Array | None = None,
) -> jax.Array:
    """Reference for kernels.ops.dtw_ea: vmapped full-row EAPrunedDTW."""
    m = candidates.shape[-1]
    win = None if window >= m else int(window)
    if cb is None:
        fn = lambda c: ea_pruned_dtw(query, c, ub, window=win)
        return jax.vmap(fn)(candidates)
    fn = lambda c, cbv: ea_pruned_dtw(query, c, ub, window=win, cb=cbv)
    return jax.vmap(fn)(candidates, cb)


def dtw_exact_ref(query: jax.Array, candidates: jax.Array, window: int) -> jax.Array:
    """Unpruned exact DTW per candidate (for value checks of survivors)."""
    m = candidates.shape[-1]
    win = None if window >= m else int(window)
    return jax.vmap(lambda c: dtw(query, c, window=win))(candidates)


def lb_all_windows_ref(
    ref: jax.Array,
    query_n: jax.Array,
    mu: jax.Array,
    sigma: jax.Array,
    length: int,
    window: int,
) -> jax.Array:
    """Reference for kernels.ops.lb_keogh_all_windows."""
    n_win = ref.shape[0] - length + 1
    starts = jnp.arange(n_win)
    cand = norm_window_slice(ref, starts, length, mu, sigma)
    u, low = envelope(query_n, window)
    return jnp.maximum(lb_keogh(cand, u, low), lb_kim_fl(query_n, cand))
