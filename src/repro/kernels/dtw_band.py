"""Pallas TPU kernel: batched early-abandoning pruned DTW, banded columns.

TPU-native shape of EAPrunedDTW (DESIGN.md §2): a grid of
``(query_blocks, candidate_blocks, row_blocks)`` programs. The query and
candidate dimensions are embarrassingly parallel
(``dimension_semantics[:2] = ("parallel", "parallel")``); the row dimension
is sequential ("arbitrary") with the DP carry living in VMEM scratch across
grid steps.

Multi-query lane layout: one launch evaluates a flattened ``(Q × K)`` lane
set. Lanes are laid out query-major — candidate block ``ci`` of query ``qi``
lives at flattened block row ``qi * num_cand_blocks + ci`` — so each grid
program still sees a plain ``(block_k, m)`` VMEM tile whose lanes all share
one query and one envelope, while the grid's leading dimension walks the Q
distinct queries. ``Q == 1`` degenerates to the single-query kernel of PR 1.

Per-lane upper bounds: ``ub`` is a ``(block_k, 1)`` VMEM vector per block —
every lane carries its own incumbent. That is what turns the kernel into a
multi-query serving primitive: lanes belonging to different queries (or to
padding) abandon against their own thresholds, and a lane whose ``ub`` is
negative (the padding / finished-query sentinel) dies on its first row
without holding the block's early-exit flag hostage. The UCR ``cb``
threshold-tightening slab is likewise per-lane (``(block_k, m)``), so the
per-row threshold ``ub[lane] - cb[lane, i + w + 1]`` is fully vectorized.

Banded column mode (the serving hot path, mirroring
``core.ea_pruned_dtw.ea_pruned_dtw_banded``): instead of full-width ``m``
rows, each row step computes only a ``band_width`` slice of columns starting
at the *window-following* offset ``lo(i) = clip(i - window, 0, m - bw)``.
Because every lane of a block shares its query and the Sakoe-Chiba window,
``lo`` is lane-uniform and a pure function of the row index, advancing by at
most one column per row. That buys two TPU-critical properties:

  * the candidate slice is a lane-uniform ``pl.ds(lo, bw)`` dynamic slice
    (no per-lane gather), and
  * realigning the previous row's band is a single select between the
    unshifted band and a static shift-by-one — ``shift = lo(i) - lo(i-1)``
    is always 0 or 1.

Per-lane pruning state (``next_start``) is kept as a mask on top of the
band, so pruning decisions are bit-identical to the full-width kernel and to
the banded JAX reference. Work per row drops from O(m) to O(band), i.e. the
prefix-scan doubling runs log2(band) steps instead of log2(m).
``band_width == m`` degenerates to the original full-width kernel
(``lo == 0`` always) and is used when ``n != m`` or the window covers the
whole matrix.

Per (block_k)-lane row step, entirely in VMEM/VREGs:
  * cost row  ``c[k, r] = (q_i - cand[k, lo + r])^2``        (VPU)
  * ``d = c + min(top, left)`` with top/left from the realigned band
  * row recurrence via prefix-sum + cumulative-min doubling (log2(band))
  * band bookkeeping: ``next_start`` per lane, per-lane abandon flags, UCR
    ``cb`` threshold tightening — all vectorized mask reductions against the
    per-lane ``ub`` column.

Early abandoning at TPU granularity: a lane whose row has no cell under its
own threshold freezes (its updates are masked out); when *every* lane of a
candidate block has abandoned, an SMEM flag turns all remaining row-blocks of
that block into ``pl.when`` no-ops — the kernel-level analogue of the paper's
border-collision early exit, at (query, candidate-block) granularity.

Optional pruning counters (``emit_info``): per-lane rows-issued and
admissible-cells accumulators, matching ``core.ea_pruned_dtw.EAInfo``
semantics, so ``SearchResult`` stats survive when search runs through the
Pallas backend. The counter-free variant carries no accumulator traffic —
the search fast round uses it by default.

Persistent search mode (DESIGN.md §2.5): ``_dtw_ea_persistent_kernel``
collapses the *entire* best-first sweep of a search into one launch. The
candidate-block grid dimension turns sequential (``"arbitrary"``), the shared
incumbent ``ub`` lives in SMEM scratch and is min-reduced from each block's
surviving lane distances before the next block is gated, and a block whose
precomputed lower bound cannot beat the carried incumbent becomes a
``pl.when`` no-op on device — the cascade stop condition without returning
to the host. The UCR ``cb`` suffix is computed as a per-block kernel
prologue (LB_Keogh terms + reverse cumsum from the query envelope), so the
host neither materializes nor streams a ``cb`` slab. One launch per search,
O(1) dispatches instead of O(rounds), with ``ub`` tightening at candidate-
block granularity instead of round granularity.

Fused in-kernel gather + z-normalization (DESIGN.md §2.10): the default
operand form no longer ships pre-gathered ``(block_k, m)`` normalized
windows. Instead the kernels take the **raw reference series** — resident
once, O(N) — plus per-lane ``(start, mu, sigma)`` vectors, and each block's
``_init`` phase slices its lanes' windows out of the series and normalizes
them into VMEM scratch (``_gather_norm_block``): per-lane lane-uniform
``pl.ds(start, m)`` copies (the Python loop over the static ``block_k`` lane
index unrolls at trace time) followed by one vectorized
``(cand - mu) / sigma``. ``sigma`` arrives pre-clamped by the host wrapper
(``clamp_sigma``), so flat windows normalize to exactly the same zeros as
the retired host-side slab. For references too large to hold in VMEM the
reference operand stays in HBM (``memory_space=ANY``) and the per-lane
window copies become explicit DMAs (``make_async_copy`` + a DMA semaphore)
— the slab-streaming tier (``ref_in_vmem=False``). The working set drops
from O(N·l) (every overlapping window re-copied) to O(N + block_k·m), which
is what lets persistent mode sweep references whose window slab could never
be materialized. The UCR ``cb`` suffix is likewise built in-kernel from the
just-normalized tile (LB_Keogh terms + tree-order suffix sum — the same
documented O(1)-ulp reformulation as the persistent prologue below).

Validated against ``ref.py`` and the banded JAX path in interpret mode on
CPU; written for TPU as the target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.common import BIG, DEAD_LANE_UB
from repro.core.lower_bounds import _lb_keogh_terms


def _shift_right(x: jax.Array, off: int, fill: float) -> jax.Array:
    """Shift last axis right by ``off`` lanes, filling with ``fill``."""
    pad = jnp.full(x.shape[:-1] + (off,), fill, x.dtype)
    return jnp.concatenate([pad, x[..., :-off]], axis=-1)


def _shift_left(x: jax.Array, off: int, fill: float) -> jax.Array:
    """Shift last axis left by ``off`` lanes, filling with ``fill``."""
    pad = jnp.full(x.shape[:-1] + (off,), fill, x.dtype)
    return jnp.concatenate([x[..., off:], pad], axis=-1)


def _prefix_sum(x: jax.Array) -> jax.Array:
    """Inclusive prefix sum along the last axis (Hillis-Steele doubling)."""
    n = x.shape[-1]
    off = 1
    while off < n:
        x = x + _shift_right(x, off, 0.0)
        off *= 2
    return x


def _suffix_sum(x: jax.Array) -> jax.Array:
    """Inclusive suffix sum along the last axis (reverse-cumsum, doubling)."""
    n = x.shape[-1]
    off = 1
    while off < n:
        x = x + _shift_left(x, off, 0.0)
        off *= 2
    return x


def _prefix_min(x: jax.Array) -> jax.Array:
    """Inclusive prefix min along the last axis (doubling)."""
    n = x.shape[-1]
    off = 1
    while off < n:
        x = jnp.minimum(x, _shift_right(x, off, jnp.inf))
        off *= 2
    return x


def _gather_norm_block(
    ref_ref,      # (1, N_pad) raw reference (VMEM, or HBM when not ref_in_vmem)
    starts_ref,   # (block_k, 1) int32 window start per lane
    mu_ref,       # (block_k, 1) per-lane window mean
    sg_ref,       # (block_k, 1) per-lane window sigma (pre-clamped)
    cand_ref,     # (block_k, m) VMEM scratch: normalized windows out
    sem,          # DMA semaphore scratch (used iff not ref_in_vmem)
    *,
    ref_in_vmem: bool,
):
    """Slice + z-normalize one block's candidate windows in-kernel.

    The fused replacement for the host-side ``gather_norm_windows`` slab:
    each lane's window is a contiguous ``pl.ds(start, m)`` slice of the
    O(N)-resident reference. The lane index is static (the Python loop
    unrolls at trace time), so only the slice *start* is dynamic — a
    supported lane-uniform dynamic slice per unrolled step. VMEM tier copies
    directly; the HBM tier (reference too large for VMEM) issues an explicit
    DMA per lane. Normalization is one vectorized step over the whole tile;
    ``sg_ref`` is pre-clamped on the host (``clamp_sigma``), making the
    output bit-identical to the retired pre-gathered slab.
    """
    block_k, m = cand_ref.shape
    for k in range(block_k):
        s = starts_ref[k, 0]
        if ref_in_vmem:
            cand_ref[k, :] = ref_ref[0, pl.ds(s, m)]
        else:
            cp = pltpu.make_async_copy(
                ref_ref.at[0, pl.ds(s, m)], cand_ref.at[k], sem
            )
            cp.start()
            cp.wait()
    cand_ref[...] = (cand_ref[...] - mu_ref[...]) / sg_ref[...]


def _dp_row(
    i,
    q_i,          # (1,) query sample for DP row ``i``
    cand_ref,     # (block_k, m) candidate block
    prev_ref,     # (block_k, bw) previous-row band scratch
    ns_ref,       # (block_k, 1) per-lane next_start scratch
    flags_ref,    # (block_k, 2) per-lane [abandoned, ok_last] scratch
    ub,           # (block_k, 1) per-lane thresholds (fixed for the block)
    cb_ref,       # (block_k, m) cumulative LB suffix (read iff use_cb)
    rel,          # (block_k, bw) column iota
    rows_ref,     # (block_k, 1) rows counter scratch (used iff emit_info)
    cells_ref,    # (block_k, 1) cells counter scratch (used iff emit_info)
    *,
    n_rows: int,
    window: int,
    band_width: int,
    use_cb: bool,
    emit_info: bool,
):
    """One banded DP row, shared by the round and persistent kernels.

    Mutates the per-block scratch refs in place; a lane whose row has no
    cell under its own threshold freezes (abandon flag), and padding rows
    (``i >= n_rows``) are no-ops.
    """
    block_k, m = cand_ref.shape
    bw = band_width
    lo_max = m - bw  # 0 in full-width mode

    valid = i < n_rows
    lo = jnp.clip(i - window, 0, lo_max)
    lo_prev = jnp.clip(i - 1 - window, 0, lo_max)
    shift = lo - lo_prev  # the window edge advances by 0 or 1

    cand = cand_ref[:, pl.ds(lo, bw)]
    c = (q_i[0] - cand) ** 2

    cols = lo + rel
    hi = jnp.minimum(m - 1, i + window)
    ns = ns_ref[...]  # (block_k, 1)
    exists = jnp.logical_and(
        jnp.logical_and(cols >= ns, cols >= i - window), cols <= hi
    )

    # Realign the previous row's band from offset lo_prev to lo.
    prev = prev_ref[...]
    big_col = jnp.full((block_k, 1), BIG, jnp.float32)
    # top[r]  = prev-row value at col lo + r      (shift left by shift)
    top = jnp.where(
        shift == 1,
        jnp.concatenate([prev[:, 1:], big_col], axis=1),
        prev,
    )
    # left[r] = prev-row value at col lo + r - 1  (shift by shift - 1)
    border = jnp.where(i == 0, 0.0, BIG)  # virtual corner at (-1, -1)
    left = jnp.where(
        shift == 1,
        prev,
        jnp.concatenate(
            [jnp.full((block_k, 1), border, jnp.float32), prev[:, :-1]],
            axis=1,
        ),
    )

    d = c + jnp.minimum(top, left)
    d = jnp.where(exists, d, BIG)
    p = _prefix_sum(c)
    curr = p + _prefix_min(d - p)
    curr = jnp.minimum(curr, BIG)
    curr = jnp.where(exists, curr, BIG)

    if use_cb:
        jcb = jnp.minimum(i + window + 1, m - 1)
        tail = cb_ref[:, pl.ds(jcb, 1)]  # (block_k, 1)
        tail = jnp.where(i + window + 1 <= m - 1, tail, 0.0)
        thr = ub - tail
    else:
        thr = ub

    le = jnp.logical_and(curr <= thr, exists)
    any_le = jnp.any(le, axis=1, keepdims=True)  # (block_k, 1)
    alive = flags_ref[:, 0:1] == 0
    upd = jnp.logical_and(jnp.logical_and(alive, any_le), valid)

    ns_new = jnp.min(jnp.where(le, cols, m), axis=1, keepdims=True)
    ns_ref[...] = jnp.where(upd, ns_new.astype(jnp.int32), ns)
    prev_ref[...] = jnp.where(upd, curr, prev)
    newly_dead = jnp.logical_and(
        alive, jnp.logical_and(jnp.logical_not(any_le), valid)
    )
    flags_ref[:, 0:1] = jnp.where(
        newly_dead, jnp.ones_like(ns), flags_ref[:, 0:1]
    )
    is_last = i == n_rows - 1
    ok_last = jnp.logical_and(
        jnp.any(jnp.logical_and(le, cols == m - 1), axis=1, keepdims=True),
        jnp.logical_and(upd, is_last),
    )
    flags_ref[:, 1:2] = jnp.where(
        jnp.logical_and(valid, is_last),
        ok_last.astype(jnp.int32),
        flags_ref[:, 1:2],
    )
    if emit_info:
        # EAInfo semantics: the abandoning row is counted too.
        issued = jnp.logical_and(alive, valid)
        rows_ref[...] = rows_ref[...] + issued.astype(jnp.int32)
        n_exist = jnp.sum(
            exists.astype(jnp.int32), axis=1, keepdims=True
        ).astype(jnp.int32)
        cells_ref[...] = (
            cells_ref[...] + jnp.where(issued, n_exist, 0)
        ).astype(jnp.int32)


def _round_init_scratch(
    prev_ref, ns_ref, flags_ref, rows_ref, cells_ref, done_ref,
    *, band_width: int, emit_info: bool,
):
    """Reset one block's DP scratch at its first row block."""
    block_k = prev_ref.shape[0]
    prev_ref[...] = jnp.full((block_k, band_width), BIG, jnp.float32)
    ns_ref[...] = jnp.zeros((block_k, 1), jnp.int32)
    flags_ref[...] = jnp.zeros((block_k, 2), jnp.int32)
    if emit_info:
        rows_ref[...] = jnp.zeros((block_k, 1), jnp.int32)
        cells_ref[...] = jnp.zeros((block_k, 1), jnp.int32)
    done_ref[0] = jnp.asarray(0, jnp.int32)  # literal 0 is int64 under x64


def _round_sweep(
    ri, ub_ref, q_ref, cand_ref, cb_ref, out_ref, rows_out, cells_out,
    prev_ref, ns_ref, flags_ref, rows_ref, cells_ref, done_ref,
    *,
    n_rows: int,
    window: int,
    row_block: int,
    band_width: int,
    use_cb: bool,
    emit_info: bool,
):
    """Row sweep + finish shared by the gathered and fused round kernels."""
    block_k, m = cand_ref.shape
    bw = band_width
    lo_max = m - bw  # 0 in full-width mode

    @pl.when(done_ref[0] == 0)
    def _rows():
        ub = ub_ref[...]  # (block_k, 1) per-lane incumbents
        rel = jax.lax.broadcasted_iota(jnp.int32, (block_k, bw), 1)

        def row(r, _):
            _dp_row(
                ri * row_block + r, q_ref[0, pl.ds(r, 1)], cand_ref,
                prev_ref, ns_ref, flags_ref, ub, cb_ref, rel,
                rows_ref, cells_ref,
                n_rows=n_rows, window=window, band_width=bw,
                use_cb=use_cb, emit_info=emit_info,
            )
            return 0

        jax.lax.fori_loop(0, row_block, row, 0, unroll=False)
        done_ref[0] = jnp.asarray(
            jnp.all(flags_ref[:, 0] == 1), jnp.int32
        ).astype(jnp.int32)

    @pl.when(ri == pl.num_programs(2) - 1)
    def _finish():
        ok = jnp.logical_and(flags_ref[:, 0] == 0, flags_ref[:, 1] == 1)
        lo_fin = min(max(n_rows - 1 - window, 0), lo_max)  # static
        last = prev_ref[:, (m - 1) - lo_fin]
        out_ref[...] = jnp.where(ok, last, jnp.inf)
        if emit_info:
            rows_out[...] = rows_ref[:, 0]
            cells_out[...] = cells_ref[:, 0]


def _dtw_ea_kernel(
    # VMEM operands
    ub_ref,      # (block_k, 1) per-lane upper bounds
    q_ref,       # (1, row_block) query slice for this (query, row) block
    cand_ref,    # (block_k, m) candidate block (lanes share one query)
    cb_ref,      # (block_k, m) cumulative LB suffix (zeros if disabled)
    # outputs
    out_ref,     # (block_k,) distances
    *rest,       # [rows_out, cells_out] if emit_info, then scratch
    n_rows: int,
    window: int,
    row_block: int,
    band_width: int,
    use_cb: bool,
    emit_info: bool,
):
    """Gathered-slab round kernel (``gather="slab"`` comparison arm)."""
    if emit_info:
        rows_out, cells_out = rest[0], rest[1]
        rest = rest[2:]
    else:
        rows_out = cells_out = None
    prev_ref, ns_ref, flags_ref, rows_ref, cells_ref, done_ref = rest

    ri = pl.program_id(2)

    @pl.when(ri == 0)
    def _init():
        _round_init_scratch(
            prev_ref, ns_ref, flags_ref, rows_ref, cells_ref, done_ref,
            band_width=band_width, emit_info=emit_info,
        )

    _round_sweep(
        ri, ub_ref, q_ref, cand_ref, cb_ref, out_ref, rows_out, cells_out,
        prev_ref, ns_ref, flags_ref, rows_ref, cells_ref, done_ref,
        n_rows=n_rows, window=window, row_block=row_block,
        band_width=band_width, use_cb=use_cb, emit_info=emit_info,
    )


def _dtw_ea_fused_kernel(
    # operands
    ub_ref,      # (block_k, 1) per-lane upper bounds
    q_ref,       # (1, row_block) query slice for this (query, row) block
    ref_ref,     # (1, N_pad) raw reference (VMEM, or HBM when streaming)
    starts_ref,  # (block_k, 1) int32 window start per lane
    mu_ref,      # (block_k, 1) per-lane window mean
    sg_ref,      # (block_k, 1) per-lane window sigma (pre-clamped)
    u_ref,       # (1, m) query envelope upper (read iff use_cb)
    low_ref,     # (1, m) query envelope lower (read iff use_cb)
    # outputs
    out_ref,     # (block_k,) distances
    *rest,       # [rows_out, cells_out] if emit_info, then scratch
    n_rows: int,
    window: int,
    row_block: int,
    band_width: int,
    use_cb: bool,
    emit_info: bool,
    ref_in_vmem: bool,
):
    """Fused round kernel: windows sliced + normalized in-kernel.

    Same DP program as ``_dtw_ea_kernel``, but the candidate tile is VMEM
    *scratch* filled by ``_gather_norm_block`` at each block's first row
    step, and the UCR ``cb`` suffix — when enabled — is built in-kernel from
    that tile and the query envelope. The in-kernel suffix sum runs in tree
    order, so fused-round ``cb`` matches the host drivers' sequential cumsum
    to the documented O(1)-ulp reformulation rounding (DESIGN.md §2.2/§2.5)
    — abandon thresholds can shift by an ulp, the winner cannot change.
    """
    if emit_info:
        rows_out, cells_out = rest[0], rest[1]
        rest = rest[2:]
    else:
        rows_out = cells_out = None
    if ref_in_vmem:
        sem = None
        (cand_ref, cb_ref, prev_ref, ns_ref, flags_ref, rows_ref,
         cells_ref, done_ref) = rest
    else:
        (cand_ref, cb_ref, prev_ref, ns_ref, flags_ref, rows_ref,
         cells_ref, done_ref, sem) = rest

    ri = pl.program_id(2)

    @pl.when(ri == 0)
    def _init():
        _gather_norm_block(
            ref_ref, starts_ref, mu_ref, sg_ref, cand_ref, sem,
            ref_in_vmem=ref_in_vmem,
        )
        if use_cb:
            terms = _lb_keogh_terms(cand_ref[...], u_ref[...], low_ref[...])
            cb_ref[...] = _suffix_sum(terms)
        _round_init_scratch(
            prev_ref, ns_ref, flags_ref, rows_ref, cells_ref, done_ref,
            band_width=band_width, emit_info=emit_info,
        )

    _round_sweep(
        ri, ub_ref, q_ref, cand_ref, cb_ref, out_ref, rows_out, cells_out,
        prev_ref, ns_ref, flags_ref, rows_ref, cells_ref, done_ref,
        n_rows=n_rows, window=window, row_block=row_block,
        band_width=band_width, use_cb=use_cb, emit_info=emit_info,
    )


def _dtw_ea_persistent_kernel(
    # operands
    ub_init_ref,  # (Q,) SMEM per-query initial incumbents
    q_ref,        # (1, row_block) query slice for this (query, row) block
    *rest,
    n_rows: int,
    window: int,
    row_block: int,
    band_width: int,
    use_cb: bool,
    fused: bool = False,
    ref_in_vmem: bool = True,
):
    """Whole best-first search in one launch (DESIGN.md §2.5).

    Operand forms (after ``ub_init``/``q``):

    * gathered (``fused=False``, the ``gather="slab"`` comparison arm):
      ``cand (block_k, m)`` pre-normalized best-first windows, then
      ``lb, starts, u, low`` — the O(N·l) slab form.
    * fused (``fused=True``, default execution form): ``ref (1, N_pad)``
      raw reference — VMEM, or HBM (``memory_space=ANY``) when
      ``ref_in_vmem=False`` — then ``lb, starts, mu, sg, u, low``; the
      candidate tile becomes VMEM scratch filled by ``_gather_norm_block``
      in each block's ``_init_block`` (gated off for skipped blocks, so a
      cascade-stopped tail costs no copies/DMAs). O(N + block_k·m) resident,
      which is what lets one launch sweep references whose window slab could
      never be materialized.

    Grid ``(Q, cand_blocks, row_blocks)`` with the candidate dimension
    *sequential*: the incumbent ``ub_s`` (and the running best start /
    block counter) live in SMEM scratch and are carried across candidate
    blocks, re-initialized from ``ub_init`` whenever a query's sweep starts
    (``ci == ri == 0``), so a core that serves several queries of a parallel
    query dimension never leaks state between them.

    Per candidate block:
      * gate: a block none of whose lanes' lower bounds beat the carried
        incumbent is a no-op (``done`` set at ``ri == 0``) — the on-device
        cascade stop. Lane-level gating rides the same comparison: a lane
        whose own bound reaches ``ub`` gets the dead-lane sentinel. The
        non-finite quarantine (DESIGN.md §2.6) rides it too: a quarantined
        window arrives with a ``+inf`` lower bound, so the kernel kills its
        lane on row 0 with no quarantine-specific code or retrace.
      * prologue (``use_cb``): the UCR ``cb`` suffix is built in VMEM from
        the candidate tile and the query envelope (LB_Keogh terms + suffix
        sum) instead of being streamed from HBM.
      * rows: the shared ``_dp_row`` banded recurrence, per-lane abandon.
      * epilogue (last row block): surviving lane distances are min-reduced
        into ``ub_s`` with first-lane tie-breaking; strict improvement only,
        matching the host round driver's incumbent update.
    """
    if fused:
        (ref_ref, lb_ref, starts_ref, mu_ref, sg_ref, u_ref, low_ref,
         dist_ref, idx_ref, blocks_ref,
         cand_ref, prev_ref, ns_ref, flags_ref, ubv_ref, cb_ref,
         done_ref, ub_s, best_s, blocks_s, *maybe_sem) = rest
        sem = maybe_sem[0] if maybe_sem else None
    else:
        (cand_ref, lb_ref, starts_ref, u_ref, low_ref,
         dist_ref, idx_ref, blocks_ref,
         prev_ref, ns_ref, flags_ref, ubv_ref, cb_ref,
         done_ref, ub_s, best_s, blocks_s) = rest

    qi = pl.program_id(0)
    ci = pl.program_id(1)
    ri = pl.program_id(2)
    block_k, m = cand_ref.shape
    bw = band_width
    lo_max = m - bw

    @pl.when(jnp.logical_and(ci == 0, ri == 0))
    def _init_query():
        ub_s[0] = ub_init_ref[qi]
        best_s[0] = jnp.asarray(-1, jnp.int32)
        blocks_s[0] = jnp.asarray(0, jnp.int32)

    @pl.when(ri == 0)
    def _init_block():
        prev_ref[...] = jnp.full((block_k, bw), BIG, jnp.float32)
        ns_ref[...] = jnp.zeros((block_k, 1), jnp.int32)
        flags_ref[...] = jnp.zeros((block_k, 2), jnp.int32)
        # Block + lane gating against the carried incumbent. Lower bounds
        # arrive sorted, so "any lane live" == "head lane live", but the
        # any() form is order-independent.
        ub_cur = ub_s[0]
        live = lb_ref[...] < ub_cur  # (block_k, 1)
        ubv_ref[...] = jnp.where(live, ub_cur, DEAD_LANE_UB)
        skip = jnp.logical_not(jnp.any(live))
        done_ref[0] = skip.astype(jnp.int32)
        blocks_s[0] = blocks_s[0] + jnp.logical_not(skip).astype(jnp.int32)

        @pl.when(jnp.logical_not(skip))
        def _materialize():
            if fused:
                # Fused tier: slice + normalize this block's windows out of
                # the resident reference. Gated blocks (cascade stop / all
                # lanes dead) skip the copies/DMAs entirely.
                _gather_norm_block(
                    ref_ref, starts_ref, mu_ref, sg_ref, cand_ref, sem,
                    ref_in_vmem=ref_in_vmem,
                )
            if use_cb:
                # (1, m) envelope broadcasts over the block's lanes. The
                # suffix sum runs in tree order (log-depth doubling) rather
                # than the host drivers' sequential cumsum — cb rounding
                # only shifts abandon thresholds by an ulp, which cannot
                # change the winner (DESIGN.md §2.2/§2.5).
                terms = _lb_keogh_terms(cand_ref[...], u_ref[...], low_ref[...])
                cb_ref[...] = _suffix_sum(terms)

    @pl.when(done_ref[0] == 0)
    def _rows():
        ub = ubv_ref[...]
        rel = jax.lax.broadcasted_iota(jnp.int32, (block_k, bw), 1)

        def row(r, _):
            _dp_row(
                ri * row_block + r, q_ref[0, pl.ds(r, 1)], cand_ref,
                prev_ref, ns_ref, flags_ref, ub, cb_ref, rel,
                None, None,
                n_rows=n_rows, window=window, band_width=bw,
                use_cb=use_cb, emit_info=False,
            )
            return 0

        jax.lax.fori_loop(0, row_block, row, 0, unroll=False)
        done_ref[0] = jnp.asarray(
            jnp.all(flags_ref[:, 0] == 1), jnp.int32
        ).astype(jnp.int32)

    @pl.when(ri == pl.num_programs(2) - 1)
    def _block_epilogue():
        # Min-reduce this block's surviving distances into the incumbent.
        # A gated block left flags at zero (ok_last == 0), so it contributes
        # nothing — the same no-op the host loop's stop condition implies.
        ok = jnp.logical_and(flags_ref[:, 0:1] == 0, flags_ref[:, 1:2] == 1)
        lo_fin = min(max(n_rows - 1 - window, 0), lo_max)  # static
        last = prev_ref[:, (m - 1) - lo_fin : (m - 1) - lo_fin + 1]
        d = jnp.where(ok, last, jnp.inf)  # (block_k, 1)
        dmin = jnp.min(d)
        improved = dmin < ub_s[0]  # strict: ties keep the incumbent

        @pl.when(improved)
        def _tighten():
            lane = jax.lax.broadcasted_iota(jnp.int32, (block_k, 1), 0)
            k = jnp.min(jnp.where(d == dmin, lane, block_k))  # first argmin
            ub_s[0] = dmin
            best_s[0] = jnp.sum(
                jnp.where(lane == k, starts_ref[...], 0), dtype=jnp.int32
            )

    @pl.when(
        jnp.logical_and(
            ci == pl.num_programs(1) - 1, ri == pl.num_programs(2) - 1
        )
    )
    def _emit():
        dist_ref[...] = jnp.full((1,), ub_s[0], jnp.float32)
        idx_ref[...] = jnp.full((1,), best_s[0], jnp.int32)
        blocks_ref[...] = jnp.full((1,), blocks_s[0], jnp.int32)
