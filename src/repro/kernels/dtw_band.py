"""Pallas TPU kernel: batched early-abandoning pruned DTW, banded columns.

TPU-native shape of EAPrunedDTW (DESIGN.md §2): a grid of
``(query_blocks, candidate_blocks, row_blocks)`` programs. The query and
candidate dimensions are embarrassingly parallel
(``dimension_semantics[:2] = ("parallel", "parallel")``); the row dimension
is sequential ("arbitrary") with the DP carry living in VMEM scratch across
grid steps.

Multi-query lane layout: one launch evaluates a flattened ``(Q × K)`` lane
set. Lanes are laid out query-major — candidate block ``ci`` of query ``qi``
lives at flattened block row ``qi * num_cand_blocks + ci`` — so each grid
program still sees a plain ``(block_k, m)`` VMEM tile whose lanes all share
one query and one envelope, while the grid's leading dimension walks the Q
distinct queries. ``Q == 1`` degenerates to the single-query kernel of PR 1.

Per-lane upper bounds: ``ub`` is a ``(block_k, 1)`` VMEM vector per block —
every lane carries its own incumbent. That is what turns the kernel into a
multi-query serving primitive: lanes belonging to different queries (or to
padding) abandon against their own thresholds, and a lane whose ``ub`` is
negative (the padding / finished-query sentinel) dies on its first row
without holding the block's early-exit flag hostage. The UCR ``cb``
threshold-tightening slab is likewise per-lane (``(block_k, m)``), so the
per-row threshold ``ub[lane] - cb[lane, i + w + 1]`` is fully vectorized.

Banded column mode (the serving hot path, mirroring
``core.ea_pruned_dtw.ea_pruned_dtw_banded``): instead of full-width ``m``
rows, each row step computes only a ``band_width`` slice of columns starting
at the *window-following* offset ``lo(i) = clip(i - window, 0, m - bw)``.
Because every lane of a block shares its query and the Sakoe-Chiba window,
``lo`` is lane-uniform and a pure function of the row index, advancing by at
most one column per row. That buys two TPU-critical properties:

  * the candidate slice is a lane-uniform ``pl.ds(lo, bw)`` dynamic slice
    (no per-lane gather), and
  * realigning the previous row's band is a single select between the
    unshifted band and a static shift-by-one — ``shift = lo(i) - lo(i-1)``
    is always 0 or 1.

Per-lane pruning state (``next_start``) is kept as a mask on top of the
band, so pruning decisions are bit-identical to the full-width kernel and to
the banded JAX reference. Work per row drops from O(m) to O(band), i.e. the
prefix-scan doubling runs log2(band) steps instead of log2(m).
``band_width == m`` degenerates to the original full-width kernel
(``lo == 0`` always) and is used when ``n != m`` or the window covers the
whole matrix.

Per (block_k)-lane row step, entirely in VMEM/VREGs:
  * cost row  ``c[k, r] = (q_i - cand[k, lo + r])^2``        (VPU)
  * ``d = c + min(top, left)`` with top/left from the realigned band
  * row recurrence via prefix-sum + cumulative-min doubling (log2(band))
  * band bookkeeping: ``next_start`` per lane, per-lane abandon flags, UCR
    ``cb`` threshold tightening — all vectorized mask reductions against the
    per-lane ``ub`` column.

Early abandoning at TPU granularity: a lane whose row has no cell under its
own threshold freezes (its updates are masked out); when *every* lane of a
candidate block has abandoned, an SMEM flag turns all remaining row-blocks of
that block into ``pl.when`` no-ops — the kernel-level analogue of the paper's
border-collision early exit, at (query, candidate-block) granularity.

Optional pruning counters (``emit_info``): per-lane rows-issued and
admissible-cells accumulators, matching ``core.ea_pruned_dtw.EAInfo``
semantics, so ``SearchResult`` stats survive when search runs through the
Pallas backend. The counter-free variant carries no accumulator traffic —
the search fast round uses it by default.

Validated against ``ref.py`` and the banded JAX path in interpret mode on
CPU; written for TPU as the target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 1.0e30


def _shift_right(x: jax.Array, off: int, fill: float) -> jax.Array:
    """Shift last axis right by ``off`` lanes, filling with ``fill``."""
    pad = jnp.full(x.shape[:-1] + (off,), fill, x.dtype)
    return jnp.concatenate([pad, x[..., :-off]], axis=-1)


def _prefix_sum(x: jax.Array) -> jax.Array:
    """Inclusive prefix sum along the last axis (Hillis-Steele doubling)."""
    n = x.shape[-1]
    off = 1
    while off < n:
        x = x + _shift_right(x, off, 0.0)
        off *= 2
    return x


def _prefix_min(x: jax.Array) -> jax.Array:
    """Inclusive prefix min along the last axis (doubling)."""
    n = x.shape[-1]
    off = 1
    while off < n:
        x = jnp.minimum(x, _shift_right(x, off, jnp.inf))
        off *= 2
    return x


def _dtw_ea_kernel(
    # VMEM operands
    ub_ref,      # (block_k, 1) per-lane upper bounds
    q_ref,       # (1, row_block) query slice for this (query, row) block
    cand_ref,    # (block_k, m) candidate block (lanes share one query)
    cb_ref,      # (block_k, m) cumulative LB suffix (zeros if disabled)
    # outputs
    out_ref,     # (block_k,) distances
    *rest,       # [rows_out, cells_out] if emit_info, then scratch
    n_rows: int,
    window: int,
    row_block: int,
    band_width: int,
    use_cb: bool,
    emit_info: bool,
):
    if emit_info:
        rows_out, cells_out = rest[0], rest[1]
        rest = rest[2:]
    prev_ref, ns_ref, flags_ref, rows_ref, cells_ref, done_ref = rest

    ri = pl.program_id(2)
    block_k, m = cand_ref.shape
    bw = band_width
    lo_max = m - bw  # 0 in full-width mode

    @pl.when(ri == 0)
    def _init():
        prev_ref[...] = jnp.full((block_k, bw), BIG, jnp.float32)
        ns_ref[...] = jnp.zeros((block_k, 1), jnp.int32)
        flags_ref[...] = jnp.zeros((block_k, 2), jnp.int32)
        if emit_info:
            rows_ref[...] = jnp.zeros((block_k, 1), jnp.int32)
            cells_ref[...] = jnp.zeros((block_k, 1), jnp.int32)
        done_ref[0] = jnp.asarray(0, jnp.int32)  # literal 0 is int64 under x64

    @pl.when(done_ref[0] == 0)
    def _rows():
        ub = ub_ref[...]  # (block_k, 1) per-lane incumbents
        rel = jax.lax.broadcasted_iota(jnp.int32, (block_k, bw), 1)

        def row(r, _):
            i = ri * row_block + r
            valid = i < n_rows
            lo = jnp.clip(i - window, 0, lo_max)
            lo_prev = jnp.clip(i - 1 - window, 0, lo_max)
            shift = lo - lo_prev  # the window edge advances by 0 or 1

            q_i = q_ref[0, pl.ds(r, 1)]  # (1,)
            cand = cand_ref[:, pl.ds(lo, bw)]
            c = (q_i[0] - cand) ** 2

            cols = lo + rel
            hi = jnp.minimum(m - 1, i + window)
            ns = ns_ref[...]  # (block_k, 1)
            exists = jnp.logical_and(
                jnp.logical_and(cols >= ns, cols >= i - window), cols <= hi
            )

            # Realign the previous row's band from offset lo_prev to lo.
            prev = prev_ref[...]
            big_col = jnp.full((block_k, 1), BIG, jnp.float32)
            # top[r]  = prev-row value at col lo + r      (shift left by shift)
            top = jnp.where(
                shift == 1,
                jnp.concatenate([prev[:, 1:], big_col], axis=1),
                prev,
            )
            # left[r] = prev-row value at col lo + r - 1  (shift by shift - 1)
            border = jnp.where(i == 0, 0.0, BIG)  # virtual corner at (-1, -1)
            left = jnp.where(
                shift == 1,
                prev,
                jnp.concatenate(
                    [jnp.full((block_k, 1), border, jnp.float32), prev[:, :-1]],
                    axis=1,
                ),
            )

            d = c + jnp.minimum(top, left)
            d = jnp.where(exists, d, BIG)
            p = _prefix_sum(c)
            curr = p + _prefix_min(d - p)
            curr = jnp.minimum(curr, BIG)
            curr = jnp.where(exists, curr, BIG)

            if use_cb:
                jcb = jnp.minimum(i + window + 1, m - 1)
                tail = cb_ref[:, pl.ds(jcb, 1)]  # (block_k, 1)
                tail = jnp.where(i + window + 1 <= m - 1, tail, 0.0)
                thr = ub - tail
            else:
                thr = ub

            le = jnp.logical_and(curr <= thr, exists)
            any_le = jnp.any(le, axis=1, keepdims=True)  # (block_k, 1)
            alive = flags_ref[:, 0:1] == 0
            upd = jnp.logical_and(jnp.logical_and(alive, any_le), valid)

            ns_new = jnp.min(jnp.where(le, cols, m), axis=1, keepdims=True)
            ns_ref[...] = jnp.where(upd, ns_new.astype(jnp.int32), ns)
            prev_ref[...] = jnp.where(upd, curr, prev)
            newly_dead = jnp.logical_and(
                alive, jnp.logical_and(jnp.logical_not(any_le), valid)
            )
            flags_ref[:, 0:1] = jnp.where(
                newly_dead, jnp.ones_like(ns), flags_ref[:, 0:1]
            )
            is_last = i == n_rows - 1
            ok_last = jnp.logical_and(
                jnp.any(jnp.logical_and(le, cols == m - 1), axis=1, keepdims=True),
                jnp.logical_and(upd, is_last),
            )
            flags_ref[:, 1:2] = jnp.where(
                jnp.logical_and(valid, is_last),
                ok_last.astype(jnp.int32),
                flags_ref[:, 1:2],
            )
            if emit_info:
                # EAInfo semantics: the abandoning row is counted too.
                issued = jnp.logical_and(alive, valid)
                rows_ref[...] = rows_ref[...] + issued.astype(jnp.int32)
                n_exist = jnp.sum(
                    exists.astype(jnp.int32), axis=1, keepdims=True
                ).astype(jnp.int32)
                cells_ref[...] = (
                    cells_ref[...] + jnp.where(issued, n_exist, 0)
                ).astype(jnp.int32)
            return 0

        jax.lax.fori_loop(0, row_block, row, 0, unroll=False)
        done_ref[0] = jnp.asarray(
            jnp.all(flags_ref[:, 0] == 1), jnp.int32
        ).astype(jnp.int32)

    @pl.when(ri == pl.num_programs(2) - 1)
    def _finish():
        ok = jnp.logical_and(flags_ref[:, 0] == 0, flags_ref[:, 1] == 1)
        lo_fin = min(max(n_rows - 1 - window, 0), lo_max)  # static
        last = prev_ref[:, (m - 1) - lo_fin]
        out_ref[...] = jnp.where(ok, last, jnp.inf)
        if emit_info:
            rows_out[...] = rows_ref[:, 0]
            cells_out[...] = cells_ref[:, 0]
