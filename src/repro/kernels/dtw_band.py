"""Pallas TPU kernel: batched early-abandoning pruned DTW.

TPU-native shape of EAPrunedDTW (DESIGN.md §2): a grid of
``(candidate_blocks, row_blocks)`` programs. The candidate dimension is
embarrassingly parallel (``dimension_semantics[0] = "parallel"``); the row
dimension is sequential ("arbitrary") with the DP carry living in VMEM
scratch across grid steps.

Per (block_k)-lane row step, entirely in VMEM/VREGs:
  * cost row  ``c[k, j] = (q_i - cand[k, j])^2``            (VPU)
  * ``d = c + min(prev, prev<<1)``                          (VPU)
  * row recurrence via prefix-sum + cumulative-min doubling (log2(m) VPU ops)
  * band bookkeeping: ``next_start`` per lane, abandon flags, UCR ``cb``
    threshold tightening — all vectorized mask reductions.

Early abandoning at TPU granularity: a lane whose row has no cell under the
threshold freezes (its updates are masked out); when *every* lane of a
candidate block has abandoned, an SMEM flag turns all remaining row-blocks of
that block into ``pl.when`` no-ops — the kernel-level analogue of the paper's
border-collision early exit.

The kernel computes full-width rows (the query length m is at most ~1k in the
paper's workload, far under VMEM limits); column pruning happens at the
banded-JAX layer, row pruning here. Validated against ``ref.py`` in
interpret mode on CPU; written for TPU as the target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 1.0e30


def _shift_right(x: jax.Array, off: int, fill: float) -> jax.Array:
    """Shift last axis right by ``off`` lanes, filling with ``fill``."""
    pad = jnp.full(x.shape[:-1] + (off,), fill, x.dtype)
    return jnp.concatenate([pad, x[..., :-off]], axis=-1)


def _prefix_sum(x: jax.Array) -> jax.Array:
    """Inclusive prefix sum along the last axis (Hillis-Steele doubling)."""
    n = x.shape[-1]
    off = 1
    while off < n:
        x = x + _shift_right(x, off, 0.0)
        off *= 2
    return x


def _prefix_min(x: jax.Array) -> jax.Array:
    """Inclusive prefix min along the last axis (doubling)."""
    n = x.shape[-1]
    off = 1
    while off < n:
        x = jnp.minimum(x, _shift_right(x, off, jnp.inf))
        off *= 2
    return x


def _dtw_ea_kernel(
    # scalars / small operands
    ub_ref,      # SMEM (1,)
    # VMEM operands
    q_ref,       # (row_block,) query slice for this row block
    cand_ref,    # (block_k, m) candidate block
    cb_ref,      # (block_k, m) cumulative LB suffix (zeros if disabled)
    # outputs
    out_ref,     # (block_k,) distances
    # scratch
    prev_ref,    # VMEM (block_k, m) previous-row values
    ns_ref,      # VMEM (block_k, 1) int32 next_start per lane
    flags_ref,   # VMEM (block_k, 2) int32: [:,0] abandoned, [:,1] ok_last
    done_ref,    # SMEM (1,) int32: all lanes abandoned
    *,
    n_rows: int,
    window: int,
    row_block: int,
    use_cb: bool,
):
    ri = pl.program_id(1)
    block_k, m = cand_ref.shape

    @pl.when(ri == 0)
    def _init():
        prev_ref[...] = jnp.full((block_k, m), BIG, jnp.float32)
        ns_ref[...] = jnp.zeros((block_k, 1), jnp.int32)
        flags_ref[...] = jnp.zeros((block_k, 2), jnp.int32)
        done_ref[0] = 0

    @pl.when(done_ref[0] == 0)
    def _rows():
        ub = ub_ref[0]
        cand = cand_ref[...]
        cols = jax.lax.broadcasted_iota(jnp.int32, (block_k, m), 1)

        def row(r, _):
            i = ri * row_block + r
            valid = i < n_rows
            q_i = q_ref[pl.ds(r, 1)]  # (1,)
            c = (q_i[0] - cand) ** 2

            ns = ns_ref[...]  # (block_k, 1)
            in_win = jnp.abs(cols - i) <= window
            exists = jnp.logical_and(cols >= ns, in_win)

            border = jnp.where(i == 0, 0.0, BIG)
            prev = prev_ref[...]
            prev_sh = jnp.concatenate(
                [jnp.full((block_k, 1), border, jnp.float32), prev[:, :-1]], axis=1
            )
            d = c + jnp.minimum(prev, prev_sh)
            d = jnp.where(exists, d, BIG)
            p = _prefix_sum(c)
            curr = p + _prefix_min(d - p)
            curr = jnp.minimum(curr, BIG)
            curr = jnp.where(exists, curr, BIG)

            if use_cb:
                jcb = jnp.minimum(i + window + 1, m - 1)
                tail = cb_ref[:, pl.ds(jcb, 1)]  # (block_k, 1)
                tail = jnp.where(i + window + 1 <= m - 1, tail, 0.0)
                thr = ub - tail
            else:
                thr = jnp.full((block_k, 1), ub, jnp.float32)

            le = jnp.logical_and(curr <= thr, exists)
            any_le = jnp.any(le, axis=1, keepdims=True)  # (block_k, 1)
            alive = flags_ref[:, 0:1] == 0
            upd = jnp.logical_and(jnp.logical_and(alive, any_le), valid)

            ns_new = jnp.min(jnp.where(le, cols, m), axis=1, keepdims=True)
            ns_ref[...] = jnp.where(upd, ns_new.astype(jnp.int32), ns)
            prev_ref[...] = jnp.where(upd, curr, prev)
            newly_dead = jnp.logical_and(
                alive, jnp.logical_and(jnp.logical_not(any_le), valid)
            )
            flags_ref[:, 0:1] = jnp.where(
                newly_dead, jnp.ones_like(ns), flags_ref[:, 0:1]
            )
            is_last = i == n_rows - 1
            ok_last = jnp.logical_and(le[:, m - 1 :], jnp.logical_and(upd, is_last))
            flags_ref[:, 1:2] = jnp.where(
                jnp.logical_and(valid, is_last),
                ok_last.astype(jnp.int32),
                flags_ref[:, 1:2],
            )
            return 0

        jax.lax.fori_loop(0, row_block, row, 0, unroll=False)
        done_ref[0] = jnp.asarray(
            jnp.all(flags_ref[:, 0] == 1), jnp.int32
        ).astype(jnp.int32)

    @pl.when(ri == pl.num_programs(1) - 1)
    def _finish():
        ok = jnp.logical_and(flags_ref[:, 0] == 0, flags_ref[:, 1] == 1)
        last = prev_ref[:, m - 1]
        out_ref[...] = jnp.where(ok, last, jnp.inf)
