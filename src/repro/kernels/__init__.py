"""Pallas TPU kernels for the paper's compute hot-spots.

  dtw_band  — batched early-abandoning pruned DTW (the paper's core loop,
              TPU-tiled: query/candidate-parallel grid x sequential
              row-blocks, flattened (Q x K) lanes with a per-lane ub vector,
              banded columns with a window-following offset, VMEM DP carry,
              SMEM abandon flag, optional rows/cells pruning counters)
  lb_keogh  — LB_Kim + LB_Keogh for every window of a reference in one pass

``ops.py`` holds the jitted wrappers (interpret=True on CPU, Mosaic on TPU):
``dtw_ea_multi`` is the multi-query launch, ``dtw_ea`` its Q = 1 form, and
``dtw_ea_persistent`` the one-launch-per-search persistent form (sequential
candidate grid dimension, incumbent carried in SMEM scratch);
``ref.py`` the pure-jnp oracles the tests sweep against.
"""
from repro.kernels.ops import (
    dtw_ea,
    dtw_ea_multi,
    dtw_ea_persistent,
    lb_keogh_all_windows,
)

__all__ = ["dtw_ea", "dtw_ea_multi", "dtw_ea_persistent", "lb_keogh_all_windows"]
