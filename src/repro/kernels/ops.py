"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU backends (kernel body executed in
Python for validation) and False on TPU (real Mosaic lowering).

Backend dispatch: ``dtw_ea`` is the Pallas side of the
``core.backend`` dispatch layer — similarity search reaches it through
``core.batch.ea_pruned_dtw_batch(backend="pallas"|"pallas_interpret")``
rather than calling it directly. ``backend="pallas"`` lowers through Mosaic
on TPU (and falls back to interpret mode elsewhere); ``"pallas_interpret"``
forces interpret mode everywhere (the CPU test/CI path). The banded column
mode (``band_width``) mirrors ``core.ea_pruned_dtw.ea_pruned_dtw_banded``:
``band_width=None`` picks the smallest lane-aligned width covering
``2*window + 1`` columns; band mode requires ``n == m`` (subsequence-search
shape) and silently widens to full rows otherwise. ``with_info=True``
additionally returns per-lane ``(rows, cells)`` pruning counters
(``EAInfo`` semantics) at the cost of two int32 accumulators per lane —
the search fast round runs counter-free.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.common import default_band_width
from repro.kernels.dtw_band import _dtw_ea_kernel
from repro.kernels.lb_keogh import _lb_kernel


# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(
    jax.jit,
    static_argnames=(
        "window", "band_width", "block_k", "row_block", "interpret", "with_info"
    ),
)
def dtw_ea(
    query: jax.Array,
    candidates: jax.Array,
    ub: jax.Array,
    window: int,
    cb: jax.Array | None = None,
    band_width: int | None = None,
    block_k: int = 8,
    row_block: int = 128,
    interpret: bool | None = None,
    with_info: bool = False,
):
    """Batched early-abandoning pruned DTW (Pallas kernel, banded columns).

    Args:
      query: ``(n,)`` z-normalized query (rows of the DP).
      candidates: ``(K, m)`` candidate windows (columns of the DP).
      ub: scalar upper bound.
      window: Sakoe-Chiba window (use ``>= m`` for unconstrained).
      cb: optional ``(K, m)`` cumulative LB_Keogh suffix sums (UCR
        tightening); ``None`` disables.
      band_width: static band columns per row. ``None`` picks the smallest
        lane-aligned width covering ``2*window + 1`` (full width when
        ``n != m`` — band mode needs the square subsequence-search shape).
      block_k: candidate lanes per grid block (the parallel grid dim).
      row_block: DP rows per sequential grid step (early-exit granularity).
      with_info: also return per-lane ``(rows, cells)`` int32 counters.
    Returns: ``(K,)`` float32 distances, ``+inf`` where abandoned; with
      ``with_info`` a ``(dists, rows, cells)`` tuple.
    """
    if interpret is None:
        interpret = _default_interpret()
    query = jnp.asarray(query, jnp.float32)
    candidates = jnp.asarray(candidates, jnp.float32)
    n = query.shape[0]
    k, m = candidates.shape
    window = int(min(window, m))

    if band_width is None:
        band_width = default_band_width(window, m) if n == m else m
    bw = int(min(band_width, m))
    full = min(2 * window + 1, m)
    if bw < full:
        raise ValueError(f"band_width {bw} < 2*window+1 = {full}")
    if bw < m and n != m:
        raise ValueError("banded dtw_ea requires equal lengths (n == m)")

    use_cb = cb is not None
    if cb is None:
        cb_arr = jnp.zeros((k, m), jnp.float32)
    else:
        cb_arr = jnp.asarray(cb, jnp.float32)

    k_pad = -(-k // block_k) * block_k
    n_pad = -(-n // row_block) * row_block
    if k_pad != k:
        candidates = jnp.pad(candidates, ((0, k_pad - k), (0, 0)))
        cb_arr = jnp.pad(cb_arr, ((0, k_pad - k), (0, 0)))
    if n_pad != n:
        query = jnp.pad(query, (0, n_pad - n))

    grid = (k_pad // block_k, n_pad // row_block)
    kernel = partial(
        _dtw_ea_kernel,
        n_rows=n,
        window=window,
        row_block=row_block,
        band_width=bw,
        use_cb=use_cb,
        emit_info=with_info,
    )
    lane_spec = pl.BlockSpec((block_k,), lambda ci, ri: (ci,))
    out_specs = [lane_spec]
    out_shape = [jax.ShapeDtypeStruct((k_pad,), jnp.float32)]
    if with_info:
        out_specs += [lane_spec, lane_spec]
        out_shape += [
            jax.ShapeDtypeStruct((k_pad,), jnp.int32),
            jax.ShapeDtypeStruct((k_pad,), jnp.int32),
        ]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((row_block,), lambda ci, ri: (ri,)),
            pl.BlockSpec((block_k, m), lambda ci, ri: (ci, 0)),
            pl.BlockSpec((block_k, m), lambda ci, ri: (ci, 0)),
        ],
        out_specs=out_specs if with_info else out_specs[0],
        out_shape=out_shape if with_info else out_shape[0],
        scratch_shapes=[
            pltpu.VMEM((block_k, bw), jnp.float32),
            pltpu.VMEM((block_k, 1), jnp.int32),
            pltpu.VMEM((block_k, 2), jnp.int32),
            pltpu.VMEM((block_k, 1), jnp.int32),
            pltpu.VMEM((block_k, 1), jnp.int32),
            pltpu.SMEM((1,), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(
        jnp.reshape(jnp.asarray(ub, jnp.float32), (1,)),
        query,
        candidates,
        cb_arr,
    )
    if with_info:
        d, rows, cells = out
        return d[:k], rows[:k], cells[:k]
    return out[:k]


@partial(
    jax.jit,
    static_argnames=("length", "chunk", "interpret"),
)
def lb_keogh_all_windows(
    ref: jax.Array,
    mu: jax.Array,
    sigma: jax.Array,
    upper: jax.Array,
    lower: jax.Array,
    qends: jax.Array,
    length: int,
    chunk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """LB_Kim + LB_Keogh for every z-normalized window of ``ref``.

    Args:
      ref: ``(N,)`` reference series (resident in VMEM — suitable for
        references up to a few MB; shard first for longer ones).
      mu, sigma: per-window stats ``(N_win,)`` (from search.znorm).
      upper, lower: query envelope ``(length,)``.
      qends: ``(2,)`` first/last value of the z-normalized query (LB_Kim).
    Returns: ``(N_win,)`` lower bounds (max of Kim and Keogh).
    """
    if interpret is None:
        interpret = _default_interpret()
    ref = jnp.asarray(ref, jnp.float32)
    n = ref.shape[0]
    n_win = n - length + 1
    n_pad = -(-n_win // chunk) * chunk
    mu_p = jnp.pad(jnp.asarray(mu, jnp.float32), (0, n_pad - n_win))
    sg_p = jnp.pad(jnp.asarray(sigma, jnp.float32), (0, n_pad - n_win), constant_values=1.0)
    # pad ref so every chunk can read ``chunk + length`` samples
    ref_p = jnp.pad(ref, (0, n_pad + length - n))

    grid = (n_pad // chunk,)
    kernel = partial(_lb_kernel, length=length, chunk=chunk, n_win=n_win)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # query endpoints (2,)
            pl.BlockSpec(memory_space=pltpu.VMEM),  # full ref in VMEM
            pl.BlockSpec((chunk,), lambda ci: (ci,)),
            pl.BlockSpec((chunk,), lambda ci: (ci,)),
            pl.BlockSpec(memory_space=pltpu.VMEM),  # envelope upper, full
            pl.BlockSpec(memory_space=pltpu.VMEM),  # envelope lower, full
        ],
        out_specs=pl.BlockSpec((chunk,), lambda ci: (ci,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(
        jnp.asarray(qends, jnp.float32),
        ref_p,
        mu_p,
        sg_p,
        jnp.asarray(upper, jnp.float32),
        jnp.asarray(lower, jnp.float32),
    )
    return out[:n_win]
