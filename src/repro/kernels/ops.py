"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU backends (kernel body executed in
Python for validation) and False on TPU (real Mosaic lowering).

Backend dispatch: ``dtw_ea`` / ``dtw_ea_multi`` are the Pallas side of the
``core.backend`` dispatch layer — similarity search reaches them through
``core.batch.ea_pruned_dtw_batch`` / ``ea_pruned_dtw_multi_batch`` with
``backend="pallas"|"pallas_interpret"`` rather than calling them directly.
``backend="pallas"`` lowers through Mosaic on TPU (and falls back to
interpret mode elsewhere); ``"pallas_interpret"`` forces interpret mode
everywhere (the CPU test/CI path).

Lane layout (multi-query): ``dtw_ea_multi`` evaluates a flattened
``(Q × K)`` lane set in one launch. Candidates are reshaped to
``(Q * k_pad, m)`` query-major, the grid is
``(Q, cand_blocks, row_blocks)``, and each grid program's ``block_k`` lanes
all belong to one query — the query/envelope tile is selected by the
leading grid index while ``ub`` rides along as a per-lane
``(block_k, 1)`` VMEM vector. Scalar ``ub`` broadcasts to every lane;
padding lanes (``K`` rounded up to ``block_k``) get a ``-1`` sentinel so
they abandon on their first row and never delay a block's early exit.

The banded column mode (``band_width``) mirrors
``core.ea_pruned_dtw.ea_pruned_dtw_banded``: ``band_width=None`` picks the
smallest lane-aligned width covering ``2*window + 1`` columns; band mode
requires ``n == m`` (subsequence-search shape) and silently widens to full
rows otherwise. ``with_info=True`` additionally returns per-lane
``(rows, cells)`` pruning counters (``EAInfo`` semantics) at the cost of two
int32 accumulators per lane — the search fast round runs counter-free.

Fused operand form (DESIGN.md §2.10, the ``gather="fused"`` default):
``dtw_ea_multi_fused`` / ``dtw_ea_persistent_fused`` take the raw reference
series once plus per-lane ``(start, mu, sigma)`` vectors and slice +
z-normalize each block's windows inside the kernel — no pre-gathered
``(Q, K, m)`` slab crosses the host→device boundary. References whose
padded byte size exceeds ``ref_budget`` (default ``REF_VMEM_BYTES``) stay
in HBM (``memory_space=ANY``) and the kernel streams each lane's window by
explicit DMA. The slab-form wrappers above remain as the ``gather="slab"``
comparison arm and the baseline cores' entry point.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.common import (
    DEAD_LANE_UB,
    default_band_width,
    pad_lanes_to_blocks,
)
from repro.kernels.dtw_band import (
    _dtw_ea_fused_kernel,
    _dtw_ea_kernel,
    _dtw_ea_persistent_kernel,
)
from repro.kernels.lb_keogh import _lb_kernel


# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

# Fused-gather reference tier threshold: a (padded) reference at or below
# this byte size rides in VMEM as a whole-array block; above it the operand
# stays in HBM (memory_space=ANY) and the kernel DMA-streams each lane's
# window slice. ~4 MB leaves headroom beside the per-block scratch within a
# ~16 MB TPU VMEM. Overridable per call (``ref_budget``) — tests force the
# DMA tier with a tiny budget.
REF_VMEM_BYTES = 4 * 1024 * 1024


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_ref_2d(ref: jax.Array) -> jax.Array:
    """Reference as a lane-aligned ``(1, N_pad)`` row (TPU wants 2-D)."""
    ref = jnp.asarray(ref, jnp.float32)
    n = ref.shape[0]
    n_pad = -(-n // 128) * 128
    if n_pad != n:
        ref = jnp.pad(ref, (0, n_pad - n))
    return ref[None, :]


@partial(
    jax.jit,
    static_argnames=(
        "window", "band_width", "block_k", "row_block", "interpret", "with_info"
    ),
)
def dtw_ea_multi(
    queries: jax.Array,
    candidates: jax.Array,
    ub: jax.Array,
    window: int,
    cb: jax.Array | None = None,
    band_width: int | None = None,
    block_k: int = 8,
    row_block: int = 128,
    interpret: bool | None = None,
    with_info: bool = False,
):
    """Multi-query batched EAPrunedDTW: one launch, ``Q × K`` lanes.

    Args:
      queries: ``(Q, n)`` z-normalized queries (rows of the DP).
      candidates: ``(Q, K, m)`` candidate windows per query.
      ub: per-lane upper bounds — scalar, ``(Q, 1)`` or ``(Q, K)``
        (broadcast to ``(Q, K)``). Lanes abandon against their own value; a
        negative entry kills its lane on row 0 (finished-query sentinel).
      window: Sakoe-Chiba window shared by all queries (``>= m`` for
        unconstrained).
      cb: optional ``(Q, K, m)`` cumulative LB_Keogh suffix sums (UCR
        tightening); ``None`` disables.
      band_width: static band columns per row. ``None`` picks the smallest
        lane-aligned width covering ``2*window + 1`` (full width when
        ``n != m`` — band mode needs the square subsequence-search shape).
      block_k: candidate lanes per grid block (a parallel grid dim).
      row_block: DP rows per sequential grid step (early-exit granularity).
      with_info: also return per-lane ``(rows, cells)`` int32 counters.
    Returns: ``(Q, K)`` float32 distances, ``+inf`` where abandoned; with
      ``with_info`` a ``(dists, rows, cells)`` tuple of ``(Q, K)`` arrays.
    """
    if interpret is None:
        interpret = _default_interpret()
    queries = jnp.asarray(queries, jnp.float32)
    candidates = jnp.asarray(candidates, jnp.float32)
    nq, n = queries.shape
    q_, k, m = candidates.shape
    assert q_ == nq, (q_, nq)
    window = int(min(window, m))

    if band_width is None:
        band_width = default_band_width(window, m) if n == m else m
    bw = int(min(band_width, m))
    full = min(2 * window + 1, m)
    if bw < full:
        raise ValueError(f"band_width {bw} < 2*window+1 = {full}")
    if bw < m and n != m:
        raise ValueError("banded dtw_ea requires equal lengths (n == m)")

    use_cb = cb is not None
    if cb is None:
        cb_arr = jnp.zeros((nq, k, m), jnp.float32)
    else:
        cb_arr = jnp.asarray(cb, jnp.float32)

    k_pad = -(-k // block_k) * block_k
    n_pad = -(-n // row_block) * row_block
    ub_arr = jnp.broadcast_to(jnp.asarray(ub, jnp.float32), (nq, k))
    if k_pad != k:
        candidates = jnp.pad(candidates, ((0, 0), (0, k_pad - k), (0, 0)))
        cb_arr = jnp.pad(cb_arr, ((0, 0), (0, k_pad - k), (0, 0)))
        ub_arr = jnp.pad(
            ub_arr, ((0, 0), (0, k_pad - k)), constant_values=DEAD_LANE_UB
        )
    if n_pad != n:
        queries = jnp.pad(queries, ((0, 0), (0, n_pad - n)))

    ncb = k_pad // block_k
    grid = (nq, ncb, n_pad // row_block)
    # query-major flattened lane set: block row qi * ncb + ci
    cand_flat = candidates.reshape(nq * k_pad, m)
    cb_flat = cb_arr.reshape(nq * k_pad, m)
    ub_flat = ub_arr.reshape(nq * k_pad, 1)

    kernel = partial(
        _dtw_ea_kernel,
        n_rows=n,
        window=window,
        row_block=row_block,
        band_width=bw,
        use_cb=use_cb,
        emit_info=with_info,
    )
    lane_block = lambda qi, ci, ri: (qi * ncb + ci,)
    lane_spec = pl.BlockSpec((block_k,), lane_block)
    out_specs = [lane_spec]
    out_shape = [jax.ShapeDtypeStruct((nq * k_pad,), jnp.float32)]
    if with_info:
        out_specs += [lane_spec, lane_spec]
        out_shape += [
            jax.ShapeDtypeStruct((nq * k_pad,), jnp.int32),
            jax.ShapeDtypeStruct((nq * k_pad,), jnp.int32),
        ]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k, 1), lambda qi, ci, ri: (qi * ncb + ci, 0)),
            pl.BlockSpec((1, row_block), lambda qi, ci, ri: (qi, ri)),
            pl.BlockSpec((block_k, m), lambda qi, ci, ri: (qi * ncb + ci, 0)),
            pl.BlockSpec((block_k, m), lambda qi, ci, ri: (qi * ncb + ci, 0)),
        ],
        out_specs=out_specs if with_info else out_specs[0],
        out_shape=out_shape if with_info else out_shape[0],
        scratch_shapes=[
            pltpu.VMEM((block_k, bw), jnp.float32),
            pltpu.VMEM((block_k, 1), jnp.int32),
            pltpu.VMEM((block_k, 2), jnp.int32),
            pltpu.VMEM((block_k, 1), jnp.int32),
            pltpu.VMEM((block_k, 1), jnp.int32),
            pltpu.SMEM((1,), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(
        ub_flat,
        queries,
        cand_flat,
        cb_flat,
    )
    if with_info:
        d, rows, cells = out
        return (
            d.reshape(nq, k_pad)[:, :k],
            rows.reshape(nq, k_pad)[:, :k],
            cells.reshape(nq, k_pad)[:, :k],
        )
    return out.reshape(nq, k_pad)[:, :k]


def dtw_ea(
    query: jax.Array,
    candidates: jax.Array,
    ub: jax.Array,
    window: int,
    cb: jax.Array | None = None,
    band_width: int | None = None,
    block_k: int = 8,
    row_block: int = 128,
    interpret: bool | None = None,
    with_info: bool = False,
):
    """Single-query batched EAPrunedDTW — ``dtw_ea_multi`` with ``Q = 1``.

    Args:
      query: ``(n,)`` z-normalized query (rows of the DP).
      candidates: ``(K, m)`` candidate windows (columns of the DP).
      ub: scalar upper bound shared by every lane, or a ``(K,)`` per-lane
        vector.
      window, cb, band_width, block_k, row_block, with_info: as in
        ``dtw_ea_multi`` (``cb`` is ``(K, m)`` here).
    Returns: ``(K,)`` float32 distances, ``+inf`` where abandoned; with
      ``with_info`` a ``(dists, rows, cells)`` tuple.
    """
    ub = jnp.asarray(ub, jnp.float32)
    out = dtw_ea_multi(
        jnp.asarray(query)[None],
        jnp.asarray(candidates)[None],
        ub[None] if ub.ndim == 1 else ub,
        window,
        cb=None if cb is None else jnp.asarray(cb)[None],
        band_width=band_width,
        block_k=block_k,
        row_block=row_block,
        interpret=interpret,
        with_info=with_info,
    )
    if with_info:
        d, rows, cells = out
        return d[0], rows[0], cells[0]
    return out[0]


@partial(
    jax.jit,
    static_argnames=(
        "window", "length", "use_cb", "band_width", "block_k", "row_block",
        "interpret", "with_info", "ref_budget",
    ),
)
def dtw_ea_multi_fused(
    queries: jax.Array,
    ref: jax.Array,
    starts: jax.Array,
    mu: jax.Array,
    sg: jax.Array,
    ub: jax.Array,
    window: int,
    length: int,
    u: jax.Array | None = None,
    low: jax.Array | None = None,
    use_cb: bool = False,
    band_width: int | None = None,
    block_k: int = 8,
    row_block: int = 128,
    interpret: bool | None = None,
    with_info: bool = False,
    ref_budget: int | None = None,
):
    """Fused-gather ``dtw_ea_multi``: windows sliced + normalized in-kernel.

    Same DP program and return contract as ``dtw_ea_multi``, but the
    candidate operand is the raw reference series (resident once, O(N))
    plus per-lane ``(start, mu, sigma)`` vectors — the kernel materializes
    each block's normalized tile into VMEM scratch, so no O(Q·K·m) window
    slab is built on the host or shipped to the device. With ``use_cb`` the
    UCR ``cb`` suffix is likewise built in-kernel from the query envelopes
    (tree-order suffix sum — the documented O(1)-ulp reformulation vs the
    host drivers' sequential cumsum; thresholds may shift by an ulp, the
    winner cannot change).

    Args (where they differ from ``dtw_ea_multi``):
      ref: ``(N,)`` raw (sanitized) reference series, shared by all lanes.
      starts: ``(Q, K)`` int32 window start per lane (in ``[0, N - length]``;
        padding lanes may repeat any valid start).
      mu, sg: ``(Q, K)`` per-lane window mean and **pre-clamped** sigma
        (``clamp_sigma`` applied by the caller — the kernel divides as-is,
        keeping flat-window output bit-identical to the retired slab).
      length: static candidate window length ``m``.
      u, low: ``(Q, m)`` query envelopes — required when ``use_cb``.
      ref_budget: VMEM byte budget for the reference operand; a padded
        reference above it stays in HBM and is DMA-streamed per lane
        (default ``REF_VMEM_BYTES``).
    """
    if interpret is None:
        interpret = _default_interpret()
    queries = jnp.asarray(queries, jnp.float32)
    starts = jnp.asarray(starts, jnp.int32)
    nq, n = queries.shape
    q_, k = starts.shape
    assert q_ == nq, (q_, nq)
    m = int(length)
    window = int(min(window, m))

    if band_width is None:
        band_width = default_band_width(window, m) if n == m else m
    bw = int(min(band_width, m))
    full = min(2 * window + 1, m)
    if bw < full:
        raise ValueError(f"band_width {bw} < 2*window+1 = {full}")
    if bw < m and n != m:
        raise ValueError("banded dtw_ea requires equal lengths (n == m)")
    if use_cb and (u is None or low is None):
        raise ValueError("use_cb requires the query envelopes (u, low)")

    ref2 = _pad_ref_2d(ref)
    n_ref_pad = ref2.shape[1]
    budget = REF_VMEM_BYTES if ref_budget is None else int(ref_budget)
    ref_in_vmem = n_ref_pad * 4 <= budget

    k_pad = -(-k // block_k) * block_k
    n_pad = -(-n // row_block) * row_block
    ub_arr = jnp.broadcast_to(jnp.asarray(ub, jnp.float32), (nq, k))
    mu_arr = jnp.asarray(mu, jnp.float32)
    sg_arr = jnp.asarray(sg, jnp.float32)
    if k_pad != k:
        pw = ((0, 0), (0, k_pad - k))
        starts = jnp.pad(starts, pw)  # start 0 is always in range
        mu_arr = jnp.pad(mu_arr, pw)
        sg_arr = jnp.pad(sg_arr, pw, constant_values=1.0)
        ub_arr = jnp.pad(ub_arr, pw, constant_values=DEAD_LANE_UB)
    if n_pad != n:
        queries = jnp.pad(queries, ((0, 0), (0, n_pad - n)))
    if u is None:
        u_arr = jnp.zeros((nq, m), jnp.float32)
        low_arr = jnp.zeros((nq, m), jnp.float32)
    else:
        u_arr = jnp.asarray(u, jnp.float32)
        low_arr = jnp.asarray(low, jnp.float32)

    ncb = k_pad // block_k
    grid = (nq, ncb, n_pad // row_block)
    starts_flat = starts.reshape(nq * k_pad, 1)
    mu_flat = mu_arr.reshape(nq * k_pad, 1)
    sg_flat = sg_arr.reshape(nq * k_pad, 1)
    ub_flat = ub_arr.reshape(nq * k_pad, 1)

    kernel = partial(
        _dtw_ea_fused_kernel,
        n_rows=n,
        window=window,
        row_block=row_block,
        band_width=bw,
        use_cb=use_cb,
        emit_info=with_info,
        ref_in_vmem=ref_in_vmem,
    )
    lane_block = lambda qi, ci, ri: (qi * ncb + ci,)
    lane_spec = pl.BlockSpec((block_k,), lane_block)
    lane2 = lambda: pl.BlockSpec(
        (block_k, 1), lambda qi, ci, ri: (qi * ncb + ci, 0)
    )
    if ref_in_vmem:
        ref_spec = pl.BlockSpec((1, n_ref_pad), lambda qi, ci, ri: (0, 0))
    else:
        ref_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    out_specs = [lane_spec]
    out_shape = [jax.ShapeDtypeStruct((nq * k_pad,), jnp.float32)]
    if with_info:
        out_specs += [lane_spec, lane_spec]
        out_shape += [
            jax.ShapeDtypeStruct((nq * k_pad,), jnp.int32),
            jax.ShapeDtypeStruct((nq * k_pad,), jnp.int32),
        ]
    scratch = [
        pltpu.VMEM((block_k, m), jnp.float32),    # normalized candidate tile
        pltpu.VMEM((block_k, m), jnp.float32),    # in-kernel cb suffix
        pltpu.VMEM((block_k, bw), jnp.float32),   # prev band
        pltpu.VMEM((block_k, 1), jnp.int32),      # next_start
        pltpu.VMEM((block_k, 2), jnp.int32),      # flags
        pltpu.VMEM((block_k, 1), jnp.int32),      # rows counter
        pltpu.VMEM((block_k, 1), jnp.int32),      # cells counter
        pltpu.SMEM((1,), jnp.int32),              # block done flag
    ]
    if not ref_in_vmem:
        scratch.append(pltpu.SemaphoreType.DMA)   # window-slice DMA sem
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            lane2(),                                           # ub
            pl.BlockSpec((1, row_block), lambda qi, ci, ri: (qi, ri)),
            ref_spec,                                          # raw reference
            lane2(),                                           # starts
            lane2(),                                           # mu
            lane2(),                                           # sigma
            pl.BlockSpec((1, m), lambda qi, ci, ri: (qi, 0)),  # envelope u
            pl.BlockSpec((1, m), lambda qi, ci, ri: (qi, 0)),  # envelope low
        ],
        out_specs=out_specs if with_info else out_specs[0],
        out_shape=out_shape if with_info else out_shape[0],
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(
        ub_flat,
        queries,
        ref2,
        starts_flat,
        mu_flat,
        sg_flat,
        u_arr,
        low_arr,
    )
    if with_info:
        d, rows, cells = out
        return (
            d.reshape(nq, k_pad)[:, :k],
            rows.reshape(nq, k_pad)[:, :k],
            cells.reshape(nq, k_pad)[:, :k],
        )
    return out.reshape(nq, k_pad)[:, :k]


@partial(
    jax.jit,
    static_argnames=(
        "window", "use_cb", "band_width", "block_k", "row_block", "interpret"
    ),
)
def dtw_ea_persistent(
    queries: jax.Array,
    candidates: jax.Array,
    lb: jax.Array,
    starts: jax.Array,
    ub_init: jax.Array,
    window: int,
    u: jax.Array | None = None,
    low: jax.Array | None = None,
    use_cb: bool = False,
    band_width: int | None = None,
    block_k: int = 8,
    row_block: int = 128,
    interpret: bool | None = None,
):
    """Whole best-first EAPrunedDTW search in ONE launch per query set.

    The persistent form of ``dtw_ea_multi`` (DESIGN.md §2.5): instead of the
    host looping best-first rounds around kernel dispatches, the candidate
    dimension of the grid turns sequential and the incumbent is carried in
    SMEM scratch across candidate blocks — tightened by each block's
    surviving minimum and gating the next block's lower bound on device.
    This wrapper is the pre-gathered **slab** arm (``gather="slab"``): it
    still takes the O(K·m) normalized window matrix, and is kept as the
    comparison baseline; the default execution form is
    ``dtw_ea_persistent_fused``, which ships the raw reference once and
    slices windows in-kernel. Lanes must arrive in best-first
    (ascending-``lb``) order in either form; gating correctness only needs
    ``lb`` to be a true lower bound, but the on-device cascade stop is only
    as good as the ordering.

    Args:
      queries: ``(Q, n)`` z-normalized queries.
      candidates: ``(Q, K, m)`` z-normalized windows, best-first per query.
      lb: ``(Q, K)`` ascending per-lane lower bounds (``+inf`` marks padding
        lanes — they never run).
      starts: ``(Q, K)`` int32 global window start of each lane (the value
        reported back for the winning lane).
      ub_init: ``(Q,)`` initial incumbents (``BIG`` for a cold start; a warm
        seed that no candidate beats is returned unchanged with start -1).
      window: Sakoe-Chiba window shared by all queries.
      u, low: ``(Q, m)`` query envelopes — required when ``use_cb`` (the cb
        suffix is computed as a kernel prologue; no host-side cb slab).
      use_cb: UCR threshold tightening on/off.
      band_width, block_k, row_block, interpret: as in ``dtw_ea_multi``.

    Returns: ``(best_dist, best_start, blocks)`` of shapes ``(Q,)`` —
      float32 incumbent distances, int32 winning window starts (-1 when the
      seed was never beaten), int32 count of candidate blocks that actually
      ran (the block-granular work metric; dispatches are 1 by construction).
    """
    if interpret is None:
        interpret = _default_interpret()
    queries = jnp.asarray(queries, jnp.float32)
    candidates = jnp.asarray(candidates, jnp.float32)
    nq, n = queries.shape
    q_, k, m = candidates.shape
    assert q_ == nq, (q_, nq)
    window = int(min(window, m))

    if band_width is None:
        band_width = default_band_width(window, m) if n == m else m
    bw = int(min(band_width, m))
    full = min(2 * window + 1, m)
    if bw < full:
        raise ValueError(f"band_width {bw} < 2*window+1 = {full}")
    if bw < m and n != m:
        raise ValueError("banded dtw_ea requires equal lengths (n == m)")
    if use_cb and (u is None or low is None):
        raise ValueError("use_cb requires the query envelopes (u, low)")

    n_pad = -(-n // row_block) * row_block
    lb_arr, starts_arr, candidates = pad_lanes_to_blocks(
        block_k, jnp.asarray(lb, jnp.float32),
        jnp.asarray(starts, jnp.int32), candidates,
    )
    k_pad = candidates.shape[1]
    if n_pad != n:
        queries = jnp.pad(queries, ((0, 0), (0, n_pad - n)))
    if u is None:
        u_arr = jnp.zeros((nq, m), jnp.float32)
        low_arr = jnp.zeros((nq, m), jnp.float32)
    else:
        u_arr = jnp.asarray(u, jnp.float32)
        low_arr = jnp.asarray(low, jnp.float32)

    ncb = k_pad // block_k
    grid = (nq, ncb, n_pad // row_block)
    cand_flat = candidates.reshape(nq * k_pad, m)
    lb_flat = lb_arr.reshape(nq * k_pad, 1)
    starts_flat = starts_arr.reshape(nq * k_pad, 1)

    kernel = partial(
        _dtw_ea_persistent_kernel,
        n_rows=n,
        window=window,
        row_block=row_block,
        band_width=bw,
        use_cb=use_cb,
    )
    lane2 = lambda shape: pl.BlockSpec(shape, lambda qi, ci, ri: (qi * ncb + ci, 0))
    q_spec = pl.BlockSpec((1,), lambda qi, ci, ri: (qi,))
    dist, idx, blocks = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),           # ub_init (Q,)
            pl.BlockSpec((1, row_block), lambda qi, ci, ri: (qi, ri)),
            lane2((block_k, m)),                              # candidates
            lane2((block_k, 1)),                              # lb
            lane2((block_k, 1)),                              # starts
            pl.BlockSpec((1, m), lambda qi, ci, ri: (qi, 0)),  # envelope u
            pl.BlockSpec((1, m), lambda qi, ci, ri: (qi, 0)),  # envelope low
        ],
        out_specs=[q_spec, q_spec, q_spec],
        out_shape=[
            jax.ShapeDtypeStruct((nq,), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, bw), jnp.float32),   # prev band
            pltpu.VMEM((block_k, 1), jnp.int32),      # next_start
            pltpu.VMEM((block_k, 2), jnp.int32),      # flags
            pltpu.VMEM((block_k, 1), jnp.float32),    # per-lane thresholds
            pltpu.VMEM((block_k, m), jnp.float32),    # cb prologue slab
            pltpu.SMEM((1,), jnp.int32),              # block done flag
            pltpu.SMEM((1,), jnp.float32),            # carried incumbent
            pltpu.SMEM((1,), jnp.int32),              # carried best start
            pltpu.SMEM((1,), jnp.int32),              # live-block counter
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(
        jnp.asarray(ub_init, jnp.float32),
        queries,
        cand_flat,
        lb_flat,
        starts_flat,
        u_arr,
        low_arr,
    )
    return dist, idx, blocks


@partial(
    jax.jit,
    static_argnames=(
        "window", "length", "use_cb", "band_width", "block_k", "row_block",
        "interpret", "ref_budget",
    ),
)
def dtw_ea_persistent_fused(
    queries: jax.Array,
    ref: jax.Array,
    lb: jax.Array,
    starts: jax.Array,
    mu: jax.Array,
    sg: jax.Array,
    ub_init: jax.Array,
    window: int,
    length: int,
    u: jax.Array | None = None,
    low: jax.Array | None = None,
    use_cb: bool = False,
    band_width: int | None = None,
    block_k: int = 8,
    row_block: int = 128,
    interpret: bool | None = None,
    ref_budget: int | None = None,
):
    """Fused-gather persistent sweep: the whole search, no window slab.

    ``dtw_ea_persistent`` with the candidate matrix replaced by the raw
    reference series plus per-lane ``(start, mu, sigma)`` vectors — each
    live candidate block's normalized tile is sliced out of the resident
    reference inside the kernel (gated blocks skip the copies entirely),
    so the launch's working set is O(N + block_k·m) instead of O(K·m).
    That is the form that completes persistent sweeps over references whose
    O(N·l) slab could never be materialized. Lanes must still arrive in
    best-first (ascending-``lb``) order.

    Args (where they differ from ``dtw_ea_persistent``):
      ref: ``(N,)`` raw (sanitized) reference series.
      mu, sg: ``(Q, K)`` per-lane window mean and **pre-clamped** sigma.
      length: static candidate window length ``m``.
      ref_budget: VMEM byte budget for the reference operand; above it the
        reference stays in HBM and windows are DMA-streamed per lane
        (default ``REF_VMEM_BYTES``).

    Returns: ``(best_dist, best_start, blocks)`` — as ``dtw_ea_persistent``.
    """
    if interpret is None:
        interpret = _default_interpret()
    queries = jnp.asarray(queries, jnp.float32)
    nq, n = queries.shape
    m = int(length)
    window = int(min(window, m))

    if band_width is None:
        band_width = default_band_width(window, m) if n == m else m
    bw = int(min(band_width, m))
    full = min(2 * window + 1, m)
    if bw < full:
        raise ValueError(f"band_width {bw} < 2*window+1 = {full}")
    if bw < m and n != m:
        raise ValueError("banded dtw_ea requires equal lengths (n == m)")
    if use_cb and (u is None or low is None):
        raise ValueError("use_cb requires the query envelopes (u, low)")

    ref2 = _pad_ref_2d(ref)
    n_ref_pad = ref2.shape[1]
    budget = REF_VMEM_BYTES if ref_budget is None else int(ref_budget)
    ref_in_vmem = n_ref_pad * 4 <= budget

    lb_arr = jnp.asarray(lb, jnp.float32)
    starts_arr = jnp.asarray(starts, jnp.int32)
    mu_arr = jnp.asarray(mu, jnp.float32)
    sg_arr = jnp.asarray(sg, jnp.float32)
    k = lb_arr.shape[-1]
    k_pad = -(-k // block_k) * block_k
    if k_pad != k:
        pw = ((0, 0), (0, k_pad - k))
        lb_arr = jnp.pad(lb_arr, pw, constant_values=jnp.inf)
        starts_arr = jnp.pad(starts_arr, pw)  # start 0 is always in range
        mu_arr = jnp.pad(mu_arr, pw)
        sg_arr = jnp.pad(sg_arr, pw, constant_values=1.0)
    n_pad = -(-n // row_block) * row_block
    if n_pad != n:
        queries = jnp.pad(queries, ((0, 0), (0, n_pad - n)))
    if u is None:
        u_arr = jnp.zeros((nq, m), jnp.float32)
        low_arr = jnp.zeros((nq, m), jnp.float32)
    else:
        u_arr = jnp.asarray(u, jnp.float32)
        low_arr = jnp.asarray(low, jnp.float32)

    ncb = k_pad // block_k
    grid = (nq, ncb, n_pad // row_block)
    lb_flat = lb_arr.reshape(nq * k_pad, 1)
    starts_flat = starts_arr.reshape(nq * k_pad, 1)
    mu_flat = mu_arr.reshape(nq * k_pad, 1)
    sg_flat = sg_arr.reshape(nq * k_pad, 1)

    kernel = partial(
        _dtw_ea_persistent_kernel,
        n_rows=n,
        window=window,
        row_block=row_block,
        band_width=bw,
        use_cb=use_cb,
        fused=True,
        ref_in_vmem=ref_in_vmem,
    )
    lane2 = lambda shape: pl.BlockSpec(shape, lambda qi, ci, ri: (qi * ncb + ci, 0))
    q_spec = pl.BlockSpec((1,), lambda qi, ci, ri: (qi,))
    if ref_in_vmem:
        ref_spec = pl.BlockSpec((1, n_ref_pad), lambda qi, ci, ri: (0, 0))
    else:
        ref_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    scratch = [
        pltpu.VMEM((block_k, m), jnp.float32),    # normalized candidate tile
        pltpu.VMEM((block_k, bw), jnp.float32),   # prev band
        pltpu.VMEM((block_k, 1), jnp.int32),      # next_start
        pltpu.VMEM((block_k, 2), jnp.int32),      # flags
        pltpu.VMEM((block_k, 1), jnp.float32),    # per-lane thresholds
        pltpu.VMEM((block_k, m), jnp.float32),    # cb prologue slab
        pltpu.SMEM((1,), jnp.int32),              # block done flag
        pltpu.SMEM((1,), jnp.float32),            # carried incumbent
        pltpu.SMEM((1,), jnp.int32),              # carried best start
        pltpu.SMEM((1,), jnp.int32),              # live-block counter
    ]
    if not ref_in_vmem:
        scratch.append(pltpu.SemaphoreType.DMA)   # window-slice DMA sem
    dist, idx, blocks = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),           # ub_init (Q,)
            pl.BlockSpec((1, row_block), lambda qi, ci, ri: (qi, ri)),
            ref_spec,                                         # raw reference
            lane2((block_k, 1)),                              # lb
            lane2((block_k, 1)),                              # starts
            lane2((block_k, 1)),                              # mu
            lane2((block_k, 1)),                              # sigma
            pl.BlockSpec((1, m), lambda qi, ci, ri: (qi, 0)),  # envelope u
            pl.BlockSpec((1, m), lambda qi, ci, ri: (qi, 0)),  # envelope low
        ],
        out_specs=[q_spec, q_spec, q_spec],
        out_shape=[
            jax.ShapeDtypeStruct((nq,), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
        ],
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(
        jnp.asarray(ub_init, jnp.float32),
        queries,
        ref2,
        lb_flat,
        starts_flat,
        mu_flat,
        sg_flat,
        u_arr,
        low_arr,
    )
    return dist, idx, blocks


@partial(
    jax.jit,
    static_argnames=("length", "chunk", "interpret"),
)
def lb_keogh_all_windows(
    ref: jax.Array,
    mu: jax.Array,
    sigma: jax.Array,
    upper: jax.Array,
    lower: jax.Array,
    qends: jax.Array,
    length: int,
    chunk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """LB_Kim + LB_Keogh for every z-normalized window of ``ref``.

    Args:
      ref: ``(N,)`` reference series (resident in VMEM — suitable for
        references up to a few MB; shard first for longer ones).
      mu, sigma: per-window stats ``(N_win,)`` (from search.znorm).
      upper, lower: query envelope ``(length,)``.
      qends: ``(2,)`` first/last value of the z-normalized query (LB_Kim).
    Returns: ``(N_win,)`` lower bounds (max of Kim and Keogh).
    """
    if interpret is None:
        interpret = _default_interpret()
    ref = jnp.asarray(ref, jnp.float32)
    n = ref.shape[0]
    n_win = n - length + 1
    n_pad = -(-n_win // chunk) * chunk
    mu_p = jnp.pad(jnp.asarray(mu, jnp.float32), (0, n_pad - n_win))
    sg_p = jnp.pad(jnp.asarray(sigma, jnp.float32), (0, n_pad - n_win), constant_values=1.0)
    # pad ref so every chunk can read ``chunk + length`` samples
    ref_p = jnp.pad(ref, (0, n_pad + length - n))

    grid = (n_pad // chunk,)
    kernel = partial(_lb_kernel, length=length, chunk=chunk, n_win=n_win)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # query endpoints (2,)
            pl.BlockSpec(memory_space=pltpu.VMEM),  # full ref in VMEM
            pl.BlockSpec((chunk,), lambda ci: (ci,)),
            pl.BlockSpec((chunk,), lambda ci: (ci,)),
            pl.BlockSpec(memory_space=pltpu.VMEM),  # envelope upper, full
            pl.BlockSpec(memory_space=pltpu.VMEM),  # envelope lower, full
        ],
        out_specs=pl.BlockSpec((chunk,), lambda ci: (ci,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(
        jnp.asarray(qends, jnp.float32),
        ref_p,
        mu_p,
        sg_p,
        jnp.asarray(upper, jnp.float32),
        jnp.asarray(lower, jnp.float32),
    )
    return out[:n_win]
