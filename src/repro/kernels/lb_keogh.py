"""Pallas TPU kernel: LB_Kim + LB_Keogh for every window, one pass.

The TPU-native formulation iterates over the *query offset* ``i`` instead of
the window start: for fixed ``i``, the contribution of offset ``i`` to all
``chunk`` windows is a unit-stride ``(chunk,)`` slice of the reference —
perfect VPU lanes — normalized per window and clamped against the scalar
envelope values ``U[i]``/``L[i]``. ``length`` iterations of ``(chunk,)``-wide
FMAs replace the CPU suite's per-candidate loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-8


def _lb_kernel(
    qends_ref,  # SMEM (2,): z-normed query first/last values
    ref_ref,    # VMEM (N_pad,) reference series
    mu_ref,     # (chunk,) per-window means
    sg_ref,     # (chunk,) per-window stds
    u_ref,      # VMEM (length,) envelope upper
    l_ref,      # VMEM (length,) envelope lower
    out_ref,    # (chunk,) lower bounds
    *,
    length: int,
    chunk: int,
    n_win: int,
):
    ci = pl.program_id(0)
    c0 = ci * chunk
    mu = mu_ref[...]
    inv = 1.0 / jnp.maximum(sg_ref[...], EPS)

    def offset_step(i, acc):
        seg = ref_ref[pl.ds(c0 + i, chunk)]
        v = (seg - mu) * inv
        ui = u_ref[pl.ds(i, 1)][0]
        li = l_ref[pl.ds(i, 1)][0]
        over = jnp.maximum(v - ui, 0.0)
        under = jnp.maximum(li - v, 0.0)
        return acc + over * over + under * under

    keogh = jax.lax.fori_loop(
        0, length, offset_step, jnp.zeros((chunk,), jnp.float32)
    )

    # LB_Kim (first/last points)
    v0 = (ref_ref[pl.ds(c0, chunk)] - mu) * inv
    vl = (ref_ref[pl.ds(c0 + length - 1, chunk)] - mu) * inv
    kim = (v0 - qends_ref[0]) ** 2 + (vl - qends_ref[1]) ** 2

    out_ref[...] = jnp.maximum(keogh, kim)
