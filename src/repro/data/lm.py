"""Deterministic synthetic LM token pipeline.

Produces a Zipf-distributed token stream with local n-gram structure (so the
loss actually decreases during the example training runs), packed into
(batch, seq) examples. Deterministic per (seed, step) — a restarted job
resumes mid-epoch without coordination, which is the property a real sharded
loader must provide for fault-tolerant training (see
distributed/fault_tolerance.py).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


class TokenStream:
    """Stateless batch generator: ``batch(step)`` is a pure function."""

    def __init__(
        self,
        vocab: int,
        batch: int,
        seq_len: int,
        seed: int = 0,
        n_shards: int = 1,
        shard: int = 0,
    ):
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.n_shards = n_shards
        self.shard = shard
        # a fixed random bigram table gives learnable local structure
        tr = np.random.default_rng(seed)
        self._successors = tr.integers(0, vocab, size=(min(vocab, 4096), 8))

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * self.n_shards + self.shard
        )
        b, s, v = self.batch, self.seq_len + 1, self.vocab
        # zipf marginals
        toks = rng.zipf(1.3, size=(b, s)).astype(np.int64) % v
        # inject bigram structure: with p=0.6 the next token is a fixed
        # successor of the current one
        follow = rng.random((b, s)) < 0.6
        idx = toks[:, :-1] % self._successors.shape[0]
        succ = self._successors[idx, rng.integers(0, 8, size=(b, s - 1))]
        toks[:, 1:] = np.where(follow[:, 1:], succ, toks[:, 1:])
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
