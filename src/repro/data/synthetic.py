"""Synthetic long time series shaped like the paper's six datasets.

The paper's experiments (§5) run over FoG, Soccer, PAMAP2, ECG, REFIT, PPG —
each one long reference series + 1024-sample queries. We generate spectrally
distinct analogues (deterministic per seed) so the benchmark suite exercises
the same regimes: quasi-periodic biosignals (ECG/PPG), random-walk-like load
measurements (REFIT), mixed activity (PAMAP2/FoG), and bursty motion
(Soccer). Queries are cut from a disjoint section of the generator stream,
matching the suite's query-vs-reference protocol.
"""
from __future__ import annotations

import zlib

import numpy as np

DATASETS = ("FoG", "Soccer", "PAMAP2", "ECG", "REFIT", "PPG")


def _ecg_like(rng: np.random.Generator, n: int, period: int = 180) -> np.ndarray:
    t = np.arange(n)
    phase = (t % period) / period
    qrs = np.exp(-((phase - 0.1) ** 2) / 0.0004) * 2.2
    pwave = np.exp(-((phase - 0.7) ** 2) / 0.004) * 0.4
    drift = 0.3 * np.sin(2 * np.pi * t / (37 * period))
    jitter = rng.normal(0, 0.05, n)
    return qrs + pwave + drift + jitter


def _ppg_like(rng, n, period=220):
    t = np.arange(n)
    base = np.sin(2 * np.pi * t / period) + 0.35 * np.sin(4 * np.pi * t / period + 0.8)
    resp = 0.25 * np.sin(2 * np.pi * t / (period * 4.7))
    return base + resp + rng.normal(0, 0.03, n)


def _walk(rng, n, scale=1.0):
    return np.cumsum(rng.normal(0, scale, n))


def _activity(rng, n, seg=2048):
    out = np.empty(n)
    i = 0
    while i < n:
        k = min(seg + int(rng.integers(-seg // 2, seg // 2)), n - i)
        freq = rng.uniform(0.01, 0.12)
        amp = rng.uniform(0.3, 2.0)
        t = np.arange(k)
        out[i : i + k] = amp * np.sin(2 * np.pi * freq * t + rng.uniform(0, 6.28))
        out[i : i + k] += rng.normal(0, 0.15, k)
        i += k
    return out + 0.05 * _walk(rng, n, 0.2)


def _bursty(rng, n):
    base = _walk(rng, n, 0.3)
    bursts = (rng.random(n) < 0.002).astype(float)
    kernel = np.exp(-np.arange(64) / 12.0)
    spikes = np.convolve(bursts * rng.normal(3, 1, n), kernel)[:n]
    return base + spikes


def make_dataset(name: str, n: int, seed: int = 0) -> np.ndarray:
    """Long reference series for a paper-analogue dataset.

    Deterministic *across processes*: the seed mixes ``zlib.crc32`` of the
    name, not Python's per-process-salted ``hash()`` — benchmark artifacts
    (BENCH_dtw.json) must be comparable between runs and PRs.
    """
    rng = np.random.default_rng((zlib.crc32(name.encode()) + 977 * seed) % (2**31))
    if name == "ECG":
        return _ecg_like(rng, n)
    if name == "PPG":
        return _ppg_like(rng, n)
    if name == "REFIT":
        return np.abs(_walk(rng, n, 0.5)) + _activity(rng, n, 4096) * 0.3
    if name == "PAMAP2":
        return _activity(rng, n, 3072)
    if name == "FoG":
        return _activity(rng, n, 1024) + 0.2 * _bursty(rng, n)
    if name == "Soccer":
        return _bursty(rng, n)
    raise ValueError(f"unknown dataset {name!r}")


def make_queries(
    name: str, n_queries: int, length: int = 1024, seed: int = 1
) -> np.ndarray:
    """Queries cut from a disjoint stretch of the same generator."""
    stream = make_dataset(name, (n_queries + 2) * length * 3, seed=seed + 1000)
    rng = np.random.default_rng(seed)
    starts = rng.choice(len(stream) - length, n_queries, replace=False)
    return np.stack([stream[s : s + length] for s in starts])
