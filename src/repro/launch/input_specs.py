"""ShapeDtypeStruct stand-ins for every (architecture x shape) cell.

Nothing here allocates: the dry-run lowers ``train_step`` / ``serve_step``
against these abstract inputs only. Modality frontends are stubs per the
assignment: ``[vlm]``/``[audio]`` cells feed precomputed patch/frame
embeddings of the assigned sequence length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch: dict = {"labels": SDS((b, s), jnp.int32)}
    if cfg.input_embeds:
        batch["embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            batch["tokens"] = SDS((b, s), jnp.int32)
    else:
        batch["tokens"] = SDS((b, s), jnp.int32)
    return batch


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.input_embeds:
        return {"embeds": SDS((b, s, cfg.d_model), jnp.bfloat16)}
    return {"tokens": SDS((b, s), jnp.int32)}


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = shape.global_batch
    return {
        "tokens": SDS((b, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }


def cache_shapes(model, shape: ShapeConfig):
    """Abstract KV/state cache for a decode cell (seq_len of context)."""
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len)
    )


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is this (arch x shape) cell runnable? (False, reason) if skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full quadratic attention; long_500k requires sub-quadratic"
    return True, ""
