import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). 512 host devices back both the 16x16 single-pod mesh
# and the 2x16x16 multi-pod mesh.

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh) cell.

For each cell this driver:
  1. builds the jitted step (train_step / prefill / decode) with the
     production in/out shardings,
  2. ``.lower(**abstract inputs).compile()`` — sharding mismatches, OOM at
     compile, and unsupported collectives all fail HERE, which is the point,
  3. records ``compiled.cost_analysis()`` (FLOPs / bytes), the collective
     operands parsed from the post-SPMD HLO, ``memory_analysis()``, and the
     analytic per-device bytes of params/optimizer/cache,
  4. writes one JSON per cell under results/dryrun/ (resumable).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--force]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES
from repro.distributed import hints
from repro.distributed.sharding import (
    batch_axes,
    make_batch_specs,
    make_cache_specs,
    make_param_specs,
    make_state_specs,
    named,
)
from repro.launch.input_specs import (
    applicable,
    decode_inputs,
    prefill_inputs,
    train_batch_specs,
)
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build
from repro.roofline.hlo_stats import analyze_hlo
from repro.train.train_step import init_state, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")
OPT_RESULTS_DIR = RESULTS_DIR + "_opt"


def _bytes_of(tree) -> int:
    return sum(
        leaf.size * jnp.dtype(leaf.dtype).itemsize for leaf in jax.tree.leaves(tree)
    )


def _sharded_bytes(shapes, specs, mesh) -> int:
    """Per-device bytes given PartitionSpecs (analytic, no allocation)."""
    total = 0
    for leaf, spec in zip(jax.tree.leaves(shapes), jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        shards = 1
        for entry in tuple(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shards *= mesh.shape[a]
        total += leaf.size * jnp.dtype(leaf.dtype).itemsize // max(shards, 1)
    return total


# Per-arch config tuning applied only in the optimized sweep (§Perf-E1):
# kimi's 384-expert dispatch conflicts with generic anchors; the shard_map
# expert-parallel MoE + halved microbatch count turns the 0.8x regression
# into a 1.67x win (collective 385->211s, memory 165->121s).
OPT_OVERRIDES: dict = {
    "kimi-k2-1t-a32b": {"train_4k": dict(moe_impl="ep", num_microbatches=8)},
}


def lower_cell(arch: str, shape_name: str, multi_pod: bool, optimized: bool = False) -> dict:
    import dataclasses

    cfg = ARCHS[arch]
    if optimized:
        over = OPT_OVERRIDES.get(arch, {}).get(shape_name)
        if over:
            cfg = dataclasses.replace(cfg, **over)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    if optimized:
        # §Perf: activation anchors everywhere; sequence parallelism for
        # prefill (long S, no backward) — measured win; hurts short-S train.
        hints.set_axes(batch_axes(mesh), seq_parallel=(shape.kind == "prefill"), mesh=mesh)
    else:
        hints.clear()
    model = build(cfg)
    t0 = time.time()
    result: dict = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "mesh": dict(zip(mesh.axis_names, [mesh.shape[a] for a in mesh.axis_names])),
        "kind": shape.kind,
    }

    pspecs = make_param_specs(model, mesh)
    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    result["param_count"] = int(sum(l.size for l in jax.tree.leaves(pshapes)))
    result["param_bytes_per_device"] = _sharded_bytes(pshapes, pspecs, mesh)

    with mesh:
        if shape.kind == "train":
            train_step = make_train_step(model)
            sspecs = make_state_specs(model, mesh)
            sshapes = jax.eval_shape(lambda k: init_state(model, k), jax.random.PRNGKey(0))
            batch = train_batch_specs(cfg, shape)
            bspecs = make_batch_specs(batch, mesh)
            result["state_bytes_per_device"] = _sharded_bytes(sshapes, sspecs, mesh)
            jitted = jax.jit(
                train_step,
                in_shardings=(named(mesh, sspecs), named(mesh, bspecs)),
                out_shardings=(named(mesh, sspecs), named(mesh, P())),
            )
            lowered = jitted.lower(sshapes, batch)
        elif shape.kind == "prefill":
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            cspecs = make_cache_specs(model, mesh, shape.global_batch, shape.seq_len)
            result["cache_bytes_per_device"] = _sharded_bytes(cache_shapes, cspecs, mesh)
            inp = prefill_inputs(cfg, shape)
            key0 = "embeds" if "embeds" in inp else "tokens"
            ispec = make_batch_specs(inp, mesh)[key0]
            if model.prefill is not None:
                fn = lambda p, cache, x: model.prefill(p, cache, **{key0: x})
                jitted = jax.jit(
                    fn,
                    in_shardings=(
                        named(mesh, pspecs),
                        named(mesh, cspecs),
                        named(mesh, ispec),
                    ),
                )
                lowered = jitted.lower(pshapes, cache_shapes, inp[key0])
            else:
                # hybrid archs: prefill compute == forward over the prompt
                fn = lambda p, x: model.forward(p, **{key0: x})
                jitted = jax.jit(
                    fn, in_shardings=(named(mesh, pspecs), named(mesh, ispec))
                )
                lowered = jitted.lower(pshapes, inp[key0])
        else:  # decode
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            cspecs = make_cache_specs(model, mesh, shape.global_batch, shape.seq_len)
            result["cache_bytes_per_device"] = _sharded_bytes(cache_shapes, cspecs, mesh)
            if optimized:
                # §Perf-D4: inference has no optimizer state; if TP-sharded
                # weights + cache fit HBM, drop FSDP sharding and its
                # per-layer weight all-gathers (measured 60x collective).
                # Only when the batch actually shards the data axis — at
                # batch=1 (long_500k) distributed weights are the win.
                param_bytes = _bytes_of(pshapes)
                tp_resident = param_bytes / mesh.shape["model"]
                budget = 14 * 2**30
                ba_tot = 1
                for a in batch_axes(mesh):
                    ba_tot *= mesh.shape[a]
                fits = tp_resident + result["cache_bytes_per_device"] <= budget
                batched = shape.global_batch % ba_tot == 0
                if fits and batched:
                    pspecs = make_param_specs(model, mesh, fsdp_shard=False)
                    result["decode_fsdp"] = False
                else:
                    result["decode_fsdp"] = True
            inp = decode_inputs(cfg, shape)
            ba = batch_axes(mesh)
            tot = 1
            for a in ba:
                tot *= mesh.shape[a]
            tok_spec = P(ba if shape.global_batch % tot == 0 else None, None)
            fn = lambda p, cache, tok, pos: model.decode_step(p, cache, tok, pos)
            jitted = jax.jit(
                fn,
                in_shardings=(
                    named(mesh, pspecs),
                    named(mesh, cspecs),
                    named(mesh, tok_spec),
                    named(mesh, P()),
                ),
                out_shardings=(None, named(mesh, cspecs)),
            )
            lowered = jitted.lower(
                pshapes, cache_shapes, inp["tokens"], inp["pos"]
            )

        result["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 2)

        ca = compiled.cost_analysis() or {}
        result["cost_analysis"] = {
            k: float(v)
            for k, v in ca.items()
            if isinstance(v, (int, float)) and k in (
                "flops", "bytes accessed", "transcendentals",
                "bytes accessed from memory", "utilization operand",
            ) or k in ("flops", "bytes accessed")
        }
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                result["memory_analysis"] = {
                    attr: int(getattr(ma, attr))
                    for attr in (
                        "argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "generated_code_size_in_bytes",
                        "alias_size_in_bytes",
                    )
                    if hasattr(ma, attr)
                }
        except Exception as e:  # CPU backend may not expose it
            result["memory_analysis_error"] = str(e)

        hlo = compiled.as_text()
        stats = analyze_hlo(hlo)
        result["hlo_stats"] = stats
        result["collectives"] = {
            "total_bytes": stats["collective_total"],
            "per_op_bytes": stats["collective_bytes"],
            "counts": stats["collective_counts"],
        }
        result["hlo_bytes"] = len(hlo)
    result["status"] = "ok"
    result["optimized"] = optimized
    result["total_s"] = round(time.time() - t0, 2)
    hints.clear()
    return result


def lower_search_cell(multi_pod: bool) -> dict:
    """Dry-run the paper's own workload: distributed EAPrunedDTW search
    sharded over every axis of the production mesh."""
    from repro.configs import SEARCH_CONFIG as SC
    from repro.search.distributed import make_distributed_search

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    result: dict = {
        "arch": "dtw-search", "shape": f"N{SC.ref_len}_l{SC.query_len}",
        "multi_pod": multi_pod, "kind": "search",
        "mesh": dict(zip(mesh.axis_names, [mesh.shape[a] for a in mesh.axis_names])),
    }
    search = make_distributed_search(
        mesh, tuple(mesh.axis_names), length=SC.query_len, window=SC.window,
        batch=SC.batch,
    )
    ref = jax.ShapeDtypeStruct((SC.ref_len,), jnp.float32)
    query = jax.ShapeDtypeStruct((SC.query_len,), jnp.float32)
    lowered = search.lower(ref, query)
    result["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    result["compile_s"] = round(time.time() - t1, 2)
    ca = compiled.cost_analysis() or {}
    result["cost_analysis"] = {
        k: float(v) for k, v in ca.items()
        if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")
    }
    hlo = compiled.as_text()
    stats = analyze_hlo(hlo)
    result["hlo_stats"] = stats
    result["collectives"] = {
        "total_bytes": stats["collective_total"],
        "per_op_bytes": stats["collective_bytes"],
        "counts": stats["collective_counts"],
    }
    result["note"] = (
        "search rounds are data-dependent (dynamic while); HLO stats are "
        "per-round lower bounds — see benchmarks/bench_suites.py for "
        "measured round counts"
    )
    result["status"] = "ok"
    result["total_s"] = round(time.time() - t0, 2)
    return result


def cell_path(arch, shape_name, multi_pod, optimized=False):
    tag = "multipod" if multi_pod else "pod"
    base = OPT_RESULTS_DIR if optimized else RESULTS_DIR
    return os.path.join(base, f"{arch}__{shape_name}__{tag}.json")


def run_cell(arch, shape_name, multi_pod, force=False, optimized=False) -> dict:
    path = cell_path(arch, shape_name, multi_pod, optimized)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    try:
        res = lower_cell(arch, shape_name, multi_pod, optimized)
    except Exception as e:
        res = {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "status": "error", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--search", action="store_true", help="dry-run the paper's search workload")
    ap.add_argument("--opt", action="store_true", help="optimized shardings (results/dryrun_opt)")
    args = ap.parse_args()

    if args.search:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        for mp in ([False, True] if args.both_meshes else [args.multipod]):
            tag = "multipod" if mp else "pod"
            path = os.path.join(RESULTS_DIR, f"dtw-search__{tag}.json")
            if os.path.exists(path) and not args.force:
                continue
            try:
                res = lower_search_cell(mp)
            except Exception as e:
                res = {"arch": "dtw-search", "multi_pod": mp, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            print(f"{res.get('status', '?').upper():5s} dtw-search {tag} "
                  f"coll={res.get('collectives', {}).get('total_bytes', 0):.3e}B "
                  f"compile={res.get('compile_s', 0)}s", flush=True)
        return

    cells = []
    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    n_ok = n_skip = n_err = 0
    for a, s, mp in cells:
        res = run_cell(a, s, mp, force=args.force, optimized=args.opt)
        status = res.get("status")
        tag = "multipod" if mp else "pod"
        if status == "ok":
            n_ok += 1
            ca = res.get("cost_analysis", {})
            print(
                f"OK   {a:24s} {s:12s} {tag:8s} "
                f"flops={ca.get('flops', 0):.3e} "
                f"coll={res['collectives'].get('total_bytes', 0):.3e}B "
                f"compile={res.get('compile_s', 0):.0f}s",
                flush=True,
            )
        elif status == "skipped":
            n_skip += 1
            print(f"SKIP {a:24s} {s:12s} {tag:8s} ({res['reason']})", flush=True)
        else:
            n_err += 1
            print(f"ERR  {a:24s} {s:12s} {tag:8s} {res.get('error')}", flush=True)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
