"""End-to-end training driver.

On the production pod this runs under the 16x16 mesh with the full configs;
on CPU (``--reduced``) it trains the same-family miniature for real — the
driver, sharding path, checkpointing and supervision are identical.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
      --steps 60 --batch 8 --seq 64 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.data.lm import TokenStream
from repro.distributed.fault_tolerance import TrainingSupervisor
from repro.distributed.sharding import (
    make_batch_specs,
    make_state_specs,
    named,
)
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.registry import build
from repro.train.train_step import init_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    if args.batch % max(cfg.num_microbatches, 1):
        cfg = dataclasses.replace(cfg, num_microbatches=1)
    model = build(cfg)

    mesh = (
        make_production_mesh()
        if args.production_mesh
        else make_local_mesh(args.model_parallel)
    )
    print(f"arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    stream = TokenStream(cfg.vocab, args.batch, args.seq, seed=args.seed)

    def data_at(step: int):
        batch = stream.batch_at(step)
        if cfg.input_embeds:
            rng = np.random.default_rng(step)
            batch["embeds"] = rng.normal(
                size=(args.batch, args.seq, cfg.d_model)
            ).astype(np.float32)
            if cfg.family == "vlm":
                batch.pop("tokens")
        specs = make_batch_specs(
            {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()},
            mesh,
        )
        return {k: jax.device_put(v, named(mesh, specs[k])) for k, v in batch.items()}

    state = init_state(model, jax.random.PRNGKey(args.seed))
    sspecs = make_state_specs(model, mesh)
    state = jax.device_put(state, named(mesh, sspecs))

    step_fn = jax.jit(
        make_train_step(model, base_lr=args.lr, warmup=10, total_steps=args.steps),
        in_shardings=(named(mesh, sspecs), None),
        out_shardings=(named(mesh, sspecs), None),
        donate_argnums=(0,),
    )

    sup = TrainingSupervisor(
        step_fn, data_at, args.ckpt, ckpt_every=args.ckpt_every
    )
    t0 = time.time()
    state, log = sup.run(state, args.steps)
    dt = time.time() - t0
    first, last = log[0]["loss"], log[-1]["loss"]
    print(
        f"steps={len(log)} loss {first:.4f} -> {last:.4f} "
        f"({dt:.1f}s, {dt / max(len(log), 1):.3f}s/step, "
        f"stragglers={len(sup.monitor.flagged)}, restarts={sup.restarts})"
    )
    assert np.isfinite(last), "training diverged"


if __name__ == "__main__":
    main()
