"""Similarity-search driver — the paper's application, as a service entry.

  PYTHONPATH=src python -m repro.launch.search --dataset ECG --ref-len 100000 \
      --query-len 256 --window-ratio 0.1 --variant eapruned

Runs all four suite variants with ``--variant all`` and prints the paper-style
comparison (runtime + pruning counters). ``--distributed`` shards candidates
over the local device mesh with shared-ub rounds.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.data.synthetic import DATASETS, make_dataset, make_queries
from repro.search import make_distributed_search, subsequence_search
from repro.search.subsequence import VARIANTS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ECG", choices=DATASETS)
    ap.add_argument("--ref-len", type=int, default=100_000)
    ap.add_argument("--query-len", type=int, default=256)
    ap.add_argument("--window-ratio", type=float, default=0.1)
    ap.add_argument("--variant", default="eapruned", choices=VARIANTS + ("all",))
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--n-queries", type=int, default=1)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    ref = jnp.asarray(make_dataset(args.dataset, args.ref_len, args.seed), jnp.float32)
    queries = make_queries(args.dataset, args.n_queries, args.query_len, args.seed)
    window = max(int(args.query_len * args.window_ratio), 1)
    variants = list(VARIANTS) if args.variant == "all" else [args.variant]

    print(
        f"dataset={args.dataset} N={args.ref_len} l={args.query_len} "
        f"w={window} batch={args.batch}"
    )
    if args.distributed:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        search = make_distributed_search(
            mesh, ("data",), length=args.query_len, window=window, batch=args.batch
        )
        for qi, q in enumerate(queries):
            t0 = time.time()
            res = search(ref, jnp.asarray(q, jnp.float32))
            jax.block_until_ready(res.best_dist)
            print(
                f"  q{qi}: start={int(res.best_start)} dist={float(res.best_dist):.5f} "
                f"rounds={int(res.rounds)} ({time.time() - t0:.2f}s)"
            )
        return

    for variant in variants:
        tot = 0.0
        for qi, q in enumerate(queries):
            t0 = time.time()
            res = subsequence_search(
                ref,
                jnp.asarray(q, jnp.float32),
                length=args.query_len,
                window=window,
                variant=variant,
                batch=args.batch,
            )
            jax.block_until_ready(res.best_dist)
            dt = time.time() - t0
            tot += dt
            print(
                f"  {variant:14s} q{qi}: start={int(res.best_start)} "
                f"dist={float(res.best_dist):.5f} lanes={int(res.lanes)} "
                f"rows={int(res.rows)} cells={int(res.cells)} ({dt:.2f}s)"
            )
        print(f"  {variant:14s} total {tot:.2f}s")


if __name__ == "__main__":
    main()
