"""Batched serving driver: prefill + decode over any registered arch.

  PYTHONPATH=src python -m repro.launch.serve --arch mistral-nemo-12b \
      --reduced --batch 4 --prompt-len 16 --new-tokens 32

On the production mesh the same driver runs with sharded params and the
sequence-sharded (or rolling/SSM) caches exercised by the decode dry-run
cells; on CPU (--reduced) it generates for real.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models.registry import build
from repro.serve.generate import generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    if model.prefill is None:
        raise SystemExit(f"{cfg.name} (family {cfg.family}) has no prefill path")
    params = model.init(jax.random.PRNGKey(args.seed))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"serving {cfg.name}: {n/1e6:.1f}M params, batch={args.batch}")

    rng = np.random.default_rng(args.seed)
    prompt = jax.numpy.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))
    )
    t0 = time.time()
    out = generate(
        model, params, prompt, args.new_tokens,
        temperature=args.temperature, key=jax.random.PRNGKey(args.seed),
    )
    jax.block_until_ready(out)
    dt = time.time() - t0
    tput = args.batch * args.new_tokens / dt
    print(f"generated {args.new_tokens} tokens x {args.batch} seqs "
          f"in {dt:.2f}s ({tput:.1f} tok/s)")
    print("sample continuation ids:", np.asarray(out[0, args.prompt_len:])[:16])


if __name__ == "__main__":
    main()
