"""Production mesh construction.

A function (never a module-level constant) so importing this module touches
no jax device state. Target: TPU v5e pods — 16x16 = 256 chips per pod;
multi-pod adds a leading "pod" axis (2 pods = 512 chips).
"""
from __future__ import annotations

import jax

PEAK_FLOPS = 197e12        # bf16 per chip (TPU v5e class)
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (ring model)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
