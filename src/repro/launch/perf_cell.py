import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Hillclimb measurement harness: lower one cell with optional experimental
toggles, print the three roofline terms (compare against results/dryrun/).

  PYTHONPATH=src python -m repro.launch.perf_cell --arch qwen2-72b \
      --shape train_4k [--hints] [--remat-policy dots] [--tag exp1]
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES
from repro.distributed import hints
from repro.distributed.sharding import (
    batch_axes,
    make_batch_specs,
    make_cache_specs,
    make_param_specs,
    make_state_specs,
    named,
)
from repro.launch.input_specs import decode_inputs, train_batch_specs
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS, make_production_mesh
from repro.models.registry import build
from repro.roofline.hlo_stats import analyze_hlo
from repro.train.train_step import init_state, make_train_step


def measure(arch: str, shape_name: str, use_hints: bool, multi_pod: bool = False):
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build(cfg)
    if use_hints:
        hints.set_axes(batch_axes(mesh), mesh=mesh)
    else:
        hints.clear()

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            train_step = make_train_step(model)
            sspecs = make_state_specs(model, mesh)
            sshapes = jax.eval_shape(lambda k: init_state(model, k), jax.random.PRNGKey(0))
            batch = train_batch_specs(cfg, shape)
            bspecs = make_batch_specs(batch, mesh)
            jitted = jax.jit(
                train_step,
                in_shardings=(named(mesh, sspecs), named(mesh, bspecs)),
                out_shardings=(named(mesh, sspecs), named(mesh, P())),
            )
            compiled = jitted.lower(sshapes, batch).compile()
        elif shape.kind == "decode":
            pspecs = make_param_specs(model, mesh)
            pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            cspecs = make_cache_specs(model, mesh, shape.global_batch, shape.seq_len)
            inp = decode_inputs(cfg, shape)
            ba = batch_axes(mesh)
            tot = 1
            for a in ba:
                tot *= mesh.shape[a]
            tok_spec = P(ba if shape.global_batch % tot == 0 else None, None)
            fn = lambda p, cache, tok, pos: model.decode_step(p, cache, tok, pos)
            jitted = jax.jit(
                fn,
                in_shardings=(named(mesh, pspecs), named(mesh, cspecs),
                              named(mesh, tok_spec), named(mesh, P())),
                out_shardings=(None, named(mesh, cspecs)),
            )
            compiled = jitted.lower(pshapes, cache_shapes, inp["tokens"], inp["pos"]).compile()
        else:  # prefill
            pspecs = make_param_specs(model, mesh)
            pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            from repro.launch.input_specs import prefill_inputs

            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            cspecs = make_cache_specs(model, mesh, shape.global_batch, shape.seq_len)
            inp = prefill_inputs(cfg, shape)
            key0 = "embeds" if "embeds" in inp else "tokens"
            ispec = make_batch_specs(inp, mesh)[key0]
            if model.prefill is not None:
                fn = lambda p, cache, x: model.prefill(p, cache, **{key0: x})
                jitted = jax.jit(fn, in_shardings=(
                    named(mesh, pspecs), named(mesh, cspecs), named(mesh, ispec)))
                compiled = jitted.lower(pshapes, cache_shapes, inp[key0]).compile()
            else:
                fn = lambda p, x: model.forward(p, **{key0: x})
                jitted = jax.jit(fn, in_shardings=(named(mesh, pspecs), named(mesh, ispec)))
                compiled = jitted.lower(pshapes, inp[key0]).compile()

        st = analyze_hlo(compiled.as_text())
    hints.clear()
    out = {
        "compute_s": st["dot_flops"] / PEAK_FLOPS,
        "memory_s": st["mem_bytes"] / HBM_BW,
        "collective_s": st["collective_total"] / ICI_BW,
        "dot_flops": st["dot_flops"],
        "mem_bytes": st["mem_bytes"],
        "collective_bytes": st["collective_bytes"],
        "compile_s": round(time.time() - t0, 1),
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--hints", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    out = measure(args.arch, args.shape, args.hints, args.multipod)
    label = f"{args.arch}/{args.shape}" + (" +hints" if args.hints else " baseline")
    if args.tag:
        label += f" [{args.tag}]"
    print(f"{label}: compute={out['compute_s']:.2f}s memory={out['memory_s']:.2f}s "
          f"collective={out['collective_s']:.2f}s (compile {out['compile_s']}s)")
    print(json.dumps({k: v for k, v in out.items() if k != 'collective_bytes'}))
    print("coll mix:", {k: f"{v:.2e}" for k, v in out["collective_bytes"].items()})


if __name__ == "__main__":
    main()
