"""SwiGLU MLP and sort-based top-k MoE (dropping, capacity-bounded).

The MoE dispatch is the production-style sort formulation (MegaBlocks /
MaxText lineage), not the GShard one-hot einsum — the (T*k) assignment sort
plus capacity-bounded scatter keeps the dispatch buffer at (E, C, D) instead
of a (T, E, C) one-hot, which is what makes the 384-expert Kimi-K2 config
compilable and shardable (experts on the "model" axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def init_mlp(key, cfg, d_ff: int | None = None) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, ff), dt),
        "w_up": dense_init(k2, (d, ff), dt),
        "w_down": dense_init(k3, (ff, d), dt),
    }


def mlp(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def init_moe(key, cfg) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    e = cfg.n_experts
    ffe = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, ffe), dt),
        "w_up": dense_init(ks[2], (e, d, ffe), dt),
        "w_down": dense_init(ks[3], (e, ffe, d), dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=ffe * cfg.n_shared_experts)
    return p


def moe(p: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """Top-k MoE layer. Returns (output, aux load-balancing loss)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    if s == 1:
        cap = t  # decode: buffer is tiny, never drop a token
    else:
        cap = min(int(t * k / e * cfg.capacity_factor) + 1, t * k)

    xf = x.reshape(t, d)
    logits = (xf.astype(jnp.float32)) @ p["router"]
    gates = jax.nn.softmax(logits, axis=-1)  # (T, E)
    top_w, top_i = jax.lax.top_k(gates, k)   # (T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

    # aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(gates, axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # sort assignments by expert id
    ids = top_i.reshape(-1)                 # (T*k,)
    wts = top_w.reshape(-1)
    order = jnp.argsort(ids)
    ids_s = ids[order]
    tok_s = order // k
    wts_s = wts[order]
    counts = jnp.zeros((e,), jnp.int32).at[ids_s].add(1)
    offsets = jnp.cumsum(counts) - counts   # start of each expert's run
    pos = jnp.arange(t * k) - offsets[ids_s]
    keep = pos < cap
    slot = jnp.where(keep, ids_s * cap + pos, e * cap)  # OOB -> dropped

    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[slot].set(xf[tok_s], mode="drop")
    buf = buf.reshape(e, cap, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(e * cap, d)

    gathered = jnp.take(y, jnp.minimum(slot, e * cap - 1), axis=0)
    gathered = gathered * (wts_s * keep).astype(x.dtype)[:, None]
    out = jnp.zeros((t, d), x.dtype).at[tok_s].add(gathered)

    if "shared" in p:
        out = out + mlp(p["shared"], xf)
    return out.reshape(b, s, d), aux


def moe_ep(p: dict, x: jax.Array, cfg, mesh, batch_axes: tuple, tp_axis: str = "model"):
    """Expert-parallel MoE via shard_map (§Perf-E1, the kimi-cell fix).

    Exploits the framework's layout invariant: activations are replicated
    across the "model" axis while experts are sharded over it. Each model
    rank therefore already holds every token — dispatch is a purely LOCAL
    select of the tokens routed to its resident experts, and combining is a
    single psum over the model axis (each token's expert outputs live on
    exactly the ranks that own those experts; everyone else contributes
    zero). Total MoE comm = one activation-sized all-reduce per layer —
    no all-to-all, no cross-rank scatter.
    """
    from jax.sharding import PartitionSpec as P

    e, k = cfg.n_experts, cfg.top_k
    n_tp = mesh.shape[tp_axis]
    assert e % n_tp == 0, (e, n_tp)
    e_loc = e // n_tp

    def local(xb, router, wg, wu, wd, shared_p):
        # xb: (B_loc, S, D) — replicated over tp; wg/wu/wd: (E_loc, ...)
        bl, s, d = xb.shape
        t = bl * s
        xf = xb.reshape(t, d)
        logits = xf.astype(jnp.float32) @ router
        gates = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(gates, k)
        top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

        me = jnp.mean(gates, axis=0)
        ce = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (t * k)
        aux = e * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, tp_axis)

        # keep only assignments owned by this model rank
        rank = jax.lax.axis_index(tp_axis)
        lo = rank * e_loc
        ids = top_i.reshape(-1)
        wts = top_w.reshape(-1)
        mine = jnp.logical_and(ids >= lo, ids < lo + e_loc)
        ids_l = jnp.where(mine, ids - lo, e_loc)  # e_loc = drop bucket
        cap = max(int(t * k / e * cfg.capacity_factor) + 1, 4) if s > 1 else t

        order = jnp.argsort(ids_l)  # drops sort to the end
        ids_s = ids_l[order]
        tok_s = order // k
        wts_s = wts[order]
        counts = jnp.zeros((e_loc + 1,), jnp.int32).at[ids_s].add(1)
        offsets = jnp.cumsum(counts) - counts
        pos = jnp.arange(t * k) - offsets[ids_s]
        keep = jnp.logical_and(ids_s < e_loc, pos < cap)
        slot = jnp.where(keep, ids_s * cap + pos, e_loc * cap)

        buf = jnp.zeros((e_loc * cap, d), xb.dtype)
        buf = buf.at[slot].set(xf[tok_s], mode="drop").reshape(e_loc, cap, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
        y = jnp.einsum("ecf,efd->ecd", h, wd).reshape(e_loc * cap, d)
        gathered = jnp.take(y, jnp.minimum(slot, e_loc * cap - 1), axis=0)
        gathered = gathered * (wts_s * keep).astype(xb.dtype)[:, None]
        out = jnp.zeros((t, d), xb.dtype).at[tok_s].add(gathered)
        if shared_p is not None:
            # shared expert: every rank holds the tokens; scale by 1/n_tp so
            # the combining psum reconstructs a single contribution
            out = out + (mlp(shared_p, xf) / n_tp).astype(out.dtype)
        out = jax.lax.psum(out, tp_axis)  # combine expert contributions
        return out.reshape(bl, s, d), aux

    ba = batch_axes
    shared = p.get("shared")
    in_specs = (
        P(ba, None, None),
        P(None, None),                     # router replicated
        P(tp_axis, None, None),            # expert weights: E over tp
        P(tp_axis, None, None),
        P(tp_axis, None, None),
        None if shared is None else jax.tree.map(lambda _: P(None, None), shared),
    )
    from repro.core.compat import shard_map

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(ba, None, None), P()),
    )
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)
