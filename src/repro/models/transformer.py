"""Generic decoder-only transformer LM (dense / GQA / SWA / MoE / embeds-in).

Covers qwen2-72b, mistral-nemo-12b, h2o-danube-3-4b, llama3.2-3b,
kimi-k2-1t-a32b, llama4-scout-17b-a16e and pixtral-12b (embeddings-in stub).

Layer parameters are stacked on a leading (L, ...) axis and applied with
``lax.scan`` (+ optional per-layer remat) — the HLO contains each layer once,
which is what keeps the 80-layer/1T-param dry-run compile tractable and is
the standard MaxText-style production layout.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed import hints
from repro.models.attention import (
    attention,
    decode_attention,
    init_attention,
    init_kv_cache,
)
from repro.models.common import cross_entropy_loss, embed_init, rms_norm
from repro.models.mlp import init_mlp, init_moe, mlp, moe


def init_params(key, cfg) -> dict:
    dt = jnp.dtype(cfg.dtype)
    l = cfg.n_layers
    keys = jax.random.split(key, l + 2)

    def layer(k):
        k1, k2 = jax.random.split(k)
        p = {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "attn": init_attention(k1, cfg),
        }
        if cfg.is_moe:
            p["moe"] = init_moe(k2, cfg)
        else:
            p["mlp"] = init_mlp(k2, cfg)
        return p

    layers = jax.vmap(layer)(jnp.stack(keys[:l]))
    params = {
        "layers": layers,
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "embed": embed_init(keys[l], (cfg.vocab, cfg.d_model), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(keys[l + 1], (cfg.d_model, cfg.vocab), dt)
    return params


def _moe_layer(cfg, lp, h_in):
    """Dense MoE by default; shard_map expert-parallel when configured and
    the mesh info is available (§Perf-E1)."""
    if cfg.moe_impl == "ep":
        info = hints.mesh_info()
        if info is not None:
            from repro.models.mlp import moe_ep

            mesh, ba, tp = info
            return moe_ep(lp["moe"], h_in, cfg, mesh, ba, tp)
    return moe(lp["moe"], h_in, cfg)


def _block(cfg, x, positions, lp):
    h = attention(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), positions, cfg)
    x = x + h
    if cfg.is_moe:
        h, aux = _moe_layer(cfg, lp, rms_norm(x, lp["ln2"], cfg.norm_eps))
    else:
        h = mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        aux = jnp.zeros((), jnp.float32)
    return x + h, aux


def forward(params: dict, cfg, tokens: jax.Array | None, embeds: jax.Array | None = None):
    """Token (or embedding) sequence -> logits (B, S, V) and aux loss."""
    if cfg.input_embeds:
        x = embeds.astype(jnp.dtype(cfg.dtype))
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    x = hints.constrain_acts(x)  # §Perf-A1 anchor
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(carry, lp):
        x, aux = carry
        x, a = _block(cfg, x, positions, lp)
        return (hints.constrain_acts(x), aux + a), None

    if cfg.remat:
        if cfg.remat_policy == "dots":
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )
        else:
            body_fn = jax.checkpoint(body)
    else:
        body_fn = body
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            (x, aux), _ = body_fn((x, aux), lp)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = hints.constrain_logits(x @ unembed)
    return logits, aux


def loss_fn(params, cfg, batch) -> jax.Array:
    logits, aux = forward(
        params, cfg, batch.get("tokens"), batch.get("embeds")
    )
    loss = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return loss + 0.01 * aux


# ----------------------------- serving ------------------------------------


def init_cache(cfg, batch: int, max_len: int) -> dict:
    """KV cache; sliding-window archs get a *rolling* cache of window length
    (§Perf-D5) — O(window) state regardless of context, the vLLM/Mistral
    serving layout. Slot = position % window; keys keep absolute RoPE."""
    length = max_len
    if cfg.sliding_window:
        length = min(max_len, cfg.sliding_window)
    one = init_kv_cache(batch, length, cfg)
    return {
        "k": jnp.zeros((cfg.n_layers,) + one["k"].shape, one["k"].dtype),
        "v": jnp.zeros((cfg.n_layers,) + one["v"].shape, one["v"].dtype),
    }


def prefill(params, cfg, tokens=None, embeds=None, cache=None):
    """Run the full prompt, filling the cache; returns (logits_last, cache).

    Implemented as forward + cache write via a scan that also emits K/V.
    """
    if cfg.input_embeds:
        x = embeds.astype(jnp.dtype(cfg.dtype))
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    x = hints.constrain_acts(x)  # §Perf-A1/B1 anchor
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    max_len = cache["k"].shape[2]

    from repro.models.attention import _project_kv  # cached K/V per layer
    from repro.models.common import rope

    def body(x, lp):
        h_in = rms_norm(x, lp["ln1"], cfg.norm_eps)
        k, v = _project_kv(lp["attn"], h_in, cfg)
        k = rope(k, positions, cfg.rope_theta)
        h = attention(lp["attn"], h_in, positions, cfg)
        x = x + h
        if cfg.is_moe:
            h2, _ = moe(lp["moe"], rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
        else:
            h2 = mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        if max_len < s:
            # rolling SWA cache: keep the last ``max_len`` keys at their
            # slot = position % max_len (keys are roped at absolute pos)
            kc = jnp.roll(k[:, s - max_len :], shift=s % max_len, axis=1)
            vc = jnp.roll(v[:, s - max_len :], shift=s % max_len, axis=1)
        else:
            pad = max_len - s
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return hints.constrain_acts(x + h2), {"k": kc, "v": vc}

    x, cache_new = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = x[:, -1:] @ unembed
    return logits, cache_new


def decode_step(params, cfg, cache, tokens, pos):
    """One decode step. tokens (B, 1); pos scalar int. Returns (logits, cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)

    cache_len = cache["k"].shape[2]
    use_roll = bool(cfg.sliding_window) and cache_len <= cfg.sliding_window

    def body(x, xs):
        lp, kcache, vcache = xs
        h, new_c = decode_attention(
            lp["attn"],
            rms_norm(x, lp["ln1"], cfg.norm_eps),
            pos,
            {"k": kcache, "v": vcache},
            cfg,
            window=cfg.sliding_window,
            write_pos=jnp.mod(pos, cache_len) if use_roll else None,
        )
        x = x + h
        if cfg.is_moe:
            h2, _ = moe(lp["moe"], rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
        else:
            h2 = mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        return x + h2, new_c

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = x @ unembed
    return logits, new_cache
