"""RecurrentGemma / Griffin: RG-LRU recurrent blocks + local attention (1:2).

Block pattern (cfg.block_pattern, default ("rec", "rec", "attn")): two
recurrent blocks per local-attention block. The RG-LRU recurrence

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t ⊙ x_t)
    a_t = sigmoid(gate)^(c) with c = 8 softplus temperature (Griffin eq. 5)

is evaluated with ``jax.lax.associative_scan`` over the sequence — log-depth,
TPU-native and the reason this arch runs the long_500k cell. Decode carries
the (B, lru_width) recurrent state + a (B, conv_width) conv tail instead of a
KV cache, so state is O(1) in sequence length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import hints
from repro.models.attention import (
    attention,
    decode_attention,
    init_attention,
    init_kv_cache,
)
from repro.models.common import cross_entropy_loss, dense_init, embed_init, rms_norm
from repro.models.mlp import init_mlp, mlp

C_TEMP = 8.0


def init_rglru_block(key, cfg) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], (d, w), dt),       # input branch
        "w_gate_in": dense_init(ks[1], (d, w), dt),  # multiplicative gate branch
        "conv_w": dense_init(ks[2], (cfg.conv_width, w), dt) * 0.1,
        "a_gate": dense_init(ks[3], (w, w), dt),
        "i_gate": dense_init(ks[4], (w, w), dt),
        "a_param": jnp.log(jnp.expm1(jnp.linspace(0.9, 0.999, w))).astype(dt),
        "w_out": dense_init(ks[5], (w, d), dt),
    }


def _rg_lru(p, x, h0=None):
    """x: (B, S, W). Returns (y, h_last). Associative scan over S."""
    bsz, s, w = x.shape
    xf = x.astype(jnp.float32)
    gate_a = jax.nn.sigmoid(xf @ p["a_gate"].astype(jnp.float32))
    gate_i = jax.nn.sigmoid(xf @ p["i_gate"].astype(jnp.float32))
    log_a0 = -C_TEMP * jax.nn.softplus(p["a_param"].astype(jnp.float32))
    log_a = gate_a * log_a0[None, None, :]          # (B, S, W), <= 0
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    inp = mult * gate_i * xf

    if h0 is not None:
        # fold the initial state in as a virtual first element
        a = jnp.concatenate([jnp.ones((bsz, 1, w), a.dtype), a], axis=1)
        inp = jnp.concatenate([h0[:, None, :].astype(jnp.float32), inp], axis=1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, inp), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(x.dtype), h[:, -1]


def _conv1d(p, x, tail=None):
    """Causal depthwise conv, width cfg.conv_width. x (B,S,W)."""
    k = p["conv_w"].shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : xp.shape[1] - (k - 1 - i)] * p["conv_w"][i][None, None, :]
        for i in range(k)
    )
    return out, xp[:, -(k - 1):]


def rglru_block(p, x, h0=None, conv_tail=None):
    """Full recurrent block: gated branch * (conv -> RG-LRU) -> out proj."""
    gate = jax.nn.gelu(x @ p["w_gate_in"])
    u = x @ p["w_x"]
    u, new_tail = _conv1d(p, u, conv_tail)
    y, h_last = _rg_lru(p, u, h0)
    return (y * gate) @ p["w_out"], h_last, new_tail


def init_params(key, cfg) -> dict:
    dt = jnp.dtype(cfg.dtype)
    pattern = cfg.block_pattern or ("rec", "rec", "attn")
    n_groups = cfg.n_layers // len(pattern)
    rem = cfg.n_layers - n_groups * len(pattern)
    keys = jax.random.split(key, 3)

    def group(k):
        ks = jax.random.split(k, len(pattern) * 2)
        g = []
        for i, kind in enumerate(pattern):
            k1, k2 = ks[2 * i], ks[2 * i + 1]
            p = {
                "ln1": jnp.zeros((cfg.d_model,), dt),
                "ln2": jnp.zeros((cfg.d_model,), dt),
                "mlp": init_mlp(k2, cfg),
            }
            if kind == "rec":
                p["rec"] = init_rglru_block(k1, cfg)
            else:
                p["attn"] = init_attention(k1, cfg)
            g.append(p)
        return tuple(g)

    gkeys = jax.random.split(keys[0], max(n_groups, 1))
    groups = jax.vmap(group)(gkeys[:n_groups]) if n_groups else ()
    rkeys = jax.random.split(keys[1], max(rem, 1))
    remainder = [group(rkeys[i])[i % len(pattern)] for i in range(rem)]
    return {
        "groups": groups,
        "remainder": remainder,
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "embed": embed_init(keys[2], (cfg.vocab, cfg.d_model), dt),
    }


def _apply_block(cfg, x, positions, p, kind):
    # attention blocks use the local window: the config sets
    # ``sliding_window == local_window`` so attention() masks correctly.
    h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "rec":
        h, _, _ = rglru_block(p["rec"], h_in)
    else:
        h = attention(p["attn"], h_in, positions, cfg)
    x = x + h
    x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return x


def forward(params, cfg, tokens, embeds=None):
    x = hints.constrain_acts(jnp.take(params["embed"], tokens, axis=0))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    pattern = cfg.block_pattern or ("rec", "rec", "attn")

    def body(x, gp):
        for i, kind in enumerate(pattern):
            x = _apply_block(cfg, x, positions, gp[i], kind)
        return hints.constrain_acts(x), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if params["groups"]:
        x, _ = jax.lax.scan(body_fn, x, params["groups"])
    for i, p in enumerate(params["remainder"]):
        x = _apply_block(cfg, x, positions, p, pattern[i % len(pattern)])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return hints.constrain_logits(x @ params["embed"].T), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg, batch) -> jax.Array:
    logits, _ = forward(params, cfg, batch["tokens"])
    return cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


# ----------------------------- serving ------------------------------------


def init_cache(cfg, batch: int, max_len: int) -> dict:
    """Recurrent state + conv tails for rec blocks; *rolling* local-window KV
    for attention blocks — state is O(window), not O(max_len), which is what
    makes the long_500k decode cell viable for this architecture."""
    pattern = cfg.block_pattern or ("rec", "rec", "attn")
    n_groups = cfg.n_layers // len(pattern)
    rem = cfg.n_layers - n_groups * len(pattern)
    w = cfg.lru_width or cfg.d_model
    attn_len = min(max_len, cfg.local_window or max_len)
    caches: dict = {"grouped": {}, "rem": {}}
    for i, kind in enumerate(pattern):
        g = caches["grouped"]
        if kind == "rec":
            g[f"h{i}"] = jnp.zeros((n_groups, batch, w), jnp.float32)
            g[f"tail{i}"] = jnp.zeros(
                (n_groups, batch, cfg.conv_width - 1, w), jnp.dtype(cfg.dtype)
            )
        else:
            kv = init_kv_cache(batch, attn_len, cfg)
            g[f"k{i}"] = jnp.zeros((n_groups,) + kv["k"].shape, kv["k"].dtype)
            g[f"v{i}"] = jnp.zeros((n_groups,) + kv["v"].shape, kv["v"].dtype)
    for i in range(rem):
        kind = pattern[i % len(pattern)]
        r = caches["rem"]
        if kind == "rec":
            r[f"h{i}"] = jnp.zeros((batch, w), jnp.float32)
            r[f"tail{i}"] = jnp.zeros(
                (batch, cfg.conv_width - 1, w), jnp.dtype(cfg.dtype)
            )
        else:
            kv = init_kv_cache(batch, attn_len, cfg)
            r[f"k{i}"] = kv["k"]
            r[f"v{i}"] = kv["v"]
    return caches


def _decode_block(cfg, x, p, kind, cc, prefix, i, pos, attn_len):
    """One block of decode; returns (x, updated cache entries)."""
    new_c = {}
    h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "rec":
        gate = jax.nn.gelu(h_in @ p["rec"]["w_gate_in"])
        u = h_in @ p["rec"]["w_x"]
        u, new_tail = _conv1d(p["rec"], u, cc[f"tail{i}"])
        y, h_last = _rg_lru(p["rec"], u, cc[f"h{i}"])
        h = (y * gate) @ p["rec"]["w_out"]
        new_c[f"h{i}"] = h_last
        new_c[f"tail{i}"] = new_tail
    else:
        h, kv = decode_attention(
            p["attn"], h_in, pos, {"k": cc[f"k{i}"], "v": cc[f"v{i}"]}, cfg,
            write_pos=jnp.mod(pos, attn_len),
        )
        new_c[f"k{i}"] = kv["k"]
        new_c[f"v{i}"] = kv["v"]
    x = x + h
    x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return x, new_c


def decode_step(params, cfg, cache, tokens, pos):
    """One-token decode; attention caches are rolling local windows."""
    x = jnp.take(params["embed"], tokens, axis=0)
    pattern = cfg.block_pattern or ("rec", "rec", "attn")
    grouped = cache["grouped"]
    attn_len = next(
        (grouped[f"k{i}"].shape[2] for i, k in enumerate(pattern) if k == "attn"),
        cfg.local_window or 1,
    )

    def body(x, xs):
        gp, cc = xs
        new_c = {}
        for i, kind in enumerate(pattern):
            x, upd = _decode_block(cfg, x, gp[i], kind, cc, "g", i, pos, attn_len)
            new_c.update(upd)
        return x, new_c

    if params["groups"]:
        x, new_grouped = jax.lax.scan(body, x, (params["groups"], grouped))
    else:
        new_grouped = grouped
    new_rem = {}
    for i, p in enumerate(params["remainder"]):
        kind = pattern[i % len(pattern)]
        x, upd = _decode_block(cfg, x, p, kind, cache["rem"], "r", i, pos, attn_len)
        new_rem.update(upd)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["embed"].T, {"grouped": new_grouped, "rem": new_rem}
