"""GQA attention with sliding-window, QKV-bias, cross-attention and KV cache.

Functional layers over explicit param dicts. Shapes:
  x: (B, S, D);  q: (B, S, H, hd);  k/v: (B, T, K, hd)  (K = KV heads)

Grouped attention reshapes q to (B, S, K, G, hd) with G = H // K so the
einsum contracts per KV head — the layout that shards cleanly with the KV
head (or head_dim) on the "model" mesh axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rope

NEG = -1.0e30


def init_attention(key, cfg, cross: bool = False) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, qd), dt),
        "wk": dense_init(ks[1], (d, kvd), dt),
        "wv": dense_init(ks[2], (d, kvd), dt),
        "wo": dense_init(ks[3], (qd, d), dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((qd,), dt)
        p["bk"] = jnp.zeros((kvd,), dt)
        p["bv"] = jnp.zeros((kvd,), dt)
    return p


def _project_q(p, x, cfg):
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    return q.reshape(x.shape[:-1] + (cfg.n_heads, cfg.head_dim))


def _project_kv(p, x, cfg):
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    shp = x.shape[:-1] + (cfg.n_kv, cfg.head_dim)
    return k.reshape(shp), v.reshape(shp)


def _attend(q, k, v, mask, cfg):
    """q (B,S,H,hd), k/v (B,T,K,hd), mask (B|1, S, T) bool -> (B,S,H*hd).

    f32 accumulation happens inside the MXU (``preferred_element_type``),
    never by materializing f32 copies of the inputs — XLA hoists per-layer
    ``astype`` of scanned KV slices into a full-cache f32 convert otherwise
    (measured 3x full-cache traffic per decode step, §Perf-D2).
    """
    b, s, h, hd = q.shape
    kheads = k.shape[2]
    g = h // kheads
    q = q.reshape(b, s, kheads, g, hd)
    scale = hd ** -0.5
    scores = jnp.einsum(
        "bskgh,btkh->bkgst", q, k, preferred_element_type=jnp.float32
    )
    scores = scores * scale
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG)
    if s == 1:  # decode: keep T sharded — flash-decode combine (§Perf-D3)
        from repro.distributed import hints

        scores = hints.constrain_decode_scores(scores)
        # explicit stable softmax so the T-reductions stay local + psum
        m = jnp.max(scores, axis=-1, keepdims=True)
        e = jnp.exp(scores - m)
        e = hints.constrain_decode_scores(e)
        probs = e / jnp.sum(e, axis=-1, keepdims=True)
        probs = hints.constrain_decode_scores(probs)
    else:
        probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v)
    return out.reshape(b, s, h * hd)


def _attend_chunked(q, k, v, cfg, causal: bool, window: int, kv_chunk: int = 1024):
    """Flash-style online-softmax attention, scanning KV chunks.

    Never materializes the (S, T) score matrix — memory per step is
    O(S * kv_chunk). Differentiable (scan of jnp ops) and remat-friendly.
    q (B,S,H,hd), k/v (B,T,K,hd) -> (B,S,H*hd).
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    kheads = k.shape[2]
    g = h // kheads
    qg = q.reshape(b, s, kheads, g, hd)
    scale = hd ** -0.5
    n_chunks = -(-t // kv_chunk)
    t_pad = n_chunks * kv_chunk
    if t_pad != t:
        k = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, kv_chunk, kheads, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, kheads, hd).transpose(1, 0, 2, 3, 4)
    rows = jnp.arange(s)[:, None]

    def step(carry, xs):
        acc, m_run, l_run = carry
        kb, vb, c0 = xs
        scores = (
            jnp.einsum("bskgh,btkh->bkgst", qg, kb, preferred_element_type=jnp.float32)
            * scale
        )
        cols = c0 + jnp.arange(kv_chunk)[None, :]
        mask = cols < t
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
            if window:
                mask = jnp.logical_and(mask, cols > rows - window)
        scores = jnp.where(mask[None, None, None, :, :], scores, NEG)
        m_new = jnp.maximum(m_run, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkh->bskgh", p.astype(vb.dtype), vb).astype(jnp.float32)
        acc = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, s, kheads, g, hd), jnp.float32)
    m0 = jnp.full((b, kheads, g, s), NEG, jnp.float32)
    l0 = jnp.zeros((b, kheads, g, s), jnp.float32)
    c0s = jnp.arange(n_chunks) * kv_chunk
    (acc, m_run, l_run), _ = jax.lax.scan(step, (acc0, m0, l0), (kc, vc, c0s))
    denom = jnp.maximum(l_run, 1e-30).transpose(0, 3, 1, 2)[..., None]
    out = (acc / denom).astype(v.dtype)
    return out.reshape(b, s, h * hd)


def causal_mask(s: int, window: int = 0, dtype=bool) -> jax.Array:
    """(1, S, S) causal (optionally sliding-window) mask."""
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = j <= i
    if window:
        m = jnp.logical_and(m, j > i - window)
    return m[None].astype(dtype)


CHUNKED_THRESHOLD = 8192  # sequences >= this use online-softmax attention


def attention(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg,
    mask: jax.Array | None = None,
    kv_x: jax.Array | None = None,
    kv_positions: jax.Array | None = None,
    use_rope: bool = True,
    causal: bool = True,
) -> jax.Array:
    """Full-sequence attention (training / prefill). Cross-attn if kv_x.

    For sequences >= CHUNKED_THRESHOLD the flash-style chunked path is used
    (mask is then derived from ``causal`` + ``cfg.sliding_window``; an
    explicit ``mask`` forces the naive path).
    """
    src = x if kv_x is None else kv_x
    q = _project_q(p, x, cfg)
    k, v = _project_kv(p, src, cfg)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        kpos = positions if kv_positions is None else kv_positions
        k = rope(k, kpos, cfg.rope_theta)
    s, t = x.shape[1], src.shape[1]
    window = cfg.sliding_window if kv_x is None else 0
    if mask is None and max(s, t) >= CHUNKED_THRESHOLD:
        out = _attend_chunked(q, k, v, cfg, causal=causal and kv_x is None, window=window)
    else:
        if mask is None:
            if causal and kv_x is None:
                mask = causal_mask(s, window)
            else:
                mask = jnp.ones((1, s, t), bool)
        out = _attend(q, k, v, mask, cfg)
    return out @ p["wo"]


def init_kv_cache(batch: int, max_len: int, cfg, dtype=None) -> dict:
    dt = dtype or jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv, cfg.head_dim), dt),
        "v": jnp.zeros((batch, max_len, cfg.n_kv, cfg.head_dim), dt),
    }


def decode_attention(
    p: dict,
    x: jax.Array,
    pos: jax.Array,
    cache: dict,
    cfg,
    window: int = 0,
    use_rope: bool = True,
    write_pos: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """One-token decode with KV cache. x: (B, 1, D); pos: absolute position.

    ``write_pos`` (defaults to ``pos``) is the cache slot — pass
    ``pos % cache_len`` for rolling local-window caches; K is always roped at
    the absolute position so relative rotations stay correct across wraps.
    Returns (output (B, 1, D), updated cache).
    """
    b = x.shape[0]
    t = cache["k"].shape[1]
    wp = pos if write_pos is None else write_pos
    rolling = write_pos is not None
    q = _project_q(p, x, cfg)
    k_new, v_new = _project_kv(p, x, cfg)
    if use_rope:
        posv = jnp.full((b, 1), pos, jnp.int32)
        q = rope(q, posv, cfg.rope_theta)
        k_new = rope(k_new, posv, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, wp, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, wp, 0, 0))
    j = jnp.arange(t)[None, None, :]
    if rolling:
        # once warmed up, every slot holds one of the last ``t`` positions
        m = jnp.logical_or(j <= pos, jnp.broadcast_to(pos >= t, j.shape))
    else:
        m = j <= pos
        if window:
            m = jnp.logical_and(m, j > pos - window)
    out = _attend(q, k, v, jnp.broadcast_to(m, (b, 1, t)), cfg)
    return out @ p["wo"], {"k": k, "v": v}


def decode_cross_attention(
    p: dict, x: jax.Array, enc_k: jax.Array, enc_v: jax.Array, cfg
) -> jax.Array:
    """Cross-attention during decode; encoder K/V precomputed at prefill."""
    b, t = enc_k.shape[0], enc_k.shape[1]
    q = _project_q(p, x, cfg)
    mask = jnp.ones((b, 1, t), bool)
    out = _attend(q, enc_k, enc_v, mask, cfg)
    return out @ p["wo"]
