"""Uniform model API over the architecture families."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from repro.models import mamba2, rglru, transformer, whisper
from repro.models.config import ModelConfig


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[..., Any]
    forward: Callable[..., Any]
    loss_fn: Callable[..., Any]
    init_cache: Callable[..., Any]
    decode_step: Callable[..., Any]
    prefill: Callable[..., Any] | None = None


def build(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        mod = transformer
        return Model(
            cfg=cfg,
            init=lambda key: mod.init_params(key, cfg),
            forward=lambda p, **kw: mod.forward(p, cfg, kw.get("tokens"), kw.get("embeds")),
            loss_fn=lambda p, batch: mod.loss_fn(p, cfg, batch),
            init_cache=lambda b, s: mod.init_cache(cfg, b, s),
            decode_step=lambda p, cache, tok, pos: mod.decode_step(p, cfg, cache, tok, pos),
            prefill=lambda p, cache, **kw: mod.prefill(
                p, cfg, kw.get("tokens"), kw.get("embeds"), cache
            ),
        )
    if cfg.family == "hybrid":
        return Model(
            cfg=cfg,
            init=lambda key: rglru.init_params(key, cfg),
            forward=lambda p, **kw: rglru.forward(p, cfg, kw.get("tokens")),
            loss_fn=lambda p, batch: rglru.loss_fn(p, cfg, batch),
            init_cache=lambda b, s: rglru.init_cache(cfg, b, s),
            decode_step=lambda p, cache, tok, pos: rglru.decode_step(p, cfg, cache, tok, pos),
        )
    if cfg.family == "ssm":
        return Model(
            cfg=cfg,
            init=lambda key: mamba2.init_params(key, cfg),
            forward=lambda p, **kw: mamba2.forward(p, cfg, kw.get("tokens")),
            loss_fn=lambda p, batch: mamba2.loss_fn(p, cfg, batch),
            init_cache=lambda b, s: mamba2.init_cache(cfg, b, s),
            decode_step=lambda p, cache, tok, pos: mamba2.decode_step(p, cfg, cache, tok, pos),
            prefill=lambda p, cache, **kw: mamba2.prefill(p, cfg, cache, kw["tokens"]),
        )
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init=lambda key: whisper.init_params(key, cfg),
            forward=lambda p, **kw: whisper.forward(
                p, cfg, tokens=kw.get("tokens"), embeds=kw.get("embeds")
            ),
            loss_fn=lambda p, batch: whisper.loss_fn(p, cfg, batch),
            init_cache=lambda b, s: whisper.init_cache(cfg, b, s),
            decode_step=lambda p, cache, tok, pos: whisper.decode_step(p, cfg, cache, tok, pos),
            prefill=lambda p, cache, **kw: whisper.prefill_encoder(
                p, cfg, kw["embeds"], cache
            ),
        )
    raise ValueError(f"unknown family {cfg.family!r}")
