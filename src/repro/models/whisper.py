"""Whisper-large-v3 backbone: transformer encoder-decoder.

Per the assignment the conv frontend is a STUB: ``input_specs()`` feeds
precomputed frame embeddings (B, S, D) straight into the encoder (the two
stride-2 convs that produce them are not part of the assigned backbone).
Encoder layers are bidirectional; decoder layers are causal self-attention +
cross-attention to the encoder output. Sinusoidal positions, MHA (kv == q
heads), pre-LN — matching arXiv:2212.04356.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import hints
from repro.models.attention import (
    attention,
    decode_attention,
    decode_cross_attention,
    init_attention,
    init_kv_cache,
    _project_kv,
)
from repro.models.common import (
    cross_entropy_loss,
    embed_init,
    rms_norm,
    sinusoidal_positions,
)
from repro.models.mlp import init_mlp, mlp


def init_params(key, cfg) -> dict:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "attn": init_attention(k1, cfg),
            "mlp": init_mlp(k2, cfg),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "ln3": jnp.zeros((cfg.d_model,), dt),
            "self_attn": init_attention(k1, cfg),
            "cross_attn": init_attention(k2, cfg, cross=True),
            "mlp": init_mlp(k3, cfg),
        }

    n_enc = cfg.n_enc_layers or cfg.n_layers
    enc_keys = jax.random.split(ks[0], n_enc)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_layers": jax.vmap(enc_layer)(enc_keys),
        "dec_layers": jax.vmap(dec_layer)(dec_keys),
        "enc_norm": jnp.zeros((cfg.d_model,), dt),
        "dec_norm": jnp.zeros((cfg.d_model,), dt),
        "embed": embed_init(ks[2], (cfg.vocab, cfg.d_model), dt),
    }


def encode(params, cfg, frames: jax.Array) -> jax.Array:
    """frames: (B, S_audio, D) stub embeddings -> encoder states."""
    b, s, _ = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + sinusoidal_positions(s, cfg.d_model)[None].astype(x.dtype)
    x = hints.constrain_acts(x)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, lp):
        h = attention(
            lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), positions, cfg,
            causal=False, use_rope=False,
        )
        x = x + h
        x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        return hints.constrain_acts(x), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_train(params, cfg, enc_out: jax.Array, tokens: jax.Array) -> jax.Array:
    """Teacher-forced decoder -> logits (B, S_dec, V)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + sinusoidal_positions(s, cfg.d_model)[None].astype(x.dtype)
    x = hints.constrain_acts(x)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, lp):
        h = attention(
            lp["self_attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), positions, cfg,
            use_rope=False,
        )
        x = x + h
        h = attention(
            lp["cross_attn"], rms_norm(x, lp["ln2"], cfg.norm_eps), positions, cfg,
            kv_x=enc_out, causal=False, use_rope=False,
        )
        x = x + h
        x = x + mlp(lp["mlp"], rms_norm(x, lp["ln3"], cfg.norm_eps))
        return hints.constrain_acts(x), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = rms_norm(x, params["dec_norm"], cfg.norm_eps)
    return hints.constrain_logits(x @ params["embed"].T)


def forward(params, cfg, tokens=None, embeds=None):
    enc_out = encode(params, cfg, embeds)
    logits = decode_train(params, cfg, enc_out, tokens)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, cfg, batch) -> jax.Array:
    logits, _ = forward(params, cfg, tokens=batch["tokens"], embeds=batch["embeds"])
    return cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


# ----------------------------- serving ------------------------------------


def init_cache(cfg, batch: int, max_len: int, enc_len: int | None = None) -> dict:
    """Decoder self-attn KV cache + precomputed encoder cross K/V."""
    one = init_kv_cache(batch, max_len, cfg)
    el = enc_len or max_len
    return {
        "k": jnp.zeros((cfg.n_layers,) + one["k"].shape, one["k"].dtype),
        "v": jnp.zeros((cfg.n_layers,) + one["v"].shape, one["v"].dtype),
        "ek": jnp.zeros(
            (cfg.n_layers, batch, el, cfg.n_kv, cfg.head_dim), jnp.dtype(cfg.dtype)
        ),
        "ev": jnp.zeros(
            (cfg.n_layers, batch, el, cfg.n_kv, cfg.head_dim), jnp.dtype(cfg.dtype)
        ),
    }


def prefill_encoder(params, cfg, frames: jax.Array, cache: dict) -> dict:
    """Run the encoder and stash per-layer cross K/V into the cache."""
    enc_out = encode(params, cfg, frames)

    def kv(lp):
        return _project_kv(lp["cross_attn"], enc_out, cfg)

    ek, ev = jax.vmap(kv)(params["dec_layers"])
    return {**cache, "ek": ek, "ev": ev}


def decode_step(params, cfg, cache, tokens, pos):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + jax.lax.dynamic_slice(
        sinusoidal_positions(cache["k"].shape[2], cfg.d_model).astype(x.dtype),
        (pos, 0), (1, cfg.d_model),
    )[None]

    def body(x, xs):
        lp, kc, vc, ek, ev = xs
        h, kv = decode_attention(
            lp["self_attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), pos,
            {"k": kc, "v": vc}, cfg, use_rope=False,
        )
        x = x + h
        h = decode_cross_attention(
            lp["cross_attn"], rms_norm(x, lp["ln2"], cfg.norm_eps), ek, ev, cfg
        )
        x = x + h
        x = x + mlp(lp["mlp"], rms_norm(x, lp["ln3"], cfg.norm_eps))
        return x, (kv["k"], kv["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["ek"], cache["ev"])
    )
    x = rms_norm(x, params["dec_norm"], cfg.norm_eps)
    return x @ params["embed"].T, {**cache, "k": nk, "v": nv}
