"""Mamba-2 (SSD — state-space duality) language model.

Chunked SSD algorithm (Dao & Gu 2024, minimal-SSD form): within a chunk the
recurrence is evaluated as a masked quadratic form (MXU-friendly), across
chunks a linear state recurrence carries (B, H, P, N) states — O(S) total
work, O(1)-state decode. Attention-free: runs every assigned shape including
long_500k.

Layer = RMSNorm -> [in_proj -> conv1d -> SSD -> gate -> out_proj] + residual.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import hints
from repro.models.common import cross_entropy_loss, dense_init, embed_init, rms_norm


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state


def init_layer(key, cfg) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    d_inner, h, n = _dims(cfg)
    conv_dim = d_inner + 2 * n  # x, B, C all pass the conv
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.zeros((d,), dt),
        # in_proj -> [z, x, B, C, dt]
        "w_in": dense_init(ks[0], (d, 2 * d_inner + 2 * n + h), dt),
        "conv_w": dense_init(ks[1], (cfg.conv_width, conv_dim), dt) * 0.1,
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "w_out": dense_init(ks[2], (d_inner, d), dt),
        "out_ln": jnp.zeros((d_inner,), dt),
    }


def _segsum(a):
    """Lower-triangular pairwise cumulative sums: out[..., i, j] = sum a[j+1..i]."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, chunk: int, h0=None):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H); a_log: (H,) log-decay rates;
    b, c: (B, S, N) (single group). Returns (y, last_state (B, H, P, N)).
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))

    A = -jnp.exp(a_log)  # (H,) negative
    xb = x.reshape(bs, nc, chunk, h, p)
    dtb = dt.reshape(bs, nc, chunk, h)
    bb = b.reshape(bs, nc, chunk, n)
    cb = c.reshape(bs, nc, chunk, n)
    da = dtb * A[None, None, None, :]          # (B, C, Q, H) log decay per step
    da_cum = jnp.cumsum(da, axis=2)            # within-chunk cumulative

    # intra-chunk (quadratic, masked)
    L = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))      # (B, C, H, Q, Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", cb, bb)      # (B, C, Q, Q)
    m = scores[:, :, None] * L                          # (B, C, H, Q, Q)
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", m, dtb, xb)

    # chunk states: contribution of each chunk to the carried state
    decay_states = jnp.exp(da_cum[:, :, -1:, :] - da_cum)      # (B, C, Q, H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", bb, decay_states * dtb, xb)

    # inter-chunk recurrence: h_{c} = exp(sum da_c) h_{c-1} + states_c
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])                 # (B, C, H)

    def combine(l, r):
        al, hl = l
        ar, hr = r
        return al * ar, hl * ar[..., None, None] + hr

    a_sc, h_sc = jax.lax.associative_scan(
        combine, (chunk_decay, states), axis=1
    )
    # state entering chunk c is h_sc[c-1] (plus h0 propagated)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_sc[:, :1]), h_sc[:, :-1]], axis=1
    )
    if h0 is not None:
        # propagate the initial state through each chunk's total decay
        total_decay = jnp.concatenate(
            [jnp.ones_like(a_sc[:, :1]), a_sc[:, :-1]], axis=1
        )
        h_prev = h_prev + total_decay[..., None, None] * h0[:, None]

    y_off = jnp.einsum(
        "bcqn,bchpn,bcqh->bcqhp", cb, h_prev, jnp.exp(da_cum)
    )
    y = (y_diag + y_off).reshape(bs, nc * chunk, h, p)[:, :s]
    last = h_sc[:, -1]
    if h0 is not None:
        last = last + a_sc[:, -1][..., None, None] * h0
    return y, last


def _conv1d(w, x, tail=None):
    k = w.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : xp.shape[1] - (k - 1 - i)] * w[i][None, None, :] for i in range(k)
    )
    return out, xp[:, -(k - 1):]


def _split_proj(p, u, cfg):
    d_inner, h, n = _dims(cfg)
    z = u[..., :d_inner]
    xc = u[..., d_inner : 2 * d_inner + 2 * n]  # conv inputs: x, B, C
    dt = u[..., 2 * d_inner + 2 * n :]
    return z, xc, dt


def layer_forward(p, x, cfg, state=None, conv_tail=None):
    """x: (B, S, D) -> (y, (new_state, new_tail))."""
    bs, s, _ = x.shape
    d_inner, h, n = _dims(cfg)
    u = rms_norm(x, p["ln"], cfg.norm_eps) @ p["w_in"]
    z, xc, dtr = _split_proj(p, u, cfg)
    xc, new_tail = _conv1d(p["conv_w"], xc, conv_tail)
    xc = jax.nn.silu(xc)
    xs = xc[..., :d_inner].reshape(bs, s, h, cfg.ssm_head_dim)
    b = xc[..., d_inner : d_inner + n]
    c = xc[..., d_inner + n :]
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    y, last = ssd_chunked(
        xs.astype(jnp.float32), dt, p["a_log"], b.astype(jnp.float32),
        c.astype(jnp.float32), cfg.ssm_chunk, h0=state,
    )
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bs, s, d_inner).astype(x.dtype)
    y = rms_norm(y, p["out_ln"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["w_out"], (last, new_tail)


def init_params(key, cfg) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 1)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(keys[: cfg.n_layers])
    return {
        "layers": layers,
        "final_norm": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
        "embed": embed_init(keys[-1], (cfg.vocab, cfg.d_model), jnp.dtype(cfg.dtype)),
    }


def forward(params, cfg, tokens, embeds=None):
    x = hints.constrain_acts(jnp.take(params["embed"], tokens, axis=0))

    def body(x, lp):
        y, _ = layer_forward(lp, x, cfg)
        return hints.constrain_acts(x + y), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return hints.constrain_logits(x @ params["embed"].T), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg, batch) -> jax.Array:
    logits, _ = forward(params, cfg, batch["tokens"])
    return cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


def init_cache(cfg, batch: int, max_len: int) -> dict:
    d_inner, h, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    return {
        "state": jnp.zeros((cfg.n_layers, batch, h, cfg.ssm_head_dim, n), jnp.float32),
        "tail": jnp.zeros(
            (cfg.n_layers, batch, cfg.conv_width - 1, conv_dim), jnp.dtype(cfg.dtype)
        ),
    }


def prefill(params, cfg, cache, tokens):
    """Run the full prompt, producing final per-layer SSM states + conv
    tails (the cache) and the last-token logits."""
    x = hints.constrain_acts(jnp.take(params["embed"], tokens, axis=0))

    def body(x, lp):
        y, (st, tail) = layer_forward(lp, x, cfg)
        return hints.constrain_acts(x + y), (st, tail)

    x, (states, tails) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1:] @ params["embed"].T
    return logits, {"state": states, "tail": tails}


def decode_step(params, cfg, cache, tokens, pos):
    """O(1)-state decode step (sequence length never appears)."""
    x = jnp.take(params["embed"], tokens, axis=0)  # (B, 1, D)

    def body(x, xs):
        lp, st, tail = xs
        y, (new_st, new_tail) = layer_forward(lp, x, cfg, state=st, conv_tail=tail)
        return x + y, (new_st, new_tail)

    x, (new_state, new_tail) = jax.lax.scan(
        body, x, (params["layers"], cache["state"], cache["tail"])
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["embed"].T, {"state": new_state, "tail": new_tail}
