"""Shared layer primitives: norms, rotary embeddings, initializers, loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Initializer = jax.nn.initializers.Initializer


def pdtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, dtype) -> jax.Array:
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """Mean token cross entropy; logits (..., V) in any dtype, fp32 math."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal position embedding table (length, dim)."""
    half = dim // 2
    scale = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / (half - 1)))
    pos = jnp.arange(length, dtype=jnp.float32)[:, None] * scale[None, :]
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=-1)
