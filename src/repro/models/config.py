"""Model configuration dataclass covering the 10 assigned architectures.

One frozen dataclass; every architecture in ``src/repro/configs/`` fills the
fields it needs. ``reduced()`` derives the small same-family config used by
CPU smoke tests (the full configs are only ever lowered shape-abstractly in
the dry-run).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | vlm | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    sliding_window: int = 0         # 0 -> full attention
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden (0 -> d_ff)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "dense"         # dense | ep (shard_map expert parallel)

    # hybrid (RG-LRU / Griffin)
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    local_window: int = 0

    # SSM (Mamba2/SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_width: int = 4

    # encoder-decoder (Whisper)
    n_enc_layers: int = 0
    cross_attn: bool = False

    # modality frontend stub: model consumes precomputed embeddings
    input_embeds: bool = False

    # numerics / training
    dtype: str = "bfloat16"         # parameter/activation dtype
    remat: bool = True              # activation checkpointing per layer
    remat_policy: str = "full"      # full | dots (save MXU outputs, §Perf-A2)
    scan_layers: bool = True        # scan-over-layers (compile-time critical)
    optimizer: str = "adamw"        # adamw | adafactor
    num_microbatches: int = 1

    # which attention dim the "model" axis shards: "heads" | "head_dim"
    tp_attn_dim: str = "heads"

    # long-context capability (sub-quadratic): used to gate long_500k cells
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def reduced(self) -> "ModelConfig":
        """Same-family miniature for CPU smoke tests."""
        changes: dict = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv=min(self.n_kv, 2) if self.n_kv else 0,
            head_dim=16,
            d_ff=128,
            vocab=256,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            local_window=min(self.local_window, 16) if self.local_window else 0,
            lru_width=64 if self.lru_width else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=64 if self.moe_d_ff else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8 if self.ssm_state else 64,
            n_enc_layers=min(self.n_enc_layers, 2),
            dtype="float32",
            num_microbatches=1,
        )
        if self.block_pattern:
            changes["block_pattern"] = self.block_pattern
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
