"""Trip-count-aware HLO statistics: dot FLOPs, HBM traffic, collective bytes.

``compiled.cost_analysis()`` counts every instruction ONCE — a ``lax.scan``
over 80 layers or 16 microbatches under-reports by that factor. This module
re-derives the three roofline numerators by walking the post-SPMD optimized
HLO text with loop trip counts multiplied through the call graph:

  * dot_flops   — 2 * prod(result dims) * contraction size, per dot; fusions
                  descended; while bodies multiplied by trip count. MXU work.
  * mem_bytes   — per top-level instruction: operand + result bytes. After
                  XLA fusion each top-level op reads its operands from HBM
                  and writes its result, so this is a first-order HBM traffic
                  model (fusion internals excluded).
  * collectives — per-device ring-traffic conventions (see below); shapes in
                  partitioned HLO are per-device shapes.

Trip counts come from the loop-condition comparison constant (lax.scan emits
``compare(iter, constant(N))``); data-dependent loops default to 1 and are
listed in ``dynamic_loops`` so the caller can bound them separately.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_NAME_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=")
# first lowercase-word token followed by '(' after the '=' — opcodes are
# lowercase; dtype tokens are always followed by '[', tiled layouts use
# uppercase T(8,128)/S(2,1), so this lands on the opcode.
_OPCODE_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*(\w+\[[\d,]*\])")


def _opcode_of(line: str) -> tuple[str, int]:
    """Return (opcode, index_of_opcode) for an instruction line, or ("", -1)."""
    eq = line.find(" = ")
    if eq < 0:
        return "", -1
    m = _OPCODE_RE.search(line, eq + 3)
    if not m:
        return "", -1
    return m.group(1), m.start(1)

MEM_EXCLUDE = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "partition-id", "replica-id",
}


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _shape_elems(dims) * DTYPE_BYTES.get(dtype, 4)


@dataclass
class Comp:
    header: str = ""
    lines: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)   # name -> (dtype, dims)
    dot_flops: float = 0.0
    mem_bytes: float = 0.0
    coll: dict = field(default_factory=dict)      # op -> bytes
    coll_counts: dict = field(default_factory=dict)
    fusions: list = field(default_factory=list)
    fusion_sites: list = field(default_factory=list)  # (body_name, result_bytes)
    calls: list = field(default_factory=list)
    whiles: list = field(default_factory=list)    # (body, cond)
    _io: tuple | None = None                      # cached fusion body IO


def _fusion_io(c: Comp) -> tuple[float, float | None]:
    """HBM traffic of one fusion body: (input_bytes, write_bytes | None).

    A body parameter consumed only through ``dynamic-slice`` reads just the
    slices (the scan-over-layers weight-stack pattern); a parameter that is
    only the in-place target of a ``dynamic-update-slice`` reads nothing.
    ``write_bytes`` is the update size when the root is a DUS (aliased
    output), else None -> caller uses the call-site result size.
    """
    if c._io is not None:
        return c._io
    params: dict[str, dict] = {}
    views: dict[str, str] = {}  # value name -> underlying param (pure views)
    for line in c.lines:
        if " parameter(" in line:
            nm = _NAME_RE.match(line)
            if nm and nm.group(1) in c.symbols:
                params[nm.group(1)] = {"sliced": 0.0, "full": False, "alias": False}
                views[nm.group(1)] = nm.group(1)
    # ops that don't force a full read of a param inside a fused kernel:
    # the generated kernel reads only the elements the slice touches.
    TRANSPARENT = {"bitcast", "reshape", "transpose", "convert", "copy", "broadcast"}
    write: float | None = None
    for line in c.lines:
        opcode, opi = _opcode_of(line)
        if not opcode or opcode == "parameter":
            continue
        args = line[line.find("(", opi) + 1 :]
        operands = [
            an.group(1) for an in re.finditer(r"%([\w\.\-]+)", args.split("),")[0])
        ]
        nm = _NAME_RE.match(line)
        result_name = nm.group(1) if nm else None
        eq = line.find(" = ")
        res = _SHAPE_RE.search(line, eq)
        rb = _shape_bytes(res.group(1), res.group(2)) if res else 0
        for k, op in enumerate(operands):
            root = views.get(op)
            if root is None:
                continue
            if opcode in TRANSPARENT and k == 0 and result_name:
                views[result_name] = root  # propagate the view
            elif opcode in ("dynamic-slice", "slice", "gather") and k == 0:
                params[root]["sliced"] += rb
            elif opcode == "dynamic-update-slice" and k == 0:
                params[root]["alias"] = True
            else:
                params[root]["full"] = True
        if line.startswith("ROOT") and opcode == "dynamic-update-slice":
            upd = c.symbols.get(operands[1]) if len(operands) > 1 else None
            if upd:
                write = float(2 * _shape_bytes(*upd))
    total_in = 0.0
    for name, info in params.items():
        sym = c.symbols.get(name)
        if sym is None:
            continue
        full_b = _shape_bytes(*sym)
        if info["full"]:
            total_in += full_b
        elif info["sliced"]:
            total_in += info["sliced"]
        elif info["alias"]:
            pass  # in-place target, not read
        else:
            total_in += full_b  # unused/indirect: be conservative
    c._io = (total_in, write)
    return c._io


def _split(hlo: str) -> dict[str, Comp]:
    comps: dict[str, Comp] = {}
    cur: Comp | None = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$", line)
            if m and " = " not in line.split("(")[0]:
                cur = Comp(header=line)
                comps[m.group(1)] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        cur.lines.append(line)
    return comps


def _analyze_comp(c: Comp) -> None:
    # symbol table: header params + instruction results
    for name, shape in _PARAM_RE.findall(c.header):
        m = _SHAPE_RE.match(shape)
        if m:
            c.symbols[name] = (m.group(1), m.group(2))
    for line in c.lines:
        nm = _NAME_RE.match(line)
        if not nm:
            continue
        name = nm.group(1)
        eq = line.find("=")
        # result shape: first shape token after '='
        m = _SHAPE_RE.search(line, eq)
        if m:
            c.symbols[name] = (m.group(1), m.group(2))

    coll = defaultdict(float)
    counts = defaultdict(int)
    for line in c.lines:
        opcode, opi = _opcode_of(line)
        # ---- collectives ----
        matched_coll = None
        for op in COLLECTIVES:
            if opcode in (op, op + "-start"):
                matched_coll = op
                break
        if matched_coll:
            eq = line.find(" = ")
            seg = line[eq + 3 : opi]
            b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(seg))
            if matched_coll == "all-reduce":
                b *= 2
            elif matched_coll == "reduce-scatter":
                m = _GROUPS_EXPL_RE.search(line)
                g = len(m.group(1).split(",")) if m else 0
                if not g:
                    m = _GROUPS_IOTA_RE.search(line)
                    g = int(m.group(2)) if m else 1
                b *= g
            coll[matched_coll] += b
            counts[matched_coll] += 1
        # ---- structure ----
        if opcode == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", line)
            cm = re.search(r"condition=%?([\w\.\-]+)", line)
            if bm:
                c.whiles.append((bm.group(1), cm.group(1) if cm else ""))
        fm = re.search(r"calls=%?([\w\.\-]+)", line)
        if fm and opcode == "fusion":
            c.fusions.append(fm.group(1))
        for cm in re.finditer(r"(?:branch_computations=\{|to_apply=)%?([\w\.\-]+)", line):
            c.calls.append(cm.group(1))
        if opcode == "call" and fm:
            c.calls.append(fm.group(1))
        # ---- dot flops ----
        if opcode in ("dot", "dot-general"):
            eq = line.find(" = ")
            res = _SHAPE_RE.search(line, eq)
            out_elems = _shape_elems(res.group(2)) if res else 0
            # lhs operand: scheduled HLO prints the shape inline
            # (``dot(f32[8,64]{1,0} %lhs, ...)``); fall back to the symbol
            # table when only the name is present.
            args = line[line.find("(", opi) + 1 :]
            am = re.match(
                r"\s*(?:(\w+)\[([\d,]*)\](?:\{[^}]*\})?\s+)?%([\w\.\-]+)",
                args,
            )
            contraction = 1
            lhs_dims = None
            if am:
                if am.group(2) is not None:
                    lhs_dims = am.group(2)
                elif am.group(3) in c.symbols:
                    lhs_dims = c.symbols[am.group(3)][1]
            if lhs_dims is not None:
                dims = [int(x) for x in lhs_dims.split(",")] if lhs_dims else []
                cm2 = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                if cm2 and cm2.group(1):
                    for ci in cm2.group(1).split(","):
                        contraction *= dims[int(ci)]
            c.dot_flops += 2.0 * out_elems * contraction
        # ---- memory ----
        if opcode and opcode not in MEM_EXCLUDE and opcode != "fusion":
            eq = line.find(" = ")
            seg = line[eq + 3 : opi]
            rb = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(seg))
            args = line[line.find("(", opi) + 1 :]
            operands = [
                an.group(1)
                for an in re.finditer(r"%([\w\.\-]+)", args.split("),")[0])
            ]
            if opcode in ("dynamic-slice", "slice", "gather"):
                # reads only the slice it produces
                c.mem_bytes += 2 * rb
            elif opcode == "dynamic-update-slice":
                # in-place: reads the update, writes the region
                upd = c.symbols.get(operands[1]) if len(operands) > 1 else None
                c.mem_bytes += 2 * _shape_bytes(*upd) if upd else rb
            else:
                ob = 0
                for name_ in operands:
                    sym = c.symbols.get(name_)
                    if sym:
                        ob += _shape_bytes(sym[0], sym[1])
                c.mem_bytes += rb + ob
        elif opcode == "fusion":
            # traffic computed from the fused body (dynamic-slice aware);
            # record the callee + result bytes for the second pass
            eq = line.find(" = ")
            seg = line[eq + 3 : opi]
            rb = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(seg))
            if fm:
                c.fusion_sites.append((fm.group(1), rb))
    c.coll = dict(coll)
    c.coll_counts = dict(counts)


def analyze_hlo(hlo: str) -> dict:
    comps = _split(hlo)
    for c in comps.values():
        _analyze_comp(c)

    def trip(cond: str) -> int:
        c = comps.get(cond)
        if c is None:
            return 1
        consts = [int(x) for l in c.lines for x in _CONST_RE.findall(l)]
        # also look one fusion deep (compare is often wrapped)
        for f in c.fusions + c.calls:
            fc = comps.get(f)
            if fc:
                consts += [int(x) for l in fc.lines for x in _CONST_RE.findall(l)]
        return max(consts) if consts else 1

    dynamic_loops: list[str] = []

    memo: dict[tuple, dict] = {}

    def total(name: str, seen=()) -> dict:
        key = (name,)
        if key in memo:
            return memo[key]
        c = comps.get(name)
        if c is None or name in seen:
            return {"flops": 0.0, "mem": 0.0, "coll": {}}
        flops = c.dot_flops
        mem = c.mem_bytes
        coll = defaultdict(float, c.coll)
        for body, rb in c.fusion_sites:
            fc = comps.get(body)
            if fc is not None:
                tin, w = _fusion_io(fc)
                mem += tin + (w if w is not None else rb)
            else:
                mem += rb
        for f in c.fusions:
            sub = total(f, seen + (name,))
            flops += sub["flops"]  # fusion internals: flops yes, HBM no
        for cal in c.calls:
            sub = total(cal, seen + (name,))
            flops += sub["flops"]
            mem += sub["mem"]
            for k, v in sub["coll"].items():
                coll[k] += v
        for body, cond in c.whiles:
            n = trip(cond)
            if n == 1:
                dynamic_loops.append(body)
            sub = total(body, seen + (name,))
            flops += n * sub["flops"]
            mem += n * sub["mem"]
            for k, v in sub["coll"].items():
                coll[k] += n * v
        out = {"flops": flops, "mem": mem, "coll": dict(coll)}
        memo[key] = out
        return out

    entry = None
    for name in comps:
        if name.startswith("main") or "entry" in name.lower():
            entry = name
            break
    if entry is None and comps:
        entry = next(iter(comps))
    t = total(entry) if entry else {"flops": 0, "mem": 0, "coll": {}}
    static_counts: dict[str, int] = defaultdict(int)
    for c in comps.values():
        for op, n in c.coll_counts.items():
            static_counts[op] += n
    return {
        "entry": entry,
        "dot_flops": float(t["flops"]),
        "mem_bytes": float(t["mem"]),
        "collective_bytes": {k: float(v) for k, v in t["coll"].items()},
        "collective_total": float(sum(t["coll"].values())),
        "collective_counts": dict(static_counts),
        "dynamic_loops": dynamic_loops[:8],
    }
