"""Three-term roofline analysis from the dry-run artifacts.

Per (arch x shape x mesh) cell:
    compute term    = per-device dot FLOPs       / 197 TFLOP/s (bf16, v5e)
    memory term     = per-device HBM bytes       / 819 GB/s
    collective term = per-device collective bytes / 50 GB/s (ICI ring model)

All numerators come from the trip-count-aware HLO walk (roofline/hlo_stats.py)
over the post-SPMD module, so they are per-device dynamic totals for one step.

MODEL_FLOPS is the analytic useful work: 6·N·D for training (N = active
params for MoE), 2·N·D for prefill/decode forward passes. The ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy/padding waste, and the
roofline fraction (useful-compute-time / dominant-term-time) is the score a
perfect implementation would push to 1.0.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHS, SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"
)


def param_counts(cfg) -> tuple[float, float]:
    """(total, active) parameter counts, analytically from the config."""
    d, v = cfg.d_model, cfg.vocab
    embed = v * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":
        d_inner = cfg.ssm_expand * d
        h = d_inner // cfg.ssm_head_dim
        per_layer = (
            d * (2 * d_inner + 2 * cfg.ssm_state + h)
            + cfg.conv_width * (d_inner + 2 * cfg.ssm_state)
            + d_inner * d
            + 3 * h + d_inner + d
        )
        total = embed + cfg.n_layers * per_layer
        return total, total

    attn = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
    if cfg.qkv_bias:
        attn += cfg.q_dim + 2 * cfg.kv_dim
    if cfg.is_moe:
        ffe = cfg.moe_d_ff or cfg.d_ff
        moe_total = cfg.n_experts * 3 * d * ffe + d * cfg.n_experts
        moe_active = (cfg.top_k) * 3 * d * ffe + d * cfg.n_experts
        shared = cfg.n_shared_experts * 3 * d * ffe
        ffn_total = moe_total + shared
        ffn_active = moe_active + shared
    else:
        ffn_total = ffn_active = 3 * d * cfg.d_ff

    if cfg.family == "hybrid":
        pattern = cfg.block_pattern or ("rec", "rec", "attn")
        w = cfg.lru_width or d
        rec = 2 * d * w + cfg.conv_width * w + 2 * w * w + w + w * d
        n_rec = sum(1 for i in range(cfg.n_layers) if pattern[i % len(pattern)] == "rec")
        n_attn = cfg.n_layers - n_rec
        total = embed + n_rec * (rec + ffn_total) + n_attn * (attn + ffn_total)
        return total, total

    layers = cfg.n_layers * (attn + ffn_total)
    layers_active = cfg.n_layers * (attn + ffn_active)
    if cfg.family == "audio":
        enc = (cfg.n_enc_layers or cfg.n_layers) * (attn + ffn_total)
        cross = cfg.n_layers * (d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d)
        layers += enc + cross
        layers_active += enc + cross
    total = embed + layers
    return total, embed + layers_active


def model_flops(cfg, shape) -> float:
    """Global useful FLOPs for one step of this cell."""
    total, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per sequence; embedding table isn't multiplied
    return 2.0 * active * shape.global_batch


def analyze_cell(res: dict) -> dict | None:
    if res.get("status") != "ok":
        return None
    cfg = ARCHS[res["arch"]]
    shape = SHAPES[res["shape"]]
    chips = 1
    for v in res["mesh"].values():
        chips *= v
    st = res["hlo_stats"]
    compute_s = st["dot_flops"] / PEAK_FLOPS
    memory_s = st["mem_bytes"] / HBM_BW
    coll_s = st["collective_total"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful_s = (mf / chips) / PEAK_FLOPS
    bound_s = max(terms.values())
    total_hlo_flops = st["dot_flops"] * chips
    return {
        "arch": res["arch"],
        "shape": res["shape"],
        "mesh": "2x16x16" if res["multi_pod"] else "16x16",
        "chips": chips,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": total_hlo_flops,
        "useful_ratio": mf / total_hlo_flops if total_hlo_flops else 0.0,
        "roofline_fraction": useful_s / bound_s if bound_s else 0.0,
        "param_bytes_per_device": res.get("param_bytes_per_device"),
        "state_bytes_per_device": res.get("state_bytes_per_device"),
        "cache_bytes_per_device": res.get("cache_bytes_per_device"),
        "collective_mix": st["collective_bytes"],
    }


FIX_NOTES = {
    "compute": "raise MXU utilization: fuse small dots, widen microbatch, drop remat on cheap layers",
    "memory": "cut HBM traffic: better fusion, bf16 intermediates, avoid full-tensor reshards",
    "collective": "re-shard to cut collective volume: overlap with compute, hierarchical reduce, flash-decode the KV all-gather",
}


def load_cells(results_dir: str = RESULTS_DIR) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            res = json.load(f)
        if res.get("arch") == "dtw-search":
            continue
        if res.get("status") == "skipped":
            rows.append({
                "arch": res["arch"], "shape": res["shape"],
                "mesh": "2x16x16" if res["multi_pod"] else "16x16",
                "skipped": res["reason"],
            })
            continue
        cell = analyze_cell(res)
        if cell:
            rows.append(cell)
    return rows


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def render_markdown(rows: list[dict], mesh_filter: str = "16x16") -> str:
    out = [
        "| arch | shape | compute | memory | collective | bound | "
        "MODEL/HLO flops | roofline frac | fix |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != mesh_filter and "skipped" not in r:
            continue
        if "skipped" in r:
            if r["mesh"] == mesh_filter:
                out.append(
                    f"| {r['arch']} | {r['shape']} | — | — | — | — | — | skip | {r['skipped']} |"
                )
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {FIX_NOTES[r['dominant']][:58]} |"
        )
    return "\n".join(out)


def main() -> None:
    rows = load_cells()
    print(render_markdown(rows, "16x16"))
    print()
    print(render_markdown(rows, "2x16x16"))


if __name__ == "__main__":
    main()
