"""Assigned architecture configs (``--arch <id>``).

Each module defines ``CONFIG`` (the exact public configuration) — smoke tests
use ``CONFIG.reduced()``. ``dtw_search`` is the paper's own workload config.
"""
from __future__ import annotations

from repro.models.config import SHAPES, ModelConfig, ShapeConfig

from repro.configs import (
    dtw_search,
    h2o_danube3_4b,
    kimi_k2_1t_a32b,
    llama3_2_3b,
    llama4_scout_17b_a16e,
    mamba2_130m,
    mistral_nemo_12b,
    pixtral_12b,
    qwen2_72b,
    recurrentgemma_2b,
    whisper_large_v3,
)

ARCHS: dict[str, ModelConfig] = {
    "qwen2-72b": qwen2_72b.CONFIG,
    "mistral-nemo-12b": mistral_nemo_12b.CONFIG,
    "h2o-danube-3-4b": h2o_danube3_4b.CONFIG,
    "llama3.2-3b": llama3_2_3b.CONFIG,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b.CONFIG,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e.CONFIG,
    "recurrentgemma-2b": recurrentgemma_2b.CONFIG,
    "pixtral-12b": pixtral_12b.CONFIG,
    "whisper-large-v3": whisper_large_v3.CONFIG,
    "mamba2-130m": mamba2_130m.CONFIG,
}

SEARCH_CONFIG = dtw_search.CONFIG

__all__ = ["ARCHS", "SHAPES", "SEARCH_CONFIG", "ModelConfig", "ShapeConfig"]
