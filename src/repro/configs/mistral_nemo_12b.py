"""Mistral-Nemo-12B: dense GQA, 128k ctx, head_dim 128 (explicit)
[hf:mistralai/Mistral-Nemo-Base-2407]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1_000_000.0,
    norm_eps=1e-5,
    num_microbatches=4,
)
