"""The paper's own workload: UCR-suite subsequence similarity search.

Not one of the 40 assigned LM cells — this is the configuration the
benchmarks and the distributed-search dry-run use (reference length x query
length x window ratio, as in Herrmann & Webb §5)."""
from dataclasses import dataclass


@dataclass(frozen=True)
class SearchConfig:
    name: str = "dtw-search"
    ref_len: int = 1_000_000         # long reference series R
    query_len: int = 1024            # paper: 128 / 256 / 512 / 1024
    window_ratio: float = 0.1        # paper: 0.1 .. 0.5
    batch: int = 256                 # candidates per shared-ub round
    variant: str = "eapruned"

    @property
    def window(self) -> int:
        return int(self.query_len * self.window_ratio)


CONFIG = SearchConfig()
