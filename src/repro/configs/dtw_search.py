"""The paper's own workload: UCR-suite subsequence similarity search.

Not one of the 40 assigned LM cells — this is the configuration the
benchmarks and the distributed-search dry-run use (reference length x query
length x window ratio, as in Herrmann & Webb §5).

Backend/tuning knobs (threaded through ``subsequence_search`` →
``core.batch.ea_pruned_dtw_batch``, see ``core.backend`` for the dispatch
rules):

  ``backend``       — ``"auto"`` resolves to the Pallas kernel on TPU and
                      the banded-vmap JAX path elsewhere; force with
                      ``"pallas"`` / ``"pallas_interpret"`` / ``"jax"`` or
                      the ``REPRO_DTW_BACKEND`` env var.
  ``band_width``    — DP band columns per row; ``None`` = smallest
                      lane-aligned width covering ``2*window + 1``.
  ``rows_per_step`` — JAX backend: DP rows per while_loop iteration
                      (amortizes vmap'd loop control; abandon granularity
                      coarsens to the block).
  ``block_k``       — Pallas backend: candidate lanes per grid block; the
                      whole block must abandon before its remaining row
                      blocks are skipped.
  ``row_block``     — Pallas backend: DP rows per sequential grid step; the
                      early-exit check runs once per row block.
  ``rounds``        — search round driver: ``"host"`` loops best-first
                      batches around the batch primitive (one dispatch and
                      one incumbent update per round); ``"persistent"``
                      collapses the whole sweep into a single launch with
                      the incumbent carried across candidate blocks on
                      device (SMEM on the Pallas backend) — O(1) dispatches,
                      block-granular ``ub`` tightening (see
                      ``search.subsequence`` for the full trade-off).

Candidate materialization knobs (DESIGN.md §2.10):

  ``gather``        — ``"fused"`` (default): the DTW stage receives the raw
                      reference once plus per-lane ``(start, mu, sigma)``
                      and slices + z-normalizes each candidate inside the
                      batch primitive / Pallas kernel — O(N + K) working
                      set. ``"slab"``: pre-gather the O(K·l) normalized
                      window matrix host-side (the retired default, kept as
                      a comparison arm). Results are identical.
  ``slab_budget``   — optional byte cap on any host-side candidate slab;
                      a ``"slab"`` dispatch that would exceed it raises
                      ``SearchInputError`` at trace time instead of
                      allocating (fused paths never materialize one).

Multi-query serving knobs (``search.multi.multi_query_search``):

  ``n_queries``     — queries per multi-query workload; one launch carries
                      ``n_queries * batch`` flattened (query x candidate)
                      lanes per round with a per-lane ``ub`` vector.
  ``warm_start``    — best-LB candidates per query full-DP'd in a prepass
                      dispatch to seed per-query incumbents; helps the
                      Pallas backend's block early exit, off for the vmap
                      backend (see ``multi_query_search``).

Streaming knobs (``serve.stream.StreamSearchEngine``):

  ``stream_chunk``  — reference samples per ingest; each ingest is one
                      jitted dispatch over the newly-valid windows, so this
                      is the latency/amortization trade. Passed as the
                      engine's fixed ingest shape, it also pins ONE compiled
                      trace for the whole stream: ragged arrivals are padded
                      (and bigger ones split) to this static shape, so not
                      even the short final chunk retraces.
  ``ring_capacity`` — monitoring ring over the last W raw samples
                      (``None`` = keep no sample history; the search itself
                      only ever needs the ``length - 1`` boundary tail).

Robustness knobs (DESIGN.md §2.6):

  ``quarantine``    — exclude windows overlapping non-finite reference
                      samples instead of letting them poison results
                      (default on; the prepass is one extra prefix-sum pass
                      — within noise on clean data, pinned by the
                      ``search/robustness`` bench row).
  ``debug_checks``  — per-ingest tripwire that no NaN reached the carried
                      incumbents; synchronous, debugging only (also
                      ``$REPRO_DEBUG_CHECKS``).

Resilience knobs (DESIGN.md §2.7; ``search.resilient`` / ``serve``):

  ``n_shards``          — work ranges the resilient executor partitions the
                          candidate starts into (independent failure
                          domains; ``search.resilient.resilient_search``).
  ``shard_max_retries`` — transient failures tolerated per (range, shard)
                          before the shard is marked failed and the range
                          reassigned to a healthy one.
  ``shard_backoff``     — base retry sleep in seconds (doubles per
                          consecutive retry), as in the supervisors.
  ``shard_timeout``     — soft per-range wall-clock budget; an attempt that
                          completes late keeps its result but strikes its
                          shard (``None`` disables).
  ``require_full_coverage`` — raise ``CoverageError`` on any uncovered
                          range instead of returning a degraded (but
                          coverage-accounted) result.
  ``async_ckpt``        — move ``SearchSupervisor`` checkpoint writes off
                          the ingest thread (``train.checkpoint
                          .AsyncCheckpointer``; restore paths barrier on
                          in-flight writes).

Hedging / health knobs (DESIGN.md §2.9; ``search.resilient`` /
``search.pipeline.HedgedExecutor``):

  ``hedge``             — race attempts that exceed the hedge delay on a
                          healthy backup shard; duplicate completions merge
                          through the strict-improvement fold, so hedging
                          can change latency but never the answer.
  ``hedge_delay``       — explicit hedge delay in seconds; ``None`` derives
                          it as ``threshold × EWMA`` from the straggler
                          monitor (no hedging until a baseline exists).
  ``hedge_max_inflight``— backup attempts raced against one straggling
                          primary (the hedging ladder depth).
  ``breaker_threshold`` — consecutive failures before a shard's circuit
                          breaker opens and routing avoids it (a pause, not
                          a verdict — distinct from ``shard_max_retries``
                          marking a shard failed).
  ``breaker_cooldown``  — seconds an open breaker sheds load before it
                          earns a single half-open probe.
  ``retry_jitter``      — decorrelate retry backoff sleeps
                          (``$REPRO_FAULT_SEED``-seeded); avoids lockstep
                          retry bursts across simultaneously-failed shards.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class SearchConfig:
    name: str = "dtw-search"
    ref_len: int = 1_000_000         # long reference series R
    query_len: int = 1024            # paper: 128 / 256 / 512 / 1024
    window_ratio: float = 0.1        # paper: 0.1 .. 0.5
    batch: int = 256                 # candidates per shared-ub round
    variant: str = "eapruned"
    backend: str = "auto"            # DTW batch backend (core.backend)
    band_width: int | None = None    # None = lane-aligned 2*window+1
    rows_per_step: int = 1           # JAX backend loop-unroll knob
    block_k: int = 8                 # Pallas candidate lanes per block
    row_block: int = 128             # Pallas rows per sequential grid step
    rounds: str = "host"             # round driver: "host" | "persistent"
    gather: str = "fused"            # candidate materialization (§2.10)
    slab_budget: int | None = None   # byte cap on host-side slabs (§2.10)
    n_queries: int = 8               # multi-query workload size (search.multi)
    warm_start: int = 0              # multi-query incumbent-seeding prepass
    stream_chunk: int = 8192         # samples per streaming ingest (serve.stream)
    ring_capacity: int | None = None  # monitoring ring over last W samples
    quarantine: bool = True          # non-finite window quarantine (§2.6)
    debug_checks: bool = False       # incumbent NaN tripwire (debug only)
    n_shards: int = 4                # resilient-search work ranges (§2.7)
    shard_max_retries: int = 2       # transient failures per (range, shard)
    shard_backoff: float = 0.05      # base retry sleep, doubles per retry
    shard_timeout: float | None = None  # soft per-range wall-clock budget
    require_full_coverage: bool = False  # degraded result -> CoverageError
    async_ckpt: bool = False         # off-thread supervisor checkpoints
    hedge: bool = False              # race stragglers on a backup shard (§2.9)
    hedge_delay: float | None = None  # None = threshold x EWMA from monitor
    hedge_max_inflight: int = 2      # backups raced per straggling attempt
    breaker_threshold: int = 3       # consecutive failures to open breaker
    breaker_cooldown: float = 1.0    # open-breaker load-shed seconds
    retry_jitter: bool = True        # decorrelated retry backoff (§2.9)

    @property
    def window(self) -> int:
        return int(self.query_len * self.window_ratio)

    def make_plan(self, **overrides):
        """Resolve this config into the pipeline's ``SearchPlan``.

        The config is the serialized/CLI-facing knob surface; the plan is
        the frozen, backend-resolved form every search stage takes as its
        static argument (``search.pipeline``). ``overrides`` replace
        individual knobs (e.g. ``backend="jax"``, ``rounds="persistent"``).
        """
        from repro.search.pipeline import make_plan  # config stays import-light

        kw = dict(
            length=self.query_len,
            window=self.window,
            variant=self.variant,
            batch=self.batch,
            band_width=self.band_width,
            backend=None if self.backend == "auto" else self.backend,
            rows_per_step=self.rows_per_step,
            block_k=self.block_k,
            row_block=self.row_block,
            rounds=self.rounds,
            quarantine=self.quarantine,
            warm_start=self.warm_start,
            gather=self.gather,
            slab_budget=self.slab_budget,
        )
        kw.update(overrides)
        return make_plan(**kw)


CONFIG = SearchConfig()
