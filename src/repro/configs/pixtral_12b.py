"""Pixtral-12B backbone: pixtral-ViT frontend (STUB: precomputed patch
embeddings) + Mistral-Nemo decoder [hf:mistralai/Pixtral-12B-2409]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    input_embeds=True,
    rope_theta=1_000_000.0,
    norm_eps=1e-5,
    num_microbatches=4,
)
