"""Llama-3.2-3B: small llama3, tied embeddings [hf:meta-llama/Llama-3.2]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv=8,
    head_dim=128,
    d_ff=8192,
    vocab=128256,
    tie_embeddings=True,
    rope_theta=500_000.0,
    norm_eps=1e-5,
    num_microbatches=2,
)
