"""Mamba2-130M: SSD (state-space duality), attention-free
[arXiv:2405.21060]. Runs every shape including long_500k."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv=0,
    head_dim=1,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
    tie_embeddings=True,
    norm_eps=1e-5,
    subquadratic=True,
)
