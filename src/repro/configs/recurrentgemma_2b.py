"""RecurrentGemma-2B (Griffin): RG-LRU + local attention 1:2
[arXiv:2402.19427]. O(1) recurrent state -> runs long_500k."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    block_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    local_window=2048,
    sliding_window=2048,
    tie_embeddings=True,
    rope_theta=10_000.0,
    norm_eps=1e-6,
    subquadratic=True,
    num_microbatches=2,
)
