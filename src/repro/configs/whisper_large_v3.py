"""Whisper-large-v3 backbone: 32-layer encoder + 32-layer decoder, MHA,
conv frontend STUB (precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    cross_attn=True,
    input_embeds=True,
    norm_eps=1e-5,
    tie_embeddings=True,
    num_microbatches=2,
)
