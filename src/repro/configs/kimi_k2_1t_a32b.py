"""Kimi-K2: trillion-parameter MoE, 384 experts top-8, 1 shared expert
[arXiv:2501.kimi2 paper-table]. Adafactor (factored second moments, no fp32
master) keeps optimizer state within HBM at this scale."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv=8,
    d_ff=2048,
    vocab=163840,
    n_experts=384,
    top_k=8,
    moe_d_ff=2048,
    n_shared_experts=1,
    rope_theta=50_000.0,
    norm_eps=1e-6,
    optimizer="adafactor",
    num_microbatches=16,
)
