#!/usr/bin/env python
"""Layering lint for the search pipeline (DESIGN.md §2.8).

The pipeline refactor holds only if the layering stays put, so this walker
fails the check when the import graph regresses:

  1. **Frontends stay thin and independent** — the five search frontends
     (``subsequence``, ``multi``, ``streaming``, ``distributed``,
     ``resilient``) must not import each other. Shared logic belongs in
     ``search.pipeline`` / ``search.incumbents``; a frontend importing a
     sibling is a private copy of pipeline behavior waiting to drift.
  2. **Nobody in ``search/`` reaches past the dispatch layer** — kernels are
     owned by ``core.batch`` (backend dispatch, input contracts); a direct
     ``repro.kernels`` import from ``search/*`` bypasses the backend
     resolution and the guard taxonomy.
  3. **The serving layer binds to frontends, not siblings' privates** —
     ``serve/*`` may import any ``search.*`` public surface but also must
     not touch ``repro.kernels`` directly.
  4. **The O(K·l) candidate slab stays retired** (DESIGN.md §2.10) —
     ``gather_norm_windows`` is the pre-gathered comparison baseline; only
     its sanctioned homes (``search.znorm`` itself, ``search.pipeline``'s
     baseline cores / explicit ``gather="slab"`` arms, and the paired
     gather benchmark) may name it. A new import elsewhere is the O(N·l)
     working set sneaking back in — use ``core.common.norm_window_slice``
     or the fused batch primitives instead.

Pure-AST: no imports are executed, so the lint is safe to run before the
package itself is importable (and costs milliseconds in check.sh).
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"
PKG = "repro"

FRONTENDS = {
    f"{PKG}.search.{m}"
    for m in ("subsequence", "multi", "streaming", "distributed", "resilient")
}
KERNELS = f"{PKG}.kernels"

# Rule 4: the O(K·l) slab gather may only be named here (DESIGN.md §2.10).
SLAB_FN = "gather_norm_windows"
SLAB_SANCTIONED = {
    f"{PKG}.search.znorm",     # definition + docstring contract
    f"{PKG}.search.pipeline",  # baseline cores + explicit gather="slab" arms
    f"{PKG}.search",           # package re-export (public surface)
}


def module_name(path: Path) -> str:
    rel = path.relative_to(SRC).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def imported_modules(path: Path, mod: str):
    """Yield (lineno, absolute module name) for every import in ``path``."""
    tree = ast.parse(path.read_text(), filename=str(path))
    pkg_parts = mod.split(".")
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                yield node.lineno, alias.name
        elif isinstance(node, ast.ImportFrom):
            if node.level:  # relative import -> resolve against this module
                base = pkg_parts[: len(pkg_parts) - node.level]
                name = ".".join(base + ([node.module] if node.module else []))
            else:
                name = node.module or ""
            yield node.lineno, name


def slab_references(path: Path):
    """Yield linenos where ``gather_norm_windows`` is imported or accessed."""
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            for alias in node.names:
                if alias.name == SLAB_FN:
                    yield node.lineno
        elif isinstance(node, ast.Attribute) and node.attr == SLAB_FN:
            yield node.lineno


def check() -> list[str]:
    errors = []
    for path in sorted((SRC / PKG).rglob("*.py")):
        mod = module_name(path)
        in_search = mod.startswith(f"{PKG}.search")
        in_serve = mod.startswith(f"{PKG}.serve")
        is_frontend = mod in FRONTENDS
        if mod not in SLAB_SANCTIONED:
            for lineno in slab_references(path):
                errors.append(
                    f"{path.relative_to(REPO)}:{lineno}: {mod} references "
                    f"{SLAB_FN} — the O(K·l) slab is retired outside its "
                    "sanctioned baselines (DESIGN.md §2.10); use "
                    "core.common.norm_window_slice or the fused batch "
                    "primitives"
                )
        for lineno, target in imported_modules(path, mod):
            loc = f"{path.relative_to(REPO)}:{lineno}"
            if (in_search or in_serve) and (
                target == KERNELS or target.startswith(KERNELS + ".")
            ):
                errors.append(
                    f"{loc}: {mod} imports {target} — search/serve must go "
                    "through core.batch, never repro.kernels directly"
                )
            if is_frontend and target in FRONTENDS and target != mod:
                errors.append(
                    f"{loc}: frontend {mod} imports sibling frontend "
                    f"{target} — shared logic belongs in search.pipeline / "
                    "search.incumbents"
                )
    return errors


def main() -> int:
    errors = check()
    if errors:
        print("layering lint FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    n = len(list((SRC / PKG).rglob("*.py")))
    print(f"layering lint OK ({n} modules)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
