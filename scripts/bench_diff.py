"""Compare a fresh quick benchmark run against the committed BENCH_dtw.json.

Perf PRs carry their own evidence: ``make bench-diff`` reruns the quick
benchmark, prints per-row ratios against the committed artifact, and exits
nonzero when any SPEEDUP row (a row whose derived fields carry a
``speedup=`` value — the headline ratios of every suite) regresses by more
than the threshold (default 20%). Raw ``us_per_call`` rows are reported for
context but never gate: absolute wall time on a shared box drifts; the
paired ratios are the stable signal.

Usage:
    python scripts/bench_diff.py [--baseline BENCH_dtw.json]
        [--current PATH]    # skip the rerun, compare an existing artifact
        [--threshold 0.2]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

SECTIONS = (
    "suites", "multiq", "stream", "robustness", "resilient", "hedged",
    "persistent", "gather", "pipeline", "dtw",
)


def _index(artifact: dict) -> dict[str, dict]:
    rows: dict[str, dict] = {}
    for sec in SECTIONS:
        for rec in artifact.get(sec, []):
            rows[rec["name"]] = rec
    return rows


def _run_quick_bench(path: str) -> None:
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--quick", "--skip-roofline",
         "--json", path],
        check=True, cwd=root, env=env,
    )


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_dtw.json")
    ap.add_argument(
        "--current", default=None,
        help="existing artifact to compare (default: rerun the quick bench)",
    )
    ap.add_argument(
        "--threshold", type=float, default=0.2,
        help="max tolerated fractional SPEEDUP regression (default 0.2)",
    )
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = json.load(f)
    tmp_path = None
    try:
        if args.current is None:
            tmp = tempfile.NamedTemporaryFile(
                suffix=".json", prefix="bench_diff_", delete=False
            )
            tmp.close()
            tmp_path = tmp.name
            _run_quick_bench(tmp_path)
            current_path = tmp_path
        else:
            current_path = args.current
        with open(current_path) as f:
            cur = json.load(f)
    finally:
        if tmp_path is not None and os.path.exists(tmp_path):
            os.unlink(tmp_path)

    if base.get("meta", {}).get("quick") != cur.get("meta", {}).get("quick"):
        print(
            f"WARNING: scale mismatch — baseline quick="
            f"{base.get('meta', {}).get('quick')} vs current quick="
            f"{cur.get('meta', {}).get('quick')}; ratios are not"
            " like-for-like", file=sys.stderr,
        )

    base_rows = _index(base)
    cur_rows = _index(cur)
    failures = []
    print(f"{'row':60s} {'base':>10s} {'current':>10s} {'ratio':>8s}  gate")
    for name in sorted(set(base_rows) | set(cur_rows)):
        b, c = base_rows.get(name), cur_rows.get(name)
        if b is None or c is None:
            side = "baseline" if c is None else "current"
            if c is None and "speedup" in b:
                # a vanished SPEEDUP row is the worst regression of all — a
                # crashed or renamed suite must not slip past the gate
                print(
                    f"{name:60s} {float(b['speedup']):10.2f} {'—':>10s}"
                    f" {'—':>8s}  MISSING SPEEDUP ROW"
                )
                failures.append((name, float(b["speedup"]), float("nan")))
            else:
                print(
                    f"{name:60s} {'—':>10s} {'—':>10s} {'—':>8s}"
                    f"  only in {side}"
                )
            continue
        gated = "speedup" in b and "speedup" in c
        if gated:
            bv, cv = float(b["speedup"]), float(c["speedup"])
            ratio = cv / bv if bv > 0 else float("inf")
            ok = cv >= bv * (1.0 - args.threshold)
            mark = "OK" if ok else f"REGRESSION >{args.threshold:.0%}"
            if not ok:
                failures.append((name, bv, cv))
        else:
            bv, cv = float(b["us_per_call"]), float(c["us_per_call"])
            ratio = cv / bv if bv > 0 else float("inf")
            mark = "info"
        print(f"{name:60s} {bv:10.2f} {cv:10.2f} {ratio:8.3f}  {mark}")

    if failures:
        print(
            f"\nFAIL: {len(failures)} SPEEDUP row(s) regressed by more than "
            f"{args.threshold:.0%} vs {args.baseline}:", file=sys.stderr,
        )
        for name, bv, cv in failures:
            print(f"  {name}: {bv:.4f} -> {cv:.4f}", file=sys.stderr)
        return 1
    print(f"\nOK: no SPEEDUP row regressed by more than {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
