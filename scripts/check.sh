#!/usr/bin/env bash
# Local regression gate: tier-1 tests + the --quick benchmark smoke.
# Catches dispatch-layer regressions (backend parity, counter plumbing)
# before they reach CI. Usage: scripts/check.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
# One known-failing seed test (LM model stack, unrelated to the DTW/search
# path) is deselected so the gate stays meaningful; drop the line once it
# is fixed.
python -m pytest -x -q \
    --deselect tests/test_elastic.py::test_ep_moe_matches_dense \
    "$@"

echo "== kernel program on CPU (pallas_interpret) =="
# Force every backend-dispatched DTW batch through the Pallas kernel in
# interpret mode so the exact kernel program is exercised in the local gate,
# not just on TPU.
REPRO_DTW_BACKEND=pallas_interpret python -m pytest -x -q \
    tests/test_backend.py tests/test_multi_query.py

echo "== benchmark smoke (--quick) =="
python -m benchmarks.run --quick --skip-roofline --json BENCH_dtw.json

echo "== check OK =="
