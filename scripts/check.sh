#!/usr/bin/env bash
# Local regression gate: tier-1 tests + the --quick benchmark smoke.
# Catches dispatch-layer regressions (backend parity, counter plumbing)
# before they reach CI. Usage: scripts/check.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
# Two known-failing seed tests (LM model stack, unrelated to the DTW/search
# path) are deselected so the gate stays meaningful; drop these lines once
# they are fixed.
python -m pytest -x -q \
    --deselect tests/test_elastic.py::test_ep_moe_matches_dense \
    --deselect tests/test_sharding.py::test_hlo_stats_trip_counts \
    "$@"

echo "== benchmark smoke (--quick) =="
python -m benchmarks.run --quick --skip-roofline --json BENCH_dtw.json

echo "== check OK =="
