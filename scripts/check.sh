#!/usr/bin/env bash
# Local regression gate: tier-1 tests + the --quick benchmark smoke.
# Catches dispatch-layer regressions (backend parity, counter plumbing)
# before they reach CI. Usage: scripts/check.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== layering lint =="
# Pure-AST import walker: frontends must not import each other, and nothing
# in search/ or serve/ may bypass core.batch into repro.kernels (§2.8).
python scripts/lint_layers.py

echo "== tier-1 tests =="
python -m pytest -x -q "$@"

echo "== kernel program on CPU (pallas_interpret) =="
# Force every backend-dispatched DTW batch through the Pallas kernel in
# interpret mode so the exact kernel program is exercised in the local gate,
# not just on TPU.
REPRO_DTW_BACKEND=pallas_interpret python -m pytest -x -q \
    tests/test_backend.py tests/test_multi_query.py tests/test_streaming.py \
    tests/test_persistent.py tests/test_robustness.py tests/test_resilient.py \
    tests/test_hedged.py tests/test_fused_gather.py

echo "== seeded fault pass (REPRO_FAULT_SEED=7, pallas_interpret) =="
# Re-run the fault-injection suites on a different data draw: recovery,
# coverage accounting, re-admission, and the hedging scenario (straggler +
# dead shard) must not depend on one lucky series.
REPRO_FAULT_SEED=7 REPRO_DTW_BACKEND=pallas_interpret python -m pytest -x -q \
    tests/test_robustness.py tests/test_resilient.py \
    tests/test_pipeline_parity.py tests/test_hedged.py \
    tests/test_fused_gather.py

echo "== benchmark smoke (--quick) + SPEEDUP regression gate =="
# One quick bench run serves both purposes: diff its artifact against the
# committed BENCH_dtw.json (>20% regression in any SPEEDUP row fails the
# check), then promote it to be the new committed artifact.
bench_tmp="$(mktemp --suffix=.json bench_check_XXXXXX)"
trap 'rm -f "$bench_tmp"' EXIT
python -m benchmarks.run --quick --skip-roofline --json "$bench_tmp"
python scripts/bench_diff.py --baseline BENCH_dtw.json --current "$bench_tmp"
mv "$bench_tmp" BENCH_dtw.json
trap - EXIT

echo "== check OK =="
