"""Train a ~100M-parameter LM for a few hundred steps (end-to-end driver).

Uses the mamba2-130m architecture at FULL width but reduced depth so it's a
real ~100M-param training run that fits CPU time budgets, exercising the
production path: sharded state, microbatching, async checkpoints, restart.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import ARCHS
from repro.data.lm import TokenStream
from repro.distributed.fault_tolerance import TrainingSupervisor
from repro.models.registry import build
from repro.train.train_step import init_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # full-width mamba2 (d_model 768, vocab 50280), reduced depth: ~90M params
    cfg = dataclasses.replace(
        ARCHS["mamba2-130m"], n_layers=args.depth, dtype="float32",
        num_microbatches=1,
    )
    model = build(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"training {cfg.name} depth={args.depth}: {n_params/1e6:.1f}M params")

    stream = TokenStream(cfg.vocab, args.batch, args.seq, seed=0)
    step_fn = jax.jit(
        make_train_step(model, base_lr=1e-3, warmup=20, total_steps=args.steps),
        donate_argnums=(0,),
    )
    sup = TrainingSupervisor(step_fn, stream.batch_at, args.ckpt, ckpt_every=100)
    t0 = time.time()
    state, log = sup.run(state, args.steps)
    dt = time.time() - t0
    losses = [m["loss"] for m in log]
    print(
        f"{len(log)} steps in {dt:.0f}s ({dt/len(log):.2f}s/step): "
        f"loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f}"
    )
    assert np.mean(losses[-10:]) < losses[0], "loss must decrease"
    print("done; checkpoints in", args.ckpt)


if __name__ == "__main__":
    main()
