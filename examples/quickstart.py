"""Quickstart: EAPrunedDTW in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np
import jax.numpy as jnp

from repro.core import dtw, ea_pruned_dtw, ea_pruned_dtw_batch
from repro.search import subsequence_search

# --- 1. exact DTW (the paper's Fig. 2 example) -----------------------------
S = jnp.asarray([3.0, 1, 4, 4, 1, 1])
T = jnp.asarray([1.0, 3, 2, 1, 2, 2])
print(f"DTW(S, T) = {float(dtw(S, T))}")  # 9.0

# --- 2. early abandoning: ub=6 proves the pair can't beat the incumbent ----
print(f"EAPrunedDTW(S, T, ub=9) = {float(ea_pruned_dtw(S, T, 9.0))}")   # 9.0
print(f"EAPrunedDTW(S, T, ub=6) = {float(ea_pruned_dtw(S, T, 6.0))}")   # inf

# --- 3. batched search: one query vs many candidates, shared ub ------------
rng = np.random.default_rng(0)
query = jnp.asarray(np.cumsum(rng.normal(size=128)), jnp.float32)
cands = jnp.asarray(np.cumsum(rng.normal(size=(64, 128)), axis=1), jnp.float32)
d = ea_pruned_dtw_batch(query, cands, ub=50.0, window=12)
print(f"batch: {int(jnp.isfinite(d).sum())}/64 candidates survived ub=50")

# --- 4. full subsequence similarity search (the paper's application) -------
ref = jnp.asarray(np.cumsum(rng.normal(size=5000)), jnp.float32)
res = subsequence_search(ref, query, length=128, window=12, variant="eapruned")
print(
    f"nearest window: start={int(res.best_start)} dist={float(res.best_dist):.4f} "
    f"({int(res.lanes)} of {5000 - 127} windows ran DTW; "
    f"{int(res.cells)} DP cells issued)"
)
