"""DTW retrieval over model-encoded feature sequences.

Ties the two halves of the framework together: a Mamba2 backbone encodes
token windows into d-dimensional activation sequences; EAPrunedDTW (which
supports multivariate series natively) retrieves the stored sequence closest
to a query sequence under DTW — the paper's technique applied to learned
representations instead of raw signals (its "other elastic measures /
downstream ensembles" future-work direction, §6).

Run:  PYTHONPATH=src python examples/feature_retrieval.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.core import dtw, ea_pruned_dtw
from repro.models.registry import build


def main() -> None:
    cfg = ARCHS["mamba2-130m"].reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    n_db, seq = 48, 32

    # database of token windows; the query is a noisy copy of entry 17
    db_tokens = rng.integers(0, cfg.vocab, (n_db, seq))
    q_tokens = db_tokens[17].copy()
    flips = rng.choice(seq, 4, replace=False)
    q_tokens[flips] = rng.integers(0, cfg.vocab, 4)

    def encode(tokens):
        logits, _ = model.forward(params, tokens=jnp.asarray(tokens))
        # use the (B, S, V) pre-softmax features' top-64 PCA-ish slice as the
        # sequence embedding: cheap stand-in for a trained encoder head
        return logits[..., :64]

    db = np.asarray(encode(db_tokens))
    q = np.asarray(encode(q_tokens[None]))[0]

    # sequential NN search with EAPrunedDTW and ub tightening — multivariate
    ub = float(dtw(jnp.asarray(q), jnp.asarray(db[0])))
    best = 0
    abandoned = 0
    for i in range(1, n_db):
        d = float(ea_pruned_dtw(jnp.asarray(q), jnp.asarray(db[i]), ub))
        if np.isinf(d):
            abandoned += 1
        elif d < ub:
            ub, best = d, i
    print(f"query was a corrupted copy of entry 17 -> retrieved entry {best}")
    print(f"early-abandoned {abandoned}/{n_db - 1} comparisons (ub={ub:.4f})")
    assert best == 17, "retrieval failed"


if __name__ == "__main__":
    main()
