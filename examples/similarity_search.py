"""End-to-end similarity search over a paper-style dataset, all four suites.

This is the serving driver of the paper's experiment (§5) at CPU scale:
a long ECG-like reference, a query, four suite variants, exactness check,
wall times and pruning counters.

Run:  PYTHONPATH=src python examples/similarity_search.py [--ref-len 50000]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.data.synthetic import make_dataset, make_queries
from repro.search import subsequence_search
from repro.search.subsequence import VARIANTS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ref-len", type=int, default=50_000)
    ap.add_argument("--query-len", type=int, default=256)
    ap.add_argument("--window-ratio", type=float, default=0.1)
    ap.add_argument("--dataset", default="ECG")
    args = ap.parse_args()

    ref = jnp.asarray(make_dataset(args.dataset, args.ref_len, seed=0), jnp.float32)
    q = jnp.asarray(make_queries(args.dataset, 1, args.query_len, seed=1)[0], jnp.float32)
    w = max(int(args.query_len * args.window_ratio), 1)
    n_win = args.ref_len - args.query_len + 1
    print(f"{args.dataset}: N={args.ref_len} ({n_win} windows), l={args.query_len}, w={w}\n")

    answers = []
    for variant in VARIANTS:
        res = subsequence_search(
            ref, q, length=args.query_len, window=w, variant=variant, batch=128
        )
        jax.block_until_ready(res.best_dist)
        t0 = time.time()
        res = subsequence_search(
            ref, q, length=args.query_len, window=w, variant=variant, batch=128
        )
        jax.block_until_ready(res.best_dist)
        dt = time.time() - t0
        # counters come from an (untimed) stats round; the timed search above
        # runs the counter-free default
        stats = subsequence_search(
            ref, q, length=args.query_len, window=w, variant=variant,
            batch=128, with_info=True,
        )
        answers.append((int(res.best_start), float(res.best_dist)))
        print(
            f"{variant:14s} -> start={int(res.best_start):7d} "
            f"dist={float(res.best_dist):10.4f}  {dt*1e3:8.1f} ms  "
            f"lanes={int(res.lanes):6d}  dp_rows={int(stats.rows):9d}"
        )
    starts = {s for s, _ in answers}
    d0 = answers[0][1]
    assert starts == {answers[0][0]}, f"variants disagree: {answers}"
    # distances agree to float32 working precision (the prefix-scan DTW
    # reformulation rounds differently per variant)
    assert all(abs(d - d0) <= 1e-4 * max(d0, 1.0) for _, d in answers), answers
    print("\nall four suites agree on the nearest neighbour (exactness).")


if __name__ == "__main__":
    main()
