"""End-to-end similarity search over a paper-style dataset, all four suites.

This is the serving driver of the paper's experiment (§5) at CPU scale:
a long ECG-like reference, a query, four suite variants, exactness check,
wall times and pruning counters. A second stage replays the same reference
as a live stream through ``StreamSearchEngine``: chunks arrive one at a
time, per-query incumbents carried across chunks tighten every later
ingest's early abandoning, and the final answers match the offline search
exactly.

Run:  PYTHONPATH=src python examples/similarity_search.py [--ref-len 50000]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.data.synthetic import make_dataset, make_queries
from repro.search import multi_query_search, subsequence_search
from repro.search.subsequence import VARIANTS
from repro.serve import StreamSearchEngine


def stream_demo(ref, args) -> None:
    """Replay ``ref`` as a stream of chunks against Q standing queries."""
    w = max(int(args.query_len * args.window_ratio), 1)
    queries = jnp.asarray(
        make_queries(args.dataset, 4, args.query_len, seed=2), jnp.float32
    )
    chunk = max(args.ref_len // 10, args.query_len)
    print(
        f"\nstreaming: {queries.shape[0]} standing queries, "
        f"{chunk}-sample chunks"
    )
    eng = StreamSearchEngine(
        queries, length=args.query_len, window=w, batch=128,
        ring_capacity=4 * args.query_len,
    )
    t0 = time.time()
    for lo in range(0, args.ref_len, chunk):
        bs, bd = eng.ingest(ref[lo : lo + chunk])
        ub = ", ".join(f"{float(d):8.3f}" for d in bd)
        print(f"  t={eng.n_seen:7d}  incumbents=[{ub}]  lanes={eng.lanes:6d}")
    dt = time.time() - t0
    off = multi_query_search(
        ref, queries, length=args.query_len, window=w, batch=128
    )
    bs, bd = eng.best()
    assert all(
        int(bs[q]) == int(off.best_start[q]) for q in range(queries.shape[0])
    ), (bs, off.best_start)
    print(
        f"stream of {eng.n_windows} windows in {dt*1e3:.1f} ms "
        f"(ring keeps last {eng.recent().shape[0]} samples); "
        "final answers match offline multi_query_search."
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ref-len", type=int, default=50_000)
    ap.add_argument("--query-len", type=int, default=256)
    ap.add_argument("--window-ratio", type=float, default=0.1)
    ap.add_argument("--dataset", default="ECG")
    args = ap.parse_args()

    ref = jnp.asarray(make_dataset(args.dataset, args.ref_len, seed=0), jnp.float32)
    q = jnp.asarray(make_queries(args.dataset, 1, args.query_len, seed=1)[0], jnp.float32)
    w = max(int(args.query_len * args.window_ratio), 1)
    n_win = args.ref_len - args.query_len + 1
    print(f"{args.dataset}: N={args.ref_len} ({n_win} windows), l={args.query_len}, w={w}\n")

    answers = []
    for variant in VARIANTS:
        res = subsequence_search(
            ref, q, length=args.query_len, window=w, variant=variant, batch=128
        )
        jax.block_until_ready(res.best_dist)
        t0 = time.time()
        res = subsequence_search(
            ref, q, length=args.query_len, window=w, variant=variant, batch=128
        )
        jax.block_until_ready(res.best_dist)
        dt = time.time() - t0
        # counters come from an (untimed) stats round; the timed search above
        # runs the counter-free default
        stats = subsequence_search(
            ref, q, length=args.query_len, window=w, variant=variant,
            batch=128, with_info=True,
        )
        answers.append((int(res.best_start), float(res.best_dist)))
        print(
            f"{variant:14s} -> start={int(res.best_start):7d} "
            f"dist={float(res.best_dist):10.4f}  {dt*1e3:8.1f} ms  "
            f"lanes={int(res.lanes):6d}  dp_rows={int(stats.rows):9d}"
        )
    starts = {s for s, _ in answers}
    d0 = answers[0][1]
    assert starts == {answers[0][0]}, f"variants disagree: {answers}"
    # distances agree to float32 working precision (the prefix-scan DTW
    # reformulation rounds differently per variant)
    assert all(abs(d - d0) <= 1e-4 * max(d0, 1.0) for _, d in answers), answers
    print("\nall four suites agree on the nearest neighbour (exactness).")

    stream_demo(ref, args)


if __name__ == "__main__":
    main()
